#!/usr/bin/env python3
"""Exact-arithmetic mirror of `xdit route --grid` for regenerating
rust/testdata/plans.golden.json without a Rust toolchain.

The authoritative generator is the Rust binary (CI's golden-plans job runs
`cargo run --release -- route --grid` and byte-diffs the snapshot); this
script transcribes the same IEEE-double arithmetic in the same operation
order so the emitted grid is byte-identical. Validate fidelity first:

    python3 tools/regen_golden.py --check-legacy   # byte-compare the
        # flat-only 8-row grid against a pre-hierarchy snapshot
    python3 tools/regen_golden.py > rust/testdata/plans.golden.json

Every formula cites the Rust source it mirrors; if the cost model changes,
change it here too (or just regenerate with cargo and delete this).
"""

import math
import sys

# ---------------------------------------------------------------- models
# rust/src/config/model.rs::all_models (paper family only)

MODELS = {
    # name: (hidden, heads, layers, s_txt, params, text_encoder_bytes,
    #        uses_cfg, frames, default_steps, variant)
    "pixart": (1152, 16, 28, 120, 0.6e9, 18e9, True, 1, 20, "cross"),
    "sd3": (1536, 24, 24, 160, 2.0e9, 19e9, True, 1, 20, "mmdit"),
    "flux": (3072, 24, 57, 512, 12.0e9, 9.1e9, False, 1, 28, "mmdit"),
    "hunyuan": (1408, 16, 40, 256, 1.5e9, 7.7e9, True, 1, 50, "skip"),
    "cogvideox": (3072, 30, 42, 226, 5.0e9, 8.9e9, True, 13, 50, "mmdit"),
}

C_LATENT = 4


class Model:
    def __init__(self, name):
        (self.hidden, self.heads, self.layers, self.s_txt, self.params,
         self.text_encoder_bytes, self.uses_cfg, self.frames,
         self.default_steps, self.variant) = MODELS[name]
        self.name = name

    def in_context_text(self):
        return self.variant == "mmdit"

    def seq_len(self, px):
        return (px // 16) * (px // 16) * self.frames

    def attn_seq_len(self, px):
        return self.seq_len(px) + (self.s_txt if self.in_context_text() else 0)

    def param_bytes(self):
        return self.params * 2.0

    def step_flops(self, px):
        s = float(self.attn_seq_len(px))
        h = float(self.hidden)
        dense = 2.0 * self.params * s
        attn = 4.0 * s * s * h * float(self.layers)
        return dense + attn


# -------------------------------------------------------------- clusters
# rust/src/config/hardware.rs

NVLINK, PCIE, PCIEQPI, ETHERNET = 0, 1, 2, 3  # link_rank order


class Cluster:
    def __init__(self, name):
        if name.startswith("l40x"):
            self.tflops, self.mem_bytes = 90.0, 48e9
            self.has_nvlink, self.gpus_per_numa = False, 4
            self.bw = {PCIE: 24e9, PCIEQPI: 12e9}
            self.lat = {PCIE: 8e-6, PCIEQPI: 12e-6}
        elif name.startswith("a100x"):
            self.tflops, self.mem_bytes = 250.0, 80e9
            self.has_nvlink, self.gpus_per_numa = True, 8
            self.bw = {NVLINK: 250e9}
            self.lat = {NVLINK: 3e-6}
        else:
            raise ValueError(name)
        self.name = name
        self.n_gpus = int(name.split("x")[1])
        self.gpus_per_node = 8
        self.inter_bw, self.inter_lat = 10e9, 50e-6

    def node_of(self, d):
        return d // self.gpus_per_node

    def link(self, a, b):
        if self.node_of(a) != self.node_of(b):
            return ETHERNET
        if self.has_nvlink:
            return NVLINK
        if a // self.gpus_per_numa != b // self.gpus_per_numa:
            return PCIEQPI
        return PCIE

    def link_bw(self, k):
        return self.inter_bw if k == ETHERNET else self.bw[k]

    def link_lat(self, k):
        return self.inter_lat if k == ETHERNET else self.lat[k]

    def p2p_time(self, a, b, bytes_):
        if a == b:
            return 0.0
        k = self.link(a, b)
        return self.link_lat(k) + bytes_ / self.link_bw(k)

    def worst_link(self, group):
        worst = NVLINK
        for i, a in enumerate(group):
            for b in group[i + 1:]:
                k = self.link(a, b)
                if k > worst:
                    worst = k
        return worst

    def collective_time(self, group, bytes_, factor):
        n = len(group)
        if n <= 1:
            return 0.0
        k = self.worst_link(group)
        bw = self.link_bw(k)
        if k == ETHERNET:
            per_node = {}
            for d in group:
                per_node[self.node_of(d)] = per_node.get(self.node_of(d), 0) + 1
            bw /= float(max(per_node.values()))
        steps = float(n - 1)
        return self.link_lat(k) * steps + bytes_ * factor / bw

    def collective_cost(self, group, bytes_, kind, algo):
        # rust/src/config/hardware.rs::collective_cost
        n = len(group)
        if n <= 1:
            return 0.0
        flat = self.collective_time(group, bytes_, flat_factor(kind, n))
        if algo == "flat":
            return flat
        per_node = {}
        for d in group:
            per_node.setdefault(self.node_of(d), []).append(d)
        subs = [per_node[k] for k in sorted(per_node)]
        if len(subs) <= 1:
            return flat
        nf = float(n)
        nodes = float(len(subs))
        ether_steps = nodes - 1.0
        ether_lat = self.inter_lat * ether_steps
        ether_bw = self.inter_bw

        def intra_max(f):
            best = 0.0
            for sub in subs:
                best = max(best, f(sub, float(len(sub))))
            return best

        if kind == "all_gather":
            gather = intra_max(lambda sub, g: self.collective_time(sub, bytes_, g - 1.0))
            inbound = max((nf - float(len(sub))) * bytes_ for sub in subs)
            leaders = ether_lat + inbound / ether_bw
            bcast = intra_max(
                lambda sub, g: self.collective_time(sub, (nf - g) * bytes_, 1.0))
            return gather + leaders + bcast
        if kind == "reduce_scatter":
            reduce = intra_max(
                lambda sub, g: self.collective_time(sub, bytes_, (g - 1.0) / g))
            leaders = ether_lat + bytes_ * ether_steps / nodes / ether_bw
            scatter = intra_max(
                lambda sub, g: self.collective_time(sub, bytes_ / max(g, 1.0), 1.0))
            return reduce + leaders + scatter
        if kind == "all_reduce":
            reduce = intra_max(
                lambda sub, g: self.collective_time(sub, bytes_, (g - 1.0) / g))
            leaders = ether_lat + bytes_ * 2.0 * ether_steps / nodes / ether_bw
            gather = intra_max(
                lambda sub, g: self.collective_time(sub, bytes_, (g - 1.0) / g))
            return reduce + leaders + gather
        if kind == "all_to_all":
            # pipelined: slowest tier's byte rate + fill/drain latencies

            def intra_lat(sub):
                if len(sub) <= 1:
                    return 0.0
                return self.link_lat(self.worst_link(sub)) * (float(len(sub)) - 1.0)

            def intra_stream(sub, vol):
                if len(sub) <= 1:
                    return 0.0
                return vol / self.link_bw(self.worst_link(sub))

            fill = 0.0
            for sub in subs:
                fill = max(fill, intra_lat(sub))
            funnel = 0.0
            for sub in subs:
                funnel = max(funnel, intra_stream(sub, bytes_))
            wire = 0.0
            for sub in subs:
                g = float(len(sub))
                wire = max(wire, g * bytes_ * (nf - g) / (nf - 1.0))
            wire = wire / ether_bw
            scatter = 0.0
            for sub in subs:
                g = float(len(sub))
                scatter = max(scatter, intra_stream(sub, g * bytes_ * (nf - g) / (nf - 1.0)))
            return ether_lat + 2.0 * fill + max(funnel, wire, scatter)
        raise ValueError(kind)


def flat_factor(kind, n):
    nf = float(n)
    if kind == "all_gather":
        return (nf - 1.0) / nf * nf
    if kind == "reduce_scatter":
        return (nf - 1.0) / nf
    if kind == "all_reduce":
        return 2.0 * (nf - 1.0) / nf
    return 1.0  # all_to_all


# -------------------------------------------------------- parallel config
# rust/src/config/parallel.rs


class PC:
    def __init__(self, cfg, pf, ul, ring, patches=None):
        self.cfg, self.pipefusion, self.ulysses, self.ring = cfg, pf, ul, ring
        self.patches = patches if patches is not None else (pf if pf > 1 else 1)

    def key(self):
        return (self.cfg, self.pipefusion, self.ulysses, self.ring, self.patches)

    def world(self):
        return self.cfg * self.pipefusion * self.ulysses * self.ring

    def sp_degree(self):
        return self.ulysses * self.ring

    def seq_shards(self):
        return self.patches * self.sp_degree()

    def is_serial(self):
        return self.world() == 1

    def describe(self):
        parts = []
        if self.cfg > 1:
            parts.append("cfg=%d" % self.cfg)
        if self.pipefusion > 1:
            parts.append("pipefusion=%d(M=%d)" % (self.pipefusion, self.patches))
        if self.ulysses > 1:
            parts.append("ulysses=%d" % self.ulysses)
        if self.ring > 1:
            parts.append("ring=%d" % self.ring)
        return ",".join(parts) if parts else "serial"

    def valid(self, m, s_img):
        if self.cfg > 2 or self.cfg == 0:
            return False
        if self.cfg == 2 and not m.uses_cfg:
            return False
        if 0 in (self.pipefusion, self.ulysses, self.ring, self.patches):
            return False
        if m.heads % self.ulysses != 0:
            return False
        if self.pipefusion > m.layers:
            return False
        if self.pipefusion > 1 and self.patches < self.pipefusion:
            return False
        if self.pipefusion > 1 and m.variant == "skip" and self.pipefusion > 2:
            return False
        shards = self.seq_shards()
        if s_img % shards != 0:
            return False
        if m.in_context_text() and m.s_txt % self.sp_degree() != 0:
            return False
        if self.ring > 1 and s_img // shards == 0:
            return False
        return True


def serial_pc():
    return PC(1, 1, 1, 1, patches=1)


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_configs(world, m, s_img):
    out, seen = [], set()
    for cfg in (1, 2):
        if world % cfg != 0:
            continue
        rest = world // cfg
        for pf in divisors(rest):
            rest2 = rest // pf
            for ul in divisors(rest2):
                ring = rest2 // ul
                for mul in ((0, 2) if pf > 1 else (0,)):
                    c = PC(cfg, pf, ul, ring)
                    if mul > 0:
                        c = PC(cfg, pf, ul, ring, patches=pf * mul)
                    if c.valid(m, s_img) and c.key() not in seen:
                        seen.add(c.key())
                        out.append(c)
    return out


# ------------------------------------------------------------ cost models


def compute_time(flops, tflops):
    return flops / (tflops * 1e12)


def ring_sync_cost(cluster):
    return 15e-6 if cluster.has_nvlink else 40e-6


def predict_latency(m, px, cluster, method, pc, steps, algo):
    # rust/src/perf/latency.rs::predict_latency_with (Hybrid + SpUlysses)
    world = max(pc.world(), 1)
    cfg = pc.cfg
    branches = 2 if m.uses_cfg else 1
    n_intra = world // cfg
    s = m.attn_seq_len(px)
    group = list(range(n_intra))
    tfl = cluster.tflops

    step_fl = m.step_flops(px)
    branch_factor = float(branches) / float(cfg)
    compute_step = compute_time(step_fl, tfl) / float(n_intra) * branch_factor

    hs = float(s) * float(m.hidden) * 2.0
    l = float(m.layers)
    n = float(n_intra)

    if method == "ulysses":
        t = l * cluster.collective_cost(group, 4.0 * hs / n, "all_to_all", algo)
        comm, warm = t * branch_factor, 0.0
    elif method == "hybrid":
        exposed = 0.0
        nsp = float(pc.sp_degree())
        if pc.ulysses > 1:
            g = group[:pc.ulysses]
            exposed += l * cluster.collective_cost(g, 4.0 * hs / n, "all_to_all", algo)
        if pc.ring > 1:
            g = group[:pc.sp_degree()]
            hop_bytes = 2.0 * hs / nsp / float(pc.patches)
            hop_t = cluster.collective_time(g, hop_bytes, 1.0) / max(
                float(pc.ring) - 1.0, 1.0)
            blk = compute_time(
                4.0 * (float(s) / nsp) * (float(s) / nsp) * float(m.hidden)
                / float(pc.patches), tfl)
            sync = ring_sync_cost(cluster)
            exposed += (max(hop_t - blk, 0.0) + sync) * (float(pc.ring) - 1.0) * l
        warm = 0.0
        if pc.pipefusion > 1:
            m_patches = max(pc.patches, 2)
            micro = compute_step / float(m_patches)
            exposed += (float(pc.pipefusion) - 1.0) * micro
            patch_bytes = hs / float(m_patches) / nsp
            stride = pc.sp_degree()
            worst = 0.0
            for i in range(stride, n_intra, stride):
                worst = max(worst, cluster.p2p_time(group[i - stride], group[i],
                                                    patch_bytes))
            exposed += max(worst - micro, 0.0) * float(m_patches)
            warm = max(compute_time(step_fl, tfl) * branch_factor - compute_step, 0.0)
        if cfg == 2:
            latent_bytes = (float(px) / 8.0) * (float(px) / 8.0) * float(C_LATENT) * 2.0
            exposed += cluster.p2p_time(0, world // 2, latent_bytes)
        comm = exposed
    else:
        raise ValueError(method)

    total = float(steps) * (compute_step + comm) + warm
    return total


def config_comm_bytes(m, px, pc):
    # rust/src/perf/comm_model.rs::config_comm_bytes
    s = m.attn_seq_len(px)
    hs = float(s) * float(m.hidden) * 2.0
    l = float(m.layers)
    total = 0.0
    if pc.ulysses > 1:
        total += 4.0 / float(pc.ulysses) * hs * l
    if pc.ring > 1:
        total += 2.0 * hs * l
    if pc.pipefusion > 1:
        total += 2.0 * hs / float(pc.sp_degree())
    if pc.cfg == 2:
        total += (float(px) / 8.0) * (float(px) / 8.0) * float(C_LATENT) * 2.0
    return total


def config_memory_total(m, px, pc):
    # rust/src/perf/memory_model.rs::config_memory
    s = float(m.attn_seq_len(px))
    sp = float(pc.sp_degree())
    pf = float(pc.pipefusion)
    kv_full = 2.0 * s * float(m.hidden) * 2.0 * float(m.layers)
    if pc.pipefusion > 1:
        kv = kv_full / pf / sp
    else:
        kv = kv_full / float(m.layers) / sp
    act_shard = s / (sp * float(max(pc.patches, 1))) * float(m.hidden) * 2.0
    activations = (8.0 * act_shard
                   + (float(px) / 8.0) * (float(px) / 8.0) * float(C_LATENT) * 4.0)
    params = m.param_bytes() / pf
    return params + m.text_encoder_bytes + kv + activations


HBM_USABLE_FRACTION = 0.92


def pick_method(pc):
    if pc.pipefusion > 1 and pc.sp_degree() > 1:
        return "hybrid"
    if pc.pipefusion > 1:
        return "pipefusion"
    if pc.sp_degree() > 1:
        return "sp"
    return "serial"


def paper_heuristic(m, px, cluster, world):
    # rust/src/coordinator/router.rs::paper_heuristic
    s_img = m.seq_len(px)
    if world <= 1:
        return serial_pc()
    cfg = 2 if m.uses_cfg and world % 2 == 0 else 1
    state = {"intra": world // cfg, "pipe": 1, "ulysses": 1, "ring": 1}

    def try_cfg(pipe, ul, ring):
        pc = PC(cfg, pipe, ul, ring)
        return pc if pc.valid(m, s_img) else None

    def grow(dim):
        while state["intra"] % 2 == 0:
            p2, u2, r2 = state["pipe"], state["ulysses"], state["ring"]
            if dim == "p":
                p2 *= 2
            elif dim == "u":
                u2 *= 2
            else:
                r2 *= 2
            if try_cfg(p2, u2, r2) is not None:
                state["pipe"], state["ulysses"], state["ring"] = p2, u2, r2
                state["intra"] //= 2
            else:
                break

    if cluster.has_nvlink:
        grow("u"), grow("p"), grow("r")
    else:
        grow("p"), grow("r"), grow("u")
    pc = try_cfg(state["pipe"], state["ulysses"], state["ring"])
    return pc if pc is not None else serial_pc()


# ---------------------------------------------------------------- planner


def price(m, px, cluster, pc, steps, forced_algo):
    # rust/src/coordinator/planner.rs::Planner::price (CostModel policy)
    if forced_algo is not None:
        return forced_algo, predict_latency(m, px, cluster, "hybrid", pc, steps,
                                            forced_algo)
    flat = predict_latency(m, px, cluster, "hybrid", pc, steps, "flat")
    n_intra = max(max(pc.world(), 1) // max(pc.cfg, 1), 1)
    if n_intra <= cluster.gpus_per_node:
        return "flat", flat
    hier = predict_latency(m, px, cluster, "hybrid", pc, steps, "hier")
    if hier < flat:
        return "hier", hier
    return "flat", flat


def score(m, px, cluster, pc, forced_algo):
    steps = m.default_steps
    algo, total = price(m, px, cluster, pc, steps, forced_algo)
    mem = config_memory_total(m, px, pc)
    return {
        "pc": pc,
        "algo": algo,
        "total": total,
        "mem": mem,
        "fits": mem < cluster.mem_bytes * HBM_USABLE_FRACTION,
        "comm": float(steps) * config_comm_bytes(m, px, pc),
    }


def plan(m, px, cluster, world, forced_algo=None):
    plans = [score(m, px, cluster, pc, forced_algo)
             for pc in enumerate_configs(world, m, m.seq_len(px))]
    if not plans:
        return score(m, px, cluster, paper_heuristic(m, px, cluster, world),
                     forced_algo)
    plans.sort(key=lambda p: (not p["fits"], p["total"]))  # stable, like Rust
    return plans[0]


def heuristic_total(m, px, cluster, world):
    # PaperHeuristic policy always prices flat
    pc = paper_heuristic(m, px, cluster, world)
    return pc, predict_latency(m, px, cluster, "hybrid", pc, m.default_steps, "flat")


def best_sp_plan(m, px, cluster, world, forced_algo):
    cands = [pc for pc in enumerate_configs(world, m, m.seq_len(px))
             if pc.cfg == 1 and pc.pipefusion == 1 and not pc.is_serial()]
    if not cands:
        return None
    best = None
    for pc in cands:
        p = score(m, px, cluster, pc, forced_algo)
        if best is None or p["total"] < best["total"]:  # first min, like min_by
            best = p
    return best


# ----------------------------------------------------------- JSON output


def rust_round(x):
    f = math.floor(x)
    d = x - f
    if d > 0.5 or (d == 0.5 and x >= 0.0):
        f += 1
    return f


def jstr(s):
    return '"%s"' % s


def render_cell(cell):
    parts = []
    for k in sorted(cell):
        v = cell[k]
        if isinstance(v, bool):
            parts.append('%s:%s' % (jstr(k), "true" if v else "false"))
        elif isinstance(v, int):
            parts.append('%s:%d' % (jstr(k), v))
        else:
            parts.append('%s:%s' % (jstr(k), jstr(v)))
    return "{%s}" % ",".join(parts)


GRID_WORLDS = [1, 2, 4, 8, 16]

LEGACY_GRID = [
    ("pixart", 2048, "l40x16"),
    ("sd3", 2048, "l40x16"),
    ("flux", 1024, "l40x16"),
    ("cogvideox", 480, "l40x8"),
    ("pixart", 2048, "a100x8"),
    ("sd3", 2048, "a100x8"),
    ("flux", 1024, "a100x8"),
    ("hunyuan", 2048, "a100x8"),
]

PAPER_GRID = LEGACY_GRID + [
    ("pixart", 4096, "l40x16"),
    ("hunyuan", 2048, "l40x16"),
    ("pixart", 2048, "a100x16"),
    ("hunyuan", 2048, "a100x16"),
]


def grid_report(rows, legacy):
    """legacy=True reproduces the pre-hierarchy generator: flat-only
    pricing, no provenance keys (the --check-legacy fidelity gate)."""
    lines = []
    for name, px, cname in rows:
        m = Model(name)
        cluster = Cluster(cname)
        for world in GRID_WORLDS:
            if world > cluster.n_gpus:
                continue
            best = plan(m, px, cluster, world, "flat" if legacy else None)
            hpc, htotal = heuristic_total(m, px, cluster, world)
            cell = {
                "model": m.name,
                "cluster": cluster.name,
                "world": world,
                "px": px,
                "config": best["pc"].describe(),
                "method": pick_method(best["pc"]),
                "predicted_us": rust_round(best["total"] * 1e6),
                "comm_bytes": rust_round(best["comm"]),
                "peak_mem_bytes": rust_round(best["mem"]),
                "fits": best["fits"],
                "heuristic_config": hpc.describe(),
                "heuristic_us": rust_round(htotal * 1e6),
            }
            if not legacy and best["algo"] == "hier":
                cell["algo"] = "hier"
            if not legacy and world > cluster.gpus_per_node:
                sp_flat = best_sp_plan(m, px, cluster, world, "flat")
                sp_auto = best_sp_plan(m, px, cluster, world, None)
                if sp_flat is not None and sp_auto is not None:
                    cell["sp_flat_config"] = sp_flat["pc"].describe()
                    cell["sp_flat_us"] = rust_round(sp_flat["total"] * 1e6)
                    cell["sp_config"] = sp_auto["pc"].describe()
                    cell["sp_us"] = rust_round(sp_auto["total"] * 1e6)
                deep = PC(1, 1, world, 1)
                if deep.valid(m, m.seq_len(px)):
                    for key, algo in (("ulysses_flat_us", "flat"),
                                      ("ulysses_hier_us", "hier")):
                        t = predict_latency(m, px, cluster, "ulysses", deep,
                                            m.default_steps, algo)
                        cell[key] = rust_round(t * 1e6)
            lines.append(render_cell(cell))
    return "[\n" + ",\n".join(lines) + "\n]\n"


if __name__ == "__main__":
    if "--check-legacy" in sys.argv:
        got = grid_report(LEGACY_GRID, legacy=True)
        path = sys.argv[sys.argv.index("--check-legacy") + 1] \
            if len(sys.argv) > sys.argv.index("--check-legacy") + 1 \
            else "rust/testdata/plans.golden.json"
        want = open(path).read()
        if got == want:
            print("legacy grid byte-identical to", path)
        else:
            sys.stdout.write(got)
            sys.exit("MISMATCH vs " + path)
    else:
        sys.stdout.write(grid_report(PAPER_GRID, legacy=False))
