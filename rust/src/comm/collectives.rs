//! Collectives over the simulated cluster: data really moves, virtual time
//! is charged per the link model.
//!
//! Algorithm-bandwidth factors (paper §4.1.3, nccl-tests PERFORMANCE.md):
//! AllReduce 2(n-1)/n, AllGather/ReduceScatter (n-1)/n, AllToAll ~(n-1)/n
//! per rank, ring P2P 1.
//!
//! Every typed collective prices through
//! [`ClusterSpec::collective_cost`], so a [`Communicator`] built with
//! [`Communicator::with_algo`]`(`[`CollectiveAlgo::Hierarchical`]`)`
//! charges the two-level decomposition (intra-node phase over the fast
//! tier, leaders-only Ethernet exchange, intra-node redistribution) while
//! moving exactly the same data. The default stays
//! [`CollectiveAlgo::FlatRing`], which is byte-exact with the historical
//! one-level pricing — existing executors and digests are unchanged
//! unless a caller opts in.

use crate::comm::clock::Clocks;
use crate::config::hardware::{ClusterSpec, CollectiveAlgo, CollectiveKind};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A recorded communication event (for accounting, tests, Table-1
/// validation).
#[derive(Debug, Clone, PartialEq)]
pub struct CommOp {
    /// Op label (`all_gather`, `all_reduce`, `p2p`, `ring_shift`, ...).
    pub kind: &'static str,
    /// Device ranks that participated.
    pub group: Vec<usize>,
    /// Payload bytes per rank.
    pub bytes: usize,
    /// Virtual seconds charged (group completion - start max).
    pub time: f64,
}

/// Ledger of all communication performed in a run.
#[derive(Debug, Default, Clone)]
pub struct CommLedger {
    /// Every op recorded, in issue order.
    pub ops: Vec<CommOp>,
}

impl CommLedger {
    /// Total bytes moved across all ops (per-rank payload × group size).
    pub fn total_bytes(&self) -> usize {
        self.ops.iter().map(|o| o.bytes * o.group.len().max(1)).sum()
    }

    /// Total virtual seconds charged across all ops.
    pub fn total_time(&self) -> f64 {
        self.ops.iter().map(|o| o.time).sum()
    }

    /// Number of recorded ops with the given label.
    pub fn count(&self, kind: &str) -> usize {
        self.ops.iter().filter(|o| o.kind == kind).count()
    }

    /// Bytes moved by ops with the given label (payload × group size).
    pub fn bytes_of(&self, kind: &str) -> usize {
        self.ops
            .iter()
            .filter(|o| o.kind == kind)
            .map(|o| o.bytes * o.group.len().max(1))
            .sum()
    }
}

/// Communicator: collectives + async P2P over a cluster, charging clocks.
pub struct Communicator<'a> {
    /// Cluster whose link model prices every transfer.
    pub cluster: &'a ClusterSpec,
    /// Per-rank virtual clocks advanced by each op.
    pub clocks: &'a mut Clocks,
    /// Accounting of every op performed (Table-1 validation, tests).
    pub ledger: CommLedger,
    /// Collective algorithm charged by the typed collectives
    /// ([`all_gather`](Communicator::all_gather) and friends). P2P and
    /// ring paths are algorithm-free.
    pub algo: CollectiveAlgo,
}

impl<'a> Communicator<'a> {
    /// A communicator with the historical flat-ring pricing.
    pub fn new(cluster: &'a ClusterSpec, clocks: &'a mut Clocks) -> Self {
        Communicator { cluster, clocks, ledger: CommLedger::default(), algo: CollectiveAlgo::FlatRing }
    }

    /// Select the collective algorithm charged by the typed collectives
    /// (data movement is identical either way).
    pub fn with_algo(mut self, algo: CollectiveAlgo) -> Self {
        self.algo = algo;
        self
    }

    fn record(&mut self, kind: &'static str, group: &[usize], bytes: usize, time: f64) {
        self.ledger.ops.push(CommOp { kind, group: group.to_vec(), bytes, time });
    }

    /// AllGather over `group`: each device contributes `parts[i]`; every
    /// device receives the row-concatenation in group order.
    pub fn all_gather(&mut self, group: &[usize], parts: &[Tensor]) -> Result<Vec<Tensor>> {
        if group.len() != parts.len() {
            return Err(Error::Comm("all_gather: group/parts mismatch".into()));
        }
        let bytes = parts.iter().map(|p| p.size_bytes()).max().unwrap_or(0);
        let n = group.len();
        let t =
            self.cluster.collective_cost(group, bytes as f64, CollectiveKind::AllGather, self.algo);
        // note: per-rank payload is `bytes`; total moved per rank is
        // (n-1)/n * n * bytes = (n-1) * bytes.
        let start = self.clocks.sync(group);
        for &d in group {
            self.clocks.wait_until(d, start + t);
        }
        self.record("all_gather", group, bytes, t);
        let gathered = Tensor::concat_rows(parts)?;
        Ok(vec![gathered; n])
    }

    /// AllReduce (sum) over `group`.
    pub fn all_reduce(&mut self, group: &[usize], parts: &[Tensor]) -> Result<Vec<Tensor>> {
        if group.len() != parts.len() {
            return Err(Error::Comm("all_reduce: group/parts mismatch".into()));
        }
        let bytes = parts[0].size_bytes();
        let t =
            self.cluster.collective_cost(group, bytes as f64, CollectiveKind::AllReduce, self.algo);
        let start = self.clocks.sync(group);
        for &d in group {
            self.clocks.wait_until(d, start + t);
        }
        self.record("all_reduce", group, bytes, t);
        let mut acc = parts[0].clone();
        for p in &parts[1..] {
            acc = acc.add(p)?;
        }
        Ok(vec![acc; group.len()])
    }

    /// AllToAll over `group`: `mat[i][j]` is the chunk rank i sends to rank
    /// j; returns per-rank received chunks (concatenated in sender order).
    /// This is SP-Ulysses' head/sequence re-partitioning primitive.
    pub fn all_to_all(&mut self, group: &[usize], mat: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
        let n = group.len();
        if mat.len() != n || mat.iter().any(|row| row.len() != n) {
            return Err(Error::Comm("all_to_all: matrix shape mismatch".into()));
        }
        // per-rank payload: everything it sends to others
        let bytes: usize = mat[0]
            .iter()
            .enumerate()
            .map(|(j, t)| if j == 0 { 0 } else { t.size_bytes() })
            .sum();
        let t =
            self.cluster.collective_cost(group, bytes as f64, CollectiveKind::AllToAll, self.algo);
        let start = self.clocks.sync(group);
        for &d in group {
            self.clocks.wait_until(d, start + t);
        }
        self.record("all_to_all", group, bytes, t);
        let mut out = Vec::with_capacity(n);
        for j in 0..n {
            let col: Vec<Tensor> = (0..n).map(|i| mat[i][j].clone()).collect();
            out.push(Tensor::concat_rows(&col)?);
        }
        Ok(out)
    }

    /// Cost-only collective: charge virtual time + record the op without
    /// moving data (used where the numeric result is computed elsewhere,
    /// e.g. TP whose math is identical to serial, or layer-granular SP
    /// whose gathered tensors are assembled directly).
    pub fn charge(&mut self, kind: &'static str, group: &[usize], bytes: usize, algbw: f64) {
        let t = self.cluster.collective_time(group, bytes as f64, algbw);
        let start = self.clocks.sync(group);
        for &d in group {
            self.clocks.wait_until(d, start + t);
        }
        self.record(kind, group, bytes, t);
    }

    /// Blocking point-to-point send: the receiver's clock advances to
    /// arrival.
    pub fn p2p(&mut self, src: usize, dst: usize, data: Tensor) -> Tensor {
        let t = self.cluster.p2p_time(src, dst, data.size_bytes() as f64);
        let arrive = self.clocks.get(src) + t;
        self.clocks.wait_until(dst, arrive);
        self.record("p2p", &[src, dst], data.size_bytes(), t);
        data
    }

    /// Asynchronous point-to-point send (PipeFusion's overlapped patch
    /// transfer): returns (data, arrival_time); the receiver calls
    /// `wait_until` only when it consumes the message, so transfer overlaps
    /// with whatever the receiver is doing meanwhile.
    pub fn p2p_async(&mut self, src: usize, dst: usize, data: Tensor) -> (Tensor, f64) {
        let t = self.cluster.p2p_time(src, dst, data.size_bytes() as f64);
        let arrive = self.clocks.get(src) + t;
        self.record("p2p_async", &[src, dst], data.size_bytes(), t);
        (data, arrive)
    }

    /// One ring hop for every rank simultaneously (SP-Ring's per-block K/V
    /// rotation): rank i sends `blocks[i]` to rank (i+1) % n. Overlapped
    /// with attention compute per the paper — callers charge compute
    /// separately and take max.
    pub fn ring_shift(&mut self, group: &[usize], blocks: Vec<Tensor>) -> Vec<Tensor> {
        let n = group.len();
        let bytes = blocks.iter().map(|b| b.size_bytes()).max().unwrap_or(0);
        // slowest link in the ring bounds the step
        let mut t = 0.0f64;
        for i in 0..n {
            let s = group[i];
            let d = group[(i + 1) % n];
            t = t.max(self.cluster.p2p_time(s, d, bytes as f64));
        }
        let start = self.clocks.sync(group);
        for &d in group {
            self.clocks.wait_until(d, start + t);
        }
        self.record("ring_shift", group, bytes, t);
        let mut out = blocks;
        out.rotate_right(1);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;

    fn mk(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len(), 1], v.to_vec()).unwrap()
    }

    #[test]
    fn all_gather_data_and_time() {
        let c = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        let mut comm = Communicator::new(&c, &mut clocks);
        let parts = vec![mk(&[1.0]), mk(&[2.0]), mk(&[3.0]), mk(&[4.0])];
        let out = comm.all_gather(&[0, 1, 2, 3], &parts).unwrap();
        assert_eq!(out.len(), 4);
        for o in &out {
            assert_eq!(o.data, vec![1.0, 2.0, 3.0, 4.0]);
        }
        assert!(comm.clocks.get(0) > 0.0);
        assert_eq!(comm.clocks.get(0), comm.clocks.get(3));
        assert_eq!(comm.ledger.count("all_gather"), 1);
    }

    #[test]
    fn all_reduce_sums() {
        let c = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        let mut comm = Communicator::new(&c, &mut clocks);
        let parts = vec![mk(&[1.0, 2.0]), mk(&[10.0, 20.0])];
        let out = comm.all_reduce(&[0, 1], &parts).unwrap();
        assert_eq!(out[0].data, vec![11.0, 22.0]);
        assert_eq!(out[1].data, vec![11.0, 22.0]);
    }

    #[test]
    fn all_to_all_transposes() {
        let c = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        let mut comm = Communicator::new(&c, &mut clocks);
        // rank i sends value 10*i+j to rank j
        let mat: Vec<Vec<Tensor>> = (0..2)
            .map(|i| (0..2).map(|j| mk(&[(10 * i + j) as f32])).collect())
            .collect();
        let out = comm.all_to_all(&[0, 1], &mat).unwrap();
        assert_eq!(out[0].data, vec![0.0, 10.0]); // from ranks 0,1 to rank 0
        assert_eq!(out[1].data, vec![1.0, 11.0]);
    }

    #[test]
    fn async_p2p_overlaps() {
        let c = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        clocks.advance(0, 1.0);
        let mut comm = Communicator::new(&c, &mut clocks);
        let (data, arrive) = comm.p2p_async(0, 1, mk(&[5.0; 1000]));
        assert!(arrive > 1.0);
        // receiver busy past arrival: no extra wait when consuming
        comm.clocks.advance(1, 10.0);
        comm.clocks.wait_until(1, arrive);
        assert_eq!(comm.clocks.get(1), 10.0);
        assert_eq!(data.data[0], 5.0);
    }

    #[test]
    fn ring_shift_rotates() {
        let c = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        let mut comm = Communicator::new(&c, &mut clocks);
        let blocks = vec![mk(&[0.0]), mk(&[1.0]), mk(&[2.0])];
        let out = comm.ring_shift(&[0, 1, 2], blocks);
        // rank 1 now holds rank 0's block
        assert_eq!(out[1].data, vec![0.0]);
        assert_eq!(out[0].data, vec![2.0]);
    }

    #[test]
    fn hierarchical_algo_same_data_less_cross_node_time() {
        let c = l40_cluster(2);
        let parts: Vec<Tensor> = (0..16).map(|i| mk(&[i as f32; 4096])).collect();
        let group: Vec<usize> = (0..16).collect();
        let mut flat_clocks = Clocks::new(16);
        let mut flat = Communicator::new(&c, &mut flat_clocks);
        let flat_out = flat.all_gather(&group, &parts).unwrap();
        let flat_t = flat.clocks.get(0);
        let mut hier_clocks = Clocks::new(16);
        let mut hier =
            Communicator::new(&c, &mut hier_clocks).with_algo(CollectiveAlgo::Hierarchical);
        let hier_out = hier.all_gather(&group, &parts).unwrap();
        let hier_t = hier.clocks.get(0);
        // identical data movement, strictly cheaper virtual time
        assert_eq!(flat_out[0].data, hier_out[0].data);
        assert!(hier_t < flat_t, "hier {hier_t} !< flat {flat_t}");
        // and inside one node the algorithms price identically
        let mut a = Clocks::new(16);
        let mut b = Clocks::new(16);
        Communicator::new(&c, &mut a).all_gather(&[0, 1, 2, 3], &parts[..4]).unwrap();
        Communicator::new(&c, &mut b)
            .with_algo(CollectiveAlgo::Hierarchical)
            .all_gather(&[0, 1, 2, 3], &parts[..4])
            .unwrap();
        assert_eq!(a.get(0).to_bits(), b.get(0).to_bits());
    }

    #[test]
    fn cross_node_costs_more() {
        let c = l40_cluster(2);
        let mut clocks = Clocks::new(16);
        let mut comm = Communicator::new(&c, &mut clocks);
        let parts: Vec<Tensor> = (0..2).map(|_| mk(&[0.0; 4096])).collect();
        comm.all_gather(&[0, 1], &parts).unwrap();
        let intra = comm.clocks.get(0);
        let mut clocks2 = Clocks::new(16);
        let mut comm2 = Communicator::new(&c, &mut clocks2);
        comm2.all_gather(&[0, 8], &parts).unwrap();
        assert!(comm2.clocks.get(0) > intra);
    }
}
