//! Per-device virtual clocks for the deterministic event model.

/// Virtual time per device, in seconds.
#[derive(Debug, Clone)]
pub struct Clocks {
    t: Vec<f64>,
}

impl Clocks {
    /// `n` device clocks, all starting at zero.
    pub fn new(n: usize) -> Clocks {
        Clocks { t: vec![0.0; n] }
    }

    /// Number of device clocks.
    pub fn n(&self) -> usize {
        self.t.len()
    }

    /// Current virtual time of device `dev`.
    pub fn get(&self, dev: usize) -> f64 {
        self.t[dev]
    }

    /// Charge `dt` seconds of local work to `dev`.
    pub fn advance(&mut self, dev: usize, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time charge");
        self.t[dev] += dt;
    }

    /// Block `dev` until at least `time` (message arrival, dependency).
    pub fn wait_until(&mut self, dev: usize, time: f64) {
        if time > self.t[dev] {
            self.t[dev] = time;
        }
    }

    /// Barrier: every device in `group` reaches the max clock of the group.
    pub fn sync(&mut self, group: &[usize]) -> f64 {
        let m = group.iter().map(|&d| self.t[d]).fold(0.0, f64::max);
        for &d in group {
            self.t[d] = m;
        }
        m
    }

    /// Makespan: the time the slowest device finishes.
    pub fn makespan(&self) -> f64 {
        self.t.iter().copied().fold(0.0, f64::max)
    }

    /// Rewind every clock to zero (session reuse across batches).
    pub fn reset(&mut self) {
        self.t.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_sync() {
        let mut c = Clocks::new(4);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        let m = c.sync(&[0, 1]);
        assert_eq!(m, 3.0);
        assert_eq!(c.get(0), 3.0);
        assert_eq!(c.get(2), 0.0);
        assert_eq!(c.makespan(), 3.0);
    }

    #[test]
    fn wait_until_never_rewinds() {
        let mut c = Clocks::new(1);
        c.advance(0, 5.0);
        c.wait_until(0, 2.0);
        assert_eq!(c.get(0), 5.0);
        c.wait_until(0, 7.0);
        assert_eq!(c.get(0), 7.0);
    }
}
