//! Simulated interconnect.
//!
//! Devices are *simulated*: data really moves (between per-device slots on
//! the leader thread) and virtual time is charged according to the cluster's
//! link model with NCCL-style algorithm-bandwidth factors (paper Table 1,
//! nccl-tests PERFORMANCE.md). The event model is deterministic: per-device
//! clocks advance monotonically; collectives synchronize the group clock;
//! async P2P (PipeFusion/DistriFusion overlap) produces a completion time
//! that the receiver observes only when it consumes the message.
//!
//! Collectives price through an explicit algorithm
//! ([`CollectiveAlgo`](crate::config::hardware::CollectiveAlgo)): the
//! default flat one-level ring, or the two-level hierarchical
//! decomposition (intra-node phase on the fast tier, leaders-only
//! Ethernet exchange, intra-node redistribution) selected with
//! [`Communicator::with_algo`]. The data moved is identical either way;
//! only the virtual time charged differs — see the "Communication model"
//! chapter of `DESIGN.md` for the per-tier cost formulas.

pub mod clock;
pub mod collectives;

pub use clock::Clocks;
pub use collectives::{CommLedger, CommOp, Communicator};
