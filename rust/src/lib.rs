//! # xDiT — a parallel inference engine for Diffusion Transformers
//!
//! Reproduction of *xDiT: an Inference Engine for Diffusion Transformers
//! (DiTs) with Massive Parallelism* (Fang et al., 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: simulated multi-device cluster,
//!   the five parallel strategies (TP, SP-Ulysses, SP-Ring, DistriFusion,
//!   PipeFusion), CFG parallelism, the hybrid mesh with the KV-consistency
//!   fix, the patch-parallel VAE, a serving front-end
//!   (router/batcher/engine), and the analytic performance model that
//!   regenerates every figure/table of the paper.
//! * **L2/L1 (build-time Python)** — the DiT compute graph and Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/` and executed here via
//!   the PJRT CPU client (`runtime`). Python never runs on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod diffusion;
pub mod error;
pub mod mesh;
pub mod model;
pub mod parallel;
pub mod perf;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;
pub mod vae;

pub use error::{Error, Result};
