//! # xDiT — a parallel inference engine for Diffusion Transformers
//!
//! Reproduction of *xDiT: an Inference Engine for Diffusion Transformers
//! (DiTs) with Massive Parallelism* (Fang et al., 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: simulated multi-device cluster,
//!   the five parallel strategies (TP, SP-Ulysses, SP-Ring, DistriFusion,
//!   PipeFusion), CFG parallelism, the hybrid mesh with the KV-consistency
//!   fix, the patch-parallel VAE, a serving front-end
//!   (router/batcher/engine) with optional staged execution — text-encode,
//!   denoise and VAE-decode on per-stage clocks with a bounded
//!   denoise→decode queue, so decode of batch N overlaps denoise of
//!   batch N+1 — and the analytic performance model that regenerates
//!   every figure/table of the paper.
//! * **L4 ([`perf::simulator`])** — the discrete-event overlap simulator:
//!   lowers any valid [`config::parallel::ParallelConfig`] into a per-GPU
//!   event [`Timeline`] (busy/idle/comm spans, critical path, achieved
//!   overlap, makespan) with each strategy's overlap semantics made
//!   explicit, and explains where the closed forms hold — the `timeline`
//!   CLI renders it as a Gantt, the [`Planner`] re-scores candidates with
//!   it under [`Fidelity::Simulated`].
//! * **L5 ([`fleet`])** — multi-replica Data Parallel serving over the
//!   two-tier (NVLink/Ethernet) cluster model: replica engines carved out
//!   of one cluster, a pluggable front-door [`fleet::Dispatcher`], seeded
//!   Poisson trace replay into a [`FleetReport`], and a frontier planner
//!   that trades replica count against intra-replica parallelism per
//!   arrival rate.
//! * **L2/L1 (build-time Python)** — the DiT compute graph and Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/` and executed here via
//!   the PJRT CPU client (`runtime`). Python never runs on the request path.
//!
//! The **entry point is [`pipeline::Pipeline`]**: a typed builder facade
//! over the coordinator/parallel/VAE layers that handles one-shot
//! generation (`generate`), batch serving (`serve`), the cost-model
//! routing decision (`plan`) and the event-timeline view of it
//! (`timeline`). Binaries, examples and benches all go through it;
//! `Engine`, `Session` and `driver` are the internal layers it composes.
//!
//! See `DESIGN.md` for the system inventory, the Pipeline quickstart and
//! the per-experiment index.

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod diffusion;
pub mod error;
pub mod fleet;
pub mod mesh;
pub mod model;
pub mod parallel;
pub mod perf;
pub mod pipeline;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;
pub mod vae;

pub use coordinator::{Fidelity, Plan, Planner, Rejection, RoutePolicy, Trace};
pub use error::{Error, Result};
pub use fleet::{DispatchPolicy, FaultLedger, Fleet, FleetFrontier, FleetReport, Health};
pub use perf::simulator::Timeline;
pub use pipeline::{ParallelPolicy, Pipeline, PipelineBuilder, ServeReport};
