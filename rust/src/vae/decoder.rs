//! Patch-parallel VAE decoding over the AOT conv-decoder entrypoints.
//!
//! The latent rows are split across devices; each device receives `halo`
//! neighbour rows (one AllGather of boundary strips — the paper's
//! "exchange of the boundary data ... by allgather communications"), image
//! borders use the edge entrypoints (true SAME-padding boundaries), and the
//! decoded strips are stitched. Exactness vs. the full decode is proven in
//! `python/tests/test_vae.py` and re-checked here end-to-end.

use crate::comm::Clocks;
use crate::config::hardware::ClusterSpec;
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Patch-parallel VAE decoder bound to a loaded [`Runtime`]: splits the
/// latent rows across `n` simulated devices, exchanges `halo` boundary
/// rows, decodes each strip through the row-windowed AOT entrypoints and
/// stitches the result — matching
/// [`decode_full`](ParallelVae::decode_full) up to conv-boundary
/// tolerance.
pub struct ParallelVae<'a> {
    rt: &'a Runtime,
    /// Neighbour rows each strip needs on either side (the manifest's
    /// `vae_halo`; the receptive-field reach of the conv stack).
    pub halo: usize,
    /// Latent spatial extent in rows/cols (`latent_hw`); the decoded
    /// image is `8·hw` pixels square.
    pub hw: usize,
    /// Latent channel count (`c_latent`).
    pub c: usize,
}

impl<'a> ParallelVae<'a> {
    /// Bind a decoder to `rt`, reading the halo width and latent shape
    /// from the runtime's manifest.
    pub fn new(rt: &'a Runtime) -> Result<ParallelVae<'a>> {
        Ok(ParallelVae {
            rt,
            halo: rt.manifest.vae_halo,
            hw: rt.manifest.model_dim("latent_hw")?,
            c: rt.manifest.model_dim("c_latent")?,
        })
    }

    /// Serial decode: `[hw, hw, c]` latent -> `[8hw, 8hw, 3]` image.
    pub fn decode_full(&self, z: &Tensor) -> Result<Tensor> {
        let out = self.rt.call("vae_decode", 0, &[ArgValue::F32(z)])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Patch-parallel decode over `n` simulated devices. Charges the halo
    /// AllGather and per-device conv compute to `clocks` when provided.
    pub fn decode_parallel(
        &self,
        z: &Tensor,
        n: usize,
        cluster: &ClusterSpec,
        clocks: &mut Clocks,
    ) -> Result<Tensor> {
        if n == 1 {
            return self.decode_full(z);
        }
        if self.hw % n != 0 {
            return Err(Error::config(format!(
                "latent rows {} not divisible by {n} devices",
                self.hw
            )));
        }
        let hp = self.hw / n;
        if ![2, 4, 8].contains(&hp) {
            return Err(Error::config(format!("no artifact for patch rows {hp}")));
        }
        let group: Vec<usize> = (0..n).collect();

        // halo exchange: each device contributes its boundary strips
        let halo_bytes = self.halo * self.hw * self.c * 4;
        let t = cluster.collective_time(&group, halo_bytes as f64, (n as f64 - 1.0) / n as f64);
        let start = clocks.sync(&group);
        for &d in &group {
            clocks.wait_until(d, start + t);
        }

        // analytic conv compute per device (the real convs run via PJRT)
        let px = 8 * self.hw;
        let per_dev = crate::vae::memory::vae_decode_flops(px) / n as f64;
        for &d in &group {
            clocks.advance(d, per_dev / (cluster.gpu.tflops * 1e12));
        }

        let mut parts = Vec::with_capacity(n);
        for i in 0..n {
            let (lo, hi) = (i * hp, (i + 1) * hp);
            let (entry, window) = if i == 0 {
                (format!("vae_decode_rows{hp}_top"), z.slice_rows(lo, hi + self.halo)?)
            } else if i == n - 1 {
                (format!("vae_decode_rows{hp}_bot"), z.slice_rows(lo - self.halo, hi)?)
            } else {
                (
                    format!("vae_decode_rows{hp}_mid"),
                    z.slice_rows(lo - self.halo, hi + self.halo)?,
                )
            };
            let out = self.rt.call(&entry, 0, &[ArgValue::F32(&window)])?;
            parts.push(out.into_iter().next().unwrap());
        }
        Tensor::concat_rows(&parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::util::rng::Rng;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn parallel_decode_exact_vs_full() {
        let Some(rt) = setup() else { return };
        let vae = ParallelVae::new(&rt).unwrap();
        let z = Tensor::randn(&[16, 16, 4], &mut Rng::new(33));
        let full = vae.decode_full(&z).unwrap();
        assert_eq!(full.dims, vec![128, 128, 3]);
        let cluster = l40_cluster(1);
        for n in [2, 4, 8] {
            let mut clocks = Clocks::new(8);
            let par = vae.decode_parallel(&z, n, &cluster, &mut clocks).unwrap();
            assert!(
                par.allclose(&full, 1e-4),
                "n={n}: {}",
                par.max_abs_diff(&full).unwrap()
            );
            assert!(clocks.makespan() > 0.0);
        }
    }

    #[test]
    fn rejects_bad_device_count() {
        let Some(rt) = setup() else { return };
        let vae = ParallelVae::new(&rt).unwrap();
        let z = Tensor::randn(&[16, 16, 4], &mut Rng::new(1));
        let cluster = l40_cluster(1);
        let mut clocks = Clocks::new(8);
        assert!(vae.decode_parallel(&z, 3, &cluster, &mut clocks).is_err());
    }
}
