//! Analytic VAE memory/latency model — Table 3's OOM boundaries and the
//! "parallel VAE lifts resolution, not speed" result.
//!
//! Calibration anchors from the paper: the SD-VAE peak activation tensor at
//! 4096px is 60.41 GB (§4.3), i.e. ~3.6 KB per output pixel; 1-GPU decode
//! at 2048px on L40 takes ~2.2 s; the naive decoder OOMs above 2048px on
//! both 48 GB and 80 GB GPUs, while 8-way patch parallel + chunked conv
//! reaches 7168px (L40) / 8192px (A100).

/// Peak *live* activation bytes of the naive (unchunked, single-device)
/// decode: the widest single tensor (~3.6 KB/pixel — the paper's 60.41 GB
/// at 4096px) plus the neighbouring input/output maps that must coexist,
/// totalling ~6 KB per output pixel.
pub fn vae_peak_bytes(px: usize, channels_latent: usize) -> f64 {
    let per_pixel = 6000.0 * (1.0 + 0.05 * (channels_latent as f64 / 4.0 - 1.0));
    per_pixel * (px as f64) * (px as f64)
}

/// The single largest tensor (the paper's §4.3 anchor).
pub fn vae_peak_tensor_bytes(px: usize) -> f64 {
    0.6 * vae_peak_bytes(px, 4)
}

/// Temporary (im2col / workspace) bytes of one conv over the widest map;
/// chunked execution divides this by `chunks`.
pub fn vae_temp_bytes(px: usize, chunks: usize) -> f64 {
    900.0 * (px as f64) * (px as f64) / chunks as f64
}

/// Decoder FLOPs (conv stack ~ 1.6 GFLOP per output megapixel at SD-VAE
/// widths).
pub fn vae_decode_flops(px: usize) -> f64 {
    1.6e9 * (px as f64) * (px as f64) / 1e6 * 1e3
}

/// Does a decode at `px` fit on a GPU with `mem` bytes using `n` patch
/// devices and `chunks`-way chunked convs?
pub fn vae_fits(px: usize, channels_latent: usize, n: usize, chunks: usize, mem: f64) -> bool {
    let act = vae_peak_bytes(px, channels_latent) / n as f64;
    let tmp = vae_temp_bytes(px, chunks) / n as f64;
    let params = 320e6;
    act + tmp + params < mem * 0.9
}

/// Decode wall-time (seconds) on `n` devices of a cluster: compute/n plus
/// halo exchange and the per-device launch overhead that makes small
/// resolutions *slower* in parallel (Table 3's pattern).
pub fn vae_decode_time(
    px: usize,
    n: usize,
    tflops: f64,
    link_bw: f64,
    link_lat: f64,
) -> f64 {
    let compute = vae_decode_flops(px) / (tflops * 1e12 * 0.15) / n as f64; // convs run at low MFU
    if n == 1 {
        return compute;
    }
    // halo strips at several feature scales + stitching allgather
    let halo_bytes = 6.0 * (px as f64) * 128.0 * 2.0;
    let comm = (n as f64 - 1.0) * (link_lat + halo_bytes / link_bw)
        + (px as f64).powi(2) * 3.0 / link_bw / n as f64;
    let overhead = 0.15 * n as f64 * link_lat / 8e-6; // kernel launch + sync
    compute + comm + overhead
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_anchor() {
        // peak single tensor: 60.41 GB at 4096px (paper §4.3), within 10%
        let gb = vae_peak_tensor_bytes(4096) / 1e9;
        assert!((54.0..66.0).contains(&gb), "{gb}");
    }

    #[test]
    fn naive_oom_above_2048() {
        // Table 3: 1 GPU decodes 2048px but OOMs at 4096px on both GPUs
        assert!(vae_fits(2048, 4, 1, 1, 48e9));
        assert!(!vae_fits(4096, 4, 1, 1, 48e9));
        assert!(!vae_fits(4096, 4, 1, 1, 80e9));
    }

    #[test]
    fn eight_way_reaches_7k_l40_8k_a100() {
        // Table 3 boundaries with 8 devices + chunked conv
        assert!(vae_fits(7168, 4, 8, 4, 48e9));
        assert!(!vae_fits(8192, 4, 8, 4, 48e9));
        assert!(vae_fits(8192, 4, 8, 4, 80e9));
    }

    #[test]
    fn parallel_does_not_speed_up_small_images() {
        // Table 3: latency at 1k/2k *increases* with more devices
        let t1 = vae_decode_time(1024, 1, 90.0, 24e9, 8e-6);
        let t8 = vae_decode_time(1024, 8, 90.0, 24e9, 8e-6);
        assert!(t8 > t1, "t8 {t8} !> t1 {t1}");
    }

    #[test]
    fn chunking_reduces_temp() {
        assert!(vae_temp_bytes(4096, 4) < vae_temp_bytes(4096, 1));
    }
}
