//! Parallel VAE (paper §4.3): patch parallelism with halo exchange for the
//! decoder, plus the analytic activation-memory model behind Table 3's OOM
//! boundaries and the chunked-conv temporary-memory mitigation.

pub mod decoder;
pub mod memory;

pub use decoder::ParallelVae;
pub use memory::{vae_decode_time, vae_fits, vae_peak_bytes};
