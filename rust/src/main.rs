//! xDiT command-line launcher.
//!
//! Every serving/generation subcommand goes through the typed
//! `xdit::Pipeline` facade (see `DESIGN.md`); `figures` and `inspect` use
//! the analytic performance model and the artifact manifest directly.
//!
//! Subcommands:
//!   generate  — generate one image with a chosen parallel config
//!   serve     — run the serving engine on a synthetic request workload
//!   fleet     — multi-replica Data Parallel serving (trace replay or the
//!               replica-count × hybrid frontier sweep)
//!   route     — show the routing decision (a `Plan`) for a model/cluster
//!   timeline  — render a strategy's per-rank event timeline as a Gantt
//!   figures   — regenerate the paper's figure/table series (analytic)
//!   inspect   — list AOT artifacts and model dims

use xdit::config::hardware::{ClusterSpec, CollectiveAlgo};
use xdit::config::model::{BlockVariant, ModelSpec};
use xdit::config::parallel::ParallelConfig;
use xdit::coordinator::{GenRequest, Scenario, SloClass, Trace, TraceEvent, TraceEventKind};
use xdit::diffusion::SchedulerKind;
use xdit::parallel::driver;
use xdit::perf::latency::{best_hybrid, predict_latency, serial_latency, Method};
use xdit::pipeline::{ParallelPolicy, Pipeline};
use xdit::runtime::Runtime;
use xdit::util::cli::Args;
use xdit::util::pgm;
use xdit::RoutePolicy;

const USAGE: &str = "xdit <command> [--flags]

commands:
  generate  --model tiny-adaln --gpus 8 --steps 8 --px 256
            --prompt '...' --seed 0 --guidance 3 --cluster l40x8
            [--method serial|tp|sp|pipefusion|hybrid (default: auto)]
            [--scheduler ddim|dpm|flow_match (default: model)]
            --out image.ppm
  serve     --gpus 8 --requests 16 --rate 0.5 --steps 4 --px 256
            --cluster l40x8 [--scheduler ddim|dpm|flow_match]
            [--capacity 64 --max-batch 4 --deadline-slack 10 --seed 0]
            [--no-plan-cache] [--session-cache 8]
            [--stage-overlap] [--vae 4] [--stage-queue 2]
            [--decode-every 1]
            [--slo interactive,standard,batch] [--cancel id@t,...]
            [--scenario burst|diurnal|mixed-media|straggler|
             failure-replan] [--degrade] [--no-preempt]
            (replays a deterministic Poisson trace through the
             continuous-batching scheduler; runs on the simulated
             backend when artifacts are absent. Prints a steady-state
             summary — plan-cache hit rate, sessions reused vs built —
             after the serving report; --no-plan-cache disables the
             routing memo for debugging, --session-cache 0 disables
             warm-session reuse. --stage-overlap runs the staged
             engine: VAE decode of batch N overlaps denoise of batch
             N+1 behind a bounded queue (--stage-queue), with the
             decode patch-sharded over --vae devices; --decode-every k
             decodes every k-th request. The report gains a per-stage
             occupancy line either way. --slo samples each request's
             SLO class from the given mix (interactive requests can
             preempt all-batch-tier batches; --no-preempt disables
             that for a control replay); --cancel schedules
             cancellations at virtual times; --scenario replays a
             seeded adversarial scenario from the catalog instead of
             the plain Poisson trace; --degrade opts batch-tier
             requests into the overload quality-shedding ladder)
  fleet     --replicas 2 --cluster l40x16 --gpus 16 --requests 256
            --rate 2.0 --steps 2 --px 256 [--model tiny-adaln]
            [--policy rr|jsq|po2 (default: jsq)] [--seed 0]
            [--max-batch 4 --capacity 64]
            [--scenario replica-kill|rolling-drain|
             cascading-stragglers|... (any catalog name)]
            [--kill-replica i@t,...] [--no-hedge]
            (Data Parallel serving: carve the cluster into N replica
             engines behind a dispatcher and replay a seeded Poisson
             trace in virtual time; prints the aggregate latency
             percentiles, the per-replica table, dispatcher imbalance
             and the determinism digest. --scenario swaps in a seeded
             adversarial trace — the fleet-scale variants schedule
             replica kills, rolling drains and cascading stragglers;
             --kill-replica injects extra replica failures at virtual
             times, exercising checkpoint-resume failover (migrated
             requests resume with completed steps credited, and the
             fault ledger prints under the summary); --no-hedge turns
             off interactive-tier hedged dispatch for an overhead
             control run)
  fleet     --frontier --model pixart --cluster l40x16 --px 2048
            [--rates 0.05,0.2,0.4,0.6]
            (sweep replica count x intra-replica hybrid, pricing
             cross-node collectives at the inter-node Ethernet tier;
             prints the throughput-optimal vs latency-optimal frontier
             with a why per arrival rate)
  route     --model pixart --cluster l40x16 --gpus 16 --px 2048
            [--policy cost|paper (default: cost)] [--memory-cap-gb 48]
            [--collective-algo flat|hier|auto (default: auto)]
            [--top-k 5] [--json]
            (cost-model auto-planner: enumerates every valid hybrid
             config, prunes by per-GPU memory, ranks by predicted
             latency; prints latency/comm/memory for the winner and a
             top-k table, or the canonical JSON plan with --json.
             --collective-algo pins how collectives are priced: flat
             one-level rings or two-level hierarchical — intra-node
             phases on the fast tier, leaders-only exchange on
             Ethernet; auto prices both on node-spanning candidates
             and keeps hierarchical only where it strictly wins, with
             the why citing the tier it saves on)
  route     --grid   (emit the canonical golden-plan JSON for the full
             figs 8-17 model x cluster x world grid — the CI snapshot;
             multi-node cells carry the flat-vs-hierarchical
             provenance keys the golden test pins)
  timeline  --model pixart --cluster l40x16 --gpus 16 --px 2048
            [--strategy serial|cfg|tp|ulysses|ring|distrifusion|
             pipefusion|hybrid|all (default: hybrid)]
            [--collective-algo flat|hier|auto (default: auto)]
            [--steps 4] [--width 72] [--json]
            [--batches 4 --stage-overlap --vae 2 --stage-queue 2]
            (discrete-event overlap simulator: lowers the strategy into
             per-rank compute/comm/idle spans and renders an ASCII Gantt
             with makespan, closed-form comparison, achieved overlap and
             the critical path; --json emits the full span timeline.
             'hybrid' asks the auto-planner at simulated fidelity, so
             the printed why cites the critical path; single-image
             timelines print the collective algorithm they were lowered
             with (--collective-algo pins it; TP and Ulysses partially
             hide their per-layer collectives behind the next layer's
             compute either way). --batches lowers
             the staged serving pipeline instead: denoise ranks feed
             dedicated --vae decode ranks through a bounded queue, and
             with --stage-overlap the decode 'v' spans of batch N render
             under the denoise '#' spans of batch N+1)
  figures   --which fig8|fig14|table1|table3|memory [--px 1024]
  inspect   [--artifacts artifacts]
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> xdit::Result<()> {
    match cmd {
        "generate" => generate(args),
        "serve" => serve(args),
        "fleet" => fleet_cmd(args),
        "route" => route_cmd(args),
        "timeline" => timeline_cmd(args),
        "figures" => figures(args),
        "inspect" => inspect(args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cluster_of(args: &Args) -> xdit::Result<ClusterSpec> {
    ClusterSpec::by_name(args.str_or("cluster", "l40x8"))
}

/// `--collective-algo flat|hier|auto`: `auto` (the default) returns None,
/// leaving the planner's per-candidate selection in charge.
fn collective_algo_of(args: &Args) -> xdit::Result<Option<CollectiveAlgo>> {
    let s = args.str_or("collective-algo", "auto");
    if s.eq_ignore_ascii_case("auto") {
        return Ok(None);
    }
    Ok(Some(CollectiveAlgo::parse(s)?))
}

fn variant_of(name: &str) -> xdit::Result<BlockVariant> {
    Ok(match name {
        "tiny-adaln" => BlockVariant::AdaLn,
        "tiny-cross" => BlockVariant::Cross,
        "tiny-mmdit" => BlockVariant::MmDit,
        "tiny-skip" => BlockVariant::Skip,
        _ => {
            return Err(xdit::Error::config(format!(
                "runnable models: tiny-adaln|tiny-cross|tiny-mmdit|tiny-skip (got {name})"
            )))
        }
    })
}

/// Parallel policy from CLI degree flags (explicit when any is given).
fn policy_of(args: &Args) -> xdit::Result<ParallelPolicy> {
    if args.has("pipefusion") || args.has("ulysses") || args.has("ring") || args.has("cfg") {
        let pc = ParallelConfig::new(
            args.usize_or("cfg", 1)?,
            args.usize_or("pipefusion", 1)?,
            args.usize_or("ulysses", 1)?,
            args.usize_or("ring", 1)?,
        )
        .with_patches(args.usize_or("patches", args.usize_or("pipefusion", 1)?.max(1))?);
        Ok(ParallelPolicy::Explicit(pc))
    } else {
        Ok(ParallelPolicy::Auto)
    }
}

fn generate(args: &Args) -> xdit::Result<()> {
    let rt = Runtime::load(args.str_or("artifacts", "artifacts"))?;
    let model = args.str_or("model", "tiny-adaln").to_string();
    let variant = variant_of(&model)?;

    let mut builder = Pipeline::builder()
        .runtime(&rt)
        .cluster(cluster_of(args)?)
        .world(args.usize_or("gpus", 1)?)
        .parallel(policy_of(args)?);
    if args.has("method") {
        builder = builder.method(driver::Method::parse(args.str_or("method", "serial"))?);
    }
    let mut pipe = builder.build()?;

    let mut req = GenRequest::new(0, args.str_or("prompt", "a photo of a mountain lake at dawn"))
        .with_variant(variant)
        .with_steps(args.usize_or("steps", 8)?)
        .with_seed(args.usize_or("seed", 0)? as u64)
        .with_guidance(args.f64_or("guidance", 3.0)? as f32)
        .with_resolution(args.usize_or("px", 256)?)
        .with_decode(true);
    if args.has("scheduler") {
        req = req.with_scheduler(SchedulerKind::parse(args.str_or("scheduler", ""))?);
    }

    let t0 = std::time::Instant::now();
    let r = pipe.generate(&req)?;
    println!(
        "model={model} method={} config=[{}] scheduler={} px={} cluster={}",
        r.method,
        r.parallel_config,
        r.scheduler,
        r.px,
        pipe.cluster().name
    );
    println!(
        "done: actual {:.3}s on {} GPUs (closed form {:.3e}s, event simulator {:.3e}s), \
         comm {:.1} MB, wall {:?}",
        r.model_seconds,
        pipe.world(),
        r.predicted_seconds,
        r.simulated_seconds,
        r.comm_bytes as f64 / 1e6,
        t0.elapsed()
    );

    let img = r
        .image
        .ok_or_else(|| xdit::Error::config("decode requested but no image returned"))?;
    let out = args.str_or("out", "xdit_out.ppm");
    pgm::write_ppm(out, &img.data, img.dims[0], img.dims[1])?;
    println!("image written to {out} ({}x{})", img.dims[0], img.dims[1]);
    Ok(())
}

/// `--slo interactive,standard,batch`: a comma-separated class mix the
/// trace samples per request (aliases: int, std).
fn parse_slo_mix(s: &str) -> xdit::Result<Vec<SloClass>> {
    let mut mix = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        mix.push(SloClass::by_name(tok).ok_or_else(|| {
            xdit::Error::config(format!(
                "unknown SLO class '{tok}' (interactive|standard|batch)"
            ))
        })?);
    }
    Ok(mix)
}

/// `--cancel id@t,id@t`: cancellation events at virtual time `t` for
/// request `id`, merged into the trace's event schedule.
fn parse_cancellations(s: &str) -> xdit::Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (id, at) = tok.split_once('@').ok_or_else(|| {
            xdit::Error::config(format!("bad --cancel entry '{tok}' (expected id@t)"))
        })?;
        let id: u64 = id
            .trim()
            .parse()
            .map_err(|_| xdit::Error::config(format!("bad request id in --cancel '{tok}'")))?;
        let at: f64 = at
            .trim()
            .parse()
            .map_err(|_| xdit::Error::config(format!("bad fire time in --cancel '{tok}'")))?;
        events.push(TraceEvent::new(at, TraceEventKind::Cancel(id)));
    }
    Ok(events)
}

/// `--kill-replica i@t,i@t`: replica-failure events at virtual time `t`
/// for replica index `i`, merged into the fleet trace's event schedule.
fn parse_kill_replicas(s: &str) -> xdit::Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (idx, at) = tok.split_once('@').ok_or_else(|| {
            xdit::Error::config(format!("bad --kill-replica entry '{tok}' (expected i@t)"))
        })?;
        let idx: usize = idx.trim().parse().map_err(|_| {
            xdit::Error::config(format!("bad replica index in --kill-replica '{tok}'"))
        })?;
        let at: f64 = at.trim().parse().map_err(|_| {
            xdit::Error::config(format!("bad fire time in --kill-replica '{tok}'"))
        })?;
        events.push(TraceEvent::on_replica(at, TraceEventKind::ReplicaFail, idx));
    }
    // keep the merged schedule sorted: the replay fires events in order
    events.sort_by(|a, b| a.at.total_cmp(&b.at));
    Ok(events)
}

fn serve(args: &Args) -> xdit::Result<()> {
    // the serving demo runs anywhere: real artifacts when built, the
    // hermetic simulator otherwise
    let rt = Runtime::load_or_simulated(args.str_or("artifacts", "artifacts"))?;
    let n = args.usize_or("requests", 16)?;
    let rate = args.f64_or("rate", 0.5)?;
    let variant = variant_of(args.str_or("model", "tiny-adaln"))?;

    let mut builder = Pipeline::builder()
        .runtime(&rt)
        .cluster(cluster_of(args)?)
        .world(args.usize_or("gpus", 8)?)
        .max_batch(args.usize_or("max-batch", 4)?)
        .queue_capacity(args.usize_or("capacity", 64)?)
        .plan_cache(!args.bool("no-plan-cache"))
        .session_cache_capacity(args.usize_or("session-cache", 8)?)
        .stage_overlap(args.bool("stage-overlap"))
        .stage_queue_capacity(args.usize_or("stage-queue", 2)?)
        .preemption(!args.bool("no-preempt"))
        .degrade(args.bool("degrade"));
    if args.has("vae") {
        builder = builder.vae_parallelism(args.usize_or("vae", 1)?);
    }
    let mut pipe = builder.build()?;

    let seed = args.usize_or("seed", 0)? as u64;
    let trace = if args.has("scenario") {
        // a named adversarial scenario replaces the plain Poisson trace
        let name = args.str_or("scenario", "burst");
        let scenario = Scenario::by_name(name).ok_or_else(|| {
            let names: Vec<&str> = Scenario::ALL.iter().map(|s| s.name()).collect();
            xdit::Error::config(format!(
                "unknown scenario '{name}' (available: {})",
                names.join(", ")
            ))
        })?;
        println!("scenario {} — {}", scenario.name(), scenario.describe());
        scenario.trace(seed, n)
    } else {
        let mut trace = Trace::poisson(seed, n, rate)
            .steps(args.usize_or("steps", 4)?)
            .variants(&[variant])
            .resolutions(&[args.usize_or("px", 256)?])
            .priorities(&[0, 0, 0, 1]);
        if args.has("decode-every") {
            trace = trace.decode_every(args.usize_or("decode-every", 0)?);
        }
        if args.has("scheduler") {
            trace = trace.schedulers(&[SchedulerKind::parse(args.str_or("scheduler", ""))?]);
        }
        if args.has("deadline-slack") {
            trace = trace.deadline_slack(args.f64_or("deadline-slack", 10.0)?);
        }
        if args.has("slo") {
            trace = trace.slos(&parse_slo_mix(args.str_or("slo", "standard"))?);
        }
        trace.build()
    };
    let trace = match parse_cancellations(args.str_or("cancel", ""))? {
        cancels if cancels.is_empty() => trace,
        cancels => {
            let mut events = trace.events().to_vec();
            events.extend(cancels);
            trace.with_events(events)
        }
    };

    let t0 = std::time::Instant::now();
    let report = pipe.serve_trace(&trace)?;
    println!("{}", report.summary());
    println!("{}", report.metrics.steady_state());
    for rej in &report.rejected {
        println!("  {rej}");
    }
    println!(
        "(host wall time {:?} for {} generations, backend {})",
        t0.elapsed(),
        report.responses.len(),
        rt.backend_name()
    );
    Ok(())
}

fn fleet_cmd(args: &Args) -> xdit::Result<()> {
    if args.bool("frontier") {
        // analytic sweep: no runtime needed, works for the paper models
        let model = ModelSpec::by_name(args.str_or("model", "pixart"))?;
        let cluster = cluster_of(args)?;
        let px = args.usize_or("px", 1024)?;
        let mut rates = Vec::new();
        for tok in args.str_or("rates", "0.05,0.2,0.4,0.6").split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            rates.push(tok.parse::<f64>().map_err(|_| {
                xdit::Error::config(format!("bad arrival rate '{tok}' in --rates"))
            })?);
        }
        let planner = xdit::Planner::default();
        let frontier = xdit::fleet::frontier(&planner, &model, px, &cluster, &rates)?;
        print!("{}", frontier.table());
        return Ok(());
    }

    let rt = Runtime::load_or_simulated(args.str_or("artifacts", "artifacts"))?;
    let n = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 2.0)?;
    let variant = variant_of(args.str_or("model", "tiny-adaln"))?;
    let seed = args.usize_or("seed", 0)? as u64;
    let policy = xdit::DispatchPolicy::parse(args.str_or("policy", "jsq"), seed)?;
    let cluster = cluster_of(args)?;
    let gpus = args.usize_or("gpus", cluster.n_gpus)?;

    let pipe = Pipeline::builder()
        .runtime(&rt)
        .cluster(cluster)
        .world(gpus)
        .replicas(args.usize_or("replicas", 2)?)
        .dispatcher(policy)
        .hedging(!args.bool("no-hedge"))
        .max_batch(args.usize_or("max-batch", 4)?)
        .queue_capacity(args.usize_or("capacity", 64)?)
        .build()?;

    let trace = if args.has("scenario") {
        let name = args.str_or("scenario", "replica-kill");
        let scenario = Scenario::by_name(name).ok_or_else(|| {
            let names: Vec<&str> = Scenario::ALL.iter().map(|s| s.name()).collect();
            xdit::Error::config(format!(
                "unknown scenario '{name}' (available: {})",
                names.join(", ")
            ))
        })?;
        println!("scenario {} — {}", scenario.name(), scenario.describe());
        scenario.trace(seed, n)
    } else {
        Trace::poisson(seed, n, rate)
            .steps(args.usize_or("steps", 2)?)
            .variants(&[variant])
            .resolutions(&[args.usize_or("px", 256)?])
            .build()
    };
    let trace = match parse_kill_replicas(args.str_or("kill-replica", ""))? {
        kills if kills.is_empty() => trace,
        kills => {
            let mut events = trace.events().to_vec();
            events.extend(kills);
            events.sort_by(|a, b| a.at.total_cmp(&b.at));
            trace.with_events(events)
        }
    };

    let t0 = std::time::Instant::now();
    let report = pipe.serve_fleet(&trace)?;
    println!("{}", report.summary());
    if report.faults.any() {
        println!("{}", report.faults.summary());
    }
    println!("{}", report.table());
    for rej in report.rejected.iter().take(8) {
        println!("  {rej}");
    }
    println!(
        "(host wall time {:?} for {} served, backend {})",
        t0.elapsed(),
        report.served,
        rt.backend_name()
    );
    Ok(())
}

fn route_cmd(args: &Args) -> xdit::Result<()> {
    if args.bool("grid") {
        // the canonical golden-plan snapshot of the figs 8-17 grid; CI
        // diffs this byte-for-byte against rust/testdata/plans.golden.json
        print!("{}", xdit::coordinator::planner::grid_report());
        return Ok(());
    }
    let model = ModelSpec::by_name(args.str_or("model", "pixart"))?;
    let cluster = cluster_of(args)?;
    let gpus = args.usize_or("gpus", cluster.n_gpus)?;
    let px = args.usize_or("px", 1024)?;
    let policy = RoutePolicy::parse(args.str_or("policy", "cost"))?;
    let mut b = Pipeline::builder().cluster(cluster).world(gpus).route_policy(policy);
    if args.has("memory-cap-gb") {
        b = b.memory_cap_gb(args.f64_or("memory-cap-gb", 0.0)?);
    }
    if let Some(algo) = collective_algo_of(args)? {
        b = b.collective_algo(algo);
    }
    let plan = b.plan(&model, px)?;
    if args.bool("json") {
        println!("{}", plan.to_json());
        return Ok(());
    }
    println!("{}", plan.describe());
    let k = args.usize_or("top-k", 5)?;
    if k == 0 {
        return Ok(());
    }
    let ranked = b.plan_candidates(&model, px)?;
    if !ranked.is_empty() {
        // the candidate table is always the cost model's ranking — under
        // --policy paper the winner above is the heuristic's pick, which
        // need not be rank 1 here
        println!(
            "\ntop-{} of {} candidates, ranked by the cost model:",
            k.min(ranked.len()),
            ranked.len()
        );
        println!(
            "{:<36} {:>12} {:>10} {:>9} {:>5}",
            "config", "predicted(s)", "comm(GB)", "mem(GB)", "fits"
        );
        for p in ranked.iter().take(k) {
            println!(
                "{:<36} {:>12.3} {:>10.2} {:>9.1} {:>5}",
                p.config.describe(),
                p.predicted.total,
                p.comm_bytes / 1e9,
                p.peak_memory_bytes / 1e9,
                if p.fits { "yes" } else { "OOM" }
            );
        }
    }
    Ok(())
}

fn timeline_cmd(args: &Args) -> xdit::Result<()> {
    use xdit::perf::simulator::{
        render, simulate, simulate_stages, simulate_with, strategy_config, StageSpec, STRATEGIES,
    };
    let model = ModelSpec::by_name(args.str_or("model", "pixart"))?;
    let cluster = cluster_of(args)?;
    let gpus = args.usize_or("gpus", cluster.n_gpus)?;
    let px = args.usize_or("px", 1024)?;
    let steps = args.usize_or("steps", 4)?;
    let width = args.usize_or("width", 72)?;
    let strat = args.str_or("strategy", "hybrid");

    if strat == "all" {
        for name in STRATEGIES {
            match strategy_config(name, &model, px, &cluster, gpus, steps) {
                Ok((method, pc)) => {
                    let mut tl = simulate(&model, px, &cluster, method, &pc, steps);
                    // serial/cfg lower through the hybrid composition;
                    // report the strategy the user asked for
                    tl.strategy = name;
                    println!("{}", render(&tl, width));
                }
                Err(e) => println!("# {name}: skipped ({e})\n"),
            }
        }
        return Ok(());
    }

    let forced_algo = collective_algo_of(args)?;

    let label = STRATEGIES.iter().find(|s| **s == strat).copied();
    let (method, pc, why, algo) = if strat == "hybrid" {
        // the auto-planner at simulated fidelity: memory-pruned ranking,
        // the event simulator breaking ties, the why citing the winner's
        // critical path (and the collective algorithm the plan is priced
        // with — forced by --collective-algo, auto-selected otherwise)
        let mut planner =
            xdit::Planner::default().with_fidelity(xdit::Fidelity::Simulated).with_steps(steps);
        if let Some(a) = forced_algo {
            planner = planner.with_collective_algo(a);
        }
        let plan = planner.plan(&model, px, &cluster, gpus);
        (Method::Hybrid, plan.config, Some(plan.why), plan.collective_algo)
    } else {
        let (method, pc) = strategy_config(strat, &model, px, &cluster, gpus, steps)?;
        (method, pc, None, forced_algo.unwrap_or(CollectiveAlgo::FlatRing))
    };
    let staged = args.has("batches") || args.bool("stage-overlap");
    let mut tl = if staged {
        // lower the staged serving pipeline: denoise ranks feed the
        // dedicated decode ranks through the bounded queue
        let spec = StageSpec {
            batches: args.usize_or("batches", 4)?,
            vae_parallelism: args.usize_or("vae", 2)?,
            queue_capacity: args.usize_or("stage-queue", 2)?,
            overlap: args.bool("stage-overlap"),
        };
        simulate_stages(&model, px, &cluster, method, &pc, steps, spec)
    } else {
        simulate_with(&model, px, &cluster, method, &pc, steps, algo)
    };
    if let Some(name) = label.filter(|_| !staged) {
        tl.strategy = name;
    }
    if args.bool("json") {
        println!("{}", tl.to_canonical_string());
        return Ok(());
    }
    print!("{}", render(&tl, width));
    if !staged {
        println!("collectives: {}", algo.label());
    }
    if let Some(why) = why {
        println!("why: {why}");
    }
    Ok(())
}

fn figures(args: &Args) -> xdit::Result<()> {
    let which = args.str_or("which", "fig8");
    let px = args.usize_or("px", 1024)?;
    match which {
        "fig8" | "fig14" => {
            let cluster = if which == "fig8" {
                xdit::config::hardware::l40_cluster(2)
            } else {
                xdit::config::hardware::a100_node()
            };
            let m = ModelSpec::by_name("pixart")?;
            println!("# {} Pixart {}px latency (s) on {}", which, px, cluster.name);
            println!("{:<14} {:>6} {:>6} {:>6} {:>6}", "method", "2", "4", "8", "16");
            for meth in [
                Method::Tp,
                Method::SpUlysses,
                Method::SpRing,
                Method::DistriFusion,
                Method::PipeFusion,
            ] {
                print!("{:<14}", meth.label());
                for n in [2usize, 4, 8, 16] {
                    if n > cluster.n_gpus {
                        print!(" {:>6}", "-");
                        continue;
                    }
                    let pc = meth.single_config(n);
                    let lb = predict_latency(&m, px, &cluster, meth, &pc, 20);
                    print!(" {:>6.1}", lb.total);
                }
                println!();
            }
            print!("{:<14}", "hybrid(best)");
            for n in [2usize, 4, 8, 16] {
                if n > cluster.n_gpus {
                    print!(" {:>6}", "-");
                    continue;
                }
                let (_, lb) = best_hybrid(&m, px, &cluster, n, 20);
                print!(" {:>6.1}", lb.total);
            }
            println!();
            println!("serial: {:.1}s", serial_latency(&m, px, &cluster, 20));
        }
        "table1" => {
            let m = ModelSpec::by_name("sd3")?;
            let s = m.seq_len(px);
            println!("# Table 1: per-step comm volume (GB) at {px}px (SD3), n=8");
            for row in [
                xdit::perf::comm_model::Row::TensorParallel,
                xdit::perf::comm_model::Row::DistriFusion,
                xdit::perf::comm_model::Row::SpRing,
                xdit::perf::comm_model::Row::SpUlysses,
                xdit::perf::comm_model::Row::PipeFusion,
            ] {
                println!(
                    "{:<22} {:>8.3} GB  overlap={}",
                    row.label(),
                    xdit::perf::comm_model::comm_bytes(row, &m, s, 8) / 1e9,
                    row.overlaps()
                );
            }
        }
        "table3" => {
            println!("# Table 3: parallel VAE time (s) / OOM, L40 48GB, c=4");
            println!("{:<6} {:>8} {:>8} {:>8} {:>8}", "GPUs", "1k", "2k", "4k", "7k");
            for n in [1usize, 2, 4, 8] {
                print!("{:<6}", n);
                for px in [1024usize, 2048, 4096, 7168] {
                    if xdit::vae::vae_fits(px, 4, n, 4, 48e9) {
                        print!(" {:>8.2}", xdit::vae::vae_decode_time(px, n, 90.0, 24e9, 8e-6));
                    } else {
                        print!(" {:>8}", "OOM");
                    }
                }
                println!();
            }
        }
        "memory" => {
            println!("# Fig 18: max memory (GB/device) at {px}px, n=8");
            for name in ["pixart", "sd3", "flux"] {
                let m = ModelSpec::by_name(name)?;
                println!("{name}:");
                for row in [
                    xdit::perf::comm_model::Row::SpUlysses,
                    xdit::perf::comm_model::Row::DistriFusion,
                    xdit::perf::comm_model::Row::PipeFusion,
                ] {
                    let f = xdit::perf::memory_model::backbone_memory(&m, px, row, 8);
                    println!(
                        "  {:<14} params {:>6.1} GB, others {:>6.1} GB",
                        row.label(),
                        f.parameters_gb(),
                        f.others_gb()
                    );
                }
            }
        }
        _ => println!("figures: fig8 fig14 table1 table3 memory (see benches/ for the full set)"),
    }
    Ok(())
}

fn inspect(args: &Args) -> xdit::Result<()> {
    let rt = Runtime::load(args.str_or("artifacts", "artifacts"))?;
    println!(
        "manifest v{} — {} entrypoints, model dims: {:?}",
        rt.manifest.version,
        rt.manifest.entries.len(),
        rt.manifest.model
    );
    println!(
        "weights: {} tensors, {:.1} MB",
        rt.host_weights.tensors.len(),
        rt.host_weights.total_bytes() as f64 / 1e6
    );
    for (name, e) in &rt.manifest.entries {
        println!(
            "  {:<28} kind={:<6} inputs={} weights={} outs={}",
            name,
            e.kind,
            e.data_inputs.len(),
            e.weights.len(),
            e.outputs.len()
        );
    }
    Ok(())
}
