//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

use crate::{Error, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = parse("serve --model tiny-mmdit --gpus=8 --verbose --steps 20");
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.str_or("model", ""), "tiny-mmdit");
        assert_eq!(a.usize_or("gpus", 1).unwrap(), 8);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 20);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("generate");
        assert_eq!(a.usize_or("gpus", 4).unwrap(), 4);
        assert_eq!(a.str_or("model", "tiny-adaln"), "tiny-adaln");
    }

    #[test]
    fn bad_int_errors() {
        let a = parse("--gpus banana");
        assert!(a.usize_or("gpus", 1).is_err());
    }
}
