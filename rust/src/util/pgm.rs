//! PPM/PGM image writers for the examples (no image crates offline).

use std::io::Write;
use std::path::Path;

use crate::Result;

/// Write an RGB image (HWC, f32, arbitrary range; min-max normalized) as
/// binary PPM (P6).
pub fn write_ppm(path: impl AsRef<Path>, data: &[f32], h: usize, w: usize) -> Result<()> {
    assert_eq!(data.len(), h * w * 3, "expected HWC RGB");
    let (lo, hi) = min_max(data);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut out = Vec::with_capacity(h * w * 3 + 32);
    out.extend_from_slice(format!("P6\n{w} {h}\n255\n").as_bytes());
    for &v in data {
        out.push(((v - lo) * scale).clamp(0.0, 255.0) as u8);
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&out)?;
    Ok(())
}

/// Write a grayscale image (HW, f32) as binary PGM (P5).
pub fn write_pgm(path: impl AsRef<Path>, data: &[f32], h: usize, w: usize) -> Result<()> {
    assert_eq!(data.len(), h * w);
    let (lo, hi) = min_max(data);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
    let mut out = Vec::with_capacity(h * w + 32);
    out.extend_from_slice(format!("P5\n{w} {h}\n255\n").as_bytes());
    for &v in data {
        out.push(((v - lo) * scale).clamp(0.0, 255.0) as u8);
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&out)?;
    Ok(())
}

fn min_max(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_ppm() {
        let dir = std::env::temp_dir().join("xdit_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        let data: Vec<f32> = (0..2 * 3 * 3).map(|i| i as f32).collect();
        write_ppm(&p, &data, 2, 3).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n3 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 18);
    }

    #[test]
    fn constant_image_ok() {
        let dir = std::env::temp_dir().join("xdit_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.pgm");
        write_pgm(&p, &[1.0; 16], 4, 4).unwrap();
        assert!(p.exists());
    }
}
