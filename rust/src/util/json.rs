//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json` and
//! the config files under `configs/`: objects, arrays, strings (with
//! escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json("expected array".into())),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json("expected string".into())),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json("expected number".into())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json("expected bool".into())),
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    /// Append this value's canonical serialization (stable key order,
    /// compact — identical bytes to `to_string()`) to `out`. The reusable
    /// entry point behind [`JsonWriter`] for the canonical-JSON hot paths
    /// (golden plan grid, timeline span export, bench snapshots): one
    /// preallocated buffer instead of a fresh `String` per value.
    pub fn write_to(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Canonical serialization (stable key order, compact) — `to_string()`
/// comes from this impl and is what golden-file snapshots diff against.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Reusable canonical-JSON writer: one growable buffer serialized into
/// over and over, instead of a fresh `String` (and its reallocations) per
/// `to_string()` call. Byte-compatible with `Display` — the golden plan
/// grid is emitted through this writer and stays byte-identical.
#[derive(Debug, Default)]
pub struct JsonWriter {
    buf: String,
}

impl JsonWriter {
    /// A writer with an empty buffer.
    pub fn new() -> JsonWriter {
        JsonWriter::default()
    }

    /// A writer whose buffer starts at `capacity` bytes (sized for the
    /// document it will render, e.g. one grid cell line).
    pub fn with_capacity(capacity: usize) -> JsonWriter {
        JsonWriter { buf: String::with_capacity(capacity) }
    }

    /// Serialize `value` into the reused buffer and return the rendered
    /// canonical text (valid until the next call).
    pub fn render(&mut self, value: &Json) -> &str {
        self.buf.clear();
        value.write_to(&mut self.buf);
        &self.buf
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, got '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| Error::Json(e.to_string()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number '{s}': {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| Error::Json(e.to_string()))?,
                                16,
                            )
                            .map_err(|e| Error::Json(e.to_string()))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json(format!("bad escape \\{}", e as char))),
                    }
                }
                c => {
                    // multi-byte UTF-8: copy the raw bytes through
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        s.push_str(
                            std::str::from_utf8(bytes).map_err(|e| Error::Json(e.to_string()))?,
                        );
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::Json(format!("expected , or }} got '{}'", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(Error::Json(format!("expected , or ] got '{}'", c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let j = Json::parse(src).unwrap();
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn usize_arr() {
        let j = Json::parse("[2, 256, 192]").unwrap();
        assert_eq!(j.usize_arr().unwrap(), vec![2, 256, 192]);
    }

    #[test]
    fn writer_matches_display_byte_for_byte() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":"q\"uote\n"}"#;
        let j = Json::parse(src).unwrap();
        let mut w = JsonWriter::with_capacity(16);
        assert_eq!(w.render(&j), j.to_string());
        // the buffer is reused across renders, not appended to
        assert_eq!(w.render(&Json::Num(7.0)), "7");
        assert_eq!(w.render(&j), j.to_string());
    }
}
