//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by `benches/*` (harness = false) and the §Perf pass: warmup, fixed
//! iteration budget, median/p10/p90 over per-iteration wall time.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} median  ({:.3?}..{:.3?}, n={})",
            self.name, self.median, self.p10, self.p90, self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations, then at least
/// `min_iters` and at most `max_iters` iterations or ~`budget` of wall time.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_cfg(name, 3, 10, 200, Duration::from_secs(2), &mut f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    budget: Duration,
    f: &mut F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters
        || (samples.len() < max_iters && start.elapsed() < budget)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchStats {
        name: name.to_string(),
        iters: n,
        median: samples[n / 2],
        p10: samples[n / 10],
        p90: samples[(n * 9) / 10],
        mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let s = bench_cfg("noop", 1, 5, 10, Duration::from_millis(50), &mut || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 5);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }
}
