//! Deterministic PRNG (SplitMix64 + xoshiro256**), used for workload
//! generation, latent noise and the property-test harness. The `rand` crate
//! facade is not available offline; `rand_core` alone ships no generator.

/// xoshiro256** seeded via SplitMix64. Passes BigCrush; more than adequate
/// for workload simulation and test-case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Exponential with the given rate (for Poisson arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-12).ln() / rate
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
