//! Infrastructure utilities: JSON, RNG, image output, CLI parsing, timing.
//!
//! The default build is dependency-free (only the optional `pjrt` feature
//! pulls in the vendored `xla` crate), so serde/clap/criterion/rand are
//! hand-rolled here (see DESIGN.md).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pgm;
pub mod rng;
