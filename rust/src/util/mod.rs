//! Infrastructure utilities: JSON, RNG, image output, CLI parsing, timing.
//!
//! The offline crate registry only ships the `xla` dependency closure, so
//! serde/clap/criterion/rand are hand-rolled here (see DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pgm;
pub mod rng;
