//! Per-replica health state machine (fault-tolerance layer L5.75).
//!
//! The fleet tracks one [`Health`] state per replica, driven by
//! replica-targeted trace events during replay:
//!
//! ```text
//!                 Straggler(f < 1) targeted
//!   Healthy ────────────────────────────────▶ Degraded{slowdown}
//!      ▲  ▲                                       │
//!      │  └──── cumulative factor back ≥ 1 ◀──────┘
//!      │
//!      │ ReplicaRecover              ReplicaDrain
//!      ├──────────────── Draining ◀──────────────── Healthy/Degraded
//!      │                     │
//!      │ ReplicaRecover      │ ReplicaFail (from any state)
//!      └───────── Failed ◀───┴───────────────────────────────────────
//! ```
//!
//! Routing reads one bit from this machine — [`Health::routable`]:
//! `Healthy` and `Degraded` replicas accept new work (a slow replica is
//! still a replica; JSQ naturally shifts load off it as its queue
//! grows), `Draining` and `Failed` replicas never do. Failure
//! additionally triggers checkpoint-resume migration in
//! `fleet/failover.rs`; draining just lets the backlog run dry.

/// Health of one fleet replica, as seen by the dispatcher.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Health {
    /// Serving normally.
    Healthy,
    /// Serving, but slowed to `slowdown` × nominal throughput by one or
    /// more targeted straggler events (cumulative factor < 1).
    Degraded {
        /// Cumulative throughput factor (product of targeted straggler
        /// factors since the replica was last healthy; always < 1 here).
        slowdown: f64,
    },
    /// Finishing its backlog for maintenance; accepts no new work.
    Draining,
    /// Crashed. Its backlog was migrated; accepts no new work.
    Failed,
}

impl Health {
    /// May the dispatcher route *new* work here?
    pub fn routable(&self) -> bool {
        matches!(self, Health::Healthy | Health::Degraded { .. })
    }

    /// Short human label for tables and summaries.
    pub fn label(&self) -> String {
        match self {
            Health::Healthy => "healthy".into(),
            Health::Degraded { slowdown } => format!("degraded({slowdown:.2}x)"),
            Health::Draining => "draining".into(),
            Health::Failed => "failed".into(),
        }
    }
}

/// The fleet's replica health ledger: current state per replica plus the
/// failure timestamp failover uses to measure recovery time.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    states: Vec<Health>,
    failed_at: Vec<Option<f64>>,
}

impl HealthTracker {
    /// All `n` replicas start healthy.
    pub fn new(n: usize) -> HealthTracker {
        HealthTracker { states: vec![Health::Healthy; n], failed_at: vec![None; n] }
    }

    /// Current state of replica `i`.
    pub fn state(&self, i: usize) -> Health {
        self.states[i]
    }

    /// True when replica `i` is `Failed`.
    pub fn failed(&self, i: usize) -> bool {
        self.states[i] == Health::Failed
    }

    /// Replicas the dispatcher may currently route to.
    pub fn routable_count(&self) -> usize {
        self.states.iter().filter(|h| h.routable()).count()
    }

    /// True when every replica is plain `Healthy`.
    pub fn all_healthy(&self) -> bool {
        self.states.iter().all(|h| *h == Health::Healthy)
    }

    /// Replica `i` crashes at virtual time `at` (idempotent).
    pub fn fail(&mut self, i: usize, at: f64) {
        if self.states[i] != Health::Failed {
            self.states[i] = Health::Failed;
            self.failed_at[i] = Some(at);
        }
    }

    /// Replica `i` starts draining (no-op when already failed: a crash
    /// outranks maintenance).
    pub fn drain(&mut self, i: usize) {
        if self.states[i] != Health::Failed {
            self.states[i] = Health::Draining;
        }
    }

    /// Replica `i` is restored to `Healthy`. Returns the downtime when it
    /// was recovering from a crash (`at` − failure time), `None` for a
    /// drain or straggler recovery.
    pub fn recover(&mut self, i: usize, at: f64) -> Option<f64> {
        let down = match self.states[i] {
            Health::Failed => self.failed_at[i].map(|t| (at - t).max(0.0)),
            _ => None,
        };
        self.states[i] = Health::Healthy;
        self.failed_at[i] = None;
        down
    }

    /// Fold a targeted straggler factor into replica `i`'s state: factors
    /// multiply (two 0.5× events make a 0.25× replica) and a cumulative
    /// factor back at or above 1 restores `Healthy`. Draining and failed
    /// replicas keep their (stronger) state — the engine-side throughput
    /// change still applies, but routing already avoids them.
    pub fn note_slowdown(&mut self, i: usize, factor: f64) {
        if !factor.is_finite() || factor <= 0.0 {
            return;
        }
        let current = match self.states[i] {
            Health::Healthy => 1.0,
            Health::Degraded { slowdown } => slowdown,
            Health::Draining | Health::Failed => return,
        };
        let cumulative = current * factor;
        self.states[i] = if cumulative >= 1.0 {
            Health::Healthy
        } else {
            Health::Degraded { slowdown: cumulative }
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_bit_tracks_the_state_machine() {
        let mut h = HealthTracker::new(3);
        assert!(h.all_healthy());
        assert_eq!(h.routable_count(), 3);

        h.note_slowdown(0, 0.5);
        assert_eq!(h.state(0), Health::Degraded { slowdown: 0.5 });
        assert!(h.state(0).routable(), "a slow replica still serves");

        h.drain(1);
        assert!(!h.state(1).routable());
        h.fail(2, 4.0);
        assert!(h.failed(2));
        assert_eq!(h.routable_count(), 1);
        assert!(!h.all_healthy());
    }

    #[test]
    fn slowdowns_multiply_and_restore_at_unity() {
        let mut h = HealthTracker::new(1);
        h.note_slowdown(0, 0.5);
        h.note_slowdown(0, 0.5);
        assert_eq!(h.state(0), Health::Degraded { slowdown: 0.25 });
        h.note_slowdown(0, 4.0);
        assert_eq!(h.state(0), Health::Healthy, "cumulative factor 1.0 restores");
        // junk factors are ignored
        h.note_slowdown(0, f64::NAN);
        h.note_slowdown(0, 0.0);
        assert_eq!(h.state(0), Health::Healthy);
    }

    #[test]
    fn fail_outranks_drain_and_recover_measures_downtime() {
        let mut h = HealthTracker::new(1);
        h.fail(0, 2.0);
        h.drain(0);
        assert!(h.failed(0), "a crash outranks maintenance");
        h.fail(0, 9.0);
        assert_eq!(h.recover(0, 5.0), Some(3.0), "idempotent fail keeps the first stamp");
        assert_eq!(h.state(0), Health::Healthy);
        // recovering a draining replica reports no downtime
        h.drain(0);
        assert_eq!(h.recover(0, 6.0), None);
        assert!(h.state(0).routable());
    }
}
