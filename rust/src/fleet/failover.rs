//! Failover bookkeeping: the fault ledger, the deterministic retry
//! backoff policy, and the deferred-submission record the fleet replay
//! loop re-dispatches.
//!
//! The mechanism itself lives in `fleet::Fleet::replay`: on a targeted
//! `ReplicaFail` the dying engine is run to the crash instant with
//! `Engine::run_to_checkpoint` (completing batches that finish first,
//! checkpointing the one the crash lands in at its last whole step
//! boundary), its backlog is evacuated with `Engine::drain_pending`, and
//! every orphan is re-routed to a surviving replica with `steps_done`
//! credited — resume, not redo. This module holds the plain-data pieces
//! so the policy (retry caps, backoff shape, ledger fields) is visible
//! and testable without a fleet.

use crate::coordinator::request::GenRequest;

/// Submission attempts after the first before a rejection becomes final
/// (so a request is offered to the fleet at most `1 + MAX_RETRIES`
/// times).
pub const MAX_RETRIES: u32 = 3;

/// Base of the exponential virtual-time backoff between retries.
pub const RETRY_BACKOFF_S: f64 = 0.25;

/// Deterministic capped exponential backoff: the delay before retry
/// number `tries + 1` (0.25 s, 0.5 s, 1.0 s, ... virtual).
pub fn backoff(tries: u32) -> f64 {
    RETRY_BACKOFF_S * (1u64 << tries.min(16)) as f64
}

/// A rejected submission parked for a later attempt, in virtual time.
#[derive(Debug, Clone)]
pub(crate) struct Deferred {
    /// Virtual instant the retry fires.
    pub due: f64,
    /// Attempts already made (caps at [`MAX_RETRIES`]).
    pub tries: u32,
    /// The request itself, progress credits and all.
    pub req: GenRequest,
}

/// The fleet's fault ledger: everything the fault-tolerance layer did
/// during a replay, folded into `FleetReport`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultLedger {
    /// Replica failures handled (checkpoint + migration).
    pub failovers: u64,
    /// Requests evacuated from dead replicas and re-routed.
    pub migrated: u64,
    /// Whole denoising steps migrated requests carried as credit — work
    /// the dead replica completed that survivors never redo.
    pub steps_credited: u64,
    /// Steps a migrated request re-ran because its credit was lost.
    /// Checkpoint-resume keeps this at zero by construction; the ledger
    /// carries it so tests can pin "resume, not redo" explicitly.
    pub steps_redone: u64,
    /// Rejected submissions re-attempted after virtual-time backoff.
    pub retries: u64,
    /// Requests whose retry budget ran out (final rejection).
    pub retries_exhausted: u64,
    /// Interactive requests submitted twice (hedged dispatch).
    pub hedges: u64,
    /// Hedges where the *secondary* replica finished first.
    pub hedges_won: u64,
    /// Hedges where the primary finished first (the duplicate is reaped).
    pub hedges_lost: u64,
    /// Per-failure recovery time: virtual seconds from the crash until
    /// the last migrated request landed on a survivor (0 when the dead
    /// replica held nothing).
    pub recovery: Vec<f64>,
}

impl FaultLedger {
    /// Did the fault layer do anything this replay?
    pub fn any(&self) -> bool {
        self.failovers + self.migrated + self.retries + self.retries_exhausted + self.hedges > 0
    }

    /// Mean per-failure recovery time (0 when no failures completed).
    pub fn mean_recovery(&self) -> f64 {
        if self.recovery.is_empty() {
            return 0.0;
        }
        self.recovery.iter().sum::<f64>() / self.recovery.len() as f64
    }

    /// One-line ledger for CLI output and logs.
    pub fn summary(&self) -> String {
        format!(
            "faults: failovers={} migrated={} steps credited={} redone={} | \
             retries={} (exhausted {}) | hedges={} won={} lost={} | mean recovery {:.3}s",
            self.failovers,
            self.migrated,
            self.steps_credited,
            self.steps_redone,
            self.retries,
            self.retries_exhausted,
            self.hedges,
            self.hedges_won,
            self.hedges_lost,
            self.mean_recovery(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_capped_exponential() {
        assert_eq!(backoff(0), 0.25);
        assert_eq!(backoff(1), 0.5);
        assert_eq!(backoff(2), 1.0);
        // the shift clamp keeps absurd counters finite
        assert!(backoff(60).is_finite());
        assert_eq!(backoff(60), backoff(16));
    }

    #[test]
    fn ledger_summary_and_recovery_mean() {
        let mut ledger = FaultLedger::default();
        assert!(!ledger.any());
        assert_eq!(ledger.mean_recovery(), 0.0);
        ledger.failovers = 1;
        ledger.migrated = 3;
        ledger.steps_credited = 12;
        ledger.recovery = vec![0.5, 1.5];
        assert!(ledger.any());
        assert_eq!(ledger.mean_recovery(), 1.0);
        let s = ledger.summary();
        assert!(s.contains("failovers=1"), "{s}");
        assert!(s.contains("migrated=3"), "{s}");
        assert!(s.contains("steps credited=12 redone=0"), "{s}");
        assert!(s.contains("mean recovery 1.000s"), "{s}");
    }
}
