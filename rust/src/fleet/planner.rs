//! Fleet planner: sweep (replica count × intra-replica hybrid) and rank
//! the cells against an arrival rate.
//!
//! For every replica count `r` that carves cleanly out of the cluster,
//! the intra-replica [`Planner`] picks the best hybrid for the carved
//! slice and prices its collectives on the slice's own topology — a
//! full-cluster hybrid pays the cross-node Ethernet tier, a single-node
//! replica stays on NVLink/PCIe. Each cell then gets an M/M/1-style
//! first-order queueing estimate: utilization `ρ = λ·L/r` and expected
//! latency `W = L/(1-ρ)` (∞ when saturated), where `L` is the cell's
//! predicted service time. Low arrival rates reward the deep low-latency
//! hybrid; high rates reward replicas, whose capacity scales linearly
//! because Data Parallel moves no bytes between replicas. The resulting
//! [`FleetFrontier`] names a throughput-optimal cell, a latency-optimal
//! cell per rate, and a human "why" citing the tier-priced comm cost.

use crate::config::hardware::{ClusterSpec, CollectiveAlgo, LinkKind};
use crate::config::model::ModelSpec;
use crate::coordinator::planner::{Plan, Planner};
use crate::{Error, Result};

/// One cell of the sweep: `replicas` copies of a `world`-GPU hybrid.
#[derive(Debug, Clone)]
pub struct FleetCell {
    /// Data-parallel replica count.
    pub replicas: usize,
    /// GPUs per replica (= carved slice size).
    pub world: usize,
    /// Whether one replica spans nodes (its collectives then pay the
    /// inter-node Ethernet tier).
    pub cross_node: bool,
    /// Predicted service time of one image on one replica (seconds).
    pub service_seconds: f64,
    /// Fleet capacity: `replicas / service_seconds` images per second.
    pub capacity: f64,
    /// The intra-replica plan the [`Planner`] chose for the carved slice.
    pub plan: Plan,
}

impl FleetCell {
    /// Short label, e.g. `2x8 [cfg=2,ring=4]`.
    pub fn label(&self) -> String {
        format!("{}x{} [{}]", self.replicas, self.world, self.plan.config.describe())
    }

    /// Utilization `ρ = λ·L/r` at arrival rate `rate` (images/second).
    pub fn utilization(&self, rate: f64) -> f64 {
        rate * self.service_seconds / self.replicas as f64
    }

    /// First-order expected latency `W = L/(1-ρ)`; ∞ once saturated.
    pub fn expected_latency(&self, rate: f64) -> f64 {
        let rho = self.utilization(rate);
        if rho < 1.0 {
            self.service_seconds / (1.0 - rho)
        } else {
            f64::INFINITY
        }
    }
}

/// The latency-optimal choice at one arrival rate.
#[derive(Debug, Clone)]
pub struct RatePoint {
    /// Arrival rate (images/second) this point was evaluated at.
    pub rate: f64,
    /// Index into [`FleetFrontier::cells`] of the latency-optimal cell.
    pub best: usize,
    /// The winner's expected latency at this rate (∞ = every cell
    /// saturates; the fleet needs admission control or more nodes).
    pub expected_latency: f64,
    /// The winner's utilization at this rate.
    pub utilization: f64,
    /// Human reason the winner beats the natural alternative, citing the
    /// tier-priced communication cost.
    pub why: String,
}

/// The (replica count × hybrid) sweep: every valid carve of the cluster,
/// a throughput-optimal cell, and a latency-optimal cell per rate.
#[derive(Debug, Clone)]
pub struct FleetFrontier {
    /// Model the sweep was run for.
    pub model: String,
    /// Resolution (pixels, square).
    pub px: usize,
    /// Cluster name the sweep carved.
    pub cluster: String,
    /// One-line topology summary (nodes × GPUs and both link tiers).
    pub topology: String,
    /// Sweep cells, ascending replica count (`cells[0]` is the deepest
    /// full-cluster hybrid).
    pub cells: Vec<FleetCell>,
    /// Index of the max-capacity cell (ties go to fewer replicas).
    pub throughput_optimal: usize,
    /// Latency-optimal cell per requested arrival rate.
    pub rates: Vec<RatePoint>,
}

impl FleetFrontier {
    /// Human frontier table: cells, the throughput-optimal pick, and one
    /// line + "why" per arrival rate (the `fleet --frontier` CLI output).
    pub fn table(&self) -> String {
        let mut out = format!(
            "fleet frontier: {} @ {}px on {} ({})\n\
             {:>9}  {:>5}  {:<18}  {:>10}  {:>15}  comm tier\n",
            self.model,
            self.px,
            self.cluster,
            self.topology,
            "replicas",
            "world",
            "config",
            "service(s)",
            "capacity(img/s)",
        );
        for c in &self.cells {
            out.push_str(&format!(
                "{:>9}  {:>5}  {:<18}  {:>10.3}  {:>15.3}  {}\n",
                c.replicas,
                c.world,
                format!("[{}]", c.plan.config.describe()),
                c.service_seconds,
                c.capacity,
                if c.cross_node { "cross-node Ethernet" } else { "intra-node" },
            ));
        }
        let best = &self.cells[self.throughput_optimal];
        out.push_str(&format!(
            "throughput-optimal: {} at {:.3} img/s\n",
            best.label(),
            best.capacity
        ));
        for p in &self.rates {
            let w = &self.cells[p.best];
            let lat = if p.expected_latency.is_finite() {
                format!("E[latency]={:.2}s", p.expected_latency)
            } else {
                "saturated".into()
            };
            out.push_str(&format!(
                "λ={:.2} img/s -> {} ({}, ρ={:.2})\n  why: {}\n",
                p.rate,
                w.label(),
                lat,
                p.utilization,
                p.why
            ));
        }
        out
    }
}

/// How a cell's collectives are priced, for the "why" strings: the tier
/// they run on *and* the algorithm the plan was priced with — a flat ring
/// bottlenecks every step on the shared-NIC Ethernet tier, while the
/// hierarchical decomposition only sends node leaders across it.
fn comm_clause(cluster: &ClusterSpec, cell: &FleetCell) -> String {
    if cell.cross_node {
        let eth = cluster.link_bw(LinkKind::Ethernet) / 1e9;
        match cell.plan.collective_algo {
            CollectiveAlgo::Hierarchical => format!(
                "cross-node collectives priced hierarchically: intra-node phases on the \
                 fast tier, a leaders-only exchange on the {eth:.1} GB/s Ethernet tier \
                 ({:.2}s exposed comm)",
                cell.plan.predicted.comm_exposed,
            ),
            CollectiveAlgo::FlatRing => format!(
                "cross-node collectives priced as a flat ring over the {eth:.1} GB/s \
                 Ethernet tier, NIC shared by every rank on the node \
                 ({:.2}s exposed comm)",
                cell.plan.predicted.comm_exposed,
            ),
        }
    } else {
        let (name, kind) = if cluster.has_nvlink {
            ("NVLink", LinkKind::NvLink)
        } else {
            ("PCIe", LinkKind::Pcie)
        };
        format!(
            "collectives on the {:.1} GB/s intra-node {} tier ({:.2}s exposed comm)",
            cluster.link_bw(kind) / 1e9,
            name,
            cell.plan.predicted.comm_exposed,
        )
    }
}

/// Sweep every valid (replica count × hybrid) cell of `cluster` for
/// `m @ px` and rank the cells at each arrival rate in `rates`
/// (images/second). The intra-replica `planner` is reused per cell.
pub fn frontier(
    planner: &Planner,
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    rates: &[f64],
) -> Result<FleetFrontier> {
    if let Some(bad) = rates.iter().find(|r| !(r.is_finite() && **r > 0.0)) {
        return Err(Error::config(format!("arrival rate must be positive and finite, got {bad}")));
    }

    let mut cells = Vec::new();
    for r in 1..=cluster.n_gpus {
        if cluster.n_gpus % r != 0 {
            continue;
        }
        let Ok(carved) = cluster.carve(r) else { continue };
        let plan = planner.plan(m, px, &carved, carved.n_gpus);
        let service = plan.predicted.total;
        cells.push(FleetCell {
            replicas: r,
            world: carved.n_gpus,
            cross_node: carved.n_gpus > carved.gpus_per_node,
            service_seconds: service,
            capacity: r as f64 / service,
            plan,
        });
    }
    debug_assert!(!cells.is_empty(), "r=1 always carves");

    let throughput_optimal = cells
        .iter()
        .enumerate()
        .fold(0, |best, (i, c)| if c.capacity > cells[best].capacity { i } else { best });

    let rate_points = rates
        .iter()
        .map(|&rate| rate_point(cluster, &cells, throughput_optimal, rate))
        .collect();

    Ok(FleetFrontier {
        model: m.name.clone(),
        px,
        cluster: cluster.name.clone(),
        topology: format!(
            "{} node(s) x {} GPUs, inter-node Ethernet {:.1} GB/s",
            cluster.n_nodes(),
            cluster.gpus_per_node,
            cluster.link_bw(LinkKind::Ethernet) / 1e9,
        ),
        cells,
        throughput_optimal,
        rates: rate_points,
    })
}

/// Rank the cells at one arrival rate and explain the winner.
fn rate_point(
    cluster: &ClusterSpec,
    cells: &[FleetCell],
    throughput_optimal: usize,
    rate: f64,
) -> RatePoint {
    // latency-optimal cell: min expected latency, ties to fewer replicas
    let best = cells.iter().enumerate().fold(0, |best, (i, c)| {
        if c.expected_latency(rate) < cells[best].expected_latency(rate) {
            i
        } else {
            best
        }
    });
    let w = &cells[best];
    let wl = w.expected_latency(rate);

    let why = if !wl.is_finite() {
        // every cell saturates: report the capacity ceiling
        let cap = &cells[throughput_optimal];
        format!(
            "λ={rate:.2} img/s exceeds the fleet's best capacity {:.3} img/s ({}, {}); \
             every cell saturates — shed load or add nodes",
            cap.capacity,
            cap.label(),
            comm_clause(cluster, cap),
        )
    } else if best == 0 {
        // the deepest full-cluster hybrid wins: latency is service time
        let alt = cells[1..].iter().fold(&cells[cells.len() - 1], |a, c| {
            if c.expected_latency(rate) < a.expected_latency(rate) {
                c
            } else {
                a
            }
        });
        format!(
            "queues stay short at λ={rate:.2} img/s (ρ={:.2}), so latency ≈ service time: \
             {} finishes an image in {:.2}s vs {:.2}s expected for {}, worth paying its {}",
            w.utilization(rate),
            w.label(),
            w.service_seconds,
            alt.expected_latency(rate),
            alt.label(),
            comm_clause(cluster, w),
        )
    } else {
        // replicas win: the deep hybrid's sub-linear scaling can't keep up
        let deep = &cells[0];
        let deep_state = if deep.expected_latency(rate).is_finite() {
            let dw = deep.expected_latency(rate);
            format!("expects {dw:.2}s at ρ={:.2}", deep.utilization(rate))
        } else {
            format!("saturates (capacity {:.3} img/s)", deep.capacity)
        };
        format!(
            "at λ={rate:.2} img/s the deep {} hybrid {deep_state} because deeper sharding \
             pays for {}; {} replicas scale capacity linearly to {:.3} img/s with {} — \
             expected latency {:.2}s at ρ={:.2}",
            deep.label(),
            comm_clause(cluster, deep),
            w.replicas,
            w.capacity,
            comm_clause(cluster, w),
            wl,
            w.utilization(rate),
        )
    };

    RatePoint { rate, best, expected_latency: wl, utilization: w.utilization(rate), why }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;

    #[test]
    fn single_node_sweep_covers_every_divisor() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let f = frontier(&Planner::default(), &m, 1024, &l40_cluster(1), &[0.1]).unwrap();
        let counts: Vec<usize> = f.cells.iter().map(|c| c.replicas).collect();
        assert_eq!(counts, vec![1, 2, 4, 8]);
        assert!(f.cells.iter().all(|c| !c.cross_node), "one node never crosses Ethernet");
        for c in &f.cells {
            assert!((c.capacity - c.replicas as f64 / c.service_seconds).abs() < 1e-12);
            assert_eq!(c.world * c.replicas, 8, "cells partition the cluster");
        }
        assert_eq!(f.rates.len(), 1);
        let table = f.table();
        assert!(table.contains("throughput-optimal"), "{table}");
        assert!(table.contains("img/s"), "{table}");
    }

    #[test]
    fn saturated_rate_reports_the_capacity_ceiling() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let f = frontier(&Planner::default(), &m, 2048, &l40_cluster(1), &[1e6]).unwrap();
        let p = &f.rates[0];
        assert!(p.expected_latency.is_infinite());
        assert!(p.why.contains("saturates"), "{}", p.why);
        assert!(p.why.contains("GB/s"), "{}", p.why);
    }

    #[test]
    fn cross_node_clause_cites_the_collective_algorithm() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let two_nodes = l40_cluster(2);
        let f = frontier(&Planner::default(), &m, 1024, &two_nodes, &[]).unwrap();
        let deep = f.cells.iter().find(|c| c.cross_node).expect("r=1 spans both nodes");
        let clause = comm_clause(&two_nodes, deep);
        // the clause names the algorithm the plan was actually priced with
        match deep.plan.collective_algo {
            CollectiveAlgo::FlatRing => {
                assert!(clause.contains("flat ring"), "{clause}");
                assert!(clause.contains("NIC shared"), "{clause}");
            }
            CollectiveAlgo::Hierarchical => {
                assert!(clause.contains("hierarchically"), "{clause}");
                assert!(clause.contains("leaders-only"), "{clause}");
            }
        }
        assert!(clause.contains("Ethernet"), "{clause}");
        // and a pinned-hierarchical planner surfaces the leader exchange
        let hier = Planner::default().with_collective_algo(CollectiveAlgo::Hierarchical);
        let fh = frontier(&hier, &m, 1024, &two_nodes, &[]).unwrap();
        let dh = fh.cells.iter().find(|c| c.cross_node).unwrap();
        let ch = comm_clause(&two_nodes, dh);
        assert!(ch.contains("leaders-only"), "{ch}");
        // single-node replicas never mention the Ethernet tier
        let intra = f.cells.iter().find(|c| !c.cross_node).unwrap();
        assert!(!comm_clause(&two_nodes, intra).contains("Ethernet"));
    }

    #[test]
    fn bad_rates_are_rejected() {
        let m = ModelSpec::by_name("pixart").unwrap();
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(frontier(&Planner::default(), &m, 1024, &l40_cluster(1), &[bad]).is_err());
        }
    }

    #[test]
    fn mm1_estimate_blows_up_near_saturation() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let f = frontier(&Planner::default(), &m, 1024, &l40_cluster(1), &[]).unwrap();
        let c = &f.cells[0];
        let low = c.expected_latency(c.capacity * 0.1);
        let high = c.expected_latency(c.capacity * 0.99);
        assert!(low < high, "latency must grow with load");
        assert!((c.expected_latency(1e-9) - c.service_seconds).abs() < 1e-6);
        assert!(c.expected_latency(c.capacity * 1.01).is_infinite());
    }
}
