//! The fleet front door: pluggable request-to-replica dispatch.
//!
//! A [`Dispatcher`] owns no replica state — each pick consumes a slice of
//! [`ReplicaView`] snapshots (pending depth + how far the replica's clock
//! has run ahead) and returns an index. All three policies are
//! deterministic: round-robin is a counter, join-shortest-queue is a pure
//! argmin, and power-of-two-choices draws its two candidates from a seeded
//! [`Rng`], so a seeded trace replays to the same routing every time.

use crate::util::rng::Rng;
use crate::{Error, Result};

/// How the fleet front door assigns an arriving request to a replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DispatchPolicy {
    /// Cycle through replicas in index order, ignoring load.
    RoundRobin,
    /// Route to the replica with the fewest pending requests (ties broken
    /// by the earlier virtual clock, then the lower index).
    JoinShortestQueue,
    /// Sample two replicas from a seeded RNG and keep the less loaded one
    /// — the classic O(1) approximation of JSQ. Deterministic per seed.
    PowerOfTwo {
        /// Seed of the sampling RNG (the whole routing sequence is a pure
        /// function of it).
        seed: u64,
    },
}

impl DispatchPolicy {
    /// Parse a CLI policy name (`rr`/`round-robin`, `jsq`, `po2`); `seed`
    /// feeds the power-of-two sampler.
    pub fn parse(s: &str, seed: u64) -> Result<DispatchPolicy> {
        match s {
            "rr" | "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "jsq" | "shortest-queue" => Ok(DispatchPolicy::JoinShortestQueue),
            "po2" | "power-of-two" => Ok(DispatchPolicy::PowerOfTwo { seed }),
            _ => Err(Error::config(format!("unknown dispatch policy '{s}' (rr, jsq, po2)"))),
        }
    }

    /// Short human label (reports and CLI output).
    pub fn label(&self) -> String {
        match self {
            DispatchPolicy::RoundRobin => "round-robin".into(),
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue".into(),
            DispatchPolicy::PowerOfTwo { seed } => format!("power-of-two(seed={seed})"),
        }
    }
}

/// What the dispatcher may observe about one replica at pick time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Requests admitted but not yet completed on this replica.
    pub pending: usize,
    /// The replica's virtual clock: when a tick overshot the dispatch
    /// time, the replica is busy until this instant (tie-breaker between
    /// equally-deep queues).
    pub busy_until: f64,
}

/// Lower key = better target: fewest pending, then the replica that frees
/// up earliest, then the lowest index (total order, so argmin is unique).
fn better(views: &[ReplicaView], a: usize, b: usize) -> usize {
    let (va, vb) = (&views[a], &views[b]);
    match va
        .pending
        .cmp(&vb.pending)
        .then(va.busy_until.total_cmp(&vb.busy_until))
        .then(a.cmp(&b))
    {
        std::cmp::Ordering::Greater => b,
        _ => a,
    }
}

/// The policy plus its (tiny) mutable state: the round-robin cursor and
/// the power-of-two sampling RNG. One request = one [`pick`].
///
/// [`pick`]: Dispatcher::pick
#[derive(Debug, Clone)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_next: usize,
    rng: Rng,
}

impl Dispatcher {
    /// A dispatcher for `policy`, its sampler seeded from the policy.
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        let seed = match policy {
            DispatchPolicy::PowerOfTwo { seed } => seed,
            _ => 0,
        };
        Dispatcher { policy, rr_next: 0, rng: Rng::new(seed) }
    }

    /// The policy this dispatcher runs.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Choose the replica for the next request. `views` must be non-empty
    /// and indexed like the fleet's replica list.
    pub fn pick(&mut self, views: &[ReplicaView]) -> usize {
        assert!(!views.is_empty(), "dispatcher needs at least one replica view");
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let k = self.rr_next % views.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                k
            }
            DispatchPolicy::JoinShortestQueue => {
                (1..views.len()).fold(0, |best, i| better(views, best, i))
            }
            DispatchPolicy::PowerOfTwo { .. } => {
                let a = self.rng.below(views.len());
                let b = self.rng.below(views.len());
                better(views, a, b)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(pending: &[usize]) -> Vec<ReplicaView> {
        pending.iter().map(|&p| ReplicaView { pending: p, busy_until: 0.0 }).collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let v = views(&[5, 0, 0]);
        assert_eq!(
            (0..6).map(|_| d.pick(&v)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2],
            "round-robin ignores load"
        );
    }

    #[test]
    fn jsq_is_argmin_with_total_tiebreak() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        assert_eq!(d.pick(&views(&[3, 1, 2])), 1);
        // equal depth: earlier clock wins
        let v = vec![
            ReplicaView { pending: 2, busy_until: 7.0 },
            ReplicaView { pending: 2, busy_until: 3.0 },
        ];
        assert_eq!(d.pick(&v), 1);
        // fully tied: lowest index
        assert_eq!(d.pick(&views(&[2, 2, 2])), 0);
    }

    #[test]
    fn po2_replays_per_seed_and_diverges_across_seeds() {
        let v = views(&[4, 0, 3, 1, 2, 0, 5, 1]);
        let run = |seed: u64| {
            let mut d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed });
            (0..64).map(|_| d.pick(&v)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed must replay identically");
        assert_ne!(run(7), run(8), "distinct seeds must sample differently");
    }

    #[test]
    fn po2_never_picks_the_worse_of_its_pair() {
        // with two replicas the sampled pair is always {0,1} or a
        // singleton, so po2 must never route to a strictly deeper queue
        let mut d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 3 });
        let v = views(&[9, 2]);
        for _ in 0..32 {
            let k = d.pick(&v);
            assert!(k == 1 || v[k].pending == v[1].pending, "picked the deeper queue");
        }
    }

    #[test]
    fn parse_round_trips_the_cli_names() {
        assert_eq!(DispatchPolicy::parse("rr", 0).unwrap(), DispatchPolicy::RoundRobin);
        assert_eq!(DispatchPolicy::parse("jsq", 0).unwrap(), DispatchPolicy::JoinShortestQueue);
        assert_eq!(
            DispatchPolicy::parse("po2", 9).unwrap(),
            DispatchPolicy::PowerOfTwo { seed: 9 }
        );
        assert!(DispatchPolicy::parse("random", 0).is_err());
        assert!(DispatchPolicy::parse("po2", 1).unwrap().label().contains("seed=1"));
    }
}
