//! The fleet front door: pluggable, health-aware request-to-replica
//! dispatch.
//!
//! A [`Dispatcher`] owns no replica state — each pick consumes a slice of
//! [`ReplicaView`] snapshots (pending depth, virtual clock, health,
//! decode backlog, deadline pressure) and returns an index. All three
//! policies are deterministic: round-robin is a counter, join-shortest-
//! queue is a pure argmin, and power-of-two-choices draws its two
//! candidates from a seeded [`Rng`], so a seeded trace replays to the
//! same routing every time.
//!
//! Health awareness is a filter, not a new policy: replicas whose
//! [`Health`] is not routable (`Failed`, `Draining`) are invisible to
//! every policy, and [`Dispatcher::pick`] returns `None` only when *no*
//! replica is routable. On an all-healthy fleet with equal backlogs and
//! no deadline pressure, each policy behaves exactly as it did before
//! the richer view existed (property-tested in `tests/fleet.rs`).

use crate::fleet::health::Health;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// How the fleet front door assigns an arriving request to a replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DispatchPolicy {
    /// Cycle through replicas in index order, ignoring load.
    RoundRobin,
    /// Route to the replica with the fewest pending requests (ties broken
    /// by the earlier virtual clock, then the smaller decode backlog,
    /// then the lower deadline pressure, then the lower index).
    JoinShortestQueue,
    /// Sample two replicas from a seeded RNG and keep the less loaded one
    /// — the classic O(1) approximation of JSQ. Deterministic per seed.
    PowerOfTwo {
        /// Seed of the sampling RNG (the whole routing sequence is a pure
        /// function of it).
        seed: u64,
    },
}

impl DispatchPolicy {
    /// Parse a CLI policy name (`rr`/`round-robin`, `jsq`, `po2`); `seed`
    /// feeds the power-of-two sampler.
    pub fn parse(s: &str, seed: u64) -> Result<DispatchPolicy> {
        match s {
            "rr" | "round-robin" => Ok(DispatchPolicy::RoundRobin),
            "jsq" | "shortest-queue" => Ok(DispatchPolicy::JoinShortestQueue),
            "po2" | "power-of-two" => Ok(DispatchPolicy::PowerOfTwo { seed }),
            _ => Err(Error::config(format!("unknown dispatch policy '{s}' (rr, jsq, po2)"))),
        }
    }

    /// Short human label (reports and CLI output).
    pub fn label(&self) -> String {
        match self {
            DispatchPolicy::RoundRobin => "round-robin".into(),
            DispatchPolicy::JoinShortestQueue => "join-shortest-queue".into(),
            DispatchPolicy::PowerOfTwo { seed } => format!("power-of-two(seed={seed})"),
        }
    }
}

/// What the dispatcher may observe about one replica at pick time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// Requests admitted but not yet completed on this replica.
    pub pending: usize,
    /// The replica's virtual clock: when a tick overshot the dispatch
    /// time, the replica is busy until this instant (tie-breaker between
    /// equally-deep queues).
    pub busy_until: f64,
    /// Health state — non-routable replicas are invisible to every
    /// policy.
    pub health: Health,
    /// Decode-stage backlog (queued VAE decodes behind the denoise
    /// clock); 0 for serial-mode replicas. Late tie-breaker.
    pub backlog: usize,
    /// SLO deadline pressure: the replica's clock minus the earliest
    /// pending deadline (positive = already past a deadline;
    /// `NEG_INFINITY` when nothing pending declares one). Late
    /// tie-breaker — an equally-loaded replica with less pressure wins.
    pub pressure: f64,
}

impl ReplicaView {
    /// A healthy, backlog-free, pressure-free view — what every replica
    /// looked like before the richer state existed. The extra fields are
    /// *late* tie-breakers, so dispatch over such views is bit-identical
    /// to the pre-health dispatcher.
    pub fn healthy(pending: usize, busy_until: f64) -> ReplicaView {
        ReplicaView {
            pending,
            busy_until,
            health: Health::Healthy,
            backlog: 0,
            pressure: f64::NEG_INFINITY,
        }
    }
}

/// Lower key = better target: fewest pending, then the replica that frees
/// up earliest, then the smaller decode backlog, then the lower deadline
/// pressure, then the lowest index (total order, so argmin is unique).
fn better(views: &[ReplicaView], a: usize, b: usize) -> usize {
    let (va, vb) = (&views[a], &views[b]);
    match va
        .pending
        .cmp(&vb.pending)
        .then(va.busy_until.total_cmp(&vb.busy_until))
        .then(va.backlog.cmp(&vb.backlog))
        .then(va.pressure.total_cmp(&vb.pressure))
        .then(a.cmp(&b))
    {
        std::cmp::Ordering::Greater => b,
        _ => a,
    }
}

/// The policy plus its (tiny) mutable state: the round-robin cursor and
/// the power-of-two sampling RNG. One request = one [`pick`].
///
/// [`pick`]: Dispatcher::pick
#[derive(Debug, Clone)]
pub struct Dispatcher {
    policy: DispatchPolicy,
    rr_next: usize,
    rng: Rng,
}

impl Dispatcher {
    /// A dispatcher for `policy`, its sampler seeded from the policy.
    pub fn new(policy: DispatchPolicy) -> Dispatcher {
        let seed = match policy {
            DispatchPolicy::PowerOfTwo { seed } => seed,
            _ => 0,
        };
        Dispatcher { policy, rr_next: 0, rng: Rng::new(seed) }
    }

    /// The policy this dispatcher runs.
    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Choose the replica for the next request, or `None` when no replica
    /// is routable (all failed/draining). `views` must be non-empty and
    /// indexed like the fleet's replica list. When every replica is
    /// routable, each policy's choice — and, for po2, its RNG stream —
    /// is identical to the health-blind dispatcher's.
    pub fn pick(&mut self, views: &[ReplicaView]) -> Option<usize> {
        assert!(!views.is_empty(), "dispatcher needs at least one replica view");
        let routable: Vec<usize> =
            (0..views.len()).filter(|&i| views[i].health.routable()).collect();
        if routable.is_empty() {
            return None;
        }
        Some(match self.policy {
            DispatchPolicy::RoundRobin => {
                // scan forward from the cursor to the next routable
                // replica; with everyone routable this is exactly the old
                // modular increment
                let n = views.len();
                let mut k = self.rr_next % n;
                while !views[k].health.routable() {
                    k = (k + 1) % n;
                }
                self.rr_next = k.wrapping_add(1);
                k
            }
            DispatchPolicy::JoinShortestQueue => routable
                .iter()
                .copied()
                .reduce(|best, i| better(views, best, i))
                .expect("routable is non-empty here"),
            DispatchPolicy::PowerOfTwo { .. } => {
                // sample from the routable list: with everyone routable
                // the list length equals the view count, so the RNG
                // stream (and every draw) matches the health-blind path
                let a = routable[self.rng.below(routable.len())];
                let b = routable[self.rng.below(routable.len())];
                better(views, a, b)
            }
        })
    }

    /// The hedge target: the best routable replica *other than*
    /// `primary`, under the same total order as JSQ — or `None` when the
    /// primary is the only routable replica. Pure argmin, no RNG, so
    /// hedging never perturbs the po2 sampling stream.
    pub fn pick_hedge(&self, views: &[ReplicaView], primary: usize) -> Option<usize> {
        (0..views.len())
            .filter(|&i| i != primary && views[i].health.routable())
            .reduce(|best, i| better(views, best, i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(pending: &[usize]) -> Vec<ReplicaView> {
        pending.iter().map(|&p| ReplicaView::healthy(p, 0.0)).collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let v = views(&[5, 0, 0]);
        assert_eq!(
            (0..6).map(|_| d.pick(&v).unwrap()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2],
            "round-robin ignores load"
        );
    }

    #[test]
    fn round_robin_skips_unroutable_replicas() {
        let mut d = Dispatcher::new(DispatchPolicy::RoundRobin);
        let mut v = views(&[0, 0, 0]);
        v[1].health = Health::Failed;
        assert_eq!(
            (0..4).map(|_| d.pick(&v).unwrap()).collect::<Vec<_>>(),
            vec![0, 2, 0, 2],
            "the cursor scans past failed replicas"
        );
        v[0].health = Health::Draining;
        v[2].health = Health::Failed;
        assert_eq!(d.pick(&v), None, "nothing routable");
    }

    #[test]
    fn jsq_is_argmin_with_total_tiebreak() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        assert_eq!(d.pick(&views(&[3, 1, 2])), Some(1));
        // equal depth: earlier clock wins
        let v = vec![ReplicaView::healthy(2, 7.0), ReplicaView::healthy(2, 3.0)];
        assert_eq!(d.pick(&v), Some(1));
        // fully tied: lowest index
        assert_eq!(d.pick(&views(&[2, 2, 2])), Some(0));
        // depth+clock tied: the smaller decode backlog, then the lower
        // deadline pressure, break the tie before the index does
        let mut v = views(&[2, 2]);
        v[0].backlog = 3;
        assert_eq!(d.pick(&v), Some(1));
        let mut v = views(&[2, 2]);
        v[0].pressure = 1.5;
        v[1].pressure = -0.5;
        assert_eq!(d.pick(&v), Some(1));
    }

    #[test]
    fn jsq_never_routes_to_a_failed_replica() {
        let mut d = Dispatcher::new(DispatchPolicy::JoinShortestQueue);
        let mut v = views(&[0, 9]);
        v[0].health = Health::Failed;
        assert_eq!(d.pick(&v), Some(1), "an empty-but-dead replica is invisible");
        v[1].health = Health::Draining;
        assert_eq!(d.pick(&v), None);
    }

    #[test]
    fn po2_replays_per_seed_and_diverges_across_seeds() {
        let v = views(&[4, 0, 3, 1, 2, 0, 5, 1]);
        let run = |seed: u64| {
            let mut d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed });
            (0..64).map(|_| d.pick(&v).unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed must replay identically");
        assert_ne!(run(7), run(8), "distinct seeds must sample differently");
    }

    #[test]
    fn po2_never_picks_the_worse_of_its_pair() {
        // with two replicas the sampled pair is always {0,1} or a
        // singleton, so po2 must never route to a strictly deeper queue
        let mut d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 3 });
        let v = views(&[9, 2]);
        for _ in 0..32 {
            let k = d.pick(&v).unwrap();
            assert!(k == 1 || v[k].pending == v[1].pending, "picked the deeper queue");
        }
    }

    #[test]
    fn po2_samples_only_routable_replicas() {
        let mut d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 11 });
        let mut v = views(&[0, 0, 0, 0]);
        v[0].health = Health::Failed;
        v[3].health = Health::Draining;
        for _ in 0..64 {
            let k = d.pick(&v).unwrap();
            assert!(k == 1 || k == 2, "sampled an unroutable replica: {k}");
        }
    }

    #[test]
    fn hedge_pick_is_second_best_and_rng_free() {
        let d = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 5 });
        let v = views(&[1, 0, 2]);
        assert_eq!(d.pick_hedge(&v, 1), Some(0), "best excluding the primary");
        assert_eq!(d.pick_hedge(&v, 0), Some(1));
        let mut v = views(&[0, 5]);
        v[1].health = Health::Failed;
        assert_eq!(d.pick_hedge(&v, 0), None, "no routable second replica");
        // immutable receiver: hedging cannot advance the po2 stream
        let mut d2 = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 5 });
        let v8 = views(&[4, 0, 3, 1]);
        let before: Vec<usize> = (0..8).map(|_| d2.pick(&v8).unwrap()).collect();
        let mut d3 = Dispatcher::new(DispatchPolicy::PowerOfTwo { seed: 5 });
        let after: Vec<usize> = (0..8)
            .map(|_| {
                let k = d3.pick(&v8).unwrap();
                let _ = d3.pick_hedge(&v8, k);
                k
            })
            .collect();
        assert_eq!(before, after, "pick_hedge must not consume RNG draws");
    }

    #[test]
    fn parse_round_trips_the_cli_names() {
        assert_eq!(DispatchPolicy::parse("rr", 0).unwrap(), DispatchPolicy::RoundRobin);
        assert_eq!(DispatchPolicy::parse("jsq", 0).unwrap(), DispatchPolicy::JoinShortestQueue);
        assert_eq!(
            DispatchPolicy::parse("po2", 9).unwrap(),
            DispatchPolicy::PowerOfTwo { seed: 9 }
        );
        assert!(DispatchPolicy::parse("random", 0).is_err());
        assert!(DispatchPolicy::parse("po2", 1).unwrap().label().contains("seed=1"));
    }
}
