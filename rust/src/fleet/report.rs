//! What a fleet replay hands back: per-replica stats, aggregate latency
//! percentiles, a dispatcher-imbalance figure, and a determinism digest.
//!
//! The digest is an FNV-1a fold over every response's (replica, id,
//! latency bits, model-seconds bits, comm bytes) plus every rejection id,
//! so two replays of the same trace on the same fleet agree on the digest
//! iff they agreed on every routing decision and every timing result —
//! that is the "deterministic across runs" acceptance gate in one `u64`.

use crate::coordinator::engine::Rejection;
use crate::coordinator::metrics::{Histogram, Metrics};
use crate::fleet::failover::FaultLedger;

/// FNV-1a offset basis (same constants as `plan_cache::fingerprint`).
pub(crate) const FNV_BASIS: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Fold one little-endian `u64` into an FNV-1a accumulator.
pub(crate) fn fold(h: &mut u64, x: u64) {
    for b in x.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// One replica's share of a fleet replay.
#[derive(Debug, Clone)]
pub struct ReplicaStat {
    /// Requests the dispatcher routed here (admitted or not).
    pub routed: usize,
    /// The replica's virtual clock after draining (its local makespan).
    pub horizon: f64,
    /// The replica engine's full metrics snapshot (latency/queue-delay
    /// histograms, occupancy, cache counters, ...).
    pub metrics: Metrics,
}

impl ReplicaStat {
    /// One table row: routing, serving, occupancy and tail latency.
    pub fn row(&self, idx: usize) -> String {
        format!(
            "  replica {idx}: routed={} served={} rejected={} | horizon {:.3}s | \
             occupancy mean {:.2} | latency p50/p95 {:.3}/{:.3}s",
            self.routed,
            self.metrics.served,
            self.metrics.rejected,
            self.horizon,
            self.metrics.mean_occupancy(),
            self.metrics.latency.quantile(0.50),
            self.metrics.latency.quantile(0.95),
        )
    }
}

/// Aggregate outcome of [`Fleet::replay`](super::Fleet::replay).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Dispatch policy label the fleet ran under.
    pub policy: String,
    /// Requests in the trace (routed = submitted; some may be rejected).
    pub submitted: usize,
    /// Requests served to completion across all replicas.
    pub served: u64,
    /// Requests cancelled by trace events (queued or mid-flight) — the
    /// third leg of the conservation invariant
    /// `served + cancelled + rejected == offered`.
    pub cancelled: u64,
    /// Every admission refusal, in arrival order (post-retry: a request
    /// appears here only after its retry budget ran out, or when no
    /// replica was routable).
    pub rejected: Vec<Rejection>,
    /// Fleet makespan: the latest replica clock after draining.
    pub makespan: f64,
    /// End-to-end latency across all replicas (aggregate p50/p95/p99).
    pub latency: Histogram,
    /// Per-replica breakdown, indexed like the fleet's engine list.
    pub replicas: Vec<ReplicaStat>,
    /// FNV-1a fold of every (replica, response) and rejection — equal
    /// digests mean bit-identical replays (see module docs).
    pub digest: u64,
    /// Everything the fault-tolerance layer did: failovers, migrations,
    /// step credits, retries, hedges, recovery times.
    pub faults: FaultLedger,
}

impl FleetReport {
    /// Aggregate latency quantile in seconds (log-bucket upper bound).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    /// Served images per virtual second over the fleet makespan.
    pub fn throughput(&self) -> f64 {
        if self.makespan > 0.0 {
            self.served as f64 / self.makespan
        } else {
            0.0
        }
    }

    /// Dispatcher imbalance: max routed over mean routed (1.0 = perfectly
    /// even; round-robin pins this to ~1.0, load-aware policies may trade
    /// a little imbalance for shorter queues).
    pub fn imbalance(&self) -> f64 {
        let max = self.replicas.iter().map(|r| r.routed).max().unwrap_or(0);
        let total: usize = self.replicas.iter().map(|r| r.routed).sum();
        if total == 0 {
            return 1.0;
        }
        max as f64 * self.replicas.len() as f64 / total as f64
    }

    /// One-line fleet summary (the CLI prints this above the per-replica
    /// table).
    pub fn summary(&self) -> String {
        format!(
            "fleet[{}] x{}: submitted={} served={} cancelled={} rejected={} | \
             makespan {:.3}s virtual, {:.2} img/s | latency p50/p95/p99 \
             {:.3}/{:.3}/{:.3}s | imbalance {:.3} | digest {:016x}",
            self.policy,
            self.replicas.len(),
            self.submitted,
            self.served,
            self.cancelled,
            self.rejected.len(),
            self.makespan,
            self.throughput(),
            self.latency_quantile(0.50),
            self.latency_quantile(0.95),
            self.latency_quantile(0.99),
            self.imbalance(),
            self.digest,
        )
    }

    /// Multi-line per-replica table (one [`ReplicaStat::row`] each).
    pub fn table(&self) -> String {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| r.row(i))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(routed: usize, served: u64) -> ReplicaStat {
        let metrics = Metrics { served, ..Default::default() };
        ReplicaStat { routed, horizon: 10.0, metrics }
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        let mut r = FleetReport {
            policy: "round-robin".into(),
            submitted: 4,
            served: 4,
            cancelled: 0,
            rejected: vec![],
            makespan: 10.0,
            latency: Histogram::new(),
            replicas: vec![stat(3, 3), stat(1, 1)],
            digest: 0,
            faults: FaultLedger::default(),
        };
        // max 3, mean 2 -> 1.5
        assert!((r.imbalance() - 1.5).abs() < 1e-12);
        r.replicas = vec![stat(2, 2), stat(2, 2)];
        assert!((r.imbalance() - 1.0).abs() < 1e-12);
        r.replicas = vec![stat(0, 0), stat(0, 0)];
        assert_eq!(r.imbalance(), 1.0, "empty fleet reads as balanced");
    }

    #[test]
    fn summary_and_table_carry_the_headline_numbers() {
        let mut latency = Histogram::new();
        latency.observe(0.5);
        latency.observe(1.5);
        let r = FleetReport {
            policy: "join-shortest-queue".into(),
            submitted: 2,
            served: 2,
            cancelled: 1,
            rejected: vec![],
            makespan: 4.0,
            latency,
            replicas: vec![stat(1, 1), stat(1, 1)],
            digest: 0xDEAD,
            faults: FaultLedger::default(),
        };
        let s = r.summary();
        assert!(s.contains("fleet[join-shortest-queue] x2"), "{s}");
        assert!(s.contains("cancelled=1"), "{s}");
        assert!(s.contains("0.50 img/s"), "{s}");
        assert!(s.contains("digest 000000000000dead"), "{s}");
        assert_eq!(r.table().lines().count(), 2);
        assert!(r.table().contains("replica 0"), "{}", r.table());
    }

    #[test]
    fn fold_matches_fnv_reference() {
        // folding zero bytes still permutes the accumulator
        let mut h = FNV_BASIS;
        fold(&mut h, 0);
        assert_ne!(h, FNV_BASIS);
        let mut a = FNV_BASIS;
        let mut b = FNV_BASIS;
        fold(&mut a, 1);
        fold(&mut b, 2);
        assert_ne!(a, b, "distinct inputs must hash apart");
    }
}
