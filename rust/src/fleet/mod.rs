//! Fleet layer (L5): multi-replica Data Parallel serving over the
//! two-tier cluster model, with fault tolerance (L5.75).
//!
//! xDiT's fourth parallel axis — Data Parallel — lives here, layered
//! *above* `coordinator`: a [`Fleet`] is N independent replica
//! [`Engine`]s carved out of one [`ClusterSpec`], each running its own
//! plan cache, warm-session cache and continuous batcher. A front-door
//! [`Dispatcher`] assigns arriving requests to replicas under a pluggable
//! [`DispatchPolicy`] (round-robin, join-shortest-queue, or seeded
//! power-of-two-choices), and [`Fleet::replay`] drives a whole seeded
//! Poisson [`Trace`] through the fleet in virtual time — 100k-request
//! traces replay deterministically, digest-equal across runs.
//!
//! The replay loop is submit-order-equivalent to
//! `Pipeline::serve_trace`: for every arrival it first runs each replica
//! forward to the arrival instant (tick while busy, then jump idle
//! clocks), snapshots per-replica [`ReplicaView`]s, lets the dispatcher
//! pick, and submits. A single-replica fleet therefore reproduces
//! `serve_trace` bit-identically — that degenerate case is pinned by a
//! regression test.
//!
//! # Fault tolerance
//!
//! Replica-targeted trace events drive a per-replica [`Health`] state
//! machine (`fleet/health.rs`). A targeted `ReplicaFail` checkpoints the
//! dying engine at the crash instant (`Engine::run_to_checkpoint`:
//! batches the cost model prices as finishing first complete, the one
//! the crash lands in is sliced at its last whole step boundary),
//! evacuates its backlog (`Engine::drain_pending`) and re-routes every
//! orphan to a survivor with `steps_done` credited — because latents are
//! always produced from the original `(seed, steps, plan)` in one piece
//! and execution charges only the un-credited fraction, migrated outputs
//! are bit-identical to an undisturbed replay and credited steps are
//! never redone. Rejected submissions retry with capped deterministic
//! virtual-time backoff (`fleet/failover.rs`), interactive-tier arrivals
//! may be *hedged* (duplicate submit to the second-best replica; first
//! completion wins, the loser is reaped through two-phase
//! `Engine::cancel`), and everything the fault layer does lands in the
//! report's [`FaultLedger`]. Conservation holds across every fault
//! schedule: `served + cancelled + rejected == offered`.
//!
//! Within one inter-arrival window the replay applies events first (in
//! fire order), then due retries (in due order) — both deterministic,
//! so a fault schedule replays to the same digest every run. Event vs
//! *arrival* ties follow the unified rule in `coordinator/trace.rs`:
//! arrivals first.
//!
//! Sizing the fleet is [`planner::frontier`]'s job: sweep (replica count
//! × intra-replica hybrid), price each cell's collectives on the tier
//! they actually traverse (cross-node cells pay Ethernet), and rank the
//! cells by first-order expected latency at each arrival rate.
//!
//! [`ClusterSpec`]: crate::config::hardware::ClusterSpec

pub mod dispatcher;
pub mod failover;
pub mod health;
pub mod planner;
pub mod report;

pub use dispatcher::{DispatchPolicy, Dispatcher, ReplicaView};
pub use failover::{backoff, FaultLedger, MAX_RETRIES};
pub use health::{Health, HealthTracker};
pub use planner::{frontier, FleetCell, FleetFrontier, RatePoint};
pub use report::{FleetReport, ReplicaStat};

use crate::coordinator::engine::{CancelOutcome, Engine, Rejection};
use crate::coordinator::metrics::Histogram;
use crate::coordinator::request::{GenRequest, GenResponse, SloClass};
use crate::coordinator::trace::{Trace, TraceEvent, TraceEventKind};
use crate::{Error, Result};
use failover::Deferred;
use report::{fold, FNV_BASIS};
use std::collections::BTreeMap;

/// One in-flight hedge: the duplicate-submitted replica pair and, once
/// either copy completes, the winner.
#[derive(Debug, Clone, Copy)]
struct Hedge {
    primary: usize,
    secondary: usize,
    winner: Option<usize>,
}

/// Mutable replay state threaded through one [`Fleet::replay`] run.
struct Replay {
    keep: bool,
    kept: Vec<GenResponse>,
    digest: u64,
    latency: Histogram,
    served: u64,
    cancelled: u64,
    routed: Vec<usize>,
    rejected: Vec<Rejection>,
    ledger: FaultLedger,
    /// Unresolved + resolved hedges by request id.
    hedges: BTreeMap<u64, Hedge>,
    /// Parked retries, sorted by (due, id).
    deferred: Vec<Deferred>,
    /// Per-failure outstanding migrated ids: recovery time is measured
    /// when the last one lands (submits, re-defers to a final verdict,
    /// or is rejected).
    migrating: Vec<(f64, std::collections::BTreeSet<u64>)>,
}

impl Replay {
    fn new(n: usize, keep: bool) -> Replay {
        Replay {
            keep,
            kept: Vec::new(),
            digest: FNV_BASIS,
            latency: Histogram::new(),
            served: 0,
            cancelled: 0,
            routed: vec![0; n],
            rejected: Vec::new(),
            ledger: FaultLedger::default(),
            hedges: BTreeMap::new(),
            deferred: Vec::new(),
            migrating: Vec::new(),
        }
    }

    /// A migrated id reached a final per-submission verdict at `now`
    /// (admitted or rejected); when it was a failure's last outstanding
    /// orphan, close that failure's recovery clock.
    fn note_landed(&mut self, id: u64, now: f64) {
        for (at, outstanding) in &mut self.migrating {
            if outstanding.remove(&id) && outstanding.is_empty() {
                self.ledger.recovery.push((now - *at).max(0.0));
            }
        }
        self.migrating.retain(|(_, o)| !o.is_empty());
    }

    /// Park a retry, keeping the schedule sorted by (due, id).
    fn defer(&mut self, d: Deferred) {
        let pos = self.deferred.partition_point(|x| {
            x.due.total_cmp(&d.due).then(x.req.id.cmp(&d.req.id)) != std::cmp::Ordering::Greater
        });
        self.deferred.insert(pos, d);
    }
}

/// N independent replica engines behind one dispatcher.
///
/// Replicas share nothing: each engine owns its queue, batcher, plan
/// cache and session cache, exactly as N separate `Pipeline`s would —
/// that is what makes Data Parallel capacity scale linearly. The fleet
/// adds the routing decision, the health/failover machinery, and the
/// aggregate report.
pub struct Fleet<'a> {
    engines: Vec<Engine<'a>>,
    dispatcher: Dispatcher,
    health: HealthTracker,
    hedging: bool,
}

impl<'a> Fleet<'a> {
    /// A fleet over `engines` (one per replica) dispatching under
    /// `policy`, every replica healthy, hedging enabled. Fails on an
    /// empty replica list.
    pub fn new(engines: Vec<Engine<'a>>, policy: DispatchPolicy) -> Result<Fleet<'a>> {
        if engines.is_empty() {
            return Err(Error::config("a fleet needs at least one replica engine"));
        }
        let health = HealthTracker::new(engines.len());
        Ok(Fleet { engines, dispatcher: Dispatcher::new(policy), health, hedging: true })
    }

    /// Enable/disable hedged dispatch for interactive-tier requests
    /// (default on). With a single replica hedging never triggers — the
    /// hedge pick needs a second routable replica.
    pub fn set_hedging(&mut self, enabled: bool) {
        self.hedging = enabled;
    }

    /// Is hedged interactive dispatch enabled?
    pub fn hedging(&self) -> bool {
        self.hedging
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// The replica engines, indexed like the dispatcher's views.
    pub fn engines(&self) -> &[Engine<'a>] {
        &self.engines
    }

    /// Current health of replica `i`.
    pub fn replica_health(&self, i: usize) -> Health {
        self.health.state(i)
    }

    /// The dispatch policy this fleet routes under.
    pub fn policy(&self) -> DispatchPolicy {
        self.dispatcher.policy()
    }

    /// Replay a trace through the fleet in virtual time and aggregate a
    /// [`FleetReport`]. Latents are dropped as they complete so a
    /// 100k-request trace does not hold 100k tensors; use
    /// [`Fleet::replay_collect`] when the responses themselves matter.
    ///
    /// Replay on a *fresh* fleet is deterministic (equal digests across
    /// runs); reusing a fleet continues its clocks, health states and
    /// cumulative metrics.
    pub fn replay(&mut self, trace: &Trace) -> Result<FleetReport> {
        Ok(self.replay_impl(trace, false)?.0)
    }

    /// [`Fleet::replay`], but also return every response (completion
    /// order). Memory scales with the trace — prefer `replay` for large
    /// traces.
    pub fn replay_collect(&mut self, trace: &Trace) -> Result<(FleetReport, Vec<GenResponse>)> {
        self.replay_impl(trace, true)
    }

    fn replay_impl(
        &mut self,
        trace: &Trace,
        keep: bool,
    ) -> Result<(FleetReport, Vec<GenResponse>)> {
        let reqs = trace.requests();
        let events = trace.events();
        let mut next_event = 0;
        let n = self.engines.len();
        let mut st = Replay::new(n, keep);

        for req in reqs {
            let t = req.arrival;
            // fire every mid-trace event scheduled strictly before this
            // arrival (strict: the unified tie-break rule — arrivals
            // first), then every retry due by now
            while next_event < events.len() && events[next_event].at < t {
                let ev = events[next_event];
                next_event += 1;
                self.apply_trace_event(ev, &mut st)?;
            }
            self.flush_retries(t, &mut st)?;
            // run every live replica forward to the arrival instant:
            // busy replicas tick (possibly overshooting t, exactly like
            // serve_trace), idle replicas jump their clock
            for i in 0..n {
                self.run_replica_to(i, t, &mut st)?;
            }
            self.route_and_submit(req.clone(), t, 0, true, &mut st)?;
        }
        // tail: interleave the remaining events and parked retries in
        // fire order (each runs the replicas it touches forward itself),
        // then drain every live replica to empty
        loop {
            let ev_at = events.get(next_event).map(|e| e.at);
            let retry_at = st.deferred.first().map(|d| d.due);
            match (ev_at, retry_at) {
                (None, None) => break,
                (Some(ea), ra) if ra.map_or(true, |r| ea <= r) => {
                    let ev = events[next_event];
                    next_event += 1;
                    self.apply_trace_event(ev, &mut st)?;
                }
                (_, Some(ra)) => self.flush_retries(ra, &mut st)?,
            }
        }
        loop {
            for i in 0..n {
                self.drain_replica(i, &mut st)?;
            }
            // a drain can strand nothing, but a rejected drain-time
            // retry may have re-deferred — keep going until the retry
            // schedule is empty (tries cap at MAX_RETRIES, so this
            // terminates)
            match st.deferred.first().map(|d| d.due) {
                Some(due) => self.flush_retries(due, &mut st)?,
                None => break,
            }
        }
        for rej in &st.rejected {
            fold(&mut st.digest, rej.id);
        }

        let replicas: Vec<ReplicaStat> = self
            .engines
            .iter()
            .zip(&st.routed)
            .map(|(e, &routed)| ReplicaStat {
                routed,
                horizon: e.horizon(),
                metrics: e.metrics.clone(),
            })
            .collect();
        let makespan = replicas.iter().fold(0.0f64, |m, r| m.max(r.horizon));
        let report = FleetReport {
            policy: self.dispatcher.policy().label(),
            submitted: reqs.len(),
            served: st.served,
            cancelled: st.cancelled,
            rejected: st.rejected,
            makespan,
            latency: st.latency,
            replicas,
            digest: st.digest,
            faults: st.ledger,
        };
        Ok((report, st.kept))
    }

    /// Record completions from replica `i`: hedge winners dedup (the
    /// first copy to complete wins, the duplicate is reaped via
    /// two-phase cancel on the losing replica), everything else folds
    /// into the digest/latency/served exactly as before.
    fn absorb(&mut self, replica: usize, resps: Vec<GenResponse>, st: &mut Replay) {
        for resp in resps {
            let mut reap: Option<usize> = None;
            if let Some(h) = st.hedges.get_mut(&resp.id) {
                if h.winner.is_some() {
                    // the losing copy completed before the reap landed
                    // (same-tick finish): drop it, the winner was counted
                    continue;
                }
                h.winner = Some(replica);
                if replica == h.secondary {
                    st.ledger.hedges_won += 1;
                } else {
                    st.ledger.hedges_lost += 1;
                }
                reap = Some(if replica == h.primary { h.secondary } else { h.primary });
            }
            fold(&mut st.digest, replica as u64);
            fold(&mut st.digest, resp.id);
            fold(&mut st.digest, resp.latency.to_bits());
            fold(&mut st.digest, resp.model_seconds.to_bits());
            fold(&mut st.digest, resp.comm_bytes as u64);
            st.latency.observe(resp.latency);
            st.served += 1;
            let id = resp.id;
            if st.keep {
                st.kept.push(resp);
            }
            if let Some(loser) = reap {
                // NotFound is fine: the duplicate may have completed in
                // the same tick (dropped by the winner check above)
                self.engines[loser].cancel(id);
            }
        }
    }

    /// Run replica `i` forward to virtual time `t` (tick while busy,
    /// then jump the idle clock). Failed replicas stay frozen at their
    /// crash instant.
    fn run_replica_to(&mut self, i: usize, t: f64, st: &mut Replay) -> Result<()> {
        if self.health.failed(i) {
            return Ok(());
        }
        while self.engines[i].pending() > 0 && self.engines[i].virtual_now() < t {
            let resps = self.engines[i].tick()?;
            self.absorb(i, resps, st);
        }
        self.engines[i].advance_to(t);
        Ok(())
    }

    /// Run replica `i` to empty (the end-of-trace drain).
    fn drain_replica(&mut self, i: usize, st: &mut Replay) -> Result<()> {
        if self.health.failed(i) {
            return Ok(());
        }
        while self.engines[i].pending() > 0 {
            let resps = self.engines[i].tick()?;
            self.absorb(i, resps, st);
        }
        Ok(())
    }

    /// Snapshot the dispatcher's view of every replica: load, clock,
    /// health, decode backlog, and SLO deadline pressure.
    fn views(&self) -> Vec<ReplicaView> {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let deadline = e.min_pending_deadline();
                ReplicaView {
                    pending: e.pending(),
                    busy_until: e.virtual_now(),
                    health: self.health.state(i),
                    backlog: e.stage_backlog(),
                    pressure: if deadline.is_finite() {
                        e.virtual_now() - deadline
                    } else {
                        f64::NEG_INFINITY
                    },
                }
            })
            .collect()
    }

    /// Route one request through the dispatcher and submit it at virtual
    /// time `now`. A rejection parks the request for a deterministic
    /// backoff retry until the attempt budget (`MAX_RETRIES`) is spent;
    /// `hedge` additionally duplicates interactive-tier submissions to
    /// the second-best replica (fresh arrivals only — retries and
    /// migrations never hedge).
    fn route_and_submit(
        &mut self,
        req: GenRequest,
        now: f64,
        tries: u32,
        hedge: bool,
        st: &mut Replay,
    ) -> Result<()> {
        let views = self.views();
        let Some(k) = self.dispatcher.pick(&views) else {
            st.note_landed(req.id, now);
            st.rejected.push(Rejection {
                id: req.id,
                reason: "no routable replica (all failed or draining)".into(),
            });
            return Ok(());
        };
        st.routed[k] += 1;
        let id = req.id;
        let slo = req.slo;
        match self.engines[k].submit(req.clone()) {
            Ok(()) => {
                st.note_landed(id, now);
                if hedge && self.hedging && slo == SloClass::Interactive {
                    if let Some(j) = self.dispatcher.pick_hedge(&views, k) {
                        if self.engines[j].submit(req).is_ok() {
                            st.routed[j] += 1;
                            st.ledger.hedges += 1;
                            st.hedges
                                .insert(id, Hedge { primary: k, secondary: j, winner: None });
                        }
                    }
                }
            }
            Err(rej) => {
                if tries >= MAX_RETRIES {
                    st.ledger.retries_exhausted += 1;
                    st.note_landed(id, now);
                    st.rejected.push(rej);
                } else {
                    st.ledger.retries += 1;
                    st.defer(Deferred { due: now + backoff(tries), tries: tries + 1, req });
                }
            }
        }
        Ok(())
    }

    /// Re-dispatch every parked retry due by `t`, in (due, id) order.
    /// Each retry first runs the fleet to its due instant so admission
    /// sees current queues.
    fn flush_retries(&mut self, t: f64, st: &mut Replay) -> Result<()> {
        while st.deferred.first().is_some_and(|d| d.due <= t) {
            let d = st.deferred.remove(0);
            for i in 0..self.engines.len() {
                self.run_replica_to(i, d.due, st)?;
            }
            self.route_and_submit(d.req, d.due, d.tries, false, st)?;
        }
        Ok(())
    }

    /// Fire one mid-trace event against the fleet.
    ///
    /// * `Cancel` probes every replica (a hedged request holds a copy on
    ///   two) and counts at most one fleet-level cancellation.
    /// * Replica-targeted events resolve their target modulo the fleet
    ///   size, run that replica forward to the fire instant, and drive
    ///   the health machine — `ReplicaFail` additionally checkpoints and
    ///   migrates (see [`Fleet::fail_replica`]).
    /// * Untargeted cluster mutations hit every replica's carved cluster
    ///   instantly (the pre-fault semantics), so all of them re-plan.
    fn apply_trace_event(&mut self, ev: TraceEvent, st: &mut Replay) -> Result<()> {
        if let TraceEventKind::Cancel(id) = ev.kind {
            let resolved_hedge = st.hedges.get(&id).map(|h| h.winner.is_some()).unwrap_or(false);
            let mut hit = false;
            for e in &mut self.engines {
                if e.cancel(id) != CancelOutcome::NotFound {
                    hit = true;
                }
            }
            if hit && !resolved_hedge {
                st.cancelled += 1;
            }
            st.hedges.remove(&id);
            return Ok(());
        }
        let target = ev.replica.map(|r| r % self.engines.len());
        match (ev.kind, target) {
            (TraceEventKind::ReplicaFail, Some(i)) => self.fail_replica(i, ev.at, st)?,
            (TraceEventKind::ReplicaDrain, Some(i)) => {
                self.run_replica_to(i, ev.at, st)?;
                self.health.drain(i);
            }
            (TraceEventKind::ReplicaRecover, Some(i)) => {
                self.run_replica_to(i, ev.at, st)?;
                if self.health.failed(i) {
                    // the crashed replica's clock froze at the crash;
                    // it re-enters service at the recovery instant
                    self.engines[i].advance_to(ev.at);
                }
                self.health.recover(i, ev.at);
            }
            (TraceEventKind::Straggler(f), Some(i)) => {
                self.run_replica_to(i, ev.at, st)?;
                self.engines[i].apply_cluster_event(TraceEventKind::Straggler(f));
                self.health.note_slowdown(i, f);
            }
            (kind, Some(i)) => {
                // a targeted RankFail/NodeShrink/NodeGrow mutates one
                // replica's carve only
                self.run_replica_to(i, ev.at, st)?;
                self.engines[i].apply_cluster_event(kind);
            }
            (
                TraceEventKind::ReplicaFail
                | TraceEventKind::ReplicaDrain
                | TraceEventKind::ReplicaRecover,
                None,
            ) => {
                // replica-lifecycle kinds without a target: documented
                // no-op (nothing to fail)
            }
            (kind, None) => {
                for e in &mut self.engines {
                    e.apply_cluster_event(kind);
                }
            }
        }
        Ok(())
    }

    /// Replica `i` crashes at virtual time `at`: checkpoint it there,
    /// mark it failed, evacuate its backlog and re-route every orphan to
    /// the survivors with progress credited. Unresolved hedge copies
    /// whose twin lives on a surviving replica simply collapse to that
    /// copy; resolved (already-served) stale copies are dropped.
    fn fail_replica(&mut self, i: usize, at: f64, st: &mut Replay) -> Result<()> {
        if self.health.failed(i) {
            return Ok(());
        }
        let (resps, _credited) = self.engines[i].run_to_checkpoint(at)?;
        self.absorb(i, resps, st);
        self.health.fail(i, at);
        st.ledger.failovers += 1;
        // survivors run forward to the crash instant so migration routes
        // against their queues as of `at`, not a stale earlier snapshot
        for j in 0..self.engines.len() {
            if j != i {
                self.run_replica_to(j, at, st)?;
            }
        }
        let orphans = self.engines[i].drain_pending();
        let mut to_migrate = Vec::new();
        for req in orphans {
            if let Some(h) = st.hedges.get(&req.id).copied() {
                if h.winner.is_some() {
                    // already served by the winner: drop the stale copy
                    continue;
                }
                let twin = if h.primary == i { h.secondary } else { h.primary };
                if twin != i && !self.health.failed(twin) {
                    // the race is void, the surviving copy just becomes
                    // the request — no migration needed
                    st.hedges.remove(&req.id);
                    continue;
                }
                st.hedges.remove(&req.id);
            }
            to_migrate.push(req);
        }
        let mut outstanding = std::collections::BTreeSet::new();
        for req in &to_migrate {
            st.ledger.migrated += 1;
            st.ledger.steps_credited += req.steps_done.min(req.steps) as u64;
            outstanding.insert(req.id);
        }
        if outstanding.is_empty() {
            // nothing to migrate: the failure recovers instantly
            st.ledger.recovery.push(0.0);
        } else {
            st.migrating.push((at, outstanding));
        }
        for req in to_migrate {
            self.route_and_submit(req, at, 0, false, st)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::runtime::Runtime;

    fn engines(rt: &Runtime, n: usize) -> Vec<Engine<'_>> {
        (0..n).map(|_| Engine::new(rt, l40_cluster(1), 4)).collect()
    }

    fn trace(n: usize) -> Trace {
        Trace::poisson(0xF1EE7, n, 2.0).steps(1).guidance(1.0).build()
    }

    #[test]
    fn empty_fleet_is_refused() {
        assert!(Fleet::new(vec![], DispatchPolicy::RoundRobin).is_err());
    }

    #[test]
    fn replay_serves_the_whole_trace_and_balances() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let r = fleet.replay(&trace(16)).unwrap();
        assert_eq!(r.submitted, 16);
        assert_eq!(r.served + r.rejected.len() as u64, 16);
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas[0].routed, 8, "round-robin splits evenly");
        assert_eq!(r.replicas[1].routed, 8);
        assert!((r.imbalance() - 1.0).abs() < 1e-12);
        assert!(r.makespan > 0.0);
        assert_eq!(r.latency.count, r.served);
        assert!(!r.faults.any(), "a healthy replay leaves an empty fault ledger");
    }

    #[test]
    fn fresh_fleets_replay_digest_equal() {
        let rt = Runtime::simulated();
        let t = trace(24);
        let run = |policy| {
            let mut fleet = Fleet::new(engines(&rt, 3), policy).unwrap();
            fleet.replay(&t).unwrap().digest
        };
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::PowerOfTwo { seed: 42 },
        ] {
            assert_eq!(run(policy), run(policy), "replay must be deterministic ({policy:?})");
        }
    }

    #[test]
    fn cancelled_requests_never_reach_the_digest() {
        let rt = Runtime::simulated();
        let base = trace(12);
        let victim = base.requests().iter().find(|r| r.id == 5).unwrap();
        let with_cancel = base
            .clone()
            .with_events(vec![TraceEvent::new(victim.arrival, TraceEventKind::Cancel(5))]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let (report, responses) = fleet.replay_collect(&with_cancel).unwrap();
        assert!(responses.iter().all(|r| r.id != 5), "cancelled request must never be served");
        let cancelled: u64 = report.replicas.iter().map(|r| r.metrics.cancelled()).sum();
        assert_eq!(cancelled, 1);
        assert_eq!(report.cancelled, 1, "the fleet ledger counts the cancel once");
        assert_eq!(report.served + cancelled + report.rejected.len() as u64, 12);
        // the digest of the cancelled replay differs from the plain one
        // (one fewer response folded in), but replays deterministically
        let mut fleet2 = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        assert_eq!(fleet2.replay(&with_cancel).unwrap().digest, report.digest);
        let mut plain = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        assert_ne!(plain.replay(&base).unwrap().digest, report.digest);
    }

    #[test]
    fn cluster_events_hit_every_replica() {
        let rt = Runtime::simulated();
        let t = trace(8);
        let shaken = t
            .clone()
            .with_events(vec![TraceEvent::new(0.5 * t.last_arrival(), TraceEventKind::RankFail)]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        fleet.replay(&shaken).unwrap();
        for e in fleet.engines() {
            assert_eq!(e.cluster.n_gpus, 7, "each replica's carve lost a GPU");
        }
    }

    #[test]
    fn replay_collect_returns_every_response() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::JoinShortestQueue).unwrap();
        let (report, responses) = fleet.replay_collect(&trace(12)).unwrap();
        assert_eq!(responses.len() as u64, report.served);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), responses.len(), "each request answered once");
    }

    #[test]
    fn a_replica_kill_migrates_the_backlog_and_serves_everyone() {
        let rt = Runtime::simulated();
        let t = trace(16);
        let killed = t.clone().with_events(vec![TraceEvent::on_replica(
            0.5 * t.last_arrival(),
            TraceEventKind::ReplicaFail,
            1,
        )]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let r = fleet.replay(&killed).unwrap();
        assert_eq!(fleet.replica_health(1), Health::Failed);
        assert_eq!(r.faults.failovers, 1);
        assert_eq!(
            r.served + r.cancelled + r.rejected.len() as u64,
            16,
            "conservation must hold across the failure"
        );
        assert_eq!(r.faults.steps_redone, 0, "checkpoint-resume never redoes credited work");
        assert_eq!(r.faults.recovery.len(), 1, "one failure, one recovery measurement");
        // the survivor froze nothing: replica 0 served the whole backlog
        assert!(r.replicas[0].metrics.served > r.replicas[1].metrics.served);
    }

    #[test]
    fn draining_a_replica_stops_new_routing_but_finishes_its_backlog() {
        let rt = Runtime::simulated();
        let t = trace(16);
        let drained = t.clone().with_events(vec![TraceEvent::on_replica(
            0.25 * t.last_arrival(),
            TraceEventKind::ReplicaDrain,
            0,
        )]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::JoinShortestQueue).unwrap();
        let r = fleet.replay(&drained).unwrap();
        assert_eq!(fleet.replica_health(0), Health::Draining);
        assert_eq!(r.served + r.rejected.len() as u64, 16, "nothing is lost in a drain");
        // replica 0 still served what it held before the drain
        assert_eq!(
            r.replicas[0].metrics.served + r.replicas[1].metrics.served,
            r.served,
            "both replicas' ledgers add up"
        );
        assert!(r.replicas[1].routed > r.replicas[0].routed, "post-drain arrivals all go to 1");
    }
}
