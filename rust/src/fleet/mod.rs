//! Fleet layer (L5): multi-replica Data Parallel serving over the
//! two-tier cluster model.
//!
//! xDiT's fourth parallel axis — Data Parallel — lives here, layered
//! *above* `coordinator`: a [`Fleet`] is N independent replica
//! [`Engine`]s carved out of one [`ClusterSpec`], each running its own
//! plan cache, warm-session cache and continuous batcher. A front-door
//! [`Dispatcher`] assigns arriving requests to replicas under a pluggable
//! [`DispatchPolicy`] (round-robin, join-shortest-queue, or seeded
//! power-of-two-choices), and [`Fleet::replay`] drives a whole seeded
//! Poisson [`Trace`] through the fleet in virtual time — 100k-request
//! traces replay deterministically, digest-equal across runs.
//!
//! The replay loop is submit-order-equivalent to
//! `Pipeline::serve_trace`: for every arrival it first runs each replica
//! forward to the arrival instant (tick while busy, then jump idle
//! clocks), snapshots per-replica [`ReplicaView`]s, lets the dispatcher
//! pick, and submits. A single-replica fleet therefore reproduces
//! `serve_trace` bit-identically — that degenerate case is pinned by a
//! regression test.
//!
//! Sizing the fleet is [`planner::frontier`]'s job: sweep (replica count
//! × intra-replica hybrid), price each cell's collectives on the tier
//! they actually traverse (cross-node cells pay Ethernet), and rank the
//! cells by first-order expected latency at each arrival rate.
//!
//! [`ClusterSpec`]: crate::config::hardware::ClusterSpec

pub mod dispatcher;
pub mod planner;
pub mod report;

pub use dispatcher::{DispatchPolicy, Dispatcher, ReplicaView};
pub use planner::{frontier, FleetCell, FleetFrontier, RatePoint};
pub use report::{FleetReport, ReplicaStat};

use crate::coordinator::engine::{CancelOutcome, Engine};
use crate::coordinator::metrics::Histogram;
use crate::coordinator::request::GenResponse;
use crate::coordinator::trace::{Trace, TraceEventKind};
use crate::{Error, Result};
use report::{fold, FNV_BASIS};

/// N independent replica engines behind one dispatcher.
///
/// Replicas share nothing: each engine owns its queue, batcher, plan
/// cache and session cache, exactly as N separate `Pipeline`s would —
/// that is what makes Data Parallel capacity scale linearly. The fleet
/// only adds the routing decision and the aggregate report.
pub struct Fleet<'a> {
    engines: Vec<Engine<'a>>,
    dispatcher: Dispatcher,
}

impl<'a> Fleet<'a> {
    /// A fleet over `engines` (one per replica) dispatching under
    /// `policy`. Fails on an empty replica list.
    pub fn new(engines: Vec<Engine<'a>>, policy: DispatchPolicy) -> Result<Fleet<'a>> {
        if engines.is_empty() {
            return Err(Error::config("a fleet needs at least one replica engine"));
        }
        Ok(Fleet { engines, dispatcher: Dispatcher::new(policy) })
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// The replica engines, indexed like the dispatcher's views.
    pub fn engines(&self) -> &[Engine<'a>] {
        &self.engines
    }

    /// The dispatch policy this fleet routes under.
    pub fn policy(&self) -> DispatchPolicy {
        self.dispatcher.policy()
    }

    /// Replay a trace through the fleet in virtual time and aggregate a
    /// [`FleetReport`]. Latents are dropped as they complete so a
    /// 100k-request trace does not hold 100k tensors; use
    /// [`Fleet::replay_collect`] when the responses themselves matter.
    ///
    /// Replay on a *fresh* fleet is deterministic (equal digests across
    /// runs); reusing a fleet continues its clocks and cumulative
    /// metrics.
    pub fn replay(&mut self, trace: &Trace) -> Result<FleetReport> {
        Ok(self.replay_impl(trace, false)?.0)
    }

    /// [`Fleet::replay`], but also return every response (completion
    /// order). Memory scales with the trace — prefer `replay` for large
    /// traces.
    pub fn replay_collect(&mut self, trace: &Trace) -> Result<(FleetReport, Vec<GenResponse>)> {
        self.replay_impl(trace, true)
    }

    fn replay_impl(
        &mut self,
        trace: &Trace,
        keep: bool,
    ) -> Result<(FleetReport, Vec<GenResponse>)> {
        let reqs = trace.requests();
        let events = trace.events();
        let mut next_event = 0;
        let n = self.engines.len();
        let mut routed = vec![0usize; n];
        let mut rejected = Vec::new();
        let mut latency = Histogram::new();
        let mut digest = FNV_BASIS;
        let mut served: u64 = 0;
        let mut kept = Vec::new();
        let mut record = |replica: usize, resp: GenResponse| {
            fold(&mut digest, replica as u64);
            fold(&mut digest, resp.id);
            fold(&mut digest, resp.latency.to_bits());
            fold(&mut digest, resp.model_seconds.to_bits());
            fold(&mut digest, resp.comm_bytes as u64);
            latency.observe(resp.latency);
            served += 1;
            if keep {
                kept.push(resp);
            }
        };

        for req in reqs {
            let t = req.arrival;
            // fire every mid-trace event scheduled strictly before this
            // arrival (strict, so a cancel stamped at its target's own
            // arrival fires after the submission): cluster mutations hit
            // all replicas (the fleet shares the physical cluster),
            // cancels find whichever replica holds the target — a
            // cancelled request never reaches the digest
            while next_event < events.len() && events[next_event].at < t {
                self.apply_event(events[next_event].kind);
                next_event += 1;
            }
            // run every replica forward to the arrival instant: busy
            // replicas tick (possibly overshooting t, exactly like
            // serve_trace), idle replicas jump their clock
            for (i, engine) in self.engines.iter_mut().enumerate() {
                while engine.pending() > 0 && engine.virtual_now() < t {
                    for resp in engine.tick()? {
                        record(i, resp);
                    }
                }
                engine.advance_to(t);
            }
            let views: Vec<ReplicaView> = self
                .engines
                .iter()
                .map(|e| ReplicaView { pending: e.pending(), busy_until: e.virtual_now() })
                .collect();
            let k = self.dispatcher.pick(&views);
            routed[k] += 1;
            if let Err(rej) = self.engines[k].submit(req.clone()) {
                rejected.push(rej);
            }
        }
        // events scheduled past the last arrival fire before the drain
        while next_event < events.len() {
            self.apply_event(events[next_event].kind);
            next_event += 1;
        }
        // drain: every replica runs to empty
        for (i, engine) in self.engines.iter_mut().enumerate() {
            while engine.pending() > 0 {
                for resp in engine.tick()? {
                    record(i, resp);
                }
            }
        }
        drop(record);
        for rej in &rejected {
            fold(&mut digest, rej.id);
        }

        let replicas: Vec<ReplicaStat> = self
            .engines
            .iter()
            .zip(&routed)
            .map(|(e, &routed)| ReplicaStat {
                routed,
                horizon: e.horizon(),
                metrics: e.metrics.clone(),
            })
            .collect();
        let makespan = replicas.iter().fold(0.0f64, |m, r| m.max(r.horizon));
        let report = FleetReport {
            policy: self.dispatcher.policy().label(),
            submitted: reqs.len(),
            served,
            rejected,
            makespan,
            latency,
            replicas,
            digest,
        };
        Ok((report, kept))
    }

    /// Fire one mid-trace event against the fleet: cancels probe the
    /// replicas until one holds the target (at most one can — requests
    /// are dispatched to exactly one replica); every other event mutates
    /// each replica's carved cluster, so all of them re-plan.
    fn apply_event(&mut self, kind: TraceEventKind) {
        if let TraceEventKind::Cancel(id) = kind {
            for e in &mut self.engines {
                if e.cancel(id) != CancelOutcome::NotFound {
                    return;
                }
            }
        } else {
            for e in &mut self.engines {
                e.apply_cluster_event(kind);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::runtime::Runtime;

    fn engines(rt: &Runtime, n: usize) -> Vec<Engine<'_>> {
        (0..n).map(|_| Engine::new(rt, l40_cluster(1), 4)).collect()
    }

    fn trace(n: usize) -> Trace {
        Trace::poisson(0xF1EE7, n, 2.0).steps(1).guidance(1.0).build()
    }

    #[test]
    fn empty_fleet_is_refused() {
        assert!(Fleet::new(vec![], DispatchPolicy::RoundRobin).is_err());
    }

    #[test]
    fn replay_serves_the_whole_trace_and_balances() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let r = fleet.replay(&trace(16)).unwrap();
        assert_eq!(r.submitted, 16);
        assert_eq!(r.served + r.rejected.len() as u64, 16);
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas[0].routed, 8, "round-robin splits evenly");
        assert_eq!(r.replicas[1].routed, 8);
        assert!((r.imbalance() - 1.0).abs() < 1e-12);
        assert!(r.makespan > 0.0);
        assert_eq!(r.latency.count, r.served);
    }

    #[test]
    fn fresh_fleets_replay_digest_equal() {
        let rt = Runtime::simulated();
        let t = trace(24);
        let run = |policy| {
            let mut fleet = Fleet::new(engines(&rt, 3), policy).unwrap();
            fleet.replay(&t).unwrap().digest
        };
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::PowerOfTwo { seed: 42 },
        ] {
            assert_eq!(run(policy), run(policy), "replay must be deterministic ({policy:?})");
        }
    }

    #[test]
    fn cancelled_requests_never_reach_the_digest() {
        use crate::coordinator::trace::TraceEvent;
        let rt = Runtime::simulated();
        let base = trace(12);
        let victim = base.requests().iter().find(|r| r.id == 5).unwrap();
        let with_cancel = base.clone().with_events(vec![TraceEvent {
            at: victim.arrival,
            kind: TraceEventKind::Cancel(5),
        }]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let (report, responses) = fleet.replay_collect(&with_cancel).unwrap();
        assert!(responses.iter().all(|r| r.id != 5), "cancelled request must never be served");
        let cancelled: u64 = report.replicas.iter().map(|r| r.metrics.cancelled()).sum();
        assert_eq!(cancelled, 1);
        assert_eq!(report.served + cancelled + report.rejected.len() as u64, 12);
        // the digest of the cancelled replay differs from the plain one
        // (one fewer response folded in), but replays deterministically
        let mut fleet2 = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        assert_eq!(fleet2.replay(&with_cancel).unwrap().digest, report.digest);
        let mut plain = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        assert_ne!(plain.replay(&base).unwrap().digest, report.digest);
    }

    #[test]
    fn cluster_events_hit_every_replica() {
        let rt = Runtime::simulated();
        let t = trace(8);
        let shaken = t.clone().with_events(vec![TraceEvent {
            at: 0.5 * t.last_arrival(),
            kind: TraceEventKind::RankFail,
        }]);
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        fleet.replay(&shaken).unwrap();
        for e in fleet.engines() {
            assert_eq!(e.cluster.n_gpus, 7, "each replica's carve lost a GPU");
        }
    }

    #[test]
    fn replay_collect_returns_every_response() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::JoinShortestQueue).unwrap();
        let (report, responses) = fleet.replay_collect(&trace(12)).unwrap();
        assert_eq!(responses.len() as u64, report.served);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), responses.len(), "each request answered once");
    }
}
