//! Fleet layer (L5): multi-replica Data Parallel serving over the
//! two-tier cluster model.
//!
//! xDiT's fourth parallel axis — Data Parallel — lives here, layered
//! *above* `coordinator`: a [`Fleet`] is N independent replica
//! [`Engine`]s carved out of one [`ClusterSpec`], each running its own
//! plan cache, warm-session cache and continuous batcher. A front-door
//! [`Dispatcher`] assigns arriving requests to replicas under a pluggable
//! [`DispatchPolicy`] (round-robin, join-shortest-queue, or seeded
//! power-of-two-choices), and [`Fleet::replay`] drives a whole seeded
//! Poisson [`Trace`] through the fleet in virtual time — 100k-request
//! traces replay deterministically, digest-equal across runs.
//!
//! The replay loop is submit-order-equivalent to
//! `Pipeline::serve_trace`: for every arrival it first runs each replica
//! forward to the arrival instant (tick while busy, then jump idle
//! clocks), snapshots per-replica [`ReplicaView`]s, lets the dispatcher
//! pick, and submits. A single-replica fleet therefore reproduces
//! `serve_trace` bit-identically — that degenerate case is pinned by a
//! regression test.
//!
//! Sizing the fleet is [`planner::frontier`]'s job: sweep (replica count
//! × intra-replica hybrid), price each cell's collectives on the tier
//! they actually traverse (cross-node cells pay Ethernet), and rank the
//! cells by first-order expected latency at each arrival rate.
//!
//! [`ClusterSpec`]: crate::config::hardware::ClusterSpec

pub mod dispatcher;
pub mod planner;
pub mod report;

pub use dispatcher::{DispatchPolicy, Dispatcher, ReplicaView};
pub use planner::{frontier, FleetCell, FleetFrontier, RatePoint};
pub use report::{FleetReport, ReplicaStat};

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Histogram;
use crate::coordinator::request::GenResponse;
use crate::coordinator::trace::Trace;
use crate::{Error, Result};
use report::{fold, FNV_BASIS};

/// N independent replica engines behind one dispatcher.
///
/// Replicas share nothing: each engine owns its queue, batcher, plan
/// cache and session cache, exactly as N separate `Pipeline`s would —
/// that is what makes Data Parallel capacity scale linearly. The fleet
/// only adds the routing decision and the aggregate report.
pub struct Fleet<'a> {
    engines: Vec<Engine<'a>>,
    dispatcher: Dispatcher,
}

impl<'a> Fleet<'a> {
    /// A fleet over `engines` (one per replica) dispatching under
    /// `policy`. Fails on an empty replica list.
    pub fn new(engines: Vec<Engine<'a>>, policy: DispatchPolicy) -> Result<Fleet<'a>> {
        if engines.is_empty() {
            return Err(Error::config("a fleet needs at least one replica engine"));
        }
        Ok(Fleet { engines, dispatcher: Dispatcher::new(policy) })
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// The replica engines, indexed like the dispatcher's views.
    pub fn engines(&self) -> &[Engine<'a>] {
        &self.engines
    }

    /// The dispatch policy this fleet routes under.
    pub fn policy(&self) -> DispatchPolicy {
        self.dispatcher.policy()
    }

    /// Replay a trace through the fleet in virtual time and aggregate a
    /// [`FleetReport`]. Latents are dropped as they complete so a
    /// 100k-request trace does not hold 100k tensors; use
    /// [`Fleet::replay_collect`] when the responses themselves matter.
    ///
    /// Replay on a *fresh* fleet is deterministic (equal digests across
    /// runs); reusing a fleet continues its clocks and cumulative
    /// metrics.
    pub fn replay(&mut self, trace: &Trace) -> Result<FleetReport> {
        Ok(self.replay_impl(trace, false)?.0)
    }

    /// [`Fleet::replay`], but also return every response (completion
    /// order). Memory scales with the trace — prefer `replay` for large
    /// traces.
    pub fn replay_collect(&mut self, trace: &Trace) -> Result<(FleetReport, Vec<GenResponse>)> {
        self.replay_impl(trace, true)
    }

    fn replay_impl(
        &mut self,
        trace: &Trace,
        keep: bool,
    ) -> Result<(FleetReport, Vec<GenResponse>)> {
        let reqs = trace.requests();
        let n = self.engines.len();
        let mut routed = vec![0usize; n];
        let mut rejected = Vec::new();
        let mut latency = Histogram::new();
        let mut digest = FNV_BASIS;
        let mut served: u64 = 0;
        let mut kept = Vec::new();
        let mut record = |replica: usize, resp: GenResponse| {
            fold(&mut digest, replica as u64);
            fold(&mut digest, resp.id);
            fold(&mut digest, resp.latency.to_bits());
            fold(&mut digest, resp.model_seconds.to_bits());
            fold(&mut digest, resp.comm_bytes as u64);
            latency.observe(resp.latency);
            served += 1;
            if keep {
                kept.push(resp);
            }
        };

        for req in reqs {
            let t = req.arrival;
            // run every replica forward to the arrival instant: busy
            // replicas tick (possibly overshooting t, exactly like
            // serve_trace), idle replicas jump their clock
            for (i, engine) in self.engines.iter_mut().enumerate() {
                while engine.pending() > 0 && engine.virtual_now() < t {
                    for resp in engine.tick()? {
                        record(i, resp);
                    }
                }
                engine.advance_to(t);
            }
            let views: Vec<ReplicaView> = self
                .engines
                .iter()
                .map(|e| ReplicaView { pending: e.pending(), busy_until: e.virtual_now() })
                .collect();
            let k = self.dispatcher.pick(&views);
            routed[k] += 1;
            if let Err(rej) = self.engines[k].submit(req.clone()) {
                rejected.push(rej);
            }
        }
        // drain: every replica runs to empty
        for (i, engine) in self.engines.iter_mut().enumerate() {
            while engine.pending() > 0 {
                for resp in engine.tick()? {
                    record(i, resp);
                }
            }
        }
        drop(record);
        for rej in &rejected {
            fold(&mut digest, rej.id);
        }

        let replicas: Vec<ReplicaStat> = self
            .engines
            .iter()
            .zip(&routed)
            .map(|(e, &routed)| ReplicaStat {
                routed,
                horizon: e.horizon(),
                metrics: e.metrics.clone(),
            })
            .collect();
        let makespan = replicas.iter().fold(0.0f64, |m, r| m.max(r.horizon));
        let report = FleetReport {
            policy: self.dispatcher.policy().label(),
            submitted: reqs.len(),
            served,
            rejected,
            makespan,
            latency,
            replicas,
            digest,
        };
        Ok((report, kept))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::runtime::Runtime;

    fn engines(rt: &Runtime, n: usize) -> Vec<Engine<'_>> {
        (0..n).map(|_| Engine::new(rt, l40_cluster(1), 4)).collect()
    }

    fn trace(n: usize) -> Trace {
        Trace::poisson(0xF1EE7, n, 2.0).steps(1).guidance(1.0).build()
    }

    #[test]
    fn empty_fleet_is_refused() {
        assert!(Fleet::new(vec![], DispatchPolicy::RoundRobin).is_err());
    }

    #[test]
    fn replay_serves_the_whole_trace_and_balances() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::RoundRobin).unwrap();
        let r = fleet.replay(&trace(16)).unwrap();
        assert_eq!(r.submitted, 16);
        assert_eq!(r.served + r.rejected.len() as u64, 16);
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas[0].routed, 8, "round-robin splits evenly");
        assert_eq!(r.replicas[1].routed, 8);
        assert!((r.imbalance() - 1.0).abs() < 1e-12);
        assert!(r.makespan > 0.0);
        assert_eq!(r.latency.count, r.served);
    }

    #[test]
    fn fresh_fleets_replay_digest_equal() {
        let rt = Runtime::simulated();
        let t = trace(24);
        let run = |policy| {
            let mut fleet = Fleet::new(engines(&rt, 3), policy).unwrap();
            fleet.replay(&t).unwrap().digest
        };
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::PowerOfTwo { seed: 42 },
        ] {
            assert_eq!(run(policy), run(policy), "replay must be deterministic ({policy:?})");
        }
    }

    #[test]
    fn replay_collect_returns_every_response() {
        let rt = Runtime::simulated();
        let mut fleet = Fleet::new(engines(&rt, 2), DispatchPolicy::JoinShortestQueue).unwrap();
        let (report, responses) = fleet.replay_collect(&trace(12)).unwrap();
        assert_eq!(responses.len() as u64, report.served);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), responses.len(), "each request answered once");
    }
}
