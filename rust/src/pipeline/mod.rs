//! # The `Pipeline` facade — the one typed entry point of the engine.
//!
//! Everything user-facing goes through here: one-shot generation, batch
//! serving, and the cost-model routing decision (`plan`). The facade owns
//! the session/VAE lifecycle (sessions are shared per batch, the parallel
//! VAE is built once), derives the routed sequence length from each
//! request's resolution, and resolves the scheduler per request — no
//! `256`, no `"ddim"`, no `tiny-` string anywhere in user code.
//!
//! The example below is hermetic — `Runtime::simulated()` executes on
//! the simulated backend, so it runs (and is tested by `cargo test
//! --doc`) without any AOT artifacts:
//!
//! ```
//! use xdit::config::hardware::l40_cluster;
//! use xdit::coordinator::GenRequest;
//! use xdit::pipeline::{ParallelPolicy, Pipeline};
//! use xdit::runtime::Runtime;
//!
//! let rt = Runtime::simulated();
//! let mut pipe = Pipeline::builder()
//!     .runtime(&rt)
//!     .cluster(l40_cluster(1))
//!     .world(8)
//!     .parallel(ParallelPolicy::Auto)
//!     .build()?;
//! let resp = pipe.generate(&GenRequest::new(0, "a red fox in snow").with_steps(2))?;
//! assert!(resp.model_seconds > 0.0);
//!
//! // batch serving through the compatibility batcher
//! let report = pipe.serve((1..4).map(|i| GenRequest::new(i, "city skyline").with_steps(2)))?;
//! assert_eq!(report.responses.len(), 3);
//!
//! // continuous batching: replay a Poisson arrival trace with admission
//! // control, priorities/deadlines and per-tick batch re-formation
//! let trace = xdit::Trace::poisson(0, 8, 2.0).steps(2).build();
//! let report = pipe.serve_trace(&trace)?;
//! println!("{}", report.summary()); // p50/p95/p99, queue delay vs exec, occupancy
//! # Ok::<(), xdit::Error>(())
//! ```
//!
//! Staged execution overlaps the VAE decode of request N with the
//! denoise of request N+1 (per-stage virtual clocks, bounded inter-stage
//! queue) and shards each decode patch-wise — same outputs, never-worse
//! makespan:
//!
//! ```
//! use xdit::pipeline::Pipeline;
//! use xdit::runtime::Runtime;
//!
//! let rt = Runtime::simulated();
//! let mut staged = Pipeline::builder()
//!     .runtime(&rt)
//!     .stage_overlap(true)       // decode of N overlaps denoise of N+1
//!     .vae_parallelism(4)        // patch-parallel VAE over 4 devices
//!     .stage_queue_capacity(2)   // bounded denoise→decode queue
//!     .build()?;
//! let trace = xdit::Trace::poisson(7, 6, 2.0).steps(1).decode_every(1).build();
//! let report = staged.serve_trace(&trace)?;
//! let (_encode, denoise, decode) = report.stage_occupancy();
//! assert!(denoise > 0.0 && decode > 0.0);
//! println!("{}", report.metrics.stages.report(report.makespan));
//! # Ok::<(), xdit::Error>(())
//! ```
//!
//! `Engine`, `Session` and `driver` remain the internal layers the facade
//! composes; see `DESIGN.md` for the module inventory.

use crate::config::hardware::{l40_cluster, ClusterSpec, CollectiveAlgo};
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::coordinator::engine::{
    CancelOutcome, Engine, Rejection, DEFAULT_QUEUE_CAPACITY, DEFAULT_SESSION_CACHE_CAPACITY,
    DEFAULT_STAGE_QUEUE_CAPACITY,
};
use crate::coordinator::planner::{Fidelity, Plan, Planner, RoutePolicy};
use crate::coordinator::request::{GenRequest, GenResponse, RequestId, SloClass};
use crate::coordinator::trace::Trace;
use crate::coordinator::{Batcher, Metrics};
use crate::diffusion::SchedulerKind;
use crate::fleet::{DispatchPolicy, Fleet, FleetReport};
use crate::parallel::driver::Method;
use crate::perf::simulator::Timeline;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// How the pipeline picks the hybrid parallel configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelPolicy {
    /// The auto-planner decides per batch, aware of the request's
    /// resolution, the cluster interconnect and the memory budget (the
    /// scoring policy is `builder.route_policy(..)`, cost-model by
    /// default).
    Auto,
    /// Pin an explicit configuration (validated against the model).
    Explicit(ParallelConfig),
}

/// Result of one `Pipeline::serve` / `Pipeline::serve_trace` call.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests offered to this call (admitted + rejected).
    pub submitted: usize,
    /// Responses in completion order.
    pub responses: Vec<GenResponse>,
    /// Requests refused admission (backpressure), with reasons. Always
    /// empty for `serve`, which bypasses the admission bound.
    pub rejected: Vec<Rejection>,
    /// Virtual makespan: end of the serving horizon when the call
    /// returned, across *all* stages (with `stage_overlap` the decode
    /// tail may drain past the last denoise — that tail is included).
    /// Reported separately from per-request latency — one is "how long
    /// the run took", the other "how long a request waited".
    pub makespan: f64,
    /// Snapshot of the engine metrics after the call. **Cumulative over
    /// the pipeline's lifetime**, not per-call: a reused pipeline keeps
    /// accumulating (that is how `vae_builds == 1` across windows is
    /// provable). Per-call counts live in `submitted` / `responses` /
    /// `rejected`.
    pub metrics: Metrics,
}

impl ServeReport {
    /// Approximate end-to-end latency quantile (0.5/0.95/0.99, ...).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.metrics.latency.quantile(q)
    }

    /// Approximate latency quantile over one SLO class only (interactive
    /// p99 and batch p99 are different promises — see `SloClass`).
    pub fn latency_quantile_class(&self, class: SloClass, q: f64) -> f64 {
        self.metrics.latency_quantile_class(class, q)
    }

    /// Requests cancelled over the pipeline's lifetime (queued +
    /// mid-flight) — cancelled requests are never in `responses`.
    pub fn cancelled(&self) -> u64 {
        self.metrics.cancelled()
    }

    /// Mean requests per launched batch (continuous-batching occupancy).
    pub fn mean_occupancy(&self) -> f64 {
        self.metrics.mean_occupancy()
    }

    /// Busy fraction of the serving horizon per stage:
    /// `(encode, denoise, decode)`.
    pub fn stage_occupancy(&self) -> (f64, f64, f64) {
        self.metrics.stages.occupancy(self.metrics.horizon)
    }

    /// One-line summary: per-call counts first, then the engine-lifetime
    /// stats — virtual makespan and the queue-delay vs execution-time
    /// breakdown as separate figures, with p50/p95/p99 latency and
    /// batch-occupancy stats alongside — and a second line with the
    /// per-stage occupancy block (encode/denoise/decode busy fractions,
    /// decode queue depth, backpressure stalls).
    pub fn summary(&self) -> String {
        format!(
            "submitted={} served={} rejected={} | engine: {}\n{}{}",
            self.submitted,
            self.responses.len(),
            self.rejected.len(),
            self.metrics.report(),
            // per-SLO-class latency/deadline rows (empty when the whole
            // workload is standard-tier — the pre-SLO summary unchanged)
            self.metrics.slo_report(),
            self.metrics.stages.report(self.metrics.horizon)
        )
    }
}

/// Typed builder for [`Pipeline`]. `runtime` is required for `build()`;
/// `plan()` works without it (routing is analytic).
pub struct PipelineBuilder<'a> {
    rt: Option<&'a Runtime>,
    cluster: Option<ClusterSpec>,
    world: Option<usize>,
    parallel: ParallelPolicy,
    route_policy: RoutePolicy,
    route_fidelity: Fidelity,
    memory_cap_gb: Option<f64>,
    deadline_admission: bool,
    scheduler: Option<SchedulerKind>,
    method: Option<Method>,
    collective_algo: Option<CollectiveAlgo>,
    max_batch: usize,
    queue_capacity: usize,
    aging_rate: f64,
    plan_cache: bool,
    session_cache_capacity: usize,
    replicas: usize,
    dispatch: DispatchPolicy,
    hedge: bool,
    stage_overlap: bool,
    vae_parallelism: Option<usize>,
    stage_queue_capacity: usize,
    preemption: bool,
    degrade: bool,
    slo_budgets: [Option<usize>; SloClass::COUNT],
}

impl<'a> Default for PipelineBuilder<'a> {
    fn default() -> Self {
        PipelineBuilder {
            rt: None,
            cluster: None,
            world: None,
            parallel: ParallelPolicy::Auto,
            route_policy: RoutePolicy::default(),
            route_fidelity: Fidelity::default(),
            memory_cap_gb: None,
            deadline_admission: false,
            scheduler: None,
            method: None,
            collective_algo: None,
            max_batch: 4,
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            aging_rate: 1.0,
            plan_cache: true,
            session_cache_capacity: DEFAULT_SESSION_CACHE_CAPACITY,
            replicas: 1,
            dispatch: DispatchPolicy::JoinShortestQueue,
            hedge: true,
            stage_overlap: false,
            vae_parallelism: None,
            stage_queue_capacity: DEFAULT_STAGE_QUEUE_CAPACITY,
            preemption: true,
            degrade: false,
            slo_budgets: [None; SloClass::COUNT],
        }
    }
}

impl<'a> PipelineBuilder<'a> {
    /// A builder with the serving defaults (see the field docs).
    pub fn new() -> Self {
        Self::default()
    }

    /// The AOT runtime executing the tiny family (required for `build`).
    pub fn runtime(mut self, rt: &'a Runtime) -> Self {
        self.rt = Some(rt);
        self
    }

    /// Simulated cluster (default: one 8×L40 PCIe node).
    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Devices to serve on (default: the whole cluster).
    pub fn world(mut self, world: usize) -> Self {
        self.world = Some(world);
        self
    }

    /// Auto-plan per batch (default) or pin an explicit config.
    pub fn parallel(mut self, policy: ParallelPolicy) -> Self {
        self.parallel = policy;
        self
    }

    /// Scoring policy behind `ParallelPolicy::Auto`: the cost-model
    /// planner (default) or the §5.2.4 paper heuristic.
    pub fn route_policy(mut self, policy: RoutePolicy) -> Self {
        self.route_policy = policy;
        self
    }

    /// Scoring fidelity behind `ParallelPolicy::Auto`: closed forms only
    /// (default) or `Fidelity::Simulated`, which re-scores the top
    /// candidates with the discrete-event overlap simulator and makes
    /// `Plan::simulated_seconds` / the critical-path "why" available.
    pub fn fidelity(mut self, fidelity: Fidelity) -> Self {
        self.route_fidelity = fidelity;
        self
    }

    /// Per-GPU HBM budget the planner prunes candidates against
    /// (default: the cluster's GPU capacity).
    pub fn memory_cap_gb(mut self, gb: f64) -> Self {
        self.memory_cap_gb = Some(gb);
        self
    }

    /// Reject deadlined requests at `submit` time when even their
    /// cheapest feasible plan predicts a miss (default off: hopeless
    /// requests are served and the miss is only counted).
    pub fn deadline_admission(mut self, enabled: bool) -> Self {
        self.deadline_admission = enabled;
        self
    }

    /// Pipeline-level scheduler default (per-request overrides win).
    pub fn scheduler(mut self, scheduler: SchedulerKind) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Force a strategy instead of the one the config implies.
    pub fn method(mut self, method: Method) -> Self {
        self.method = Some(method);
        self
    }

    /// Pin the collective algorithm every plan is priced with — flat ring
    /// or two-level hierarchical — instead of the default auto-selection
    /// (flat everywhere; hierarchical only where a candidate's collectives
    /// span nodes *and* it strictly lowers the predicted cost). The CLI's
    /// `--collective-algo flat|hier` maps here; `auto` leaves it unset.
    pub fn collective_algo(mut self, algo: CollectiveAlgo) -> Self {
        self.collective_algo = Some(algo);
        self
    }

    /// Max requests per compatibility batch (default 4).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Bound on the admission queue: `submit`/`serve_trace` reject with
    /// backpressure beyond this backlog (default 64).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.queue_capacity = n.max(1);
        self
    }

    /// Effective-priority units a waiting request gains per virtual second
    /// (default 1.0; 0 = strict priorities, starvation possible).
    pub fn aging_rate(mut self, rate: f64) -> Self {
        self.aging_rate = rate.max(0.0);
        self
    }

    /// Enable/disable routing-plan memoization (default on). Off, every
    /// batch re-runs the cold enumerate + score sweep — results are
    /// bit-identical either way (the cache is a pure memo; see
    /// `Metrics::plan_cache_hits`), so this is a debugging escape hatch
    /// (`serve --no-plan-cache` on the CLI).
    pub fn plan_cache(mut self, enabled: bool) -> Self {
        self.plan_cache = enabled;
        self
    }

    /// Bound the engine's warm-session cache (default
    /// [`DEFAULT_SESSION_CACHE_CAPACITY`]; 0 disables reuse so every
    /// batch builds its session cold — see `Metrics::sessions_reused`).
    ///
    /// [`DEFAULT_SESSION_CACHE_CAPACITY`]:
    /// crate::coordinator::engine::DEFAULT_SESSION_CACHE_CAPACITY
    pub fn session_cache_capacity(mut self, capacity: usize) -> Self {
        self.session_cache_capacity = capacity;
        self
    }

    /// Data Parallel replica count for [`Pipeline::serve_fleet`]
    /// (default 1). The cluster is carved into `n` equal slices — whole
    /// nodes when `n` ≤ the node count — and both the cluster size and
    /// the pipeline's `world` must divide evenly by `n`.
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// Fleet dispatch policy for [`Pipeline::serve_fleet`] (default
    /// join-shortest-queue).
    pub fn dispatcher(mut self, policy: DispatchPolicy) -> Self {
        self.dispatch = policy;
        self
    }

    /// Hedge interactive-tier fleet requests (default on): fresh
    /// interactive arrivals are duplicated onto the second-best routable
    /// replica, the first completion wins and the loser is cancelled.
    /// Turn off to measure the hedging overhead (`fleet --no-hedge`).
    pub fn hedging(mut self, enabled: bool) -> Self {
        self.hedge = enabled;
        self
    }

    /// Staged execution (default off): run text-encode → denoise →
    /// VAE-decode on per-stage virtual clocks so the decode of request N
    /// overlaps the denoise of request N+1. Outputs (latents, images,
    /// fleet digests at `stage_overlap(false)`) are bit-identical to the
    /// serial path; the virtual makespan is never worse and strictly
    /// better whenever a decode actually overlaps — see the per-stage
    /// occupancy block in [`ServeReport::summary`].
    pub fn stage_overlap(mut self, enabled: bool) -> Self {
        self.stage_overlap = enabled;
        self
    }

    /// Devices the parallel VAE shards each decode across patch-wise
    /// (default: `min(plan world, 8)`). The latent row count must divide
    /// by it into strips of 2/4/8 rows — on the tiny family (16 latent
    /// rows) the valid values are 1, 2, 4 and 8.
    pub fn vae_parallelism(mut self, n: usize) -> Self {
        self.vae_parallelism = Some(n.max(1));
        self
    }

    /// Bound on the denoise→decode inter-stage queue in staged mode
    /// (default [`DEFAULT_STAGE_QUEUE_CAPACITY`]): when this many decodes
    /// are queued, the next decode-bound denoise launch stalls — bounded
    /// backpressure instead of unbounded queue growth.
    ///
    /// [`DEFAULT_STAGE_QUEUE_CAPACITY`]:
    /// crate::coordinator::engine::DEFAULT_STAGE_QUEUE_CAPACITY
    pub fn stage_queue_capacity(mut self, capacity: usize) -> Self {
        self.stage_queue_capacity = capacity.max(1);
        self
    }

    /// Batch-tier preemption during trace replay (default on): when the
    /// next interactive arrival would miss its deadline behind an
    /// all-batch-tier batch, the batch yields with its progress credited.
    /// Off = the preemption-free control replay (outputs bit-identical,
    /// only latencies move).
    pub fn preemption(mut self, enabled: bool) -> Self {
        self.preemption = enabled;
        self
    }

    /// Degrade-under-overload ladder (default off): batch-tier requests
    /// shed diffusion steps (backlog ≥ half the queue capacity) and then
    /// resolution (≥ three quarters) at admission instead of being
    /// rejected. Quality cost quantified by `benches/fig19_quality`.
    pub fn degrade(mut self, enabled: bool) -> Self {
        self.degrade = enabled;
        self
    }

    /// Cap the pending (admitted, unserved) requests of one SLO class —
    /// per-class admission budgets on top of the shared queue bound.
    pub fn slo_budget(mut self, class: SloClass, budget: usize) -> Self {
        self.slo_budgets[class.index()] = Some(budget);
        self
    }

    fn resolve_cluster_world(&self) -> Result<(ClusterSpec, usize)> {
        let cluster = self.cluster.clone().unwrap_or_else(|| l40_cluster(1));
        let world = self.world.unwrap_or(cluster.n_gpus);
        if world == 0 || world > cluster.n_gpus {
            return Err(Error::config(format!(
                "world size {world} invalid for cluster '{}' ({} devices)",
                cluster.name, cluster.n_gpus
            )));
        }
        if let ParallelPolicy::Explicit(pc) = self.parallel {
            // an explicit config must fit the declared device budget, or a
            // pipeline would silently simulate on more devices than it says
            if pc.world() > world {
                return Err(Error::config(format!(
                    "explicit config [{}] needs {} devices but the pipeline \
                     declared world {world}",
                    pc.describe(),
                    pc.world()
                )));
            }
        }
        Ok((cluster, world))
    }

    fn planner(&self) -> Planner {
        let mut planner = Planner::default()
            .with_policy(self.route_policy)
            .with_fidelity(self.route_fidelity);
        if let Some(gb) = self.memory_cap_gb {
            planner = planner.with_memory_cap_gb(gb);
        }
        if let Some(algo) = self.collective_algo {
            planner = planner.with_collective_algo(algo);
        }
        planner
    }

    /// Routing decision + analytic cost prediction for `(model, px)` on
    /// this builder's cluster/world: the auto-planner's best plan (or the
    /// explicit config, scored). Needs no runtime or artifacts, so it
    /// works for the paper-scale analytic models too.
    pub fn plan(&self, model: &ModelSpec, px: usize) -> Result<Plan> {
        let (cluster, world) = self.resolve_cluster_world()?;
        let planner = self.planner();
        let mut plan = match self.parallel {
            ParallelPolicy::Auto => planner.plan(model, px, &cluster, world),
            ParallelPolicy::Explicit(pc) => {
                pc.validate(model, model.seq_len(px))?;
                let mut p = planner.score(model, px, &cluster, &pc);
                p.why = "explicit ParallelPolicy pinned by the caller".into();
                p
            }
        };
        if let Some(method) = self.method {
            // the prediction must describe the forced strategy, not the
            // config's best case — baselines get their own closed forms
            // and their own Table-1 comm/memory rows
            planner.reprice_for_method(&mut plan, method, model, &cluster);
        }
        // pinned/forced plans skip the planner's re-scoring pass, so the
        // simulated figure the fidelity knob promises is attached here
        planner.attach_simulation(&mut plan, model, &cluster);
        Ok(plan)
    }

    /// Every candidate plan for `(model, px)`, ranked (feasible plans
    /// first, ascending predicted latency) — the typed form of the
    /// `route --top-k` table.
    pub fn plan_candidates(&self, model: &ModelSpec, px: usize) -> Result<Vec<Plan>> {
        let (cluster, world) = self.resolve_cluster_world()?;
        Ok(self.planner().rank(model, px, &cluster, world))
    }

    /// The per-rank event [`Timeline`] of the plan this builder would run
    /// for `(model, px)` — the typed form of the `timeline` CLI command.
    /// Like [`plan`](Self::plan) it needs no runtime or artifacts.
    ///
    /// ```
    /// use xdit::config::hardware::l40_cluster;
    /// use xdit::config::model::ModelSpec;
    /// use xdit::pipeline::Pipeline;
    ///
    /// let m = ModelSpec::by_name("pixart")?;
    /// let tl = Pipeline::builder().cluster(l40_cluster(2)).world(16).timeline(&m, 2048)?;
    /// assert_eq!(tl.ranks.len(), 16);
    /// assert!(tl.makespan >= tl.max_rank_compute());
    /// println!("{}", xdit::perf::simulator::render(&tl, 72));
    /// # Ok::<(), xdit::Error>(())
    /// ```
    pub fn timeline(&self, model: &ModelSpec, px: usize) -> Result<Timeline> {
        let (cluster, _world) = self.resolve_cluster_world()?;
        let plan = self.plan(model, px)?;
        Ok(self.planner().simulate_plan(&plan, model, &cluster))
    }

    /// Materialize the pipeline: validates cluster/world/config and
    /// configures the engine. Requires `.runtime(&rt)`.
    pub fn build(self) -> Result<Pipeline<'a>> {
        let rt = self.rt.ok_or_else(|| {
            Error::config("Pipeline::builder() needs .runtime(&rt) before .build()")
        })?;
        let (cluster, world) = self.resolve_cluster_world()?;
        if self.replicas == 0 {
            return Err(Error::config("replicas must be >= 1"));
        }
        if self.replicas > 1 {
            // fail fast: serve_fleet will carve the cluster and split the
            // world across replicas, so both must divide evenly now
            cluster.carve(self.replicas)?;
            if world % self.replicas != 0 {
                return Err(Error::config(format!(
                    "world {world} does not split across {} replicas",
                    self.replicas
                )));
            }
        }
        let mut engine = Engine::new(rt, cluster, world);
        engine.batcher = Batcher::new(self.max_batch).with_aging_rate(self.aging_rate);
        engine.set_queue_capacity(self.queue_capacity);
        if let ParallelPolicy::Explicit(pc) = self.parallel {
            engine.force_config = Some(pc);
        }
        engine.route_policy = self.route_policy;
        engine.route_fidelity = self.route_fidelity;
        engine.memory_cap_bytes = self.memory_cap_gb.map(|gb| gb * 1e9);
        engine.deadline_admission = self.deadline_admission;
        engine.force_method = self.method;
        engine.collective_algo = self.collective_algo;
        engine.default_scheduler = self.scheduler;
        engine.stage_overlap = self.stage_overlap;
        engine.vae_parallelism = self.vae_parallelism;
        engine.stage_queue_capacity = self.stage_queue_capacity;
        engine.preemption = self.preemption;
        engine.degrade = self.degrade;
        engine.slo_budgets = self.slo_budgets;
        engine.set_plan_cache_enabled(self.plan_cache);
        engine.set_session_cache_capacity(self.session_cache_capacity);
        Ok(Pipeline {
            engine,
            policy: self.parallel,
            replicas: self.replicas,
            dispatch: self.dispatch,
            hedge: self.hedge,
        })
    }
}

/// The engine facade: generate one image, serve a request window, or plan
/// a routing decision — all through one object that owns the
/// session/VAE/metrics lifecycle.
pub struct Pipeline<'a> {
    engine: Engine<'a>,
    policy: ParallelPolicy,
    replicas: usize,
    dispatch: DispatchPolicy,
    hedge: bool,
}

impl<'a> Pipeline<'a> {
    /// Start building a pipeline (the only way to construct one).
    pub fn builder() -> PipelineBuilder<'a> {
        PipelineBuilder::new()
    }

    /// Run one request to completion (routing, denoising, optional VAE
    /// decode) and return its response.
    pub fn generate(&mut self, req: &GenRequest) -> Result<GenResponse> {
        let mut one_shot = req.clone();
        // a one-shot arrives "now" on the engine's virtual timeline (unless
        // the caller stamped a later arrival), so its reported latency is
        // not inflated by work this pipeline served earlier
        one_shot.arrival = one_shot.arrival.max(self.engine.virtual_now());
        let mut out = self.engine.serve(vec![one_shot])?;
        out.pop()
            .ok_or_else(|| Error::config("engine returned no response for the request"))
    }

    /// Serve a window of requests through the compatibility batcher and
    /// return the responses plus a metrics snapshot. This path bypasses
    /// the admission bound (nothing is rejected); use [`serve_trace`]
    /// (or `submit`/`tick`) for admission-controlled serving.
    ///
    /// [`serve_trace`]: Pipeline::serve_trace
    pub fn serve(
        &mut self,
        requests: impl IntoIterator<Item = GenRequest>,
    ) -> Result<ServeReport> {
        let window: Vec<GenRequest> = requests.into_iter().collect();
        let submitted = window.len();
        let responses = self.engine.serve(window)?;
        Ok(ServeReport {
            submitted,
            responses,
            rejected: Vec::new(),
            makespan: self.engine.horizon(),
            metrics: self.engine.metrics.clone(),
        })
    }

    /// Replay a virtual-time arrival trace against the continuous-batching
    /// scheduler: requests are admitted when the virtual clock reaches
    /// their arrival stamp (a full queue rejects them with backpressure),
    /// and every tick re-forms compatibility batches from whatever is
    /// waiting. Deterministic: the same trace on a fresh pipeline yields
    /// bit-identical responses and metrics.
    ///
    /// Mid-trace [`TraceEvent`](crate::coordinator::TraceEvent)s fire
    /// when the clock reaches them: cluster mutations flip the spec
    /// fingerprint (the next batch re-plans against the new topology),
    /// cancel events route to [`Pipeline::cancel`]. Before each tick the
    /// loop hands the engine a lookahead at the next future interactive
    /// arrival, which is what arms batch-tier preemption
    /// (`builder.preemption(..)`, on by default).
    pub fn serve_trace(&mut self, trace: &Trace) -> Result<ServeReport> {
        let reqs = trace.requests();
        let events = trace.events();
        let mut responses = Vec::with_capacity(reqs.len());
        let mut rejected = Vec::new();
        let mut next = 0;
        let mut next_event = 0;
        loop {
            // interleave admissions and event firings in timestamp order;
            // an arrival wins a tie, so a cancel stamped at its target's
            // own arrival finds the request already admitted
            let now = self.engine.virtual_now();
            loop {
                let arrival_due = next < reqs.len() && reqs[next].arrival <= now;
                let event_due = next_event < events.len() && events[next_event].at <= now;
                if event_due && (!arrival_due || events[next_event].at < reqs[next].arrival) {
                    self.engine.apply_cluster_event(events[next_event].kind);
                    next_event += 1;
                } else if arrival_due {
                    if let Err(rej) = self.engine.submit(reqs[next].clone()) {
                        rejected.push(rej);
                    }
                    next += 1;
                } else {
                    break;
                }
            }
            if self.engine.pending() == 0 {
                // idle gap: jump the virtual clock to whatever comes
                // first — the next arrival or the next scheduled event
                let arrival = reqs.get(next).map(|r| r.arrival).unwrap_or(f64::INFINITY);
                let fire = events.get(next_event).map(|e| e.at).unwrap_or(f64::INFINITY);
                let horizon = arrival.min(fire);
                if horizon.is_finite() {
                    self.engine.advance_to(horizon);
                    continue;
                }
                break;
            }
            let lookahead = self.next_interactive(reqs, next);
            self.engine.set_preempt_lookahead(lookahead);
            responses.extend(self.engine.tick()?);
        }
        self.engine.set_preempt_lookahead(None);
        Ok(ServeReport {
            submitted: reqs.len(),
            responses,
            rejected,
            makespan: self.engine.horizon(),
            metrics: self.engine.metrics.clone(),
        })
    }

    /// The replay loop's preemption lookahead: the next interactive
    /// request still in the future, as (arrival, deadline, estimated
    /// exec seconds from its own routed plan). `None` when the rest of
    /// the trace carries no future interactive work — the common case,
    /// which costs nothing (no planning happens).
    fn next_interactive(
        &self,
        reqs: &[GenRequest],
        from: usize,
    ) -> Option<(f64, Option<f64>, f64)> {
        let now = self.engine.virtual_now();
        let r = reqs[from..]
            .iter()
            .find(|r| r.slo == SloClass::Interactive && r.arrival > now)?;
        let spec = ModelSpec::for_variant(r.variant).ok()?;
        let est = self.engine.plan_for(&spec, r.px, r.steps).predicted.total;
        Some((r.arrival, r.deadline, est))
    }

    /// Cancel a request wherever it currently is (admission queue or
    /// waiting set): the typed form of the CLI's `--cancel id@t` and of
    /// `Cancel` trace events. Completed requests are a no-op
    /// ([`CancelOutcome::NotFound`]) — cancellation never un-serves.
    pub fn cancel(&mut self, id: RequestId) -> CancelOutcome {
        self.engine.cancel(id)
    }

    /// Replay a virtual-time arrival trace through a Data Parallel fleet:
    /// `builder.replicas(n)` fresh replica engines, each on an equal
    /// carve of the cluster with this pipeline's serving knobs (batcher,
    /// queue bound, caches, routing policy), behind the
    /// `builder.dispatcher(..)` policy. Replicas are rebuilt per call
    /// with zeroed clocks, so repeated replays of the same trace are
    /// digest-equal — and a single-replica fleet reproduces
    /// [`serve_trace`](Pipeline::serve_trace) bit-identically.
    ///
    /// This pipeline's own engine is untouched (its metrics do not
    /// accumulate fleet work); the per-replica snapshots live in the
    /// returned [`FleetReport`].
    pub fn serve_fleet(&self, trace: &Trace) -> Result<FleetReport> {
        let mut fleet = Fleet::new(self.replica_engines()?, self.dispatch)?;
        fleet.set_hedging(self.hedge);
        fleet.replay(trace)
    }

    /// Build the fleet's replica engines: carve the cluster, split the
    /// world, copy every serving knob off this pipeline's engine.
    fn replica_engines(&self) -> Result<Vec<Engine<'a>>> {
        let r = self.replicas;
        let carved = self.engine.cluster.carve(r)?;
        if self.engine.world % r != 0 {
            return Err(Error::config(format!(
                "world {} does not split across {r} replicas",
                self.engine.world
            )));
        }
        let world = self.engine.world / r;
        if let Some(pc) = self.engine.force_config {
            if pc.world() > world {
                return Err(Error::config(format!(
                    "explicit config [{}] needs {} devices but each of the {r} replicas \
                     serves on {world}",
                    pc.describe(),
                    pc.world()
                )));
            }
        }
        Ok((0..r)
            .map(|_| {
                let mut e = Engine::new(self.engine.rt, carved.clone(), world);
                e.batcher = Batcher::new(self.engine.batcher.max_batch)
                    .with_aging_rate(self.engine.batcher.aging_rate);
                e.set_queue_capacity(self.engine.queue_capacity());
                e.set_plan_cache_enabled(self.engine.plan_cache_enabled());
                e.set_session_cache_capacity(self.engine.session_cache_capacity());
                e.force_config = self.engine.force_config;
                e.route_policy = self.engine.route_policy;
                e.route_fidelity = self.engine.route_fidelity;
                e.memory_cap_bytes = self.engine.memory_cap_bytes;
                e.deadline_admission = self.engine.deadline_admission;
                e.force_method = self.engine.force_method;
                e.default_scheduler = self.engine.default_scheduler;
                e.stage_overlap = self.engine.stage_overlap;
                e.vae_parallelism = self.engine.vae_parallelism;
                e.stage_queue_capacity = self.engine.stage_queue_capacity;
                e.preemption = self.engine.preemption;
                e.degrade = self.engine.degrade;
                e.slo_budgets = self.engine.slo_budgets;
                e
            })
            .collect())
    }

    /// Admit one request into the bounded queue (continuous serving). Pair
    /// with [`Pipeline::tick`]; arrival stamps are the caller's virtual
    /// clock.
    pub fn submit(&mut self, req: GenRequest) -> std::result::Result<(), Rejection> {
        self.engine.submit(req)
    }

    /// One scheduler tick: launch the most urgent compatibility batch from
    /// the waiting set and return its responses (empty = idle).
    pub fn tick(&mut self) -> Result<Vec<GenResponse>> {
        self.engine.tick()
    }

    /// Requests admitted but not yet completed.
    pub fn pending(&self) -> usize {
        self.engine.pending()
    }

    /// Current end of the virtual serving horizon.
    pub fn virtual_now(&self) -> f64 {
        self.engine.virtual_now()
    }

    /// The routing decision this pipeline would make for `(model, px)`.
    pub fn plan(&self, model: &ModelSpec, px: usize) -> Result<Plan> {
        self.as_builder().plan(model, px)
    }

    /// The per-rank event [`Timeline`] of the plan this pipeline would
    /// run for `(model, px)` (see `perf::simulator`).
    pub fn timeline(&self, model: &ModelSpec, px: usize) -> Result<Timeline> {
        self.as_builder().timeline(model, px)
    }

    /// A builder mirroring this pipeline's routing knobs (what `plan` and
    /// `timeline` consult, without touching the engine's live state).
    fn as_builder(&self) -> PipelineBuilder<'_> {
        let mut b = PipelineBuilder::new()
            .cluster(self.engine.cluster.clone())
            .world(self.engine.world)
            .parallel(self.policy)
            .route_policy(self.engine.route_policy)
            .fidelity(self.engine.route_fidelity);
        if let Some(cap) = self.engine.memory_cap_bytes {
            b = b.memory_cap_gb(cap / 1e9);
        }
        if let Some(m) = self.engine.force_method {
            b = b.method(m);
        }
        b
    }

    /// Decode a final latent over `n` simulated devices with the
    /// pipeline-owned parallel VAE. Returns (image, simulated seconds).
    pub fn decode_latent(&mut self, latent: &Tensor, n: usize) -> Result<(Tensor, f64)> {
        self.engine.decode_latent(latent, n)
    }

    /// Exact single-device decode (reference for the parallel path).
    pub fn decode_reference(&mut self, latent: &Tensor) -> Result<Tensor> {
        self.engine.decode_reference(latent)
    }

    /// Cumulative engine-lifetime serving metrics.
    pub fn metrics(&self) -> &Metrics {
        &self.engine.metrics
    }

    /// The simulated cluster this pipeline serves on.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.engine.cluster
    }

    /// Devices this pipeline serves on.
    pub fn world(&self) -> usize {
        self.engine.world
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};

    #[test]
    fn build_requires_runtime() {
        let err = Pipeline::builder().cluster(l40_cluster(1)).build().err().unwrap();
        assert!(err.to_string().contains("runtime"), "{err}");
    }

    #[test]
    fn world_validated_against_cluster() {
        // plan() shares the same resolution logic as build()
        let m = ModelSpec::by_name("pixart").unwrap();
        assert!(Pipeline::builder().cluster(a100_node()).world(16).plan(&m, 1024).is_err());
        assert!(Pipeline::builder().cluster(a100_node()).world(0).plan(&m, 1024).is_err());
    }

    #[test]
    fn plan_is_resolution_aware_and_valid() {
        let m = ModelSpec::by_name("pixart").unwrap();
        for px in [1024usize, 2048, 4096] {
            let plan = Pipeline::builder()
                .cluster(l40_cluster(2))
                .world(16)
                .plan(&m, px)
                .unwrap();
            assert_eq!(plan.s_img, m.seq_len(px));
            assert_eq!(plan.config.world(), 16, "{}", plan.describe());
            plan.config.validate(&m, plan.s_img).unwrap();
            assert!(plan.predicted.total > 0.0);
            assert!(plan.serial_seconds > 0.0);
        }
    }

    #[test]
    fn explicit_policy_is_validated() {
        // tiny family has 6 heads: ulysses=4 must be rejected at plan time
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let bad = ParallelPolicy::Explicit(ParallelConfig::new(1, 1, 4, 1));
        assert!(Pipeline::builder()
            .cluster(a100_node())
            .world(4)
            .parallel(bad)
            .plan(&m, 256)
            .is_err());
        let good = ParallelPolicy::Explicit(ParallelConfig::new(1, 1, 2, 1));
        let plan = Pipeline::builder()
            .cluster(a100_node())
            .world(4)
            .parallel(good)
            .plan(&m, 256)
            .unwrap();
        assert_eq!(plan.config.ulysses, 2);
        assert_eq!(plan.method, Method::Sp);
    }

    #[test]
    fn explicit_config_cannot_exceed_declared_world() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        // 8-way config against a declared world of 2: rejected up front
        let oversized = ParallelPolicy::Explicit(ParallelConfig::new(2, 2, 2, 1));
        let err = Pipeline::builder()
            .cluster(l40_cluster(1))
            .world(2)
            .parallel(oversized)
            .plan(&m, 256)
            .err()
            .unwrap();
        assert!(err.to_string().contains("declared world"), "{err}");
        // exactly-fitting config passes
        assert!(Pipeline::builder()
            .cluster(l40_cluster(1))
            .world(8)
            .parallel(oversized)
            .plan(&m, 256)
            .is_ok());
    }

    #[test]
    fn route_policy_flows_through_plan() {
        use crate::coordinator::paper_heuristic;
        let m = ModelSpec::by_name("pixart").unwrap();
        let cluster = l40_cluster(2);
        let paper = Pipeline::builder()
            .cluster(cluster.clone())
            .world(16)
            .route_policy(RoutePolicy::PaperHeuristic)
            .plan(&m, 2048)
            .unwrap();
        assert_eq!(paper.config, paper_heuristic(&m, 2048, &cluster, 16));
        let cost = Pipeline::builder().cluster(cluster).world(16).plan(&m, 2048).unwrap();
        assert!(cost.predicted.total <= paper.predicted.total + 1e-12);
        assert!(cost.candidates > 1, "{}", cost.why);
    }

    #[test]
    fn plan_candidates_rank_and_include_the_winner() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let b = Pipeline::builder().cluster(l40_cluster(1)).world(8);
        let ranked = b.plan_candidates(&m, 2048).unwrap();
        let best = b.plan(&m, 2048).unwrap();
        assert!(!ranked.is_empty());
        assert_eq!(ranked[0].config, best.config);
        assert!(ranked[0].comm_bytes >= 0.0 && ranked[0].peak_memory_bytes > 0.0);
    }

    #[test]
    fn deadline_admission_flows_through_the_facade() {
        let rt = Runtime::simulated();
        let mut pipe = Pipeline::builder()
            .runtime(&rt)
            .cluster(l40_cluster(1))
            .world(4)
            .deadline_admission(true)
            .build()
            .unwrap();
        let hopeless = GenRequest::new(0, "x").with_steps(1).with_deadline(1e-15);
        let rej = pipe.submit(hopeless).unwrap_err();
        assert!(rej.reason.contains("deadline infeasible"), "{}", rej.reason);
        assert!(pipe.submit(GenRequest::new(1, "y").with_steps(1)).is_ok());
    }

    #[test]
    fn timeline_and_fidelity_flow_through_the_facade() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let b = Pipeline::builder().cluster(l40_cluster(1)).world(8).fidelity(Fidelity::Simulated);
        let plan = b.plan(&m, 2048).unwrap();
        assert!(plan.simulated_seconds.is_some(), "{}", plan.why);
        let tl = b.timeline(&m, 2048).unwrap();
        assert_eq!(tl.ranks.len(), 8);
        assert!(tl.makespan > 0.0);
        // built pipelines expose the same accessor
        let rt = Runtime::simulated();
        let pipe =
            Pipeline::builder().runtime(&rt).cluster(l40_cluster(1)).world(4).build().unwrap();
        let tiny = ModelSpec::by_name("tiny-adaln").unwrap();
        let tl = pipe.timeline(&tiny, 256).unwrap();
        assert_eq!(tl.ranks.len(), 4);
        assert!(tl.makespan >= tl.max_rank_compute());
        // pinned configs skip the re-scoring pass but still honour the
        // fidelity knob
        let explicit = Pipeline::builder()
            .cluster(l40_cluster(1))
            .world(8)
            .parallel(ParallelPolicy::Explicit(ParallelConfig::new(2, 2, 2, 1)))
            .fidelity(Fidelity::Simulated)
            .plan(&m, 2048)
            .unwrap();
        assert!(explicit.simulated_seconds.is_some(), "{}", explicit.why);
    }

    #[test]
    fn serve_fleet_replays_deterministically_and_validates_the_carve() {
        let rt = Runtime::simulated();
        let pipe = Pipeline::builder()
            .runtime(&rt)
            .cluster(l40_cluster(1))
            .world(8)
            .replicas(2)
            .dispatcher(DispatchPolicy::RoundRobin)
            .max_batch(2)
            .queue_capacity(16)
            .build()
            .unwrap();
        let trace = Trace::poisson(0xAB, 12, 2.0).steps(1).guidance(1.0).build();
        let a = pipe.serve_fleet(&trace).unwrap();
        let b = pipe.serve_fleet(&trace).unwrap();
        assert_eq!(a.digest, b.digest, "fresh replicas per call: digest-equal replays");
        assert_eq!(a.replicas.len(), 2);
        assert_eq!(a.submitted, 12);
        assert_eq!(a.served + a.rejected.len() as u64, 12);
        // each replica serves on world/replicas devices of a half-cluster
        assert!(a.replicas.iter().all(|r| r.metrics.served > 0));
        // replica validation is fail-fast at build time
        let misaligned =
            Pipeline::builder().runtime(&rt).cluster(l40_cluster(1)).world(4).replicas(3).build();
        assert!(misaligned.is_err(), "8 GPUs cannot carve into 3 replicas");
        let odd_world =
            Pipeline::builder().runtime(&rt).cluster(l40_cluster(1)).world(5).replicas(2).build();
        assert!(odd_world.is_err(), "world 5 cannot split across 2 replicas");
        let zero = Pipeline::builder().runtime(&rt).cluster(l40_cluster(1)).replicas(0).build();
        assert!(zero.is_err());
    }

    #[test]
    fn plan_respects_method_override() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let plan = Pipeline::builder()
            .cluster(a100_node())
            .world(2)
            .method(Method::Serial)
            .plan(&m, 256)
            .unwrap();
        assert_eq!(plan.method, Method::Serial);
        // the prediction must describe the forced method, not the routed
        // config's best case: forcing Serial predicts the serial baseline
        assert!((plan.predicted.total - plan.serial_seconds).abs() < 1e-12);
        assert_eq!(plan.predicted.comm_exposed, 0.0);
        assert!((plan.speedup() - 1.0).abs() < 1e-9);
    }
}
