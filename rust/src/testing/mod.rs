//! Minimal property-testing harness (proptest is not in the offline crate
//! set). Runs N randomized cases from a deterministic seed; on failure it
//! reports the failing case index and seed so the case can be replayed
//! exactly.
//!
//! Used for the coordinator invariants (routing, batching, queue
//! conservation), mesh bijectivity, tensor split/scatter round-trips and
//! comm-cost monotonicity.

use crate::util::rng::Rng;

/// Outcome of a property check over one generated case.
pub type CaseResult = std::result::Result<(), String>;

/// Run `cases` random cases of property `prop`. Panics with a replayable
/// seed on the first failure.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cases: usize, mut prop: F) {
    check_seeded(name, 0xDEC0DE, cases, &mut prop);
}

pub fn check_seeded<F: FnMut(&mut Rng) -> CaseResult>(
    name: &str,
    seed: u64,
    cases: usize,
    prop: &mut F,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// A divisor of n, uniformly among divisors.
    pub fn divisor_of(rng: &mut Rng, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *rng.pick(&divs)
    }

    /// Power of two <= max.
    pub fn pow2_upto(rng: &mut Rng, max: usize) -> usize {
        let mut opts = vec![1usize];
        while opts.last().unwrap() * 2 <= max {
            let next = opts.last().unwrap() * 2;
            opts.push(next);
        }
        *rng.pick(&opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports() {
        check("fails", 10, |rng| {
            let x = rng.below(4);
            if x != 3 {
                Ok(())
            } else {
                Err(format!("hit {x}"))
            }
        });
    }

    #[test]
    fn generators_in_range() {
        check("gen ranges", 100, |rng| {
            let d = gen::divisor_of(rng, 24);
            if 24 % d != 0 {
                return Err(format!("{d} not a divisor"));
            }
            let p = gen::pow2_upto(rng, 16);
            if !p.is_power_of_two() || p > 16 {
                return Err(format!("bad pow2 {p}"));
            }
            let u = gen::usize_in(rng, 3, 7);
            if !(3..=7).contains(&u) {
                return Err(format!("out of range {u}"));
            }
            Ok(())
        });
    }
}
