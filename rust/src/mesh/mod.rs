//! Process mesh for hybrid parallelism (paper §4.1.4).
//!
//! World devices are arranged as a 4-D mesh `cfg × pipefusion × ring ×
//! ulysses` (outermost to innermost). Innermost dimensions map to adjacent
//! device ids, which on real clusters keeps the highest-frequency
//! communication (Ulysses All2All) on the fastest links — exactly the
//! paper's recommendation (CFG outermost / inter-node, then PipeFusion,
//! then SP).

use crate::config::parallel::ParallelConfig;

/// Coordinates of a device in the hybrid mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshCoord {
    pub cfg: usize,
    pub pipe: usize,
    pub ring: usize,
    pub ulysses: usize,
}

/// The process mesh: bijection world-rank <-> coordinates, plus the process
/// groups each parallel dimension communicates over.
#[derive(Debug, Clone)]
pub struct Mesh {
    pub pc: ParallelConfig,
}

impl Mesh {
    pub fn new(pc: ParallelConfig) -> Mesh {
        Mesh { pc }
    }

    pub fn world(&self) -> usize {
        self.pc.world()
    }

    /// rank -> coordinates (ulysses fastest-varying).
    pub fn coord(&self, rank: usize) -> MeshCoord {
        let u = self.pc.ulysses;
        let r = self.pc.ring;
        let p = self.pc.pipefusion;
        let ulysses = rank % u;
        let ring = (rank / u) % r;
        let pipe = (rank / (u * r)) % p;
        let cfg = rank / (u * r * p);
        MeshCoord { cfg, pipe, ring, ulysses }
    }

    /// coordinates -> rank.
    pub fn rank(&self, c: MeshCoord) -> usize {
        let u = self.pc.ulysses;
        let r = self.pc.ring;
        let p = self.pc.pipefusion;
        ((c.cfg * p + c.pipe) * r + c.ring) * u + c.ulysses
    }

    /// The SP group (ulysses × ring flattened) containing `rank`.
    pub fn sp_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coord(rank);
        let mut g = Vec::new();
        for ring in 0..self.pc.ring {
            for ulysses in 0..self.pc.ulysses {
                g.push(self.rank(MeshCoord { ring, ulysses, ..c }));
            }
        }
        g
    }

    /// Ulysses subgroup of `rank`.
    pub fn ulysses_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coord(rank);
        (0..self.pc.ulysses).map(|ulysses| self.rank(MeshCoord { ulysses, ..c })).collect()
    }

    /// Ring subgroup of `rank`.
    pub fn ring_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coord(rank);
        (0..self.pc.ring).map(|ring| self.rank(MeshCoord { ring, ..c })).collect()
    }

    /// The pipeline group of `rank` (same cfg/sp coordinates, all stages,
    /// ordered by stage).
    pub fn pipe_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coord(rank);
        (0..self.pc.pipefusion).map(|pipe| self.rank(MeshCoord { pipe, ..c })).collect()
    }

    /// The CFG pair group of `rank` (ordered by cfg coordinate).
    pub fn cfg_group(&self, rank: usize) -> Vec<usize> {
        let c = self.coord(rank);
        (0..self.pc.cfg).map(|cfg| self.rank(MeshCoord { cfg, ..c })).collect()
    }

    /// Sequence-shard index of a device within its image replica: patches
    /// are split over the SP group; the shard index orders [ring, ulysses].
    pub fn sp_index(&self, rank: usize) -> usize {
        let c = self.coord(rank);
        c.ring * self.pc.ulysses + c.ulysses
    }

    /// All ranks that work on CFG branch `b` (b in 0..cfg).
    pub fn cfg_branch_ranks(&self, b: usize) -> Vec<usize> {
        (0..self.world()).filter(|&r| self.coord(r).cfg == b).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh(cfg: usize, pipe: usize, ulysses: usize, ring: usize) -> Mesh {
        Mesh::new(ParallelConfig::new(cfg, pipe, ulysses, ring))
    }

    #[test]
    fn coord_rank_bijection() {
        let m = mesh(2, 2, 2, 2);
        for r in 0..16 {
            assert_eq!(m.rank(m.coord(r)), r);
        }
    }

    #[test]
    fn ulysses_innermost_adjacent() {
        let m = mesh(2, 2, 2, 1);
        assert_eq!(m.ulysses_group(0), vec![0, 1]);
        assert_eq!(m.ulysses_group(3), vec![2, 3]);
    }

    #[test]
    fn cfg_outermost() {
        let m = mesh(2, 2, 2, 1);
        // cfg pairs are world/2 apart (inter-node on a 2-node cluster)
        assert_eq!(m.cfg_group(0), vec![0, 4]);
        assert_eq!(m.cfg_branch_ranks(0), vec![0, 1, 2, 3]);
        assert_eq!(m.cfg_branch_ranks(1), vec![4, 5, 6, 7]);
    }

    #[test]
    fn groups_partition_world() {
        let m = mesh(2, 2, 2, 2);
        // SP groups partition the world into world/(u*r) disjoint groups
        let mut seen = std::collections::BTreeSet::new();
        for r in 0..16 {
            for d in m.sp_group(r) {
                if m.sp_group(d) == m.sp_group(r) {
                    seen.insert(d);
                }
            }
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn sp_index_orders_shards() {
        let m = mesh(1, 1, 2, 2);
        let idx: Vec<usize> = (0..4).map(|r| m.sp_index(r)).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pipe_group_ordered_by_stage() {
        let m = mesh(1, 4, 2, 1);
        let g = m.pipe_group(1);
        assert_eq!(g.len(), 4);
        for (stage, &r) in g.iter().enumerate() {
            assert_eq!(m.coord(r).pipe, stage);
        }
    }
}
