//! Model specifications.
//!
//! Two families:
//! * `tiny-*` — the runnable models whose AOT artifacts live in
//!   `artifacts/` (executed through PJRT by the numeric engine);
//! * the paper's five evaluation models (Pixart, SD3, Flux.1, HunyuanDiT,
//!   CogVideoX) — analytic specs with the real dimensions, consumed by the
//!   performance model that regenerates the paper's figures.

use crate::{Error, Result};

/// DiT block architecture variants (paper Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BlockVariant {
    /// adaLN-Zero conditioning (original DiT).
    AdaLn,
    /// Cross-attention conditioning (Pixart, HunyuanDiT).
    Cross,
    /// MM-DiT in-context conditioning (SD3, Flux.1, CogVideoX).
    MmDit,
    /// U-ViT-style long skip connections (HunyuanDiT topology).
    Skip,
}

impl BlockVariant {
    pub fn key(&self) -> &'static str {
        match self {
            BlockVariant::AdaLn => "adaln",
            BlockVariant::Cross => "cross",
            BlockVariant::MmDit => "mmdit",
            BlockVariant::Skip => "skip",
        }
    }

    /// Does the full attention sequence include the text tokens?
    pub fn in_context_text(&self) -> bool {
        matches!(self, BlockVariant::MmDit)
    }
}

/// A DiT model: either runnable (tiny) or analytic (paper-scale).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub layers: usize,
    pub mlp_ratio: usize,
    pub variant: BlockVariant,
    /// Latent channels.
    pub c_latent: usize,
    /// Text sequence length (in-context tokens or cross-attn memory).
    pub s_txt: usize,
    /// Parameter count of the transformer (for memory model), in units.
    pub params: f64,
    /// Text-encoder bytes on disk (Table 2).
    pub text_encoder_bytes: f64,
    /// VAE bytes on disk (Table 2).
    pub vae_bytes: f64,
    /// Whether the model uses classifier-free guidance (Flux.1 does not).
    pub uses_cfg: bool,
    /// True for the runnable tiny family (artifacts exist).
    pub runnable: bool,
    /// Video models: frames per clip (1 for images).
    pub frames: usize,
    /// Diffusion steps of the paper's benchmark scheduler.
    pub default_steps: usize,
    pub scheduler: &'static str,
}

impl ModelSpec {
    /// Image-token sequence length for a generation at `px` resolution
    /// (square). DiTs patchify the 8×-downsampled latent with patch size 2:
    /// tokens = (px/16)^2 per frame.
    pub fn seq_len(&self, px: usize) -> usize {
        (px / 16) * (px / 16) * self.frames
    }

    /// Total attention sequence (image + in-context text).
    pub fn attn_seq_len(&self, px: usize) -> usize {
        self.seq_len(px) + if self.variant.in_context_text() { self.s_txt } else { 0 }
    }

    /// Transformer parameter bytes (fp16 on GPUs, as deployed).
    pub fn param_bytes(&self) -> f64 {
        self.params * 2.0
    }

    /// FLOPs of one denoising forward at resolution `px` (per image in the
    /// batch). Standard transformer accounting: 2*P*S for the dense part +
    /// attention 4*S^2*hidden per layer (QK^T and PV, fwd only, x2 MACs).
    pub fn step_flops(&self, px: usize) -> f64 {
        let s = self.attn_seq_len(px) as f64;
        let h = self.hidden as f64;
        let dense = 2.0 * self.params * s;
        let attn = 4.0 * s * s * h * self.layers as f64;
        dense + attn
    }

    /// Per-layer K+V bytes for the full sequence (fp16) — the unit of the
    /// paper's Table-1 memory analysis.
    pub fn kv_bytes_per_layer(&self, px: usize) -> f64 {
        2.0 * self.attn_seq_len(px) as f64 * self.hidden as f64 * 2.0
    }

    /// Activation bytes (hidden state for the sequence, fp16).
    pub fn act_bytes(&self, px: usize) -> f64 {
        self.attn_seq_len(px) as f64 * self.hidden as f64 * 2.0
    }

    /// The runnable tiny-family spec that executes a block variant — the
    /// single place that knows the `tiny-` naming convention.
    pub fn for_variant(variant: BlockVariant) -> Result<ModelSpec> {
        Self::by_name(&format!("tiny-{}", variant.key()))
    }

    pub fn by_name(name: &str) -> Result<ModelSpec> {
        all_models()
            .into_iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                Error::config(format!(
                    "unknown model '{name}' (available: {})",
                    all_models().iter().map(|m| m.name.clone()).collect::<Vec<_>>().join(", ")
                ))
            })
    }
}

fn base(name: &str, variant: BlockVariant) -> ModelSpec {
    ModelSpec {
        name: name.into(),
        hidden: 0,
        heads: 0,
        head_dim: 0,
        layers: 0,
        mlp_ratio: 4,
        variant,
        c_latent: 4,
        s_txt: 0,
        params: 0.0,
        text_encoder_bytes: 0.0,
        vae_bytes: 320e6,
        uses_cfg: true,
        runnable: false,
        frames: 1,
        default_steps: 20,
        scheduler: "dpm",
    }
}

/// The paper's five evaluation models (Table 2 dims) + the tiny family.
pub fn all_models() -> Vec<ModelSpec> {
    let mut v = Vec::new();

    // Pixart-alpha/sigma: 0.6B, d=1152, 28 layers, 16 heads, cross-attn.
    let mut m = base("pixart", BlockVariant::Cross);
    m.hidden = 1152;
    m.heads = 16;
    m.head_dim = 72;
    m.layers = 28;
    m.s_txt = 120;
    m.params = 0.6e9;
    m.text_encoder_bytes = 18e9;
    m.scheduler = "dpm";
    v.push(m);

    // SD3-medium: 2B MM-DiT, d=1536, 24 layers, 24 heads.
    let mut m = base("sd3", BlockVariant::MmDit);
    m.hidden = 1536;
    m.heads = 24;
    m.head_dim = 64;
    m.layers = 24;
    m.s_txt = 160; // 154 CLIP+T5 tokens, padded to an SP-divisible multiple
    m.params = 2.0e9;
    m.text_encoder_bytes = 19e9;
    m.scheduler = "flow_match";
    v.push(m);

    // Flux.1-dev: 12B MM-DiT (19 dual + 38 single blocks ~ 57), d=3072,
    // 24 heads; no CFG.
    let mut m = base("flux", BlockVariant::MmDit);
    m.hidden = 3072;
    m.heads = 24;
    m.head_dim = 128;
    m.layers = 57;
    m.s_txt = 512;
    m.params = 12.0e9;
    m.text_encoder_bytes = 9.1e9;
    m.uses_cfg = false;
    m.default_steps = 28;
    m.scheduler = "flow_match";
    v.push(m);

    // HunyuanDiT: 1.5B, d=1408, 40 blocks with long skip connections.
    let mut m = base("hunyuan", BlockVariant::Skip);
    m.hidden = 1408;
    m.heads = 16;
    m.head_dim = 88;
    m.layers = 40;
    m.s_txt = 256;
    m.params = 1.5e9;
    m.text_encoder_bytes = 7.7e9;
    m.default_steps = 50;
    m.scheduler = "dpm";
    v.push(m);

    // CogVideoX-5B: video MM-DiT, d=3072, 30 heads, 42 layers;
    // 49 frames at 480x720 (13 latent frames after 4x temporal compress).
    let mut m = base("cogvideox", BlockVariant::MmDit);
    m.hidden = 3072;
    m.heads = 30;
    m.head_dim = 102;
    m.layers = 42;
    m.s_txt = 226;
    m.params = 5.0e9;
    m.text_encoder_bytes = 8.9e9;
    m.vae_bytes = 412e6;
    m.frames = 13;
    m.default_steps = 50;
    m.scheduler = "ddim";
    v.push(m);

    // Runnable tiny family (matches python/compile/configs.py TINY).
    for (suffix, variant) in [
        ("adaln", BlockVariant::AdaLn),
        ("cross", BlockVariant::Cross),
        ("mmdit", BlockVariant::MmDit),
        ("skip", BlockVariant::Skip),
    ] {
        let mut m = base(&format!("tiny-{suffix}"), variant);
        m.hidden = 192;
        m.heads = 6;
        m.head_dim = 32;
        m.layers = 8;
        m.s_txt = 32;
        // ~ per-layer param estimate x layers (exact value irrelevant for
        // the tiny family; the numeric path uses real weights).
        m.params = match variant {
            BlockVariant::MmDit => 10.6e6,
            BlockVariant::Cross => 6.5e6,
            _ => 5.5e6,
        };
        m.text_encoder_bytes = (256 * 192 * 4) as f64;
        m.vae_bytes = 80e3;
        m.runnable = true;
        m.default_steps = 8;
        m.scheduler = "ddim";
        v.push(m);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert!(ModelSpec::by_name("pixart").is_ok());
        assert!(ModelSpec::by_name("tiny-mmdit").unwrap().runnable);
        assert!(ModelSpec::by_name("nope").is_err());
    }

    #[test]
    fn for_variant_resolves_runnable_family() {
        for v in [
            BlockVariant::AdaLn,
            BlockVariant::Cross,
            BlockVariant::MmDit,
            BlockVariant::Skip,
        ] {
            let m = ModelSpec::for_variant(v).unwrap();
            assert!(m.runnable);
            assert_eq!(m.variant, v);
            assert_eq!(m.name, format!("tiny-{}", v.key()));
        }
    }

    #[test]
    fn seq_lengths_match_paper() {
        let pixart = ModelSpec::by_name("pixart").unwrap();
        // Paper §3: 1024px -> 4K tokens; 4096px -> 64K image tokens.
        assert_eq!(pixart.seq_len(1024), 4096);
        assert_eq!(pixart.seq_len(4096), 65536);
        let flux = ModelSpec::by_name("flux").unwrap();
        assert!(flux.variant.in_context_text());
        assert_eq!(flux.attn_seq_len(1024), 4096 + 512);
    }

    #[test]
    fn flops_scale_superlinearly_with_resolution() {
        let m = ModelSpec::by_name("sd3").unwrap();
        let f1 = m.step_flops(1024);
        let f2 = m.step_flops(2048);
        // 4x tokens -> >4x flops (attention quadratic term).
        assert!(f2 > 4.0 * f1);
    }

    #[test]
    fn flux_has_no_cfg() {
        assert!(!ModelSpec::by_name("flux").unwrap().uses_cfg);
        assert!(ModelSpec::by_name("sd3").unwrap().uses_cfg);
    }

    #[test]
    fn video_model_sequence() {
        let m = ModelSpec::by_name("cogvideox").unwrap();
        // 480x720 -> (30*45) tokens/frame x 13 latent frames ~ 17K (paper §3)
        let tokens = (480 / 16) * (720 / 16) * m.frames;
        assert!((15_000..20_000).contains(&tokens), "{tokens}");
    }
}
