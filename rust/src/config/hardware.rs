//! Hardware cluster specifications for the performance model and the
//! virtual-time interconnect simulator.
//!
//! Mirrors the paper's two testbeds:
//! * `l40_cluster(n_nodes)` — nodes of 8×L40-48GB on PCIe Gen4 x16 (two
//!   4-GPU groups bridged by the CPU QPI), nodes connected by 100 Gbps
//!   Ethernet;
//! * `a100_node()` — 8×A100-80GB, full NVLink (600 GB/s any-to-any).

use crate::{Error, Result};

/// GPU compute/memory spec.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    /// Dense fp16/bf16 TFLOP/s actually achievable on DiT workloads
    /// (sustained, not peak marketing numbers).
    pub tflops: f64,
    /// HBM/GDDR capacity in bytes.
    pub mem_bytes: f64,
}

/// Classes of links between two devices, ordered by bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// NVLink (A100: 600 GB/s bidirectional any-to-any in the node).
    NvLink,
    /// PCIe Gen4 x16 within one CPU root complex.
    Pcie,
    /// PCIe crossing the CPU-interconnect (QPI/UPI) — the paper calls out
    /// the All2All collapse across this hop.
    PcieQpi,
    /// Inter-node Ethernet.
    Ethernet,
}

/// One homogeneous simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub gpu: GpuSpec,
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    /// GPUs per PCIe root complex (QPI boundary); == gpus_per_node when the
    /// node has a single switch (NVLink systems).
    pub gpus_per_numa: usize,
    /// Unidirectional bandwidth in bytes/s per link kind.
    pub bw: fn(LinkKind) -> f64,
    /// Per-message latency in seconds per link kind.
    pub lat: fn(LinkKind) -> f64,
    pub has_nvlink: bool,
}

impl ClusterSpec {
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_node
    }

    pub fn numa_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_numa
    }

    /// Link class between two devices.
    pub fn link(&self, a: usize, b: usize) -> LinkKind {
        if self.node_of(a) != self.node_of(b) {
            LinkKind::Ethernet
        } else if self.has_nvlink {
            LinkKind::NvLink
        } else if self.numa_of(a) != self.numa_of(b) {
            LinkKind::PcieQpi
        } else {
            LinkKind::Pcie
        }
    }

    /// Time to move `bytes` point-to-point between devices a and b.
    pub fn p2p_time(&self, a: usize, b: usize, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        let k = self.link(a, b);
        (self.lat)(k) + bytes / (self.bw)(k)
    }

    /// The slowest link class inside a device group (collectives are
    /// bottlenecked by it).
    pub fn worst_link(&self, group: &[usize]) -> LinkKind {
        let mut worst = LinkKind::NvLink;
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                let k = self.link(a, b);
                if link_rank(k) > link_rank(worst) {
                    worst = k;
                }
            }
        }
        worst
    }

    /// Ring-based collective time for `bytes` per rank over `group`,
    /// with the NCCL algorithm-bandwidth factor `algbw_factor(n)` applied
    /// (2(n-1)/n for AllReduce, (n-1)/n for AllGather/ReduceScatter).
    ///
    /// When the group spans nodes, every rank's cross-node traffic funnels
    /// through its node's single NIC, dividing the effective per-rank
    /// Ethernet bandwidth — this is what collapses collective-heavy methods
    /// from 8 to 16 GPUs in the paper's §5.2.1.
    pub fn collective_time(&self, group: &[usize], bytes: f64, algbw_factor: f64) -> f64 {
        let n = group.len();
        if n <= 1 {
            return 0.0;
        }
        let k = self.worst_link(group);
        let mut bw = (self.bw)(k);
        if k == LinkKind::Ethernet {
            // ranks per node sharing the NIC
            let mut per_node = std::collections::BTreeMap::new();
            for &d in group {
                *per_node.entry(self.node_of(d)).or_insert(0usize) += 1;
            }
            let sharing = per_node.values().copied().max().unwrap_or(1) as f64;
            bw /= sharing;
        }
        let steps = (n - 1) as f64;
        (self.lat)(k) * steps + bytes * algbw_factor / bw
    }

    pub fn by_name(name: &str) -> Result<ClusterSpec> {
        match name {
            "l40x8" => Ok(l40_cluster(1)),
            "l40x16" => Ok(l40_cluster(2)),
            "a100x8" => Ok(a100_node()),
            _ => Err(Error::config(format!(
                "unknown cluster '{name}' (l40x8, l40x16, a100x8)"
            ))),
        }
    }
}

fn link_rank(k: LinkKind) -> u8 {
    match k {
        LinkKind::NvLink => 0,
        LinkKind::Pcie => 1,
        LinkKind::PcieQpi => 2,
        LinkKind::Ethernet => 3,
    }
}

fn l40_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!("L40 nodes have no NVLink"),
        LinkKind::Pcie => 24e9,     // PCIe Gen4 x16 ~ 24 GB/s effective
        LinkKind::PcieQpi => 12e9,  // QPI-crossing penalty (paper §4.1.4)
        LinkKind::Ethernet => 10e9, // 100 Gbps ~ 10 GB/s effective (RoCE-less)
    }
}

fn l40_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!(),
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

fn a100_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 250e9, // 600 GB/s bidir marketing ~ 250 GB/s algo
        LinkKind::Pcie => 24e9,
        LinkKind::PcieQpi => 12e9,
        LinkKind::Ethernet => 10e9,
    }
}

fn a100_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 3e-6,
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

/// `n_nodes` nodes of 8×L40 (PCIe Gen4, two NUMA domains of 4), 100 Gbps
/// Ethernet between nodes.
pub fn l40_cluster(n_nodes: usize) -> ClusterSpec {
    ClusterSpec {
        name: format!("l40x{}", 8 * n_nodes),
        gpu: GpuSpec { name: "L40-48GB".into(), tflops: 90.0, mem_bytes: 48e9 },
        n_gpus: 8 * n_nodes,
        gpus_per_node: 8,
        gpus_per_numa: 4,
        bw: l40_bw,
        lat: l40_lat,
        has_nvlink: false,
    }
}

/// One node of 8×A100-80GB with NVLink.
pub fn a100_node() -> ClusterSpec {
    ClusterSpec {
        name: "a100x8".into(),
        gpu: GpuSpec { name: "A100-80GB".into(), tflops: 250.0, mem_bytes: 80e9 },
        n_gpus: 8,
        gpus_per_node: 8,
        gpus_per_numa: 8,
        bw: a100_bw,
        lat: a100_lat,
        has_nvlink: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l40_topology() {
        let c = l40_cluster(2);
        assert_eq!(c.n_gpus, 16);
        assert_eq!(c.link(0, 1), LinkKind::Pcie);
        assert_eq!(c.link(0, 5), LinkKind::PcieQpi);
        assert_eq!(c.link(0, 8), LinkKind::Ethernet);
        assert_eq!(c.link(9, 15), LinkKind::PcieQpi);
    }

    #[test]
    fn a100_topology() {
        let c = a100_node();
        assert_eq!(c.link(0, 7), LinkKind::NvLink);
    }

    #[test]
    fn p2p_monotone_in_bytes() {
        let c = l40_cluster(1);
        assert!(c.p2p_time(0, 1, 2e6) > c.p2p_time(0, 1, 1e6));
        assert_eq!(c.p2p_time(3, 3, 1e9), 0.0);
    }

    #[test]
    fn worst_link_dominates_collective() {
        let c = l40_cluster(2);
        let intra = c.collective_time(&[0, 1, 2, 3], 1e6, 1.0);
        let cross = c.collective_time(&[0, 1, 8, 9], 1e6, 1.0);
        assert!(cross > intra);
    }

    #[test]
    fn nvlink_much_faster_than_ethernet() {
        let a = a100_node();
        let l = l40_cluster(2);
        let b = 100e6;
        assert!(a.p2p_time(0, 1, b) * 10.0 < l.p2p_time(0, 8, b));
    }
}
