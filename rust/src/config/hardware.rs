//! Hardware cluster specifications for the performance model and the
//! virtual-time interconnect simulator.
//!
//! Mirrors the paper's two testbeds:
//! * `l40_cluster(n_nodes)` — nodes of 8×L40-48GB on PCIe Gen4 x16 (two
//!   4-GPU groups bridged by the CPU QPI), nodes connected by 100 Gbps
//!   Ethernet;
//! * `a100_node()` / `a100_cluster(n_nodes)` — nodes of 8×A100-80GB with
//!   full NVLink (600 GB/s any-to-any), Ethernet between nodes.
//!
//! A spec is **two-tier**: the per-kind `bw`/`lat` link model prices the
//! intra-node tier (NVLink / PCIe / PCIe-QPI), while the explicit
//! [`InterNodeLink`] prices every cross-node hop ([`LinkKind::Ethernet`]).
//! Single-node specs are the degenerate case — their `inter_node` field is
//! never consulted because no device pair crosses a node. The fleet layer
//! carves a multi-node spec into per-replica slices with
//! [`ClusterSpec::carve`].

use crate::{Error, Result};

/// GPU compute/memory spec.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    /// Dense fp16/bf16 TFLOP/s actually achievable on DiT workloads
    /// (sustained, not peak marketing numbers).
    pub tflops: f64,
    /// HBM/GDDR capacity in bytes.
    pub mem_bytes: f64,
}

/// Classes of links between two devices, ordered by bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// NVLink (A100: 600 GB/s bidirectional any-to-any in the node).
    NvLink,
    /// PCIe Gen4 x16 within one CPU root complex.
    Pcie,
    /// PCIe crossing the CPU-interconnect (QPI/UPI) — the paper calls out
    /// the All2All collapse across this hop.
    PcieQpi,
    /// Inter-node Ethernet.
    Ethernet,
}

/// The inter-node tier of a two-tier cluster: what every cross-node hop
/// costs. Defaults to the paper's 100 Gbps Ethernet (10 GB/s effective,
/// 50 µs per message), which is exactly what the single-tier link models
/// priced before the tier split — so existing specs behave identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterNodeLink {
    /// Unidirectional bandwidth in bytes/s of one node's NIC.
    pub bw: f64,
    /// Per-message latency in seconds.
    pub lat: f64,
}

impl Default for InterNodeLink {
    fn default() -> Self {
        InterNodeLink { bw: 10e9, lat: 50e-6 }
    }
}

/// One homogeneous simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub gpu: GpuSpec,
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    /// GPUs per PCIe root complex (QPI boundary); == gpus_per_node when the
    /// node has a single switch (NVLink systems).
    pub gpus_per_numa: usize,
    /// Unidirectional bandwidth in bytes/s per link kind — the intra-node
    /// tier. The `Ethernet` arm is superseded by `inter_node` (kept so the
    /// function stays total).
    pub bw: fn(LinkKind) -> f64,
    /// Per-message latency in seconds per link kind (intra-node tier; the
    /// `Ethernet` arm is superseded by `inter_node`).
    pub lat: fn(LinkKind) -> f64,
    pub has_nvlink: bool,
    /// The inter-node tier: bandwidth/latency of every cross-node hop.
    pub inter_node: InterNodeLink,
}

impl ClusterSpec {
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_node
    }

    pub fn numa_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_numa
    }

    /// Number of nodes in the cluster (the outer tier's extent).
    pub fn n_nodes(&self) -> usize {
        self.n_gpus.div_ceil(self.gpus_per_node)
    }

    /// Replace the inter-node tier (e.g. model a faster RoCE fabric).
    pub fn with_inter_node(mut self, inter_node: InterNodeLink) -> ClusterSpec {
        self.inter_node = inter_node;
        self
    }

    /// Tier-aware bandwidth for a link kind: cross-node hops are priced by
    /// the `inter_node` tier, everything else by the intra-node link model.
    pub fn link_bw(&self, k: LinkKind) -> f64 {
        match k {
            LinkKind::Ethernet => self.inter_node.bw,
            _ => (self.bw)(k),
        }
    }

    /// Tier-aware per-message latency for a link kind (see
    /// [`link_bw`](ClusterSpec::link_bw)).
    pub fn link_lat(&self, k: LinkKind) -> f64 {
        match k {
            LinkKind::Ethernet => self.inter_node.lat,
            _ => (self.lat)(k),
        }
    }

    /// Link class between two devices.
    pub fn link(&self, a: usize, b: usize) -> LinkKind {
        if self.node_of(a) != self.node_of(b) {
            LinkKind::Ethernet
        } else if self.has_nvlink {
            LinkKind::NvLink
        } else if self.numa_of(a) != self.numa_of(b) {
            LinkKind::PcieQpi
        } else {
            LinkKind::Pcie
        }
    }

    /// Time to move `bytes` point-to-point between devices a and b.
    pub fn p2p_time(&self, a: usize, b: usize, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        let k = self.link(a, b);
        self.link_lat(k) + bytes / self.link_bw(k)
    }

    /// The slowest link class inside a device group (collectives are
    /// bottlenecked by it).
    pub fn worst_link(&self, group: &[usize]) -> LinkKind {
        let mut worst = LinkKind::NvLink;
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                let k = self.link(a, b);
                if link_rank(k) > link_rank(worst) {
                    worst = k;
                }
            }
        }
        worst
    }

    /// Ring-based collective time for `bytes` per rank over `group`,
    /// with the NCCL algorithm-bandwidth factor `algbw_factor(n)` applied
    /// (2(n-1)/n for AllReduce, (n-1)/n for AllGather/ReduceScatter).
    ///
    /// When the group spans nodes, every rank's cross-node traffic funnels
    /// through its node's single NIC, dividing the effective per-rank
    /// Ethernet bandwidth — this is what collapses collective-heavy methods
    /// from 8 to 16 GPUs in the paper's §5.2.1.
    pub fn collective_time(&self, group: &[usize], bytes: f64, algbw_factor: f64) -> f64 {
        let n = group.len();
        if n <= 1 {
            return 0.0;
        }
        let k = self.worst_link(group);
        let mut bw = self.link_bw(k);
        if k == LinkKind::Ethernet {
            // ranks per node sharing the NIC
            let mut per_node = std::collections::BTreeMap::new();
            for &d in group {
                *per_node.entry(self.node_of(d)).or_insert(0usize) += 1;
            }
            let sharing = per_node.values().copied().max().unwrap_or(1) as f64;
            bw /= sharing;
        }
        let steps = (n - 1) as f64;
        self.link_lat(k) * steps + bytes * algbw_factor / bw
    }

    /// Carve the cluster into `replicas` equal, topology-aligned slices and
    /// return one slice (they are all identical — the cluster is
    /// homogeneous). A replica either owns whole nodes or divides one node
    /// evenly, so a slice never straddles a node boundary asymmetrically.
    /// `carve(1)` returns the spec unchanged (same name), which is what
    /// makes single-replica fleet serving bit-identical to `serve_trace`.
    pub fn carve(&self, replicas: usize) -> Result<ClusterSpec> {
        if replicas == 0 {
            return Err(Error::config("cannot carve a cluster into 0 replicas"));
        }
        if replicas == 1 {
            return Ok(self.clone());
        }
        if self.n_gpus % replicas != 0 {
            return Err(Error::config(format!(
                "cannot carve {} GPUs of '{}' into {replicas} equal replicas",
                self.n_gpus, self.name
            )));
        }
        let per = self.n_gpus / replicas;
        let aligned = if per >= self.gpus_per_node {
            per % self.gpus_per_node == 0
        } else {
            self.gpus_per_node % per == 0
        };
        if !aligned {
            return Err(Error::config(format!(
                "replica size {per} does not align with '{}' nodes of {} GPUs",
                self.name, self.gpus_per_node
            )));
        }
        let mut slice = self.clone();
        slice.name = format!("{}/r{replicas}", self.name);
        slice.n_gpus = per;
        slice.gpus_per_node = self.gpus_per_node.min(per);
        slice.gpus_per_numa = self.gpus_per_numa.min(per);
        Ok(slice)
    }

    /// Parse a cluster name: the paper's testbeds (`l40x8`, `l40x16`,
    /// `a100x8`) plus the generic two-tier families `l40xN` / `a100xN` for
    /// any N that is a multiple of 8 (N/8 Ethernet-connected nodes).
    pub fn by_name(name: &str) -> Result<ClusterSpec> {
        let parse_nodes = |n: &str| -> Option<usize> {
            let gpus: usize = n.parse().ok()?;
            if gpus > 0 && gpus % 8 == 0 {
                Some(gpus / 8)
            } else {
                None
            }
        };
        if let Some(n) = name.strip_prefix("l40x").and_then(parse_nodes) {
            return Ok(l40_cluster(n));
        }
        if let Some(n) = name.strip_prefix("a100x").and_then(parse_nodes) {
            return Ok(a100_cluster(n));
        }
        Err(Error::config(format!(
            "unknown cluster '{name}' (l40xN or a100xN, N a multiple of 8)"
        )))
    }
}

fn link_rank(k: LinkKind) -> u8 {
    match k {
        LinkKind::NvLink => 0,
        LinkKind::Pcie => 1,
        LinkKind::PcieQpi => 2,
        LinkKind::Ethernet => 3,
    }
}

fn l40_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!("L40 nodes have no NVLink"),
        LinkKind::Pcie => 24e9,     // PCIe Gen4 x16 ~ 24 GB/s effective
        LinkKind::PcieQpi => 12e9,  // QPI-crossing penalty (paper §4.1.4)
        LinkKind::Ethernet => 10e9, // 100 Gbps ~ 10 GB/s effective (RoCE-less)
    }
}

fn l40_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!(),
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

fn a100_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 250e9, // 600 GB/s bidir marketing ~ 250 GB/s algo
        LinkKind::Pcie => 24e9,
        LinkKind::PcieQpi => 12e9,
        LinkKind::Ethernet => 10e9,
    }
}

fn a100_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 3e-6,
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

/// `n_nodes` nodes of 8×L40 (PCIe Gen4, two NUMA domains of 4), 100 Gbps
/// Ethernet between nodes.
pub fn l40_cluster(n_nodes: usize) -> ClusterSpec {
    ClusterSpec {
        name: format!("l40x{}", 8 * n_nodes),
        gpu: GpuSpec { name: "L40-48GB".into(), tflops: 90.0, mem_bytes: 48e9 },
        n_gpus: 8 * n_nodes,
        gpus_per_node: 8,
        gpus_per_numa: 4,
        bw: l40_bw,
        lat: l40_lat,
        has_nvlink: false,
        inter_node: InterNodeLink::default(),
    }
}

/// One node of 8×A100-80GB with NVLink.
pub fn a100_node() -> ClusterSpec {
    a100_cluster(1)
}

/// `n_nodes` nodes of 8×A100-80GB — NVLink inside each node, 100 Gbps
/// Ethernet between nodes: the genuinely two-tier testbed (a 250 GB/s to
/// 10 GB/s cliff at every node boundary).
pub fn a100_cluster(n_nodes: usize) -> ClusterSpec {
    ClusterSpec {
        name: format!("a100x{}", 8 * n_nodes),
        gpu: GpuSpec { name: "A100-80GB".into(), tflops: 250.0, mem_bytes: 80e9 },
        n_gpus: 8 * n_nodes,
        gpus_per_node: 8,
        gpus_per_numa: 8,
        bw: a100_bw,
        lat: a100_lat,
        has_nvlink: true,
        inter_node: InterNodeLink::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l40_topology() {
        let c = l40_cluster(2);
        assert_eq!(c.n_gpus, 16);
        assert_eq!(c.link(0, 1), LinkKind::Pcie);
        assert_eq!(c.link(0, 5), LinkKind::PcieQpi);
        assert_eq!(c.link(0, 8), LinkKind::Ethernet);
        assert_eq!(c.link(9, 15), LinkKind::PcieQpi);
    }

    #[test]
    fn a100_topology() {
        let c = a100_node();
        assert_eq!(c.link(0, 7), LinkKind::NvLink);
    }

    #[test]
    fn p2p_monotone_in_bytes() {
        let c = l40_cluster(1);
        assert!(c.p2p_time(0, 1, 2e6) > c.p2p_time(0, 1, 1e6));
        assert_eq!(c.p2p_time(3, 3, 1e9), 0.0);
    }

    #[test]
    fn worst_link_dominates_collective() {
        let c = l40_cluster(2);
        let intra = c.collective_time(&[0, 1, 2, 3], 1e6, 1.0);
        let cross = c.collective_time(&[0, 1, 8, 9], 1e6, 1.0);
        assert!(cross > intra);
    }

    #[test]
    fn nvlink_much_faster_than_ethernet() {
        let a = a100_node();
        let l = l40_cluster(2);
        let b = 100e6;
        assert!(a.p2p_time(0, 1, b) * 10.0 < l.p2p_time(0, 8, b));
    }

    #[test]
    fn default_inter_node_matches_the_single_tier_constants() {
        // the tier split must be a pure refactor for the stock specs:
        // cross-node pricing through `inter_node` equals what the old
        // single-tier link models charged
        for c in [l40_cluster(2), a100_cluster(2)] {
            assert_eq!(c.link_bw(LinkKind::Ethernet), (c.bw)(LinkKind::Ethernet));
            assert_eq!(c.link_lat(LinkKind::Ethernet), (c.lat)(LinkKind::Ethernet));
        }
        // and a mutated tier actually reprices cross-node hops
        let fast = l40_cluster(2).with_inter_node(InterNodeLink { bw: 50e9, lat: 5e-6 });
        assert!(fast.p2p_time(0, 8, 100e6) < l40_cluster(2).p2p_time(0, 8, 100e6));
        // ...while intra-node hops are untouched
        assert_eq!(fast.p2p_time(0, 1, 100e6), l40_cluster(2).p2p_time(0, 1, 100e6));
    }

    #[test]
    fn n_nodes_counts_the_outer_tier() {
        assert_eq!(l40_cluster(1).n_nodes(), 1);
        assert_eq!(l40_cluster(2).n_nodes(), 2);
        assert_eq!(a100_cluster(4).n_nodes(), 4);
    }

    #[test]
    fn carve_whole_nodes() {
        let c = l40_cluster(2);
        let r = c.carve(2).unwrap();
        assert_eq!(r.n_gpus, 8);
        assert_eq!(r.gpus_per_node, 8);
        assert_eq!(r.gpus_per_numa, 4);
        assert_eq!(r.n_nodes(), 1);
        assert_eq!(r.name, "l40x16/r2");
        // a whole-node replica prices links exactly like the matching
        // single-node spec
        let solo = l40_cluster(1);
        assert_eq!(r.link(0, 1), solo.link(0, 1));
        assert_eq!(r.link(0, 5), solo.link(0, 5));
        assert_eq!(
            r.collective_time(&[0, 1, 4, 5], 1e6, 1.0),
            solo.collective_time(&[0, 1, 4, 5], 1e6, 1.0)
        );
    }

    #[test]
    fn carve_within_a_node() {
        let c = l40_cluster(2);
        let r = c.carve(4).unwrap();
        assert_eq!(r.n_gpus, 4);
        assert_eq!(r.gpus_per_node, 4);
        assert_eq!(r.gpus_per_numa, 4);
        // all four devices share one NUMA domain: pure PCIe
        assert_eq!(r.worst_link(&[0, 1, 2, 3]), LinkKind::Pcie);
    }

    #[test]
    fn carve_one_is_identity() {
        let c = l40_cluster(2);
        let r = c.carve(1).unwrap();
        assert_eq!(r.name, c.name);
        assert_eq!(r.n_gpus, c.n_gpus);
        assert_eq!(r.gpus_per_node, c.gpus_per_node);
    }

    #[test]
    fn carve_rejects_misaligned_splits() {
        assert!(l40_cluster(2).carve(0).is_err());
        // 16 % 3 != 0
        assert!(l40_cluster(2).carve(3).is_err());
        // per = 16/2 = 8 aligns; per = 24/3 = 8 aligns; but a 12-GPU slice
        // of 8-GPU nodes would straddle a node boundary
        assert!(l40_cluster(3).carve(2).is_err());
    }

    #[test]
    fn by_name_parses_the_generic_families() {
        assert_eq!(ClusterSpec::by_name("l40x8").unwrap().n_gpus, 8);
        assert_eq!(ClusterSpec::by_name("l40x32").unwrap().n_nodes(), 4);
        let a = ClusterSpec::by_name("a100x16").unwrap();
        assert_eq!(a.n_nodes(), 2);
        assert!(a.has_nvlink);
        assert_eq!(a.link(0, 8), LinkKind::Ethernet);
        assert!(ClusterSpec::by_name("l40x12").is_err());
        assert!(ClusterSpec::by_name("h100x8").is_err());
    }
}
