//! Hardware cluster specifications for the performance model and the
//! virtual-time interconnect simulator.
//!
//! Mirrors the paper's two testbeds:
//! * `l40_cluster(n_nodes)` — nodes of 8×L40-48GB on PCIe Gen4 x16 (two
//!   4-GPU groups bridged by the CPU QPI), nodes connected by 100 Gbps
//!   Ethernet;
//! * `a100_node()` / `a100_cluster(n_nodes)` — nodes of 8×A100-80GB with
//!   full NVLink (600 GB/s any-to-any), Ethernet between nodes.
//!
//! A spec is **two-tier**: the per-kind `bw`/`lat` link model prices the
//! intra-node tier (NVLink / PCIe / PCIe-QPI), while the explicit
//! [`InterNodeLink`] prices every cross-node hop ([`LinkKind::Ethernet`]).
//! Single-node specs are the degenerate case — their `inter_node` field is
//! never consulted because no device pair crosses a node. The fleet layer
//! carves a multi-node spec into per-replica slices with
//! [`ClusterSpec::carve`].

use crate::{Error, Result};

/// GPU compute/memory spec.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    /// Dense fp16/bf16 TFLOP/s actually achievable on DiT workloads
    /// (sustained, not peak marketing numbers).
    pub tflops: f64,
    /// HBM/GDDR capacity in bytes.
    pub mem_bytes: f64,
}

/// Classes of links between two devices, ordered by bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// NVLink (A100: 600 GB/s bidirectional any-to-any in the node).
    NvLink,
    /// PCIe Gen4 x16 within one CPU root complex.
    Pcie,
    /// PCIe crossing the CPU-interconnect (QPI/UPI) — the paper calls out
    /// the All2All collapse across this hop.
    PcieQpi,
    /// Inter-node Ethernet.
    Ethernet,
}

/// The inter-node tier of a two-tier cluster: what every cross-node hop
/// costs. Defaults to the paper's 100 Gbps Ethernet (10 GB/s effective,
/// 50 µs per message), which is exactly what the single-tier link models
/// priced before the tier split — so existing specs behave identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterNodeLink {
    /// Unidirectional bandwidth in bytes/s of one node's NIC.
    pub bw: f64,
    /// Per-message latency in seconds.
    pub lat: f64,
}

impl Default for InterNodeLink {
    fn default() -> Self {
        InterNodeLink { bw: 10e9, lat: 50e-6 }
    }
}

/// Collective primitives the cost model prices. The variant fixes the
/// NCCL-style ring algorithm-bandwidth factor used by the flat lowering
/// (see [`CollectiveKind::flat_factor`]) and the three-phase decomposition
/// used by [`CollectiveAlgo::Hierarchical`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Every rank contributes one part of `bytes`; all ranks end with all
    /// parts. Ring factor `n-1` on the per-rank part size.
    AllGather,
    /// Reduce a `bytes` buffer, leaving each rank one shard. Ring factor
    /// `(n-1)/n` on the buffer size.
    ReduceScatter,
    /// Reduce-scatter + all-gather: ring factor `2(n-1)/n` on the buffer.
    AllReduce,
    /// Every rank sends `bytes` total, split across the other ranks
    /// (Ulysses). Factor `1.0` on the per-rank send volume.
    AllToAll,
}

impl CollectiveKind {
    /// The ring algorithm-bandwidth factor applied to this kind's `bytes`
    /// argument by the flat one-level lowering. These match what the
    /// closed-form model has always charged, so
    /// [`CollectiveAlgo::FlatRing`] pricing is byte-exact with the
    /// historical [`ClusterSpec::collective_time`] call sites.
    pub fn flat_factor(self, n: usize) -> f64 {
        let nf = n as f64;
        match self {
            // written as (n-1)/n * n — numerically n-1, but kept in the
            // historical call-site form so FlatRing pricing stays
            // bit-exact for every group size (the product is not exactly
            // n-1 for non-dyadic n)
            CollectiveKind::AllGather => (nf - 1.0) / nf * nf,
            CollectiveKind::ReduceScatter => (nf - 1.0) / nf,
            CollectiveKind::AllReduce => 2.0 * (nf - 1.0) / nf,
            CollectiveKind::AllToAll => 1.0,
        }
    }

    /// Short lowercase label (`all_gather`, `all_reduce`, ...).
    pub fn label(self) -> &'static str {
        match self {
            CollectiveKind::AllGather => "all_gather",
            CollectiveKind::ReduceScatter => "reduce_scatter",
            CollectiveKind::AllReduce => "all_reduce",
            CollectiveKind::AllToAll => "all_to_all",
        }
    }
}

/// Which collective algorithm prices a group's communication.
///
/// `FlatRing` is the historical one-level ring: every rank is a ring peer,
/// the slowest link in the group bottlenecks every step, and cross-node
/// traffic divides each node's NIC bandwidth by the ranks sharing it.
/// `Hierarchical` decomposes a multi-node group into three phases — an
/// intra-node collective over the fast tier, a leaders-only exchange over
/// Ethernet (one rank per node talks, so the NIC is never shared), and an
/// intra-node broadcast/scatter of the result. On a single-node group the
/// two are identical by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveAlgo {
    /// One-level ring over the whole group (NCCL default on flat fabrics).
    FlatRing,
    /// Two-level: intra-node phase, inter-node leader exchange, intra-node
    /// redistribution.
    Hierarchical,
}

impl CollectiveAlgo {
    /// Parse a CLI/user spelling: `flat` / `flat-ring` or `hier` /
    /// `hierarchical`.
    pub fn parse(s: &str) -> Result<CollectiveAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "flat" | "flat-ring" | "flatring" | "ring" => Ok(CollectiveAlgo::FlatRing),
            "hier" | "hierarchical" => Ok(CollectiveAlgo::Hierarchical),
            other => Err(Error::config(format!(
                "unknown collective algorithm '{other}' (flat|hier)"
            ))),
        }
    }

    /// Stable short key used in plan-cache fingerprints and JSON output.
    pub fn key(self) -> &'static str {
        match self {
            CollectiveAlgo::FlatRing => "flat",
            CollectiveAlgo::Hierarchical => "hier",
        }
    }

    /// Human label for `route`/`timeline` output and "why" strings.
    pub fn label(self) -> &'static str {
        match self {
            CollectiveAlgo::FlatRing => "flat ring",
            CollectiveAlgo::Hierarchical => "hierarchical",
        }
    }
}

/// One homogeneous simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub gpu: GpuSpec,
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    /// GPUs per PCIe root complex (QPI boundary); == gpus_per_node when the
    /// node has a single switch (NVLink systems).
    pub gpus_per_numa: usize,
    /// Unidirectional bandwidth in bytes/s per link kind — the intra-node
    /// tier. The `Ethernet` arm is superseded by `inter_node` (kept so the
    /// function stays total).
    pub bw: fn(LinkKind) -> f64,
    /// Per-message latency in seconds per link kind (intra-node tier; the
    /// `Ethernet` arm is superseded by `inter_node`).
    pub lat: fn(LinkKind) -> f64,
    pub has_nvlink: bool,
    /// The inter-node tier: bandwidth/latency of every cross-node hop.
    pub inter_node: InterNodeLink,
}

impl ClusterSpec {
    pub fn node_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_node
    }

    pub fn numa_of(&self, dev: usize) -> usize {
        dev / self.gpus_per_numa
    }

    /// Number of nodes in the cluster (the outer tier's extent).
    pub fn n_nodes(&self) -> usize {
        self.n_gpus.div_ceil(self.gpus_per_node)
    }

    /// Replace the inter-node tier (e.g. model a faster RoCE fabric).
    pub fn with_inter_node(mut self, inter_node: InterNodeLink) -> ClusterSpec {
        self.inter_node = inter_node;
        self
    }

    /// Tier-aware bandwidth for a link kind: cross-node hops are priced by
    /// the `inter_node` tier, everything else by the intra-node link model.
    pub fn link_bw(&self, k: LinkKind) -> f64 {
        match k {
            LinkKind::Ethernet => self.inter_node.bw,
            _ => (self.bw)(k),
        }
    }

    /// Tier-aware per-message latency for a link kind (see
    /// [`link_bw`](ClusterSpec::link_bw)).
    pub fn link_lat(&self, k: LinkKind) -> f64 {
        match k {
            LinkKind::Ethernet => self.inter_node.lat,
            _ => (self.lat)(k),
        }
    }

    /// Link class between two devices.
    pub fn link(&self, a: usize, b: usize) -> LinkKind {
        if self.node_of(a) != self.node_of(b) {
            LinkKind::Ethernet
        } else if self.has_nvlink {
            LinkKind::NvLink
        } else if self.numa_of(a) != self.numa_of(b) {
            LinkKind::PcieQpi
        } else {
            LinkKind::Pcie
        }
    }

    /// Time to move `bytes` point-to-point between devices a and b.
    pub fn p2p_time(&self, a: usize, b: usize, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        let k = self.link(a, b);
        self.link_lat(k) + bytes / self.link_bw(k)
    }

    /// The slowest link class inside a device group (collectives are
    /// bottlenecked by it).
    pub fn worst_link(&self, group: &[usize]) -> LinkKind {
        let mut worst = LinkKind::NvLink;
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                let k = self.link(a, b);
                if link_rank(k) > link_rank(worst) {
                    worst = k;
                }
            }
        }
        worst
    }

    /// Ring-based collective time for `bytes` per rank over `group`,
    /// with the NCCL algorithm-bandwidth factor `algbw_factor(n)` applied
    /// (2(n-1)/n for AllReduce, (n-1)/n for AllGather/ReduceScatter).
    ///
    /// When the group spans nodes, every rank's cross-node traffic funnels
    /// through its node's single NIC, dividing the effective per-rank
    /// Ethernet bandwidth — this is what collapses collective-heavy methods
    /// from 8 to 16 GPUs in the paper's §5.2.1.
    pub fn collective_time(&self, group: &[usize], bytes: f64, algbw_factor: f64) -> f64 {
        let n = group.len();
        if n <= 1 {
            return 0.0;
        }
        let k = self.worst_link(group);
        let mut bw = self.link_bw(k);
        if k == LinkKind::Ethernet {
            // ranks per node sharing the NIC
            let mut per_node = std::collections::BTreeMap::new();
            for &d in group {
                *per_node.entry(self.node_of(d)).or_insert(0usize) += 1;
            }
            let sharing = per_node.values().copied().max().unwrap_or(1) as f64;
            bw /= sharing;
        }
        let steps = (n - 1) as f64;
        self.link_lat(k) * steps + bytes * algbw_factor / bw
    }

    /// Algorithm-aware collective time for `bytes` per rank over `group`.
    ///
    /// [`CollectiveAlgo::FlatRing`] delegates to
    /// [`collective_time`](ClusterSpec::collective_time) with the kind's
    /// ring factor — byte-exact with the historical call sites.
    /// [`CollectiveAlgo::Hierarchical`] decomposes a multi-node group into
    /// three phases:
    ///
    /// 1. **intra-node** collective over the fast tier, in parallel across
    ///    nodes (the slowest node bounds the phase);
    /// 2. **inter-node leader exchange** over Ethernet — one rank per node
    ///    talks, so the NIC-sharing division of the flat ring never
    ///    applies, and only node-aggregated payloads cross the wire;
    /// 3. **intra-node** broadcast/scatter of the remote results.
    ///
    /// The reduction collectives sum the phases (each depends on the
    /// previous one's full result); the all-to-all streams independent
    /// per-destination chunks through all three tiers at once, so it pays
    /// the slowest tier's byte rate plus one pipeline fill/drain.
    ///
    /// A group confined to one node degenerates to the flat ring exactly
    /// (same code path), and a group with one rank per node degenerates to
    /// a leaders-only ring that prices identically to flat.
    pub fn collective_cost(
        &self,
        group: &[usize],
        bytes: f64,
        kind: CollectiveKind,
        algo: CollectiveAlgo,
    ) -> f64 {
        let n = group.len();
        if n <= 1 {
            return 0.0;
        }
        let flat = self.collective_time(group, bytes, kind.flat_factor(n));
        if algo == CollectiveAlgo::FlatRing {
            return flat;
        }
        let mut per_node: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for &d in group {
            per_node.entry(self.node_of(d)).or_default().push(d);
        }
        let n_nodes = per_node.len();
        if n_nodes <= 1 {
            // single-node group: hierarchy has nothing to exploit
            return flat;
        }
        let nf = n as f64;
        let nodes = n_nodes as f64;
        let ether_steps = nodes - 1.0;
        let ether_lat = self.inter_node.lat * ether_steps;
        let ether_bw = self.inter_node.bw;
        // phase-time helpers: max over nodes of an intra-node collective
        let intra_max = |f: &dyn Fn(&[usize], f64) -> f64| {
            per_node.values().map(|sub| f(sub, sub.len() as f64)).fold(0.0f64, f64::max)
        };
        match kind {
            CollectiveKind::AllGather => {
                // 1. per-node all-gather of the local parts
                let gather = intra_max(&|sub, g| self.collective_time(sub, bytes, g - 1.0));
                // 2. leaders ring-allgather the node aggregates: the
                //    busiest leader receives every remote part
                let inbound = per_node
                    .values()
                    .map(|sub| (nf - sub.len() as f64) * bytes)
                    .fold(0.0f64, f64::max);
                let leaders = ether_lat + inbound / ether_bw;
                // 3. each leader pipelines the remote parts to its peers
                let bcast = intra_max(&|sub, g| {
                    self.collective_time(sub, (nf - g) * bytes, 1.0)
                });
                gather + leaders + bcast
            }
            CollectiveKind::ReduceScatter => {
                let reduce = intra_max(&|sub, g| {
                    self.collective_time(sub, bytes, (g - 1.0) / g)
                });
                let leaders = ether_lat + bytes * ether_steps / nodes / ether_bw;
                let scatter = intra_max(&|sub, g| {
                    self.collective_time(sub, bytes / g.max(1.0), 1.0)
                });
                reduce + leaders + scatter
            }
            CollectiveKind::AllReduce => {
                // reduce-scatter in the node, allreduce across leaders,
                // all-gather back out — the classic two-level allreduce
                let reduce = intra_max(&|sub, g| {
                    self.collective_time(sub, bytes, (g - 1.0) / g)
                });
                let leaders = ether_lat + bytes * 2.0 * ether_steps / nodes / ether_bw;
                let gather = intra_max(&|sub, g| {
                    self.collective_time(sub, bytes, (g - 1.0) / g)
                });
                reduce + leaders + gather
            }
            CollectiveKind::AllToAll => {
                // Unlike the reduction collectives, the three phases are
                // not dependent stages: per-destination chunks stream, so
                // ranks funnel remote-bound data to their leader over the
                // fast tier WHILE the leaders exchange node aggregates
                // over Ethernet and inbound chunks scatter to local
                // ranks. In steady state the slowest tier's byte rate
                // governs; the intra-node hop chains and the leader hop
                // only fill and drain the pipe once. A node of g ranks
                // exchanges g*b*(n-g)/(n-1) bytes with its peers over the
                // wire (leader-only: no NIC sharing).
                let intra_lat = |sub: &[usize]| {
                    if sub.len() <= 1 {
                        0.0
                    } else {
                        self.link_lat(self.worst_link(sub)) * (sub.len() as f64 - 1.0)
                    }
                };
                let intra_stream = |sub: &[usize], vol: f64| {
                    if sub.len() <= 1 {
                        0.0
                    } else {
                        vol / self.link_bw(self.worst_link(sub))
                    }
                };
                // pipe fill (send-side funnel) + drain (receive-side scatter)
                let fill = per_node.values().map(|sub| intra_lat(sub)).fold(0.0f64, f64::max);
                // steady-state byte time of each tier: local exchange +
                // funnel moves each rank's full payload once ...
                let funnel =
                    per_node.values().map(|sub| intra_stream(sub, bytes)).fold(0.0f64, f64::max);
                // ... the busiest leader streams its node's remote-bound
                // aggregate outward ...
                let outbound = per_node
                    .values()
                    .map(|sub| {
                        let g = sub.len() as f64;
                        g * bytes * (nf - g) / (nf - 1.0)
                    })
                    .fold(0.0f64, f64::max);
                let wire = outbound / ether_bw;
                // ... and the inbound remote aggregate scatters locally
                let scatter = per_node
                    .values()
                    .map(|sub| {
                        let g = sub.len() as f64;
                        intra_stream(sub, g * bytes * (nf - g) / (nf - 1.0))
                    })
                    .fold(0.0f64, f64::max);
                ether_lat + 2.0 * fill + funnel.max(wire).max(scatter)
            }
        }
    }

    /// Carve the cluster into `replicas` equal, topology-aligned slices and
    /// return one slice (they are all identical — the cluster is
    /// homogeneous). A replica either owns whole nodes or divides one node
    /// evenly, so a slice never straddles a node boundary asymmetrically.
    /// `carve(1)` returns the spec unchanged (same name), which is what
    /// makes single-replica fleet serving bit-identical to `serve_trace`.
    pub fn carve(&self, replicas: usize) -> Result<ClusterSpec> {
        if replicas == 0 {
            return Err(Error::config("cannot carve a cluster into 0 replicas"));
        }
        if replicas == 1 {
            return Ok(self.clone());
        }
        if self.n_gpus % replicas != 0 {
            return Err(Error::config(format!(
                "cannot carve {} GPUs of '{}' into {replicas} equal replicas",
                self.n_gpus, self.name
            )));
        }
        let per = self.n_gpus / replicas;
        let aligned = if per >= self.gpus_per_node {
            per % self.gpus_per_node == 0
        } else {
            self.gpus_per_node % per == 0
        };
        if !aligned {
            return Err(Error::config(format!(
                "replica size {per} does not align with '{}' nodes of {} GPUs",
                self.name, self.gpus_per_node
            )));
        }
        let mut slice = self.clone();
        slice.name = format!("{}/r{replicas}", self.name);
        slice.n_gpus = per;
        slice.gpus_per_node = self.gpus_per_node.min(per);
        slice.gpus_per_numa = self.gpus_per_numa.min(per);
        Ok(slice)
    }

    /// Parse a cluster name: the paper's testbeds (`l40x8`, `l40x16`,
    /// `a100x8`) plus the generic two-tier families `l40xN` / `a100xN` for
    /// any N that is a multiple of 8 (N/8 Ethernet-connected nodes).
    pub fn by_name(name: &str) -> Result<ClusterSpec> {
        let parse_nodes = |n: &str| -> Option<usize> {
            let gpus: usize = n.parse().ok()?;
            if gpus > 0 && gpus % 8 == 0 {
                Some(gpus / 8)
            } else {
                None
            }
        };
        if let Some(n) = name.strip_prefix("l40x").and_then(parse_nodes) {
            return Ok(l40_cluster(n));
        }
        if let Some(n) = name.strip_prefix("a100x").and_then(parse_nodes) {
            return Ok(a100_cluster(n));
        }
        Err(Error::config(format!(
            "unknown cluster '{name}' (l40xN or a100xN, N a multiple of 8)"
        )))
    }
}

fn link_rank(k: LinkKind) -> u8 {
    match k {
        LinkKind::NvLink => 0,
        LinkKind::Pcie => 1,
        LinkKind::PcieQpi => 2,
        LinkKind::Ethernet => 3,
    }
}

fn l40_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!("L40 nodes have no NVLink"),
        LinkKind::Pcie => 24e9,     // PCIe Gen4 x16 ~ 24 GB/s effective
        LinkKind::PcieQpi => 12e9,  // QPI-crossing penalty (paper §4.1.4)
        LinkKind::Ethernet => 10e9, // 100 Gbps ~ 10 GB/s effective (RoCE-less)
    }
}

fn l40_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => unreachable!(),
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

fn a100_bw(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 250e9, // 600 GB/s bidir marketing ~ 250 GB/s algo
        LinkKind::Pcie => 24e9,
        LinkKind::PcieQpi => 12e9,
        LinkKind::Ethernet => 10e9,
    }
}

fn a100_lat(k: LinkKind) -> f64 {
    match k {
        LinkKind::NvLink => 3e-6,
        LinkKind::Pcie => 8e-6,
        LinkKind::PcieQpi => 12e-6,
        LinkKind::Ethernet => 50e-6,
    }
}

/// `n_nodes` nodes of 8×L40 (PCIe Gen4, two NUMA domains of 4), 100 Gbps
/// Ethernet between nodes.
pub fn l40_cluster(n_nodes: usize) -> ClusterSpec {
    ClusterSpec {
        name: format!("l40x{}", 8 * n_nodes),
        gpu: GpuSpec { name: "L40-48GB".into(), tflops: 90.0, mem_bytes: 48e9 },
        n_gpus: 8 * n_nodes,
        gpus_per_node: 8,
        gpus_per_numa: 4,
        bw: l40_bw,
        lat: l40_lat,
        has_nvlink: false,
        inter_node: InterNodeLink::default(),
    }
}

/// One node of 8×A100-80GB with NVLink.
pub fn a100_node() -> ClusterSpec {
    a100_cluster(1)
}

/// `n_nodes` nodes of 8×A100-80GB — NVLink inside each node, 100 Gbps
/// Ethernet between nodes: the genuinely two-tier testbed (a 250 GB/s to
/// 10 GB/s cliff at every node boundary).
pub fn a100_cluster(n_nodes: usize) -> ClusterSpec {
    ClusterSpec {
        name: format!("a100x{}", 8 * n_nodes),
        gpu: GpuSpec { name: "A100-80GB".into(), tflops: 250.0, mem_bytes: 80e9 },
        n_gpus: 8 * n_nodes,
        gpus_per_node: 8,
        gpus_per_numa: 8,
        bw: a100_bw,
        lat: a100_lat,
        has_nvlink: true,
        inter_node: InterNodeLink::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l40_topology() {
        let c = l40_cluster(2);
        assert_eq!(c.n_gpus, 16);
        assert_eq!(c.link(0, 1), LinkKind::Pcie);
        assert_eq!(c.link(0, 5), LinkKind::PcieQpi);
        assert_eq!(c.link(0, 8), LinkKind::Ethernet);
        assert_eq!(c.link(9, 15), LinkKind::PcieQpi);
    }

    #[test]
    fn a100_topology() {
        let c = a100_node();
        assert_eq!(c.link(0, 7), LinkKind::NvLink);
    }

    #[test]
    fn p2p_monotone_in_bytes() {
        let c = l40_cluster(1);
        assert!(c.p2p_time(0, 1, 2e6) > c.p2p_time(0, 1, 1e6));
        assert_eq!(c.p2p_time(3, 3, 1e9), 0.0);
    }

    #[test]
    fn worst_link_dominates_collective() {
        let c = l40_cluster(2);
        let intra = c.collective_time(&[0, 1, 2, 3], 1e6, 1.0);
        let cross = c.collective_time(&[0, 1, 8, 9], 1e6, 1.0);
        assert!(cross > intra);
    }

    #[test]
    fn nvlink_much_faster_than_ethernet() {
        let a = a100_node();
        let l = l40_cluster(2);
        let b = 100e6;
        assert!(a.p2p_time(0, 1, b) * 10.0 < l.p2p_time(0, 8, b));
    }

    #[test]
    fn default_inter_node_matches_the_single_tier_constants() {
        // the tier split must be a pure refactor for the stock specs:
        // cross-node pricing through `inter_node` equals what the old
        // single-tier link models charged
        for c in [l40_cluster(2), a100_cluster(2)] {
            assert_eq!(c.link_bw(LinkKind::Ethernet), (c.bw)(LinkKind::Ethernet));
            assert_eq!(c.link_lat(LinkKind::Ethernet), (c.lat)(LinkKind::Ethernet));
        }
        // and a mutated tier actually reprices cross-node hops
        let fast = l40_cluster(2).with_inter_node(InterNodeLink { bw: 50e9, lat: 5e-6 });
        assert!(fast.p2p_time(0, 8, 100e6) < l40_cluster(2).p2p_time(0, 8, 100e6));
        // ...while intra-node hops are untouched
        assert_eq!(fast.p2p_time(0, 1, 100e6), l40_cluster(2).p2p_time(0, 1, 100e6));
    }

    #[test]
    fn n_nodes_counts_the_outer_tier() {
        assert_eq!(l40_cluster(1).n_nodes(), 1);
        assert_eq!(l40_cluster(2).n_nodes(), 2);
        assert_eq!(a100_cluster(4).n_nodes(), 4);
    }

    #[test]
    fn carve_whole_nodes() {
        let c = l40_cluster(2);
        let r = c.carve(2).unwrap();
        assert_eq!(r.n_gpus, 8);
        assert_eq!(r.gpus_per_node, 8);
        assert_eq!(r.gpus_per_numa, 4);
        assert_eq!(r.n_nodes(), 1);
        assert_eq!(r.name, "l40x16/r2");
        // a whole-node replica prices links exactly like the matching
        // single-node spec
        let solo = l40_cluster(1);
        assert_eq!(r.link(0, 1), solo.link(0, 1));
        assert_eq!(r.link(0, 5), solo.link(0, 5));
        assert_eq!(
            r.collective_time(&[0, 1, 4, 5], 1e6, 1.0),
            solo.collective_time(&[0, 1, 4, 5], 1e6, 1.0)
        );
    }

    #[test]
    fn carve_within_a_node() {
        let c = l40_cluster(2);
        let r = c.carve(4).unwrap();
        assert_eq!(r.n_gpus, 4);
        assert_eq!(r.gpus_per_node, 4);
        assert_eq!(r.gpus_per_numa, 4);
        // all four devices share one NUMA domain: pure PCIe
        assert_eq!(r.worst_link(&[0, 1, 2, 3]), LinkKind::Pcie);
    }

    #[test]
    fn carve_one_is_identity() {
        let c = l40_cluster(2);
        let r = c.carve(1).unwrap();
        assert_eq!(r.name, c.name);
        assert_eq!(r.n_gpus, c.n_gpus);
        assert_eq!(r.gpus_per_node, c.gpus_per_node);
    }

    #[test]
    fn carve_rejects_misaligned_splits() {
        assert!(l40_cluster(2).carve(0).is_err());
        // 16 % 3 != 0
        assert!(l40_cluster(2).carve(3).is_err());
        // per = 16/2 = 8 aligns; per = 24/3 = 8 aligns; but a 12-GPU slice
        // of 8-GPU nodes would straddle a node boundary
        assert!(l40_cluster(3).carve(2).is_err());
    }

    #[test]
    fn single_node_hierarchical_is_byte_exact_with_flat() {
        // hierarchy has nothing to exploit inside one node: the two algos
        // must price identically (same code path, not merely close)
        let kinds = [
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllReduce,
            CollectiveKind::AllToAll,
        ];
        for c in [l40_cluster(1), a100_node(), l40_cluster(2)] {
            let group: Vec<usize> = (0..8).collect(); // first node only
            for kind in kinds {
                for bytes in [1e3, 1e6, 1e9] {
                    let flat = c.collective_cost(&group, bytes, kind, CollectiveAlgo::FlatRing);
                    let hier =
                        c.collective_cost(&group, bytes, kind, CollectiveAlgo::Hierarchical);
                    assert_eq!(flat.to_bits(), hier.to_bits(), "{kind:?} bytes={bytes}");
                }
            }
        }
    }

    #[test]
    fn flat_ring_matches_the_historical_factors() {
        // CollectiveKind::flat_factor must reproduce what call sites have
        // always passed to collective_time directly
        let c = l40_cluster(2);
        let group: Vec<usize> = (0..16).collect();
        let n = group.len() as f64;
        let b = 4e6;
        assert_eq!(
            c.collective_cost(&group, b, CollectiveKind::AllReduce, CollectiveAlgo::FlatRing),
            c.collective_time(&group, b, 2.0 * (n - 1.0) / n)
        );
        assert_eq!(
            c.collective_cost(&group, b, CollectiveKind::AllGather, CollectiveAlgo::FlatRing),
            c.collective_time(&group, b, (n - 1.0) / n * n)
        );
        assert_eq!(
            c.collective_cost(&group, b, CollectiveKind::AllToAll, CollectiveAlgo::FlatRing),
            c.collective_time(&group, b, 1.0)
        );
    }

    #[test]
    fn hierarchical_never_worse_when_ethernet_is_the_slow_tier() {
        // on both stock multi-node testbeds the inter-node tier is far
        // slower than any intra-node link, so the leader exchange always
        // beats funneling NIC-shared ring traffic
        let kinds = [
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllReduce,
            CollectiveKind::AllToAll,
        ];
        for c in [l40_cluster(2), a100_cluster(2), l40_cluster(4), a100_cluster(4)] {
            let group: Vec<usize> = (0..c.n_gpus).collect();
            for kind in kinds {
                for bytes in [1e3, 1e6, 64e6, 1e9] {
                    let flat = c.collective_cost(&group, bytes, kind, CollectiveAlgo::FlatRing);
                    let hier =
                        c.collective_cost(&group, bytes, kind, CollectiveAlgo::Hierarchical);
                    assert!(
                        hier <= flat,
                        "{} {kind:?} bytes={bytes}: hier {hier} > flat {flat}",
                        c.name
                    );
                }
            }
        }
    }

    #[test]
    fn one_rank_per_node_degenerates_to_a_leader_ring() {
        // with one rank per node the "hierarchy" IS the flat ring (no NIC
        // sharing either way): the decomposition must not invent savings
        let c = l40_cluster(4);
        let group = [0usize, 8, 16, 24];
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllReduce] {
            let flat = c.collective_cost(&group, 8e6, kind, CollectiveAlgo::FlatRing);
            let hier = c.collective_cost(&group, 8e6, kind, CollectiveAlgo::Hierarchical);
            let rel = (flat - hier).abs() / flat;
            assert!(rel < 1e-9, "{kind:?}: flat {flat} vs hier {hier}");
        }
    }

    #[test]
    fn collective_algo_parse_and_keys() {
        assert_eq!(CollectiveAlgo::parse("flat").unwrap(), CollectiveAlgo::FlatRing);
        assert_eq!(CollectiveAlgo::parse("ring").unwrap(), CollectiveAlgo::FlatRing);
        assert_eq!(CollectiveAlgo::parse("hier").unwrap(), CollectiveAlgo::Hierarchical);
        assert_eq!(CollectiveAlgo::parse("Hierarchical").unwrap(), CollectiveAlgo::Hierarchical);
        assert!(CollectiveAlgo::parse("auto").is_err());
        assert_eq!(CollectiveAlgo::FlatRing.key(), "flat");
        assert_eq!(CollectiveAlgo::Hierarchical.key(), "hier");
        assert_eq!(CollectiveKind::AllReduce.label(), "all_reduce");
    }

    #[test]
    fn by_name_parses_the_generic_families() {
        assert_eq!(ClusterSpec::by_name("l40x8").unwrap().n_gpus, 8);
        assert_eq!(ClusterSpec::by_name("l40x32").unwrap().n_nodes(), 4);
        let a = ClusterSpec::by_name("a100x16").unwrap();
        assert_eq!(a.n_nodes(), 2);
        assert!(a.has_nvlink);
        assert_eq!(a.link(0, 8), LinkKind::Ethernet);
        assert!(ClusterSpec::by_name("l40x12").is_err());
        assert!(ClusterSpec::by_name("h100x8").is_err());
    }
}
