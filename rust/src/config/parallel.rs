//! The hybrid parallel configuration: `cfg × pipefusion × ulysses × ring`
//! (paper §4.1.4), with validation of the paper's divisibility constraints
//! (heads % ulysses, sequence % shards, layers % pipefusion, CFG usability).

use crate::config::model::{BlockVariant, ModelSpec};
use crate::{Error, Result};

/// Degrees of each parallel dimension. The world size is their product.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelConfig {
    /// CFG (inter-image) parallel degree: 1 or 2.
    pub cfg: usize,
    /// PipeFusion (patch-level pipeline) degree.
    pub pipefusion: usize,
    /// SP-Ulysses degree.
    pub ulysses: usize,
    /// SP-Ring degree.
    pub ring: usize,
    /// PipeFusion patch count M (>= pipefusion when pipefusion > 1).
    pub patches: usize,
    /// Synchronous warmup diffusion steps before pipelining (paper: 1).
    pub warmup_steps: usize,
}

impl ParallelConfig {
    pub fn serial() -> Self {
        ParallelConfig { cfg: 1, pipefusion: 1, ulysses: 1, ring: 1, patches: 1, warmup_steps: 0 }
    }

    pub fn new(cfg: usize, pipefusion: usize, ulysses: usize, ring: usize) -> Self {
        let patches = if pipefusion > 1 { pipefusion } else { 1 };
        ParallelConfig { cfg, pipefusion, ulysses, ring, patches, warmup_steps: 1 }
    }

    pub fn with_patches(mut self, m: usize) -> Self {
        self.patches = m;
        self
    }

    pub fn world(&self) -> usize {
        self.cfg * self.pipefusion * self.ulysses * self.ring
    }

    pub fn sp_degree(&self) -> usize {
        self.ulysses * self.ring
    }

    /// Total sequence shards per image: patches × sp (each patch is further
    /// split across the SP group — paper Fig 7).
    pub fn seq_shards(&self) -> usize {
        self.patches * self.sp_degree()
    }

    pub fn is_serial(&self) -> bool {
        self.world() == 1
    }

    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.cfg > 1 {
            parts.push(format!("cfg={}", self.cfg));
        }
        if self.pipefusion > 1 {
            parts.push(format!("pipefusion={}(M={})", self.pipefusion, self.patches));
        }
        if self.ulysses > 1 {
            parts.push(format!("ulysses={}", self.ulysses));
        }
        if self.ring > 1 {
            parts.push(format!("ring={}", self.ring));
        }
        if parts.is_empty() {
            "serial".into()
        } else {
            parts.join(",")
        }
    }

    /// Validate against a model + sequence length (paper constraints):
    /// * `cfg ∈ {1,2}`, and 2 only when the model uses CFG;
    /// * heads divisible by ulysses (SP-Ulysses head partitioning);
    /// * layers divisible by pipefusion;
    /// * image sequence divisible by patches × sp;
    /// * text sequence divisible by sp for in-context models (Fig 3);
    /// * PipeFusion needs M >= pipefusion.
    pub fn validate(&self, model: &ModelSpec, s_img: usize) -> Result<()> {
        if self.cfg > 2 || self.cfg == 0 {
            return Err(Error::config(format!("cfg degree must be 1 or 2, got {}", self.cfg)));
        }
        if self.cfg == 2 && !model.uses_cfg {
            return Err(Error::config(format!(
                "model '{}' does not use CFG; cfg parallel not applicable",
                model.name
            )));
        }
        if [self.pipefusion, self.ulysses, self.ring, self.patches].contains(&0) {
            return Err(Error::config("parallel degrees must be >= 1"));
        }
        if model.heads % self.ulysses != 0 {
            return Err(Error::config(format!(
                "heads ({}) not divisible by ulysses degree {}",
                model.heads, self.ulysses
            )));
        }
        // The runnable tiny family needs exact stage shapes (AOT grid);
        // paper-scale analytic models tolerate uneven stages (real xDiT
        // balances them) as long as there is at least one layer per stage.
        if model.runnable && model.layers % self.pipefusion != 0 {
            return Err(Error::config(format!(
                "layers ({}) not divisible by pipefusion degree {}",
                model.layers, self.pipefusion
            )));
        }
        if self.pipefusion > model.layers {
            return Err(Error::config(format!(
                "pipefusion degree {} exceeds layer count {}",
                self.pipefusion, model.layers
            )));
        }
        if self.pipefusion > 1 && self.patches < self.pipefusion {
            return Err(Error::config(format!(
                "patches (M={}) must be >= pipefusion degree {}",
                self.patches, self.pipefusion
            )));
        }
        if self.pipefusion > 1 && model.variant == BlockVariant::Skip && self.pipefusion > 2 {
            return Err(Error::config(
                "skip-connection models support pipefusion degree <= 2 \
                 (enc/dec stage split)",
            ));
        }
        let shards = self.seq_shards();
        if s_img % shards != 0 {
            return Err(Error::config(format!(
                "image sequence {s_img} not divisible by patches*sp = {shards}"
            )));
        }
        if model.variant.in_context_text() && model.s_txt % self.sp_degree() != 0 {
            return Err(Error::config(format!(
                "text sequence {} not divisible by sp degree {} (in-context split)",
                model.s_txt,
                self.sp_degree()
            )));
        }
        // SP-Ring needs at least 1 KV block per rank.
        if self.ring > 1 && s_img / shards == 0 {
            return Err(Error::config("ring degree too large for sequence"));
        }
        Ok(())
    }

    /// Enumerate all valid configs for a world size (used by the router and
    /// the hybrid-sweep figures).
    pub fn enumerate(world: usize, model: &ModelSpec, s_img: usize) -> Vec<ParallelConfig> {
        let mut out = Vec::new();
        for cfg in [1, 2] {
            if world % cfg != 0 {
                continue;
            }
            let rest = world / cfg;
            for pf in divisors(rest) {
                let rest2 = rest / pf;
                for ul in divisors(rest2) {
                    let ring = rest2 / ul;
                    // try a few patch counts for pipefusion
                    let m_opts: &[usize] = if pf > 1 { &[0, 2] } else { &[0] };
                    for &mul in m_opts {
                        let mut c = ParallelConfig::new(cfg, pf, ul, ring);
                        if mul > 0 {
                            c = c.with_patches(pf * mul);
                        }
                        if c.validate(model, s_img).is_ok() && !out.contains(&c) {
                            out.push(c);
                        }
                    }
                }
            }
        }
        out
    }
}

fn divisors(n: usize) -> Vec<usize> {
    (1..=n).filter(|d| n % d == 0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::ModelSpec;

    fn tiny() -> ModelSpec {
        ModelSpec::by_name("tiny-mmdit").unwrap()
    }

    #[test]
    fn world_product() {
        let c = ParallelConfig::new(2, 2, 2, 1);
        assert_eq!(c.world(), 8);
        assert_eq!(c.sp_degree(), 2);
        assert_eq!(c.seq_shards(), 4);
    }

    #[test]
    fn validate_divisibility() {
        let m = tiny(); // heads=6, layers=8, s_txt=32
        assert!(ParallelConfig::new(1, 1, 2, 1).validate(&m, 256).is_ok());
        assert!(ParallelConfig::new(1, 1, 4, 1).validate(&m, 256).is_err()); // 6 % 4
        assert!(ParallelConfig::new(1, 1, 3, 1).validate(&m, 256).is_err()); // 256 % 3
        assert!(ParallelConfig::new(1, 3, 1, 1).validate(&m, 256).is_err()); // 8 % 3
        assert!(ParallelConfig::new(1, 2, 1, 1).validate(&m, 256).is_ok());
    }

    #[test]
    fn cfg_rules() {
        let mut m = tiny();
        assert!(ParallelConfig::new(2, 1, 1, 1).validate(&m, 256).is_ok());
        m.uses_cfg = false; // Flux-like
        assert!(ParallelConfig::new(2, 1, 1, 1).validate(&m, 256).is_err());
    }

    #[test]
    fn paper_constraints_sd3_ulysses16() {
        // Paper §5.2.1: SP-Ulysses degree 16 impossible on SD3 (24 heads).
        let sd3 = ModelSpec::by_name("sd3").unwrap();
        let c = ParallelConfig::new(1, 1, 16, 1);
        assert!(c.validate(&sd3, sd3.seq_len(1024)).is_err());
        let c8 = ParallelConfig::new(2, 1, 8, 1);
        assert!(c8.validate(&sd3, sd3.seq_len(1024)).is_ok());
    }

    #[test]
    fn paper_constraints_cogvideo_ulysses4() {
        // Paper §5.2.1: heads=30 forbids ulysses=4 on CogVideoX.
        let m = ModelSpec::by_name("cogvideox").unwrap();
        assert!(ParallelConfig::new(1, 1, 4, 1).validate(&m, 17550).is_err());
        assert!(ParallelConfig::new(1, 1, 2, 1).validate(&m, 17550).is_ok());
    }

    #[test]
    fn skip_model_pipe_limit() {
        let m = ModelSpec::by_name("tiny-skip").unwrap();
        assert!(ParallelConfig::new(1, 2, 1, 1).validate(&m, 256).is_ok());
        assert!(ParallelConfig::new(1, 4, 1, 1).validate(&m, 256).is_err());
    }

    #[test]
    fn enumerate_yields_valid_unique() {
        let m = tiny();
        let all = ParallelConfig::enumerate(8, &m, 256);
        assert!(!all.is_empty());
        for c in &all {
            assert_eq!(c.world(), 8);
            c.validate(&m, 256).unwrap();
        }
        // contains the paper's favourite: cfg=2 x pipefusion=4
        assert!(all.iter().any(|c| c.cfg == 2 && c.pipefusion == 4));
    }

    #[test]
    fn patches_at_least_pipe() {
        let m = tiny();
        let c = ParallelConfig::new(1, 4, 1, 1).with_patches(2);
        assert!(c.validate(&m, 256).is_err());
    }
}
