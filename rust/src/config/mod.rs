//! Configuration system: model specs (the paper's five DiTs + the runnable
//! tiny family), hardware cluster specs (2×8×L40 PCIe/Ethernet, 8×A100
//! NVLink), and the parallel configuration `cfg × pipefusion × ulysses ×
//! ring` with the paper's divisibility constraints.

pub mod hardware;
pub mod model;
pub mod parallel;

pub use hardware::{ClusterSpec, GpuSpec, LinkKind};
pub use model::{BlockVariant, ModelSpec};
pub use parallel::ParallelConfig;
