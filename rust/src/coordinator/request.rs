//! Request/response types of the serving API.
//!
//! A `GenRequest` is the unit of work the `Pipeline` facade accepts:
//! besides prompt/steps/seed it carries the *target resolution* (`px`) and
//! an optional per-request scheduler override, so neither is hardcoded on
//! the engine path. Resolution drives the §5.2.4 routing decision and the
//! latency accounting; the runnable tiny family executes at its compiled
//! native shape as the numeric proxy (see `DESIGN.md`).

use crate::config::model::BlockVariant;
use crate::diffusion::SchedulerKind;

/// Caller-assigned request identifier (echoed in responses/rejections).
pub type RequestId = u64;

/// Service-level objective tier of a request (ROADMAP item 4).
///
/// The class drives *scheduling*, never *numerics*: it is deliberately
/// excluded from `GenRequest::batch_key` so a mixed-tier trace still
/// batches by compiled shape. Interactive work gets a priority boost and
/// a tight default deadline; batch work is preemptible and (opt-in)
/// degradable under overload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloClass {
    /// Latency-sensitive, tight deadline; may preempt batch-tier work.
    Interactive,
    /// The default tier: scheduled on priority + aging, never preempted.
    #[default]
    Standard,
    /// Throughput tier: preemptible, degradable, loosest deadline.
    Batch,
}

impl SloClass {
    /// Number of SLO classes (sizes the per-class metric arrays).
    pub const COUNT: usize = 3;

    /// All classes, in `index()` order.
    pub const ALL: [SloClass; SloClass::COUNT] =
        [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Dense index for per-class metric arrays.
    pub fn index(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    /// Priority boost folded into the batcher's urgency score. Large
    /// enough to dominate user priorities (small ints) without making
    /// batch-tier aging unable to catch up.
    pub fn priority_boost(self) -> f64 {
        match self {
            SloClass::Interactive => 1.0e3,
            SloClass::Standard => 0.0,
            SloClass::Batch => -1.0e3,
        }
    }

    /// Default completion-deadline slack (virtual seconds past arrival)
    /// applied by the trace/scenario builders when a request has no
    /// explicit deadline. Batch tier has no deadline.
    pub fn deadline_slack(self) -> Option<f64> {
        match self {
            SloClass::Interactive => Some(30.0),
            SloClass::Standard => Some(240.0),
            SloClass::Batch => None,
        }
    }

    /// Parse a CLI/scenario spelling of a class name.
    pub fn by_name(name: &str) -> Option<SloClass> {
        match name {
            "interactive" | "int" => Some(SloClass::Interactive),
            "standard" | "std" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// Stable lowercase spelling (metrics report rows, CLI echo).
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }
}

/// Default target resolution (pixels, square) — matches the tiny family's
/// native 256-token latent grid (256px / patch 16).
pub const DEFAULT_PX: usize = 256;

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Caller-assigned id, echoed in the response.
    pub id: RequestId,
    /// Text prompt (embedded by the deterministic text encoder).
    pub prompt: String,
    /// Model variant to serve (tiny family; paper-scale models are
    /// analytic-only).
    pub variant: BlockVariant,
    /// Diffusion steps to run.
    pub steps: usize,
    /// RNG seed for the initial latent.
    pub seed: u64,
    /// CFG guidance scale (1.0 or 0.0 disables the uncond branch).
    pub guidance: f32,
    /// Target resolution in pixels (square). Routed on — the parallel
    /// config is chosen for `seq_len(px)` tokens, not a hardcoded count.
    pub px: usize,
    /// Per-request scheduler; `None` uses the pipeline default, falling
    /// back to the model's benchmark scheduler.
    pub scheduler: Option<SchedulerKind>,
    /// Arrival time (seconds since engine start) for latency accounting.
    pub arrival: f64,
    /// Decode the latent to pixels with the parallel VAE.
    pub decode: bool,
    /// Scheduling priority (higher = sooner). The batcher ages waiting
    /// requests, so a low priority delays service but can never starve it.
    pub priority: i32,
    /// Optional completion deadline in virtual seconds (absolute, same
    /// clock as `arrival`). Missing it is recorded in `Metrics`, not an
    /// error — the engine still serves the request.
    pub deadline: Option<f64>,
    /// SLO tier (scheduling only — excluded from `batch_key`).
    pub slo: SloClass,
    /// Diffusion steps already credited by a preemption slice. Only the
    /// *remaining* virtual time is charged when the request finally runs;
    /// the latent itself is always produced from the original `steps`, so
    /// preemption cannot change the output bits.
    pub steps_done: usize,
    /// How many times this request has been preempted. Bounded by the
    /// engine (`MAX_PREEMPTIONS`) so batch-tier work cannot live-lock.
    pub preemptions: u32,
}

impl GenRequest {
    /// A request with serving defaults: tiny-adaln, 4 steps, guidance 3,
    /// 256px, no decode, priority 0, no deadline.
    pub fn new(id: RequestId, prompt: impl Into<String>) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            variant: BlockVariant::AdaLn,
            steps: 4,
            seed: id,
            guidance: 3.0,
            px: DEFAULT_PX,
            scheduler: None,
            arrival: 0.0,
            decode: false,
            priority: 0,
            deadline: None,
            slo: SloClass::Standard,
            steps_done: 0,
            preemptions: 0,
        }
    }

    /// Serve a different runnable model variant.
    pub fn with_variant(mut self, variant: BlockVariant) -> Self {
        self.variant = variant;
        self
    }

    /// Replace the diffusion step count.
    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Replace the latent RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Replace the CFG guidance scale.
    pub fn with_guidance(mut self, guidance: f32) -> Self {
        self.guidance = guidance;
        self
    }

    /// Target resolution in pixels (drives routing).
    pub fn with_resolution(mut self, px: usize) -> Self {
        self.px = px;
        self
    }

    /// Pin a per-request scheduler override.
    pub fn with_scheduler(mut self, scheduler: SchedulerKind) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Stamp the virtual arrival time (latency accounting).
    pub fn with_arrival(mut self, arrival: f64) -> Self {
        self.arrival = arrival;
        self
    }

    /// Decode the final latent to pixels with the parallel VAE.
    pub fn with_decode(mut self, decode: bool) -> Self {
        self.decode = decode;
        self
    }

    /// Scheduling priority (higher = sooner; aging bounds starvation).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Absolute completion deadline on the virtual clock.
    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Assign the SLO tier. If the request has no explicit deadline yet,
    /// the class default slack (relative to the *current* `arrival`) is
    /// applied — call after `with_arrival` for non-zero arrivals.
    pub fn with_slo(mut self, slo: SloClass) -> Self {
        self.slo = slo;
        if self.deadline.is_none() {
            self.deadline = slo.deadline_slack().map(|s| self.arrival + s);
        }
        self
    }

    /// Two requests can share a batch iff their compiled shapes, step
    /// counts, guidance-usage and routed resolution coincide. (Schedulers
    /// may differ within a batch — they change the update rule, not the
    /// mesh or the compiled shapes.)
    pub fn batch_key(&self) -> (BlockVariant, usize, bool, usize) {
        (
            self.variant,
            self.steps,
            self.guidance != 1.0 && self.guidance != 0.0,
            self.px,
        )
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Id of the request this response answers.
    pub id: RequestId,
    /// Final latent (and optionally decoded image).
    pub latent: crate::tensor::Tensor,
    /// Decoded image when the request asked for it.
    pub image: Option<crate::tensor::Tensor>,
    /// Simulated cluster seconds spent on the denoising loop.
    pub model_seconds: f64,
    /// End-to-end virtual latency including queueing.
    pub latency: f64,
    /// Bytes moved between simulated devices for this request.
    pub comm_bytes: usize,
    /// The hybrid parallel config the batch ran under (`describe()` form).
    pub parallel_config: String,
    /// What the routing plan's cost model predicted for this generation
    /// (seconds) — compare against `model_seconds` to see how far the
    /// analytic model and the simulated cluster agree.
    pub predicted_seconds: f64,
    /// What the discrete-event overlap simulator predicted for the
    /// batch's cell (seconds): the third column of the simulated vs
    /// closed-form vs actual comparison (`perf::simulator`).
    pub simulated_seconds: f64,
    /// Strategy that ran the denoising loop.
    pub method: String,
    /// Scheduler that produced the trajectory (request override, pipeline
    /// default, or the model's benchmark scheduler — in that order).
    pub scheduler: String,
    /// Resolution the request was routed at (echo of `GenRequest::px`).
    pub px: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_groups_compatible() {
        let a = GenRequest::new(1, "x");
        let mut b = GenRequest::new(2, "y");
        assert_eq!(a.batch_key(), b.batch_key());
        b.steps = 8;
        assert_ne!(a.batch_key(), b.batch_key());
        let mut c = GenRequest::new(3, "z");
        c.guidance = 1.0; // no CFG
        assert_ne!(a.batch_key(), c.batch_key());
        // resolution is routed on, so it splits batches too
        let d = GenRequest::new(4, "w").with_resolution(1024);
        assert_ne!(a.batch_key(), d.batch_key());
        // scheduler does not split a batch (same mesh, same shapes)
        let e = GenRequest::new(5, "v").with_scheduler(SchedulerKind::FlowMatch);
        assert_eq!(a.batch_key(), e.batch_key());
    }

    #[test]
    fn builder_helpers_set_fields() {
        let r = GenRequest::new(9, "p")
            .with_variant(BlockVariant::MmDit)
            .with_steps(6)
            .with_seed(11)
            .with_guidance(5.0)
            .with_resolution(512)
            .with_scheduler(SchedulerKind::Dpm)
            .with_arrival(2.5)
            .with_decode(true)
            .with_priority(3)
            .with_deadline(9.0);
        assert_eq!(r.variant, BlockVariant::MmDit);
        assert_eq!(r.steps, 6);
        assert_eq!(r.seed, 11);
        assert_eq!(r.guidance, 5.0);
        assert_eq!(r.px, 512);
        assert_eq!(r.scheduler, Some(SchedulerKind::Dpm));
        assert_eq!(r.arrival, 2.5);
        assert!(r.decode);
        assert_eq!(r.priority, 3);
        assert_eq!(r.deadline, Some(9.0));
    }

    #[test]
    fn priority_and_deadline_do_not_split_batches() {
        // compatibility is about compiled shapes, not urgency
        let a = GenRequest::new(1, "x");
        let b = GenRequest::new(2, "y").with_priority(9).with_deadline(1.0);
        assert_eq!(a.batch_key(), b.batch_key());
        // the SLO tier is scheduling-only: mixed tiers still co-batch
        let c = GenRequest::new(3, "z").with_slo(SloClass::Interactive);
        let d = GenRequest::new(4, "w").with_slo(SloClass::Batch);
        assert_eq!(c.batch_key(), d.batch_key());
        assert_eq!(a.batch_key(), c.batch_key());
    }

    #[test]
    fn slo_defaults_and_deadline_inheritance() {
        // default tier is Standard with no implicit deadline
        let a = GenRequest::new(1, "x");
        assert_eq!(a.slo, SloClass::Standard);
        assert_eq!(a.deadline, None);
        // with_slo applies the class slack relative to arrival ...
        let b = GenRequest::new(2, "y").with_arrival(10.0).with_slo(SloClass::Interactive);
        assert_eq!(b.deadline, Some(10.0 + 30.0));
        // ... never overrides an explicit deadline ...
        let c = GenRequest::new(3, "z").with_deadline(5.0).with_slo(SloClass::Interactive);
        assert_eq!(c.deadline, Some(5.0));
        // ... and batch tier stays deadline-free
        let d = GenRequest::new(4, "w").with_slo(SloClass::Batch);
        assert_eq!(d.deadline, None);
        // boosts are ordered and round-trip through the CLI spellings
        assert!(SloClass::Interactive.priority_boost() > SloClass::Standard.priority_boost());
        assert!(SloClass::Standard.priority_boost() > SloClass::Batch.priority_boost());
        for class in SloClass::ALL {
            assert_eq!(SloClass::by_name(class.name()), Some(class));
            assert_eq!(SloClass::ALL[class.index()], class);
        }
        assert_eq!(SloClass::by_name("gold"), None);
    }
}
