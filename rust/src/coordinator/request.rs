//! Request/response types of the serving API.

use crate::config::model::BlockVariant;

pub type RequestId = u64;

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: RequestId,
    pub prompt: String,
    /// Model variant to serve (tiny family; paper-scale models are
    /// analytic-only).
    pub variant: BlockVariant,
    pub steps: usize,
    pub seed: u64,
    pub guidance: f32,
    /// Arrival time (seconds since engine start) for latency accounting.
    pub arrival: f64,
    /// Decode the latent to pixels with the parallel VAE.
    pub decode: bool,
}

impl GenRequest {
    pub fn new(id: RequestId, prompt: impl Into<String>) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            variant: BlockVariant::AdaLn,
            steps: 4,
            seed: id,
            guidance: 3.0,
            arrival: 0.0,
            decode: false,
        }
    }

    /// Two requests can share a batch iff their compiled shapes and step
    /// counts coincide (same variant, steps, guidance-usage).
    pub fn batch_key(&self) -> (BlockVariant, usize, bool) {
        (self.variant, self.steps, self.guidance != 1.0 && self.guidance != 0.0)
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: RequestId,
    /// Final latent (and optionally decoded image).
    pub latent: crate::tensor::Tensor,
    pub image: Option<crate::tensor::Tensor>,
    /// Simulated cluster seconds spent on the denoising loop.
    pub model_seconds: f64,
    /// End-to-end virtual latency including queueing.
    pub latency: f64,
    pub parallel_config: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_groups_compatible() {
        let a = GenRequest::new(1, "x");
        let mut b = GenRequest::new(2, "y");
        assert_eq!(a.batch_key(), b.batch_key());
        b.steps = 8;
        assert_ne!(a.batch_key(), b.batch_key());
        let mut c = GenRequest::new(3, "z");
        c.guidance = 1.0; // no CFG
        assert_ne!(a.batch_key(), c.batch_key());
    }
}
