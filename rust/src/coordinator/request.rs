//! Request/response types of the serving API.
//!
//! A `GenRequest` is the unit of work the `Pipeline` facade accepts:
//! besides prompt/steps/seed it carries the *target resolution* (`px`) and
//! an optional per-request scheduler override, so neither is hardcoded on
//! the engine path. Resolution drives the §5.2.4 routing decision and the
//! latency accounting; the runnable tiny family executes at its compiled
//! native shape as the numeric proxy (see `DESIGN.md`).

use crate::config::model::BlockVariant;
use crate::diffusion::SchedulerKind;

/// Caller-assigned request identifier (echoed in responses/rejections).
pub type RequestId = u64;

/// Default target resolution (pixels, square) — matches the tiny family's
/// native 256-token latent grid (256px / patch 16).
pub const DEFAULT_PX: usize = 256;

/// One image-generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Caller-assigned id, echoed in the response.
    pub id: RequestId,
    /// Text prompt (embedded by the deterministic text encoder).
    pub prompt: String,
    /// Model variant to serve (tiny family; paper-scale models are
    /// analytic-only).
    pub variant: BlockVariant,
    /// Diffusion steps to run.
    pub steps: usize,
    /// RNG seed for the initial latent.
    pub seed: u64,
    /// CFG guidance scale (1.0 or 0.0 disables the uncond branch).
    pub guidance: f32,
    /// Target resolution in pixels (square). Routed on — the parallel
    /// config is chosen for `seq_len(px)` tokens, not a hardcoded count.
    pub px: usize,
    /// Per-request scheduler; `None` uses the pipeline default, falling
    /// back to the model's benchmark scheduler.
    pub scheduler: Option<SchedulerKind>,
    /// Arrival time (seconds since engine start) for latency accounting.
    pub arrival: f64,
    /// Decode the latent to pixels with the parallel VAE.
    pub decode: bool,
    /// Scheduling priority (higher = sooner). The batcher ages waiting
    /// requests, so a low priority delays service but can never starve it.
    pub priority: i32,
    /// Optional completion deadline in virtual seconds (absolute, same
    /// clock as `arrival`). Missing it is recorded in `Metrics`, not an
    /// error — the engine still serves the request.
    pub deadline: Option<f64>,
}

impl GenRequest {
    /// A request with serving defaults: tiny-adaln, 4 steps, guidance 3,
    /// 256px, no decode, priority 0, no deadline.
    pub fn new(id: RequestId, prompt: impl Into<String>) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            variant: BlockVariant::AdaLn,
            steps: 4,
            seed: id,
            guidance: 3.0,
            px: DEFAULT_PX,
            scheduler: None,
            arrival: 0.0,
            decode: false,
            priority: 0,
            deadline: None,
        }
    }

    /// Serve a different runnable model variant.
    pub fn with_variant(mut self, variant: BlockVariant) -> Self {
        self.variant = variant;
        self
    }

    /// Replace the diffusion step count.
    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Replace the latent RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Replace the CFG guidance scale.
    pub fn with_guidance(mut self, guidance: f32) -> Self {
        self.guidance = guidance;
        self
    }

    /// Target resolution in pixels (drives routing).
    pub fn with_resolution(mut self, px: usize) -> Self {
        self.px = px;
        self
    }

    /// Pin a per-request scheduler override.
    pub fn with_scheduler(mut self, scheduler: SchedulerKind) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Stamp the virtual arrival time (latency accounting).
    pub fn with_arrival(mut self, arrival: f64) -> Self {
        self.arrival = arrival;
        self
    }

    /// Decode the final latent to pixels with the parallel VAE.
    pub fn with_decode(mut self, decode: bool) -> Self {
        self.decode = decode;
        self
    }

    /// Scheduling priority (higher = sooner; aging bounds starvation).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Absolute completion deadline on the virtual clock.
    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Two requests can share a batch iff their compiled shapes, step
    /// counts, guidance-usage and routed resolution coincide. (Schedulers
    /// may differ within a batch — they change the update rule, not the
    /// mesh or the compiled shapes.)
    pub fn batch_key(&self) -> (BlockVariant, usize, bool, usize) {
        (
            self.variant,
            self.steps,
            self.guidance != 1.0 && self.guidance != 0.0,
            self.px,
        )
    }
}

/// The served result.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Id of the request this response answers.
    pub id: RequestId,
    /// Final latent (and optionally decoded image).
    pub latent: crate::tensor::Tensor,
    /// Decoded image when the request asked for it.
    pub image: Option<crate::tensor::Tensor>,
    /// Simulated cluster seconds spent on the denoising loop.
    pub model_seconds: f64,
    /// End-to-end virtual latency including queueing.
    pub latency: f64,
    /// Bytes moved between simulated devices for this request.
    pub comm_bytes: usize,
    /// The hybrid parallel config the batch ran under (`describe()` form).
    pub parallel_config: String,
    /// What the routing plan's cost model predicted for this generation
    /// (seconds) — compare against `model_seconds` to see how far the
    /// analytic model and the simulated cluster agree.
    pub predicted_seconds: f64,
    /// What the discrete-event overlap simulator predicted for the
    /// batch's cell (seconds): the third column of the simulated vs
    /// closed-form vs actual comparison (`perf::simulator`).
    pub simulated_seconds: f64,
    /// Strategy that ran the denoising loop.
    pub method: String,
    /// Scheduler that produced the trajectory (request override, pipeline
    /// default, or the model's benchmark scheduler — in that order).
    pub scheduler: String,
    /// Resolution the request was routed at (echo of `GenRequest::px`).
    pub px: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_groups_compatible() {
        let a = GenRequest::new(1, "x");
        let mut b = GenRequest::new(2, "y");
        assert_eq!(a.batch_key(), b.batch_key());
        b.steps = 8;
        assert_ne!(a.batch_key(), b.batch_key());
        let mut c = GenRequest::new(3, "z");
        c.guidance = 1.0; // no CFG
        assert_ne!(a.batch_key(), c.batch_key());
        // resolution is routed on, so it splits batches too
        let d = GenRequest::new(4, "w").with_resolution(1024);
        assert_ne!(a.batch_key(), d.batch_key());
        // scheduler does not split a batch (same mesh, same shapes)
        let e = GenRequest::new(5, "v").with_scheduler(SchedulerKind::FlowMatch);
        assert_eq!(a.batch_key(), e.batch_key());
    }

    #[test]
    fn builder_helpers_set_fields() {
        let r = GenRequest::new(9, "p")
            .with_variant(BlockVariant::MmDit)
            .with_steps(6)
            .with_seed(11)
            .with_guidance(5.0)
            .with_resolution(512)
            .with_scheduler(SchedulerKind::Dpm)
            .with_arrival(2.5)
            .with_decode(true)
            .with_priority(3)
            .with_deadline(9.0);
        assert_eq!(r.variant, BlockVariant::MmDit);
        assert_eq!(r.steps, 6);
        assert_eq!(r.seed, 11);
        assert_eq!(r.guidance, 5.0);
        assert_eq!(r.px, 512);
        assert_eq!(r.scheduler, Some(SchedulerKind::Dpm));
        assert_eq!(r.arrival, 2.5);
        assert!(r.decode);
        assert_eq!(r.priority, 3);
        assert_eq!(r.deadline, Some(9.0));
    }

    #[test]
    fn priority_and_deadline_do_not_split_batches() {
        // compatibility is about compiled shapes, not urgency
        let a = GenRequest::new(1, "x");
        let b = GenRequest::new(2, "y").with_priority(9).with_deadline(1.0);
        assert_eq!(a.batch_key(), b.batch_key());
    }
}
