//! The routing policy of §5.2.4: pick a hybrid parallel configuration for
//! (model, cluster, world size).
//!
//! Paper recommendation, implemented verbatim:
//! 1. prioritize CFG parallel (when the model uses CFG and world is even);
//! 2. on low-bandwidth interconnects (PCIe/Ethernet): PipeFusion first,
//!    then SP-Ring;
//! 3. on NVLink: SP-Ulysses first, then PipeFusion;
//! all subject to the divisibility constraints (`ParallelConfig::validate`).

use crate::config::hardware::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;

/// Choose the parallel config for `world` devices.
pub fn route(model: &ModelSpec, s_img: usize, cluster: &ClusterSpec, world: usize) -> ParallelConfig {
    let mut best = ParallelConfig::serial();
    if world <= 1 {
        return best;
    }
    let cfg = if model.uses_cfg && world % 2 == 0 { 2 } else { 1 };
    let mut intra = world / cfg;

    // allocate the intra-image degrees by the bandwidth-priority order
    let (mut pipe, mut ulysses, mut ring) = (1usize, 1usize, 1usize);
    let prefer_sp_first = cluster.has_nvlink;

    let try_cfg = |pipe: usize, ulysses: usize, ring: usize| -> Option<ParallelConfig> {
        let pc = ParallelConfig::new(cfg, pipe, ulysses, ring);
        pc.validate(model, s_img).ok().map(|_| pc)
    };

    // greedy: grow the preferred dimension by factors of 2 while valid
    let grow = |dim: char, pipe: &mut usize, ulysses: &mut usize, ring: &mut usize,
                    intra: &mut usize| {
        while *intra % 2 == 0 {
            let (p2, u2, r2) = match dim {
                'p' => (*pipe * 2, *ulysses, *ring),
                'u' => (*pipe, *ulysses * 2, *ring),
                _ => (*pipe, *ulysses, *ring * 2),
            };
            if try_cfg(p2, u2, r2).is_some() {
                *pipe = p2;
                *ulysses = u2;
                *ring = r2;
                *intra /= 2;
            } else {
                break;
            }
        }
    };

    if prefer_sp_first {
        grow('u', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        // skip models scale pipefusion poorly (Fig 17): cap at 2
        grow('p', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('r', &mut pipe, &mut ulysses, &mut ring, &mut intra);
    } else {
        grow('p', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('r', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('u', &mut pipe, &mut ulysses, &mut ring, &mut intra);
    }

    if let Some(pc) = try_cfg(pipe, ulysses, ring) {
        best = pc;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};

    #[test]
    fn prioritizes_cfg() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = route(&m, 256, &l40_cluster(1), 8);
        assert_eq!(pc.cfg, 2, "{}", pc.describe());
        assert_eq!(pc.world(), 8);
    }

    #[test]
    fn pcie_prefers_pipefusion() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = route(&m, 256, &l40_cluster(1), 8);
        assert!(pc.pipefusion >= pc.ulysses, "{}", pc.describe());
    }

    #[test]
    fn nvlink_prefers_ulysses() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = route(&m, 256, &a100_node(), 8);
        assert!(pc.ulysses >= pc.pipefusion, "{}", pc.describe());
    }

    #[test]
    fn no_cfg_for_flux_like() {
        let mut m = ModelSpec::by_name("tiny-mmdit").unwrap();
        m.uses_cfg = false;
        let pc = route(&m, 256, &l40_cluster(1), 8);
        assert_eq!(pc.cfg, 1);
        assert_eq!(pc.world(), 8);
    }

    #[test]
    fn always_valid_and_full_world() {
        for world in [1, 2, 4, 8] {
            for name in ["tiny-adaln", "tiny-mmdit", "tiny-cross", "tiny-skip"] {
                let m = ModelSpec::by_name(name).unwrap();
                for cluster in [l40_cluster(1), a100_node()] {
                    let pc = route(&m, 256, &cluster, world);
                    pc.validate(&m, 256).unwrap_or_else(|e| {
                        panic!("router produced invalid config for {name} w={world}: {e}")
                    });
                    assert_eq!(pc.world(), world, "{name} w={world}: {}", pc.describe());
                }
            }
        }
    }
}
