//! Routing: pick a hybrid parallel configuration for (model, resolution,
//! cluster, world size).
//!
//! [`route`] is a thin policy layer over the cost-model auto-planner
//! (`coordinator::planner`): by default every candidate config is scored
//! with the analytic latency/comm models and pruned by the memory model,
//! and the argmin wins. The paper's §5.2.4 recommendation survives as
//! [`paper_heuristic`] — the `RoutePolicy::PaperHeuristic` fallback and
//! the oracle the planner is property-tested against:
//!
//! 1. prioritize CFG parallel (when the model uses CFG and world is even);
//! 2. on low-bandwidth interconnects (PCIe/Ethernet): PipeFusion first,
//!    then SP-Ring;
//! 3. on NVLink: SP-Ulysses first, then PipeFusion;
//! all subject to the divisibility constraints (`ParallelConfig::validate`).

use crate::config::hardware::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::coordinator::planner::{Planner, RoutePolicy};

/// Choose the parallel config for `world` devices under the default
/// (cost-model) policy, for a generation at `px` resolution.
pub fn route(model: &ModelSpec, px: usize, cluster: &ClusterSpec, world: usize) -> ParallelConfig {
    route_with_policy(RoutePolicy::default(), model, px, cluster, world)
}

/// Choose the parallel config under an explicit policy.
pub fn route_with_policy(
    policy: RoutePolicy,
    model: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
) -> ParallelConfig {
    Planner::default().with_policy(policy).plan(model, px, cluster, world).config
}

/// The §5.2.4 bandwidth-priority greedy heuristic, verbatim from the
/// paper. Kept as the planner's fallback and test oracle.
pub fn paper_heuristic(
    model: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
) -> ParallelConfig {
    let s_img = model.seq_len(px);
    let mut best = ParallelConfig::serial();
    if world <= 1 {
        return best;
    }
    let cfg = if model.uses_cfg && world % 2 == 0 { 2 } else { 1 };
    let mut intra = world / cfg;

    // allocate the intra-image degrees by the bandwidth-priority order
    let (mut pipe, mut ulysses, mut ring) = (1usize, 1usize, 1usize);
    let prefer_sp_first = cluster.has_nvlink;

    let try_cfg = |pipe: usize, ulysses: usize, ring: usize| -> Option<ParallelConfig> {
        let pc = ParallelConfig::new(cfg, pipe, ulysses, ring);
        pc.validate(model, s_img).ok().map(|_| pc)
    };

    // greedy: grow the preferred dimension by factors of 2 while valid
    let grow = |dim: char, pipe: &mut usize, ulysses: &mut usize, ring: &mut usize,
                    intra: &mut usize| {
        while *intra % 2 == 0 {
            let (p2, u2, r2) = match dim {
                'p' => (*pipe * 2, *ulysses, *ring),
                'u' => (*pipe, *ulysses * 2, *ring),
                _ => (*pipe, *ulysses, *ring * 2),
            };
            if try_cfg(p2, u2, r2).is_some() {
                *pipe = p2;
                *ulysses = u2;
                *ring = r2;
                *intra /= 2;
            } else {
                break;
            }
        }
    };

    if prefer_sp_first {
        grow('u', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        // skip models scale pipefusion poorly (Fig 17): cap at 2
        grow('p', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('r', &mut pipe, &mut ulysses, &mut ring, &mut intra);
    } else {
        grow('p', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('r', &mut pipe, &mut ulysses, &mut ring, &mut intra);
        grow('u', &mut pipe, &mut ulysses, &mut ring, &mut intra);
    }

    if let Some(pc) = try_cfg(pipe, ulysses, ring) {
        best = pc;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};

    // ---- §5.2.4 heuristic oracle tests (PaperHeuristic policy) ----

    #[test]
    fn prioritizes_cfg() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = paper_heuristic(&m, 256, &l40_cluster(1), 8);
        assert_eq!(pc.cfg, 2, "{}", pc.describe());
        assert_eq!(pc.world(), 8);
    }

    #[test]
    fn pcie_prefers_pipefusion() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = paper_heuristic(&m, 256, &l40_cluster(1), 8);
        assert!(pc.pipefusion >= pc.ulysses, "{}", pc.describe());
    }

    #[test]
    fn nvlink_prefers_ulysses() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = paper_heuristic(&m, 256, &a100_node(), 8);
        assert!(pc.ulysses >= pc.pipefusion, "{}", pc.describe());
    }

    #[test]
    fn no_cfg_for_flux_like() {
        let mut m = ModelSpec::by_name("tiny-mmdit").unwrap();
        m.uses_cfg = false;
        let pc = paper_heuristic(&m, 256, &l40_cluster(1), 8);
        assert_eq!(pc.cfg, 1);
        assert_eq!(pc.world(), 8);
    }

    #[test]
    fn pcie_remainder_goes_to_ring_not_ulysses() {
        // §5.2.4 low-bandwidth order is PipeFusion *then* Ring: on a skip
        // model PipeFusion is capped at 2 (enc/dec split), so the leftover
        // intra degree must land on Ring, never on Ulysses.
        let m = ModelSpec::by_name("tiny-skip").unwrap();
        let pc = paper_heuristic(&m, 256, &l40_cluster(1), 8);
        assert_eq!(pc.cfg, 2, "{}", pc.describe());
        assert_eq!(pc.pipefusion, 2, "{}", pc.describe());
        assert_eq!(pc.ring, 2, "{}", pc.describe());
        assert_eq!(pc.ulysses, 1, "{}", pc.describe());
        assert_eq!(pc.world(), 8);
    }

    #[test]
    fn pcie_pipefusion_takes_whole_intra_when_unconstrained() {
        // adaln has 8 layers: PipeFusion can absorb the full intra degree
        // on a 16-GPU PCIe cluster (cfg=2 x pipefusion=8).
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = paper_heuristic(&m, 256, &l40_cluster(2), 16);
        assert_eq!(pc.cfg, 2, "{}", pc.describe());
        assert_eq!(pc.pipefusion, 8, "{}", pc.describe());
        assert_eq!(pc.world(), 16);
    }

    #[test]
    fn nvlink_grows_ulysses_before_pipefusion() {
        // NVLink order is Ulysses first; Ulysses stops at 2 because the
        // tiny family has 6 heads (6 % 4 != 0) and the remainder flows to
        // PipeFusion.
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        let pc = paper_heuristic(&m, 256, &a100_node(), 8);
        assert_eq!(pc.cfg, 2, "{}", pc.describe());
        assert_eq!(pc.ulysses, 2, "{}", pc.describe());
        assert_eq!(pc.pipefusion, 2, "{}", pc.describe());
        assert_eq!(pc.ring, 1, "{}", pc.describe());
    }

    #[test]
    fn cfg_degree_needs_even_world() {
        let m = ModelSpec::by_name("tiny-adaln").unwrap();
        for cluster in [l40_cluster(1), a100_node()] {
            // odd world: CFG parallelism (degree 2) cannot split it
            let odd = paper_heuristic(&m, 256, &cluster, 5);
            assert_eq!(odd.cfg, 1, "{}", odd.describe());
            odd.validate(&m, 256).unwrap();
            // the smallest even world goes entirely to the CFG branches
            let pair = paper_heuristic(&m, 256, &cluster, 2);
            assert_eq!(pair.cfg, 2, "{}", pair.describe());
            assert_eq!(pair.world(), 2);
        }
    }

    #[test]
    fn head_divisibility_caps_ulysses() {
        // 6 heads: ulysses degree can only be a divisor of 6 reached by
        // doubling, i.e. never more than 2 — on any cluster or world, and
        // under either routing policy.
        let m = ModelSpec::by_name("tiny-mmdit").unwrap();
        for world in [2usize, 4, 8] {
            for cluster in [l40_cluster(1), a100_node()] {
                for policy in [RoutePolicy::CostModel, RoutePolicy::PaperHeuristic] {
                    let pc = route_with_policy(policy, &m, 256, &cluster, world);
                    pc.validate(&m, 256).unwrap();
                    assert!(
                        pc.ulysses <= 2,
                        "w={world} {} {:?}: {}",
                        cluster.name,
                        policy,
                        pc.describe()
                    );
                    assert_eq!(pc.world(), world);
                }
            }
        }
    }

    // ---- policy-layer tests (cost model is the default) ----

    #[test]
    fn always_valid_and_full_world_under_both_policies() {
        for world in [1, 2, 4, 8] {
            for name in ["tiny-adaln", "tiny-mmdit", "tiny-cross", "tiny-skip"] {
                let m = ModelSpec::by_name(name).unwrap();
                for cluster in [l40_cluster(1), a100_node()] {
                    for policy in [RoutePolicy::CostModel, RoutePolicy::PaperHeuristic] {
                        let pc = route_with_policy(policy, &m, 256, &cluster, world);
                        pc.validate(&m, 256).unwrap_or_else(|e| {
                            panic!("{policy:?} invalid config for {name} w={world}: {e}")
                        });
                        assert_eq!(pc.world(), world, "{name} w={world}: {}", pc.describe());
                    }
                }
            }
        }
    }

    #[test]
    fn default_route_is_the_cost_model_policy() {
        let m = ModelSpec::by_name("pixart").unwrap();
        for cluster in [l40_cluster(2), a100_node()] {
            for world in [4usize, 8] {
                let defaulted = route(&m, 2048, &cluster, world);
                let explicit =
                    route_with_policy(RoutePolicy::CostModel, &m, 2048, &cluster, world);
                assert_eq!(defaulted, explicit);
            }
        }
    }
}
