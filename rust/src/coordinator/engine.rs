//! The generation engine: drains the queue in batch windows, routes each
//! batch to a hybrid parallel config (paper §5.2.4 policy), runs the
//! denoising loop on the simulated cluster, optionally decodes with the
//! parallel VAE, and records metrics.
//!
//! Virtual-time semantics: requests arrive with `arrival` stamps; the
//! cluster serves batches one after another (the whole mesh is owned by one
//! generation at a time, as in xDiT); latency = finish - arrival.

use crate::comm::Clocks;
use crate::config::hardware::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::coordinator::router::route;
use crate::parallel::{driver, GenParams, Session};
use crate::runtime::Runtime;
use crate::vae::ParallelVae;
use crate::Result;

pub struct Engine<'a> {
    pub rt: &'a Runtime,
    pub cluster: ClusterSpec,
    pub world: usize,
    pub batcher: Batcher,
    pub metrics: Metrics,
    /// Override the router (None = paper policy).
    pub force_config: Option<ParallelConfig>,
    /// Virtual clock of the serving horizon.
    now: f64,
}

impl<'a> Engine<'a> {
    pub fn new(rt: &'a Runtime, cluster: ClusterSpec, world: usize) -> Engine<'a> {
        Engine {
            rt,
            cluster,
            world,
            batcher: Batcher::new(4),
            metrics: Metrics::default(),
            force_config: None,
            now: 0.0,
        }
    }

    /// Serve a window of requests (already drained from the queue) to
    /// completion. Returns responses in completion order.
    pub fn serve(&mut self, window: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let mut out = Vec::with_capacity(window.len());
        let batches = self.batcher.form(window);
        for batch in batches {
            let first = &batch.requests[0];
            let spec = ModelSpec::by_name(&format!("tiny-{}", first.variant.key()))?;
            let pc = self
                .force_config
                .unwrap_or_else(|| route(&spec, 256, &self.cluster, self.world));
            let method = pick_method(&pc);

            for req in &batch.requests {
                // the batch shares the mesh; requests run back-to-back on it
                let mut sess =
                    Session::new(self.rt, req.variant, self.cluster.clone(), pc)?;
                let params = GenParams {
                    prompt: req.prompt.clone(),
                    steps: req.steps,
                    seed: req.seed,
                    guidance: req.guidance,
                    scheduler: "ddim".into(),
                };
                let r = driver::generate(&mut sess, method, &params)?;
                let mut image = None;
                let mut decode_time = 0.0;
                if req.decode {
                    let vae = ParallelVae::new(self.rt)?;
                    let mut clocks = Clocks::new(self.cluster.n_gpus);
                    let z = r.latent.reshape(&[16, 16, 4])?;
                    let n_vae = pc.world().min(8);
                    image = Some(vae.decode_parallel(&z, n_vae, &self.cluster, &mut clocks)?);
                    decode_time = clocks.makespan();
                }
                let start = self.now.max(req.arrival);
                let finish = start + r.makespan + decode_time;
                self.now = finish;
                let latency = finish - req.arrival;
                self.metrics.latency.observe(latency);
                self.metrics.queue_wait.observe(start - req.arrival);
                self.metrics.served += 1;
                self.metrics.model_seconds += r.makespan;
                out.push(GenResponse {
                    id: req.id,
                    latent: r.latent,
                    image,
                    model_seconds: r.makespan,
                    latency,
                    parallel_config: pc.describe(),
                });
            }
        }
        self.metrics.horizon = self.now;
        Ok(out)
    }
}

/// Strategy implied by a hybrid config.
pub fn pick_method(pc: &ParallelConfig) -> driver::Method {
    if pc.pipefusion > 1 && pc.sp_degree() > 1 {
        driver::Method::Hybrid
    } else if pc.pipefusion > 1 {
        driver::Method::PipeFusion
    } else if pc.sp_degree() > 1 {
        driver::Method::Sp
    } else {
        driver::Method::Serial
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn serves_batch_and_records_metrics() {
        let Some(rt) = setup() else { return };
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut reqs = Vec::new();
        for i in 0..3u64 {
            let mut r = GenRequest::new(i, format!("prompt {i}"));
            r.steps = 2;
            r.arrival = i as f64 * 0.01;
            reqs.push(r);
        }
        let out = eng.serve(reqs).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(eng.metrics.served, 3);
        assert!(eng.metrics.throughput() > 0.0);
        // completion order preserves arrival order within a batch
        assert!(out[0].latency <= out[2].latency + out[2].model_seconds);
        for r in &out {
            assert_eq!(r.latent.dims, vec![256, 4]);
        }
    }

    #[test]
    fn method_picker() {
        assert_eq!(pick_method(&ParallelConfig::new(2, 2, 2, 1)), driver::Method::Hybrid);
        assert_eq!(pick_method(&ParallelConfig::new(2, 4, 1, 1)), driver::Method::PipeFusion);
        assert_eq!(pick_method(&ParallelConfig::new(1, 1, 2, 2)), driver::Method::Sp);
        assert_eq!(pick_method(&ParallelConfig::new(2, 1, 1, 1)), driver::Method::Serial);
    }
}
