//! The generation engine: drains the queue in batch windows, routes each
//! batch to a hybrid parallel config (paper §5.2.4 policy), runs the
//! denoising loop on the simulated cluster, optionally decodes with the
//! parallel VAE, and records metrics.
//!
//! This is an *internal* layer: user code enters through
//! `crate::pipeline::Pipeline`, which owns an `Engine` and configures its
//! policy knobs (`force_config`, `force_method`, `default_scheduler`).
//!
//! Lifecycle invariants (asserted by `Metrics`):
//! * one `Session` per *batch*, not per request — requests that share a
//!   batch reuse the mesh, clocks and buffers;
//! * one `ParallelVae` per *engine* — built lazily on the first decode and
//!   reused forever after (`Metrics::vae_builds` stays at 1).
//!
//! Virtual-time semantics: requests arrive with `arrival` stamps; the
//! cluster serves batches one after another (the whole mesh is owned by one
//! generation at a time, as in xDiT); latency = finish - arrival.

use crate::comm::Clocks;
use crate::config::hardware::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::coordinator::router::route;
use crate::diffusion::SchedulerKind;
use crate::parallel::{driver, GenParams, Session};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::vae::ParallelVae;
use crate::Result;

pub struct Engine<'a> {
    pub rt: &'a Runtime,
    pub cluster: ClusterSpec,
    pub world: usize,
    pub batcher: Batcher,
    pub metrics: Metrics,
    /// Override the router (None = paper policy, resolution-aware).
    pub force_config: Option<ParallelConfig>,
    /// Override the strategy implied by the config (None = `pick_method`).
    pub force_method: Option<driver::Method>,
    /// Pipeline-level scheduler default; per-request overrides win, the
    /// model's benchmark scheduler is the final fallback.
    pub default_scheduler: Option<SchedulerKind>,
    /// Patch-parallel VAE, built once per engine on first decode.
    vae: Option<ParallelVae<'a>>,
    /// Virtual clock of the serving horizon.
    now: f64,
}

impl<'a> Engine<'a> {
    pub fn new(rt: &'a Runtime, cluster: ClusterSpec, world: usize) -> Engine<'a> {
        Engine {
            rt,
            cluster,
            world,
            batcher: Batcher::new(4),
            metrics: Metrics::default(),
            force_config: None,
            force_method: None,
            default_scheduler: None,
            vae: None,
            now: 0.0,
        }
    }

    /// Scheduler for a request: request override > engine default > model
    /// benchmark scheduler. No literal anywhere on this path.
    fn scheduler_for(&self, spec: &ModelSpec, req: &GenRequest) -> Result<SchedulerKind> {
        match req.scheduler.or(self.default_scheduler) {
            Some(kind) => Ok(kind),
            None => SchedulerKind::parse(spec.scheduler),
        }
    }

    /// Serve a window of requests (already drained from the queue) to
    /// completion. Returns responses in completion order.
    pub fn serve(&mut self, window: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let mut out = Vec::with_capacity(window.len());
        let batches = self.batcher.form(window);
        let rt = self.rt;
        for batch in batches {
            let first = &batch.requests[0];
            let spec = ModelSpec::for_variant(first.variant)?;
            // the routed sequence length follows the requested resolution
            let s_img = spec.seq_len(first.px);
            let pc = self
                .force_config
                .unwrap_or_else(|| route(&spec, s_img, &self.cluster, self.world));
            let method = self.force_method.unwrap_or_else(|| pick_method(&pc));

            // one session per batch: the whole batch shares the mesh and
            // runs back-to-back on it
            let mut sess = Session::new(rt, first.variant, self.cluster.clone(), pc)?;
            self.metrics.sessions_built += 1;

            for req in &batch.requests {
                let scheduler = self.scheduler_for(&spec, req)?;
                let params = GenParams {
                    prompt: req.prompt.clone(),
                    steps: req.steps,
                    seed: req.seed,
                    guidance: req.guidance,
                    scheduler,
                };
                // the session's clocks/ledger persist across the batch;
                // driver::generate reports per-generation deltas
                let r = driver::generate(&mut sess, method, &params)?;
                let model_seconds = r.makespan;
                let comm_bytes = r.comm_bytes;

                let mut image = None;
                let mut decode_time = 0.0;
                if req.decode {
                    let (img, t) = self.decode_latent(&r.latent, pc.world().min(8))?;
                    image = Some(img);
                    decode_time = t;
                }
                let start = self.now.max(req.arrival);
                let finish = start + model_seconds + decode_time;
                self.now = finish;
                let latency = finish - req.arrival;
                self.metrics.latency.observe(latency);
                self.metrics.queue_wait.observe(start - req.arrival);
                self.metrics.served += 1;
                self.metrics.model_seconds += model_seconds;
                out.push(GenResponse {
                    id: req.id,
                    latent: r.latent,
                    image,
                    model_seconds,
                    latency,
                    comm_bytes,
                    parallel_config: pc.describe(),
                    method: r.method,
                    scheduler: scheduler.key().to_string(),
                    px: req.px,
                });
            }
        }
        self.metrics.horizon = self.now;
        Ok(out)
    }

    /// Decode a final latent with the engine-owned parallel VAE over `n`
    /// simulated devices. Returns the image and the simulated decode time.
    pub fn decode_latent(&mut self, latent: &Tensor, n: usize) -> Result<(Tensor, f64)> {
        self.ensure_vae()?;
        let vae = self.vae.as_ref().unwrap();
        let z = latent.reshape(&[vae.hw, vae.hw, vae.c])?;
        let mut clocks = Clocks::new(self.cluster.n_gpus);
        let img = vae.decode_parallel(&z, n, &self.cluster, &mut clocks)?;
        Ok((img, clocks.makespan()))
    }

    /// Current end of the virtual serving horizon (seconds since engine
    /// start) — where the next arriving request would start.
    pub fn virtual_now(&self) -> f64 {
        self.now
    }

    /// Exact single-device decode (the reference the parallel path is
    /// checked against).
    pub fn decode_reference(&mut self, latent: &Tensor) -> Result<Tensor> {
        self.ensure_vae()?;
        let vae = self.vae.as_ref().unwrap();
        let z = latent.reshape(&[vae.hw, vae.hw, vae.c])?;
        vae.decode_full(&z)
    }

    fn ensure_vae(&mut self) -> Result<()> {
        if self.vae.is_none() {
            self.vae = Some(ParallelVae::new(self.rt)?);
            self.metrics.vae_builds += 1;
        }
        Ok(())
    }
}

/// Strategy implied by a hybrid config.
pub fn pick_method(pc: &ParallelConfig) -> driver::Method {
    if pc.pipefusion > 1 && pc.sp_degree() > 1 {
        driver::Method::Hybrid
    } else if pc.pipefusion > 1 {
        driver::Method::PipeFusion
    } else if pc.sp_degree() > 1 {
        driver::Method::Sp
    } else {
        driver::Method::Serial
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn serves_batch_and_records_metrics() {
        let Some(rt) = setup() else { return };
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut reqs = Vec::new();
        for i in 0..3u64 {
            let mut r = GenRequest::new(i, format!("prompt {i}"));
            r.steps = 2;
            r.arrival = i as f64 * 0.01;
            reqs.push(r);
        }
        let out = eng.serve(reqs).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(eng.metrics.served, 3);
        assert!(eng.metrics.throughput() > 0.0);
        // identical batch keys -> one shared session for all three
        assert_eq!(eng.metrics.sessions_built, 1);
        // completion order preserves arrival order within a batch
        assert!(out[0].latency <= out[2].latency + out[2].model_seconds);
        for r in &out {
            assert_eq!(r.latent.dims, vec![256, 4]);
            assert!(r.model_seconds > 0.0);
        }
    }

    #[test]
    fn vae_is_built_once_per_engine() {
        let Some(rt) = setup() else { return };
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut reqs = Vec::new();
        for i in 0..3u64 {
            let mut r = GenRequest::new(i, "decode me");
            r.steps = 2;
            r.decode = true;
            reqs.push(r);
        }
        let out = eng.serve(reqs).unwrap();
        assert!(out.iter().all(|r| r.image.is_some()));
        assert_eq!(eng.metrics.vae_builds, 1, "VAE must be reused across requests");
        // a second window still reuses it
        let mut r = GenRequest::new(9, "again");
        r.steps = 2;
        r.decode = true;
        eng.serve(vec![r]).unwrap();
        assert_eq!(eng.metrics.vae_builds, 1);
    }

    #[test]
    fn method_picker() {
        assert_eq!(pick_method(&ParallelConfig::new(2, 2, 2, 1)), driver::Method::Hybrid);
        assert_eq!(pick_method(&ParallelConfig::new(2, 4, 1, 1)), driver::Method::PipeFusion);
        assert_eq!(pick_method(&ParallelConfig::new(1, 1, 2, 2)), driver::Method::Sp);
        assert_eq!(pick_method(&ParallelConfig::new(2, 1, 1, 1)), driver::Method::Serial);
    }
}
