//! The generation engine: a continuous-batching scheduler over the
//! simulated cluster.
//!
//! Admission path: producers [`Engine::submit`] into the bounded
//! [`RequestQueue`]; a full queue rejects with a reason (backpressure)
//! instead of buffering unboundedly. Every [`Engine::tick`] the waiting
//! set is re-grouped by the compatibility [`Batcher`] and the single most
//! urgent batch (priority + aging, deadlines, arrival order) is routed to
//! a hybrid parallel config by the cost-model auto-planner (or the §5.2.4
//! heuristic under `RoutePolicy::PaperHeuristic`), run through the
//! denoising loop, optionally decoded with the parallel VAE, and recorded
//! in [`Metrics`]. Late arrivals join the *next* batch of their group —
//! batches are formed per tick, never ahead of time. With
//! `deadline_admission` set, `submit` additionally rejects deadlined
//! requests whose cheapest feasible plan already predicts a miss.
//!
//! This is an *internal* layer: user code enters through
//! `crate::pipeline::Pipeline`, which owns an `Engine` and configures its
//! policy knobs (`force_config`, `force_method`, `default_scheduler`).
//!
//! Lifecycle invariants (asserted by `Metrics`):
//! * one `Session` per *batch*, not per request — requests that share a
//!   batch reuse the mesh, clocks and buffers;
//! * one `ParallelVae` per *engine* — built lazily on the first decode and
//!   reused forever after (`Metrics::vae_builds` stays at 1).
//!
//! Virtual-time semantics: requests arrive with `arrival` stamps; the
//! cluster serves batches one after another (the whole mesh is owned by one
//! generation at a time, as in xDiT); latency = finish - arrival, split
//! into queue delay (arrival -> launch) and execution (launch -> finish).
//!
//! Staged execution (`stage_overlap`, off by default): each request flows
//! text-encode → denoise → VAE-decode with one virtual clock per stage
//! and a bounded denoise→decode queue (`stage_queue_capacity`), so the
//! decode of request N overlaps the denoise of request N+1 — the PipeDiT
//! decoupling. `virtual_now()` stays the *denoise* clock (admission keeps
//! flowing while decode drains); [`Engine::horizon`] is the true end of
//! the run including the decode tail. Outputs are bit-identical to the
//! serial path (the same decode runs, just earlier relative to later
//! denoises) and the makespan is provably never worse — see the
//! "Staged execution (L4.5)" chapter of `DESIGN.md` for the induction.

use std::cell::RefCell;
use std::collections::VecDeque;

use crate::comm::Clocks;
use crate::config::hardware::{ClusterSpec, CollectiveAlgo};
use crate::config::model::{BlockVariant, ModelSpec};
use crate::config::parallel::ParallelConfig;
use crate::coordinator::batcher::{Batch, Batcher, WaitingSet};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::plan_cache::{fingerprint, PlanCache, PlanKey};
use crate::coordinator::planner::{Fidelity, Plan, Planner, RoutePolicy};
use crate::coordinator::queue::{PushError, RequestQueue};
use crate::coordinator::request::{GenRequest, GenResponse, RequestId, SloClass};
use crate::coordinator::trace::TraceEventKind;
use crate::diffusion::SchedulerKind;
use crate::parallel::{driver, GenParams, Session};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::vae::ParallelVae;
use crate::Result;

/// Default bound on the admission queue.
pub const DEFAULT_QUEUE_CAPACITY: usize = 64;

/// Default bound on warm sessions the engine keeps between batches.
pub const DEFAULT_SESSION_CACHE_CAPACITY: usize = 8;

/// Default bound on the denoise→decode inter-stage queue (staged mode).
pub const DEFAULT_STAGE_QUEUE_CAPACITY: usize = 2;

/// Most preemption slices a single request may absorb. After this many,
/// a batch-tier batch runs to completion even if an interactive deadline
/// is at risk — a hard bound that makes live-lock impossible (each slice
/// also advances the virtual clock, so progress is monotone anyway).
pub const MAX_PREEMPTIONS: u32 = 4;

/// Lowest resolution the overload degrade ladder may drop a batch-tier
/// request to (half of the tiny family's native 256px grid).
pub const MIN_DEGRADE_PX: usize = 128;

/// Shape of a warm session: requests routed to the same (variant,
/// resolution, config) can reuse the mesh/model the last batch built.
type SessionKey = (BlockVariant, usize, ParallelConfig);

/// Bounded most-recently-used cache of warm [`Session`]s. Sessions are
/// *taken out* for the duration of a batch (so the engine can borrow
/// itself freely while serving) and re-inserted at the front afterwards;
/// capacity 0 disables reuse entirely (the cold-build debug path). A
/// cluster-fingerprint mismatch empties the cache, mirroring the
/// `PlanCache` invalidation rule.
struct SessionCache<'a> {
    entries: Vec<(SessionKey, Session<'a>)>,
    capacity: usize,
    cluster_fp: Option<u64>,
}

impl<'a> SessionCache<'a> {
    fn new(capacity: usize) -> SessionCache<'a> {
        SessionCache { entries: Vec::new(), capacity, cluster_fp: None }
    }

    /// Empty the cache when the cluster spec changed under the engine.
    fn check_cluster(&mut self, fp: u64) {
        if self.cluster_fp != Some(fp) {
            self.entries.clear();
            self.cluster_fp = Some(fp);
        }
    }

    fn take(&mut self, key: &SessionKey) -> Option<Session<'a>> {
        let i = self.entries.iter().position(|(k, _)| k == key)?;
        Some(self.entries.remove(i).1)
    }

    fn store(&mut self, key: SessionKey, sess: Session<'a>) {
        if self.capacity == 0 {
            return;
        }
        self.entries.insert(0, (key, sess));
        self.entries.truncate(self.capacity);
    }
}

/// Why a request was refused admission (returned by [`Engine::submit`]).
#[derive(Debug, Clone)]
pub struct Rejection {
    /// The request that was refused admission.
    pub id: RequestId,
    /// Human-readable refusal reason (backpressure, deadline infeasible).
    pub reason: String,
}

impl std::fmt::Display for Rejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} rejected: {}", self.id, self.reason)
    }
}

/// Outcome of an [`Engine::cancel`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Removed from the admission queue; the capacity slot is refunded
    /// immediately (a blocked producer can admit into it).
    Queued,
    /// Removed mid-flight from the batcher's waiting set — the request
    /// was admitted but had not launched in a batch yet.
    MidFlight,
    /// Unknown id: never admitted, already completed, or already
    /// cancelled. Cancellation is idempotent.
    NotFound,
}

/// The continuous-batching serving engine (see the module docs for the
/// admission path and lifecycle invariants). Internal: user code enters
/// through `crate::pipeline::Pipeline`.
pub struct Engine<'a> {
    /// Execution runtime (PJRT artifacts or the hermetic simulation).
    pub rt: &'a Runtime,
    /// Simulated cluster topology batches are timed against.
    pub cluster: ClusterSpec,
    /// Devices this engine serves on.
    pub world: usize,
    /// Compatibility batcher (max batch size, priority aging).
    pub batcher: Batcher,
    /// Cumulative engine-lifetime serving metrics.
    pub metrics: Metrics,
    /// Override the auto-planner (None = planner policy, resolution-aware).
    pub force_config: Option<ParallelConfig>,
    /// Routing policy for un-forced batches (default: cost-model planner).
    pub route_policy: RoutePolicy,
    /// Scoring fidelity of the per-batch routing decision (default:
    /// closed forms; `Fidelity::Simulated` re-scores top candidates with
    /// the event simulator on every batch launch).
    pub route_fidelity: Fidelity,
    /// Per-GPU HBM budget the planner prunes with (None = cluster GPU).
    pub memory_cap_bytes: Option<f64>,
    /// When set, `submit` rejects a deadlined request whose *cheapest
    /// feasible plan* already predicts a miss — admission control on the
    /// cost model instead of serving work that cannot make it.
    pub deadline_admission: bool,
    /// Override the strategy implied by the config (None = `pick_method`).
    pub force_method: Option<driver::Method>,
    /// Pin the collective algorithm plans are priced with (`None` = the
    /// planner auto-selects: flat ring everywhere, two-level hierarchical
    /// where a candidate's collectives span nodes and it strictly wins).
    pub collective_algo: Option<CollectiveAlgo>,
    /// Pipeline-level scheduler default; per-request overrides win, the
    /// model's benchmark scheduler is the final fallback.
    pub default_scheduler: Option<SchedulerKind>,
    /// Staged execution: overlap the VAE decode of request N with the
    /// denoise of request N+1 on per-stage virtual clocks (off = the
    /// serial reference path; outputs are bit-identical either way).
    pub stage_overlap: bool,
    /// Devices the parallel VAE shards each decode across patch-wise
    /// (`None` = `min(plan world, 8)`, the auto default). The latent row
    /// count must divide by it with a per-device strip of 2/4/8 rows —
    /// `decode_latent` rejects other values.
    pub vae_parallelism: Option<usize>,
    /// Bound on the denoise→decode queue in staged mode: when this many
    /// decodes are still queued, the next decode-bound denoise launch
    /// stalls (backpressure — `Metrics::stages` counts the stalls).
    pub stage_queue_capacity: usize,
    /// Batch-tier preemption (on by default). When the replay loop's
    /// lookahead says an interactive request will arrive mid-batch and
    /// miss its deadline unless served promptly, an all-batch-tier batch
    /// yields at the arrival with its completed steps credited
    /// (`maybe_preempt`). Disable for a preemption-free control replay —
    /// latents are bit-identical either way, only latencies move.
    pub preemption: bool,
    /// Degrade-under-overload ladder (opt-in): at admission, batch-tier
    /// requests lose diffusion steps (backlog ≥ half capacity) and then
    /// resolution (backlog ≥ three quarters) — trading batch-tier output
    /// quality for queue headroom. Quantified by `benches/fig19_quality`.
    pub degrade: bool,
    /// Per-class admission budgets: `Some(n)` caps the pending requests
    /// of that class admitted through `submit` (index by
    /// `SloClass::index()`). `None` = only the shared queue bound.
    pub slo_budgets: [Option<usize>; SloClass::COUNT],
    /// The replay loop's preemption lookahead: the next not-yet-admitted
    /// interactive request as (arrival, deadline, estimated exec
    /// seconds). Stale entries (arrival ≤ now) are ignored.
    preempt_lookahead: Option<(f64, Option<f64>, f64)>,
    /// Pending (submitted, unserved) counts per SLO class — the budget
    /// quantity. Tracks the `submit`/`tick` path only; `serve` windows
    /// bypass admission and the budgets with it.
    pending_by_class: [usize; SloClass::COUNT],
    /// Bounded admission queue. Engine admission itself is leader-side
    /// (`submit` takes `&mut self`); cross-thread producers feed an
    /// *external* `RequestQueue` handle the leader drains into a `Trace`
    /// or `submit` loop, as `examples/serve_hybrid.rs` does.
    queue: RequestQueue,
    /// Admitted requests awaiting a batch slot, bucketed by compatibility
    /// at admission (`Batcher::next_batch_indexed` selects from here
    /// without rescanning the backlog).
    waiting: WaitingSet,
    /// Memoized routing decisions (pure function of the plan key + the
    /// cluster — see `coordinator::plan_cache`). Interior-mutable because
    /// `plan_for` serves read paths through `&self`.
    plan_cache: RefCell<PlanCache>,
    /// Warm sessions keyed by (variant, px, config); reused across
    /// batches with clocks/ledger reset so `sessions_built` tracks
    /// distinct shapes, not batch count.
    sessions: SessionCache<'a>,
    /// Patch-parallel VAE, built once per engine on first decode.
    vae: Option<ParallelVae<'a>>,
    /// Virtual clock of the denoise stage (the serving horizon in serial
    /// mode; admission and batching key off this clock in both modes).
    now: f64,
    /// Virtual clock of the text-encode stage (staged mode; monotone in
    /// arrival order, so it never gates anything on the tiny family's
    /// zero-cost encode — kept for honest stage structure).
    enc_clock: f64,
    /// Virtual clock of the VAE-decode stage (staged mode): when the
    /// decoder finishes its last queued decode.
    dec_clock: f64,
    /// Decode start times of the most recent `stage_queue_capacity`
    /// decodes (staged mode): the front entry is when the denoiser's
    /// queue slot frees up — the backpressure gate.
    decode_starts: VecDeque<f64>,
}

impl<'a> Engine<'a> {
    /// An engine over `world` devices of `cluster`, with default policy
    /// knobs (cost-model routing, bounded queue, no forced strategy).
    pub fn new(rt: &'a Runtime, cluster: ClusterSpec, world: usize) -> Engine<'a> {
        Engine {
            rt,
            cluster,
            world,
            batcher: Batcher::new(4),
            metrics: Metrics::default(),
            force_config: None,
            route_policy: RoutePolicy::default(),
            route_fidelity: Fidelity::default(),
            memory_cap_bytes: None,
            deadline_admission: false,
            force_method: None,
            collective_algo: None,
            default_scheduler: None,
            stage_overlap: false,
            vae_parallelism: None,
            stage_queue_capacity: DEFAULT_STAGE_QUEUE_CAPACITY,
            preemption: true,
            degrade: false,
            slo_budgets: [None; SloClass::COUNT],
            preempt_lookahead: None,
            pending_by_class: [0; SloClass::COUNT],
            queue: RequestQueue::new(DEFAULT_QUEUE_CAPACITY),
            waiting: WaitingSet::new(1.0),
            plan_cache: RefCell::new(PlanCache::default()),
            sessions: SessionCache::new(DEFAULT_SESSION_CACHE_CAPACITY),
            vae: None,
            now: 0.0,
            enc_clock: 0.0,
            dec_clock: 0.0,
            decode_starts: VecDeque::new(),
        }
    }

    /// Replace the admission queue bound. Anything already queued is
    /// carried over into the waiting set, so resizing can never drop
    /// admitted work.
    pub fn set_queue_capacity(&mut self, capacity: usize) {
        self.waiting.extend(self.queue.drain_upto(usize::MAX));
        self.queue = RequestQueue::new(capacity.max(1));
    }

    /// Enable/disable plan memoization (`--no-plan-cache`). Off, every
    /// batch re-runs the cold enumerate + score sweep — bit-identical
    /// results, steady-state cost restored; for debugging the cache only.
    pub fn set_plan_cache_enabled(&mut self, enabled: bool) {
        self.plan_cache.borrow_mut().set_enabled(enabled);
    }

    /// Whether plan memoization is active.
    pub fn plan_cache_enabled(&self) -> bool {
        self.plan_cache.borrow().is_enabled()
    }

    /// Bound the warm-session cache (0 disables reuse: every batch builds
    /// a fresh session, the pre-cache behavior).
    pub fn set_session_cache_capacity(&mut self, capacity: usize) {
        self.sessions.capacity = capacity;
        self.sessions.entries.truncate(capacity);
    }

    /// Current bound on the warm-session cache.
    pub fn session_cache_capacity(&self) -> usize {
        self.sessions.capacity
    }

    /// Current bound on the admission queue.
    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity
    }

    /// Admit one request, or reject it with a reason when the engine's
    /// backlog (queued + waiting) is at capacity — backpressure bounds the
    /// *total* admitted-but-unserved set, not just the mpsc front, so a
    /// live submit/tick loop cannot grow `waiting` without bound.
    /// Rejections are counted.
    pub fn submit(&mut self, mut req: GenRequest) -> std::result::Result<(), Rejection> {
        if self.degrade && req.slo == SloClass::Batch {
            self.maybe_degrade(&mut req);
        }
        let class = req.slo;
        if let Some(budget) = self.slo_budgets[class.index()] {
            if self.pending_by_class[class.index()] >= budget {
                self.metrics.rejected += 1;
                return Err(Rejection {
                    id: req.id,
                    reason: format!(
                        "slo budget: {} {} requests pending >= class budget {}",
                        self.pending_by_class[class.index()],
                        class.name(),
                        budget
                    ),
                });
            }
        }
        if self.deadline_admission {
            let rej = self.deadline_rejection(&req);
            // the admission check planned through the cache: reflect its
            // counters in the metrics snapshot
            self.sync_cache_metrics();
            if let Some(rej) = rej {
                self.metrics.rejected += 1;
                return Err(rej);
            }
        }
        if self.pending() >= self.queue.capacity {
            self.metrics.rejected += 1;
            return Err(Rejection {
                id: req.id,
                reason: format!(
                    "backpressure: {} requests pending >= capacity {}",
                    self.pending(),
                    self.queue.capacity
                ),
            });
        }
        match self.queue.push(req) {
            Ok(()) => {
                self.pending_by_class[class.index()] += 1;
                Ok(())
            }
            // unreachable in practice: the pre-check bounds pending() which
            // dominates queue.len(), and the engine never closes its own
            // queue — kept as defense with the same backpressure contract
            Err(PushError::Backpressure(r)) | Err(PushError::Closed(r)) => {
                self.metrics.rejected += 1;
                Err(Rejection {
                    id: r.id,
                    reason: format!(
                        "backpressure: queue refused admission (capacity {})",
                        self.queue.capacity
                    ),
                })
            }
        }
    }

    /// Requests admitted but not yet completed.
    pub fn pending(&self) -> usize {
        self.waiting.len() + self.queue.len()
    }

    /// Overload degrade ladder (opt-in via [`Engine::degrade`]): shed
    /// batch-tier work *quality* instead of rejecting it. Backlog at half
    /// the queue capacity halves the step count; at three quarters the
    /// resolution halves too (floored at [`MIN_DEGRADE_PX`]). Degraded
    /// outputs are *different* outputs — the bit-identity invariant only
    /// covers non-degraded requests, which is why the ladder is opt-in.
    fn maybe_degrade(&mut self, req: &mut GenRequest) {
        let backlog = self.pending();
        let cap = self.queue.capacity.max(1);
        if backlog * 2 < cap {
            return;
        }
        let mut touched = false;
        let halved = req.steps.div_ceil(2).max(1);
        if halved < req.steps {
            req.steps = halved;
            touched = true;
        }
        if backlog * 4 >= cap * 3 && req.px / 2 >= MIN_DEGRADE_PX {
            req.px /= 2;
            touched = true;
        }
        if touched {
            self.metrics.degraded += 1;
        }
    }

    /// Cancel a request by id, wherever it currently is. Queued requests
    /// refund their admission slot immediately; mid-flight (admitted,
    /// waiting for a batch) requests leave the waiting set and are never
    /// launched. Completed or unknown ids are a no-op (`NotFound`) —
    /// cancellation is idempotent and never un-serves a response.
    pub fn cancel(&mut self, id: RequestId) -> CancelOutcome {
        if let Some(r) = self.queue.remove(id) {
            self.metrics.cancelled_queued += 1;
            self.dec_pending(r.slo);
            return CancelOutcome::Queued;
        }
        if let Some(r) = self.waiting.remove(id) {
            self.metrics.cancelled_midflight += 1;
            self.dec_pending(r.slo);
            return CancelOutcome::MidFlight;
        }
        CancelOutcome::NotFound
    }

    fn dec_pending(&mut self, class: SloClass) {
        let c = &mut self.pending_by_class[class.index()];
        *c = c.saturating_sub(1);
    }

    /// Apply a mid-trace cluster mutation ([`TraceEventKind`]) to the
    /// engine's world. The mutated spec's fingerprint differs, so the
    /// next planning decision self-invalidates the plan *and* session
    /// caches and re-plans against the new topology — the PR 5
    /// invalidation seam, now exercised mid-trace. `Cancel` events route
    /// to [`Engine::cancel`]. The serving world only ever clamps *down*
    /// (to the surviving GPU count); regrowth adds planner headroom for
    /// engines built at a larger world but never exceeds the original.
    pub fn apply_cluster_event(&mut self, kind: TraceEventKind) {
        match kind {
            TraceEventKind::RankFail => {
                self.cluster.n_gpus = self.cluster.n_gpus.saturating_sub(1).max(1);
            }
            TraceEventKind::NodeShrink => {
                let node = self.cluster.gpus_per_node.max(1);
                self.cluster.n_gpus = self.cluster.n_gpus.saturating_sub(node).max(1);
            }
            TraceEventKind::NodeGrow => {
                self.cluster.n_gpus += self.cluster.gpus_per_node.max(1);
            }
            TraceEventKind::Straggler(f) => {
                if f.is_finite() && f > 0.0 {
                    self.cluster.gpu.tflops *= f;
                }
            }
            TraceEventKind::Cancel(id) => {
                self.cancel(id);
                return;
            }
            TraceEventKind::ReplicaFail
            | TraceEventKind::ReplicaDrain
            | TraceEventKind::ReplicaRecover => {
                // replica-lifecycle events only have meaning at fleet
                // scope (health state machine + failover routing in
                // `fleet::Fleet::replay`); a single engine has no replica
                // identity, so they are harmless no-ops here
                return;
            }
        }
        self.world = self.world.min(self.cluster.n_gpus).max(1);
    }

    /// Feed the replay loop's preemption lookahead: the next
    /// not-yet-admitted interactive request as (arrival, deadline,
    /// estimated exec seconds). `None` (or a stale arrival ≤ now)
    /// disables preemption for the next batch.
    pub fn set_preempt_lookahead(&mut self, lookahead: Option<(f64, Option<f64>, f64)>) {
        self.preempt_lookahead = lookahead;
    }

    /// The plan the engine would run a request under: the forced config
    /// scored, or the policy planner's best — always for the request's own
    /// resolution and step count. A forced method re-prices the plan with
    /// that strategy's closed form (mirroring `Pipeline::plan`), so
    /// `predicted_seconds` and deadline admission describe what will
    /// actually run, not the config's best case.
    ///
    /// Memoized through the engine's `PlanCache`: the decision is a pure
    /// function of `(model, px, steps)` and the engine's policy knobs
    /// (all part of the key), so a hit returns a byte-identical clone of
    /// the cold computation. The cache self-invalidates when the cluster
    /// spec changes.
    pub fn plan_for(&self, spec: &ModelSpec, px: usize, steps: usize) -> Plan {
        let fp = fingerprint(&self.cluster);
        self.plan_for_keyed(&self.plan_key(spec, px, steps), fp, spec, px, steps)
    }

    /// `plan_for` with a caller-built key and cluster fingerprint, so the
    /// batch path constructs each exactly once and shares them with the
    /// sim memo and the session cache.
    fn plan_for_keyed(
        &self,
        key: &PlanKey,
        cluster_fp: u64,
        spec: &ModelSpec,
        px: usize,
        steps: usize,
    ) -> Plan {
        {
            let mut cache = self.plan_cache.borrow_mut();
            cache.check_cluster(cluster_fp);
            if let Some(plan) = cache.lookup(key) {
                return plan;
            }
        }
        let plan = self.plan_cold(spec, px, steps);
        self.plan_cache.borrow_mut().insert(key.clone(), plan.clone());
        plan
    }

    /// The un-memoized planning sweep `plan_for` caches (enumerate, prune,
    /// score, reprice, attach simulation).
    fn plan_cold(&self, spec: &ModelSpec, px: usize, steps: usize) -> Plan {
        let planner = self.planner(steps);
        let mut plan = match self.force_config {
            Some(pc) => planner.score(spec, px, &self.cluster, &pc),
            None => planner.plan(spec, px, &self.cluster, self.world),
        };
        if let Some(method) = self.force_method {
            planner.reprice_for_method(&mut plan, method, spec, &self.cluster);
        }
        // forced/pinned plans skip the re-scoring pass; honour the
        // engine's fidelity by attaching the simulated makespan here
        planner.attach_simulation(&mut plan, spec, &self.cluster);
        plan
    }

    /// Everything the routing decision for `(spec, px, steps)` depends on
    /// besides the cluster (which the cache fingerprints separately).
    fn plan_key(&self, spec: &ModelSpec, px: usize, steps: usize) -> PlanKey {
        PlanKey {
            model: spec.name.clone(),
            px,
            steps,
            world: self.world,
            policy: self.route_policy,
            fidelity: self.route_fidelity,
            memory_cap_bits: self.memory_cap_bytes.map(f64::to_bits),
            force_config: self.force_config,
            force_method: self.force_method,
            collective_algo: self.collective_algo,
        }
    }

    /// Copy the plan-cache counters into the metrics snapshot (called at
    /// every engine operation that may have planned).
    fn sync_cache_metrics(&mut self) {
        let (hits, misses, invalidations) = self.plan_cache.borrow().counters();
        self.metrics.plan_cache_hits = hits;
        self.metrics.plan_cache_misses = misses;
        self.metrics.plan_cache_invalidations = invalidations;
    }

    /// The planner this engine's policy knobs configure, predicting for
    /// `steps` diffusion steps.
    fn planner(&self, steps: usize) -> Planner {
        Planner {
            policy: self.route_policy,
            steps: Some(steps),
            memory_cap_bytes: self.memory_cap_bytes,
            fidelity: self.route_fidelity,
            collective_algo: self.collective_algo,
        }
    }

    /// Deadline admission: reject iff even an immediate launch of the
    /// cheapest feasible plan would predict a miss (`None` = admissible).
    fn deadline_rejection(&self, req: &GenRequest) -> Option<Rejection> {
        let deadline = req.deadline?;
        let spec = ModelSpec::for_variant(req.variant).ok()?;
        let plan = self.plan_for(&spec, req.px, req.steps);
        let finish = self.now.max(req.arrival) + plan.predicted.total;
        if finish > deadline {
            return Some(Rejection {
                id: req.id,
                reason: format!(
                    "deadline infeasible: cheapest plan [{}] predicts {:.3e}s, \
                     finishing at {:.3}s > deadline {:.3}s",
                    plan.config.describe(),
                    plan.predicted.total,
                    finish,
                    deadline
                ),
            });
        }
        None
    }

    /// One scheduler tick: drain the queue into the waiting set, re-form
    /// compatibility batches, launch the most urgent one, and return its
    /// responses. Empty result = nothing was waiting (an idle tick).
    pub fn tick(&mut self) -> Result<Vec<GenResponse>> {
        self.metrics.ticks += 1;
        self.waiting.extend(self.queue.drain_upto(usize::MAX));
        match self.batcher.next_batch_indexed(&mut self.waiting, self.now) {
            Some(batch) => match self.maybe_preempt(batch)? {
                Some(batch) => self.execute_batch(batch),
                // preempted: the members are back in the waiting set with
                // progress credited and the clock sits at the interactive
                // arrival — the next tick serves the urgent work first
                None => Ok(Vec::new()),
            },
            None => {
                self.metrics.idle_ticks += 1;
                Ok(Vec::new())
            }
        }
    }

    /// Batch-tier preemption decision for a selected batch. Returns the
    /// batch unchanged ("run it") unless ALL of the following hold, in
    /// which case the batch yields (`None`) at the interactive arrival:
    ///
    /// * preemption is on and a lookahead `(arr, deadline, exec)` with
    ///   `arr > now` is set;
    /// * every member is batch-tier with preemption budget left
    ///   ([`MAX_PREEMPTIONS`]);
    /// * the interactive request would arrive mid-batch
    ///   (`arr < est_finish`), would miss its deadline if it waited for
    ///   the batch (`est_finish + exec > deadline`), and preempting
    ///   actually saves it (`arr + exec <= deadline`).
    ///
    /// The yield credits each member the whole steps its fair share of
    /// the `[now, arr)` window covers (never to completion — at least one
    /// step remains so the final pass always runs and produces the
    /// latent), re-admits the members, and advances the clock to `arr`.
    /// Only the *remaining* steps are charged when a member finally runs,
    /// so a preempted request pays its compute once; the latent is
    /// produced from the original parameters in one piece, which is what
    /// keeps preempted outputs bit-identical to a preemption-free replay.
    fn maybe_preempt(&mut self, batch: Batch) -> Result<Option<Batch>> {
        if !self.preemption {
            return Ok(Some(batch));
        }
        let Some((arr, deadline, est_exec)) = self.preempt_lookahead else {
            return Ok(Some(batch));
        };
        if arr <= self.now {
            return Ok(Some(batch));
        }
        let preemptible = batch
            .requests
            .iter()
            .all(|r| r.slo == SloClass::Batch && r.preemptions < MAX_PREEMPTIONS);
        if !preemptible {
            return Ok(Some(batch));
        }
        let first = &batch.requests[0];
        let spec = ModelSpec::for_variant(first.variant)?;
        let plan = self.plan_for(&spec, first.px, first.steps);
        self.sync_cache_metrics();
        let per_step = plan.per_step(first.steps);
        if per_step <= 0.0 || !per_step.is_finite() {
            return Ok(Some(batch));
        }
        let remaining: usize =
            batch.requests.iter().map(|r| r.steps - r.steps_done.min(r.steps)).sum();
        let est_finish = self.now + per_step * remaining as f64;
        let dl = deadline.unwrap_or(f64::INFINITY);
        let arrives_mid_batch = arr < est_finish;
        let misses_if_waiting = est_finish + est_exec > dl;
        let saved_by_preempting = arr + est_exec <= dl;
        if !(arrives_mid_batch && misses_if_waiting && saved_by_preempting) {
            return Ok(Some(batch));
        }
        // fair-share slice of the [now, arr) window across the members
        let window = arr - self.now;
        let k = (window / (per_step * batch.len() as f64)).floor() as usize;
        let mut charged = 0.0;
        for mut r in batch.requests {
            let rem = r.steps - r.steps_done.min(r.steps);
            let credit = k.min(rem.saturating_sub(1));
            charged += credit as f64 * per_step;
            r.steps_done += credit;
            r.preemptions += 1;
            self.waiting.push(r);
        }
        self.metrics.preemptions += 1;
        self.metrics.model_seconds += charged;
        self.metrics.stages.denoise_busy += charged;
        self.now = arr;
        self.metrics.horizon = self.horizon();
        Ok(None)
    }

    /// Run the engine forward to the crash instant `at`, completing every
    /// batch the cost model prices as finishing by then and checkpointing
    /// the batch the crash lands in at its last whole step boundary:
    /// members go back to the waiting set with `steps_done` credited
    /// (capped one short of completion, exactly the [`Engine::tick`]
    /// preemption slicer) and the credited work is charged to this
    /// engine's ledger — the dying replica really did run those steps.
    /// Returns the completed responses plus the steps credited.
    ///
    /// The fleet failover path calls this before evacuating the backlog
    /// via [`Engine::drain_pending`]: because latents are always produced
    /// from the original `(seed, steps, plan)` in one piece and execution
    /// charges only the un-credited fraction, a migrated request's output
    /// stays bit-identical to an undisturbed replay and its credited
    /// compute is never redone on the surviving replica.
    pub fn run_to_checkpoint(&mut self, at: f64) -> Result<(Vec<GenResponse>, u64)> {
        let mut out = Vec::new();
        let mut credited: u64 = 0;
        while self.now < at {
            self.waiting.extend(self.queue.drain_upto(usize::MAX));
            let Some(batch) = self.batcher.next_batch_indexed(&mut self.waiting, self.now)
            else {
                break;
            };
            let first = &batch.requests[0];
            let spec = ModelSpec::for_variant(first.variant)?;
            let plan = self.plan_for(&spec, first.px, first.steps);
            self.sync_cache_metrics();
            let per_step = plan.per_step(first.steps);
            let remaining: usize =
                batch.requests.iter().map(|r| r.steps - r.steps_done.min(r.steps)).sum();
            let est_finish = self.now + per_step * remaining as f64;
            if per_step <= 0.0 || !per_step.is_finite() || est_finish <= at {
                // finishes by the crash instant (or is unpriceable, in
                // which case slicing is meaningless): run it whole
                self.metrics.ticks += 1;
                out.extend(self.execute_batch(batch)?);
                continue;
            }
            // the crash lands mid-batch: credit each member the whole
            // fair-share steps of the [now, at) window — the same
            // arithmetic as maybe_preempt, but unconditional (a crash
            // does not check SLO classes or preemption budgets)
            let window = at - self.now;
            let k = (window / (per_step * batch.len() as f64)).floor() as usize;
            let mut charged = 0.0;
            for mut r in batch.requests {
                let rem = r.steps - r.steps_done.min(r.steps);
                let credit = k.min(rem.saturating_sub(1));
                charged += credit as f64 * per_step;
                credited += credit as u64;
                r.steps_done += credit;
                self.waiting.push(r);
            }
            self.metrics.model_seconds += charged;
            self.metrics.stages.denoise_busy += charged;
            self.metrics.checkpoint_steps += credited;
            self.now = at;
            self.metrics.horizon = self.horizon();
            break;
        }
        // an idle (or early-finished) replica still dies at `at`
        self.advance_to(at);
        Ok((out, credited))
    }

    /// Evacuate every admitted-but-unserved request (failover migration):
    /// the admission queue and waiting set empty out, per-class pending
    /// counters reset, and the orphans come back sorted by (arrival, id)
    /// so surviving replicas admit them in a deterministic order.
    /// Progress already credited (`steps_done`) rides along — the
    /// checkpoint that makes migration resume instead of redo.
    pub fn drain_pending(&mut self) -> Vec<GenRequest> {
        let mut out = self.queue.drain_upto(usize::MAX);
        out.extend(self.waiting.drain());
        for r in &out {
            let c = &mut self.pending_by_class[r.slo.index()];
            *c = c.saturating_sub(1);
        }
        out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        out
    }

    /// Decode-stage backlog: queued decodes whose start lies past the
    /// denoise clock (staged mode; always 0 in serial mode, where the
    /// decode deque stays empty). The fleet folds this into
    /// `ReplicaView` so dispatch can see a replica whose decoder is the
    /// bottleneck even when its denoise queue looks short.
    pub fn stage_backlog(&self) -> usize {
        self.decode_starts.iter().filter(|&&s| s > self.now).count()
    }

    /// Earliest declared deadline over the admitted-but-unserved backlog
    /// (∞ when nothing pending declares one) — O(#groups) through the
    /// waiting set's bucket aggregates plus a scan of the short admission
    /// queue. The fleet derives SLO deadline pressure from it.
    pub fn min_pending_deadline(&self) -> f64 {
        self.waiting.min_deadline().min(self.queue.min_deadline())
    }

    /// Serve exactly this window of requests to completion, bypassing the
    /// admission bound (the one-shot / legacy path — nothing is ever
    /// rejected). The engine's live backlog (`submit`/`tick`) is left
    /// untouched: the window runs on its own waiting set, so mixing
    /// `generate`/`serve` with the continuous API never steals or returns
    /// someone else's requests. Returns responses in completion order.
    pub fn serve(&mut self, window: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let mut local = WaitingSet::new(self.batcher.aging_rate);
        let mut out = Vec::with_capacity(window.len());
        local.extend(window);
        while let Some(batch) = self.batcher.next_batch_indexed(&mut local, self.now) {
            self.metrics.ticks += 1;
            out.extend(self.execute_batch(batch)?);
        }
        Ok(out)
    }

    /// Scheduler for a request: request override > engine default > model
    /// benchmark scheduler. No literal anywhere on this path.
    fn scheduler_for(&self, spec: &ModelSpec, req: &GenRequest) -> Result<SchedulerKind> {
        match req.scheduler.or(self.default_scheduler) {
            Some(kind) => Ok(kind),
            None => SchedulerKind::parse(spec.scheduler),
        }
    }

    /// Run one compatibility batch on the simulated cluster: route, build
    /// the shared session, generate back-to-back, account the split times.
    fn execute_batch(&mut self, batch: Batch) -> Result<Vec<GenResponse>> {
        let mut out = Vec::with_capacity(batch.len());
        self.metrics.observe_batch(batch.len());
        let rt = self.rt;
        let first = &batch.requests[0];
        let spec = ModelSpec::for_variant(first.variant)?;
        // the plan follows the requested resolution and step count (the
        // batch key guarantees they are uniform across the batch); the
        // key and cluster fingerprint are built once per batch and shared
        // with the sim memo and the session cache below
        let key = self.plan_key(&spec, first.px, first.steps);
        let cluster_fp = fingerprint(&self.cluster);
        let plan = self.plan_for_keyed(&key, cluster_fp, &spec, first.px, first.steps);
        let pc = plan.config;
        let method = self.force_method.unwrap_or_else(|| pick_method(&pc));
        // one event-simulation per *shape*, not per batch: the makespan is
        // a pure function of the plan key, so it is memoized next to the
        // plan (a plan scored at Fidelity::Simulated already carries it).
        // Responses report simulated vs closed-form vs virtual-actual
        // seconds side by side. (`cached_sim` is bound to a local first —
        // a match scrutinee would keep the RefMut borrow alive into the
        // arm that needs to borrow again.)
        let simulated_seconds = match plan.simulated_seconds {
            Some(s) => s,
            None => {
                let memoized = self.plan_cache.borrow_mut().cached_sim(&key);
                match memoized {
                    Some(s) => s,
                    None => {
                        let s = self
                            .planner(first.steps)
                            .simulate_plan(&plan, &spec, &self.cluster)
                            .makespan;
                        self.plan_cache.borrow_mut().store_sim(&key, s);
                        s
                    }
                }
            }
        };

        // one session per batch, *recycled* across batches of the same
        // (variant, px, config): a warm session gets its clocks and comm
        // ledger reset, making it observationally identical to a fresh
        // build (model, mesh and config are pure functions of the key)
        let skey = (first.variant, first.px, pc);
        self.sessions.check_cluster(cluster_fp);
        let mut sess = match self.sessions.take(&skey) {
            Some(mut warm) => {
                warm.clocks.reset();
                warm.ledger.ops.clear();
                self.metrics.sessions_reused += 1;
                warm
            }
            None => {
                let built = Session::new(rt, first.variant, self.cluster.clone(), pc)?;
                self.metrics.sessions_built += 1;
                built
            }
        };

        for req in &batch.requests {
            let scheduler = self.scheduler_for(&spec, req)?;
            let params = GenParams {
                prompt: req.prompt.clone(),
                steps: req.steps,
                seed: req.seed,
                guidance: req.guidance,
                scheduler,
            };
            // the session's clocks/ledger persist across the batch;
            // driver::generate reports per-generation deltas
            let r = driver::generate(&mut sess, method, &params)?;
            // progress credit: a preempted request already paid for
            // `steps_done` of its steps in the slice window, so only the
            // remaining fraction is charged here. `frac` is exactly 1.0
            // for never-preempted requests (`x * 1.0 == x` bit-exactly,
            // so the pre-SLO timing arithmetic is unchanged).
            let done = req.steps_done.min(req.steps);
            let frac = if req.steps == 0 {
                1.0
            } else {
                (req.steps - done) as f64 / req.steps as f64
            };
            let model_seconds = r.makespan * frac;
            let comm_bytes = r.comm_bytes;

            let mut image = None;
            let mut decode_time = 0.0;
            if req.decode {
                let n = self.vae_parallelism.unwrap_or_else(|| pc.world().min(8)).max(1);
                let (img, t) = self.decode_latent(&r.latent, n)?;
                image = Some(img);
                decode_time = t;
            }
            let (start, exec, finish) = if self.stage_overlap {
                self.staged_times(req.arrival, req.decode, model_seconds, decode_time)
            } else {
                // the serial reference path: denoise + decode charged
                // back-to-back on the single clock (kept literal so the
                // bit-identity of the off mode is auditable)
                let start = self.now.max(req.arrival);
                let exec = model_seconds + decode_time;
                let finish = start + exec;
                self.now = finish;
                (start, exec, finish)
            };
            self.metrics.stages.denoise_busy += model_seconds;
            self.metrics.stages.decode_busy += decode_time;
            let latency = finish - req.arrival;
            self.metrics.observe_latency(req.slo, latency);
            self.metrics.queue_delay.observe(start - req.arrival);
            self.metrics.exec_time.observe(exec);
            if matches!(req.deadline, Some(d) if finish > d) {
                self.metrics.observe_deadline_miss(req.slo);
            }
            self.metrics.served += 1;
            self.metrics.model_seconds += model_seconds;
            self.dec_pending(req.slo);
            out.push(GenResponse {
                id: req.id,
                latent: r.latent,
                image,
                model_seconds,
                latency,
                comm_bytes,
                parallel_config: pc.describe(),
                predicted_seconds: plan.predicted.total,
                simulated_seconds,
                method: r.method,
                scheduler: scheduler.key().to_string(),
                px: req.px,
            });
        }
        self.metrics.horizon = self.horizon();
        self.sessions.store(skey, sess);
        self.sync_cache_metrics();
        Ok(out)
    }

    /// Staged-mode timing of one request: advance the per-stage clocks
    /// and return `(start, exec, finish)`.
    ///
    /// Recurrences (request k, arrival `a`, denoise `m`, decode `d`):
    /// * encode finishes at `e = max(enc_clock, a)` (zero-cost stage);
    /// * denoise starts at `start = max(now, e, gate)` where `gate` is
    ///   the decode *start* of the request `capacity` decodes back — the
    ///   bounded-queue backpressure (a denoise may not finish into a full
    ///   queue, so it is not launched before a slot frees);
    /// * denoise finishes at `now = start + m`;
    /// * the decode runs `[max(dec_clock, now), .. + d]` on the decode
    ///   clock, overlapping later denoises.
    ///
    /// Induction vs the serial path (`S_k = max(F_{k-1}, a_k)`,
    /// `F_k = S_k + m_k + d_k`): every staged clock is `<= F_{k-1}` when
    /// request k launches, so `start_k <= S_k` and `finish_k <= F_k` —
    /// the staged makespan is never worse, and strictly better whenever a
    /// decode overlaps the next denoise. `tests/stages.rs` property-tests
    /// both directions.
    fn staged_times(
        &mut self,
        arrival: f64,
        decode: bool,
        model_seconds: f64,
        decode_time: f64,
    ) -> (f64, f64, f64) {
        let e_fin = self.enc_clock.max(arrival);
        self.enc_clock = e_fin;
        let cap = self.stage_queue_capacity.max(1);
        let ready = self.now.max(e_fin);
        let gate = match (decode, self.decode_starts.front()) {
            (true, Some(&slot)) if self.decode_starts.len() >= cap => slot,
            _ => 0.0,
        };
        let start = ready.max(gate);
        if start > ready {
            self.metrics.stages.decode_stalls += 1;
            self.metrics.stages.stall_seconds += start - ready;
        }
        let den_fin = start + model_seconds;
        self.now = den_fin;
        if !decode {
            return (start, den_fin - start, den_fin);
        }
        let v_start = self.dec_clock.max(den_fin);
        let v_fin = v_start + decode_time;
        self.dec_clock = v_fin;
        // queue depth at enqueue: this request plus every earlier decode
        // the decoder has not yet started (bounded by `cap` via the gate)
        let depth = 1 + self.decode_starts.iter().filter(|&&s| s > den_fin).count();
        self.metrics.stages.queue_depth.observe(depth);
        self.decode_starts.push_back(v_start);
        while self.decode_starts.len() > cap {
            self.decode_starts.pop_front();
        }
        (start, v_fin - start, v_fin)
    }

    /// Decode a final latent with the engine-owned parallel VAE over `n`
    /// simulated devices. Returns the image and the simulated decode time.
    /// Also tracks the peak per-device activation bytes of the decode in
    /// `Metrics::stages` (the `vae::memory` budget quantity).
    pub fn decode_latent(&mut self, latent: &Tensor, n: usize) -> Result<(Tensor, f64)> {
        self.ensure_vae()?;
        let vae = self.vae.as_ref().unwrap();
        let z = latent.reshape(&[vae.hw, vae.hw, vae.c])?;
        let peak = crate::vae::vae_peak_bytes(8 * vae.hw, vae.c) / n.max(1) as f64;
        let mut clocks = Clocks::new(self.cluster.n_gpus);
        let img = vae.decode_parallel(&z, n, &self.cluster, &mut clocks)?;
        if peak > self.metrics.stages.decode_peak_bytes {
            self.metrics.stages.decode_peak_bytes = peak;
        }
        Ok((img, clocks.makespan()))
    }

    /// Current end of the virtual serving horizon (seconds since engine
    /// start) — where the next arriving request would start *denoising*.
    /// In staged mode the decode stage may still be draining past this
    /// point; [`Engine::horizon`] includes that tail.
    pub fn virtual_now(&self) -> f64 {
        self.now
    }

    /// True end of the run across all stages: the denoise clock or the
    /// decode drain, whichever is later. Equal to [`virtual_now`] when
    /// staging is off.
    ///
    /// [`virtual_now`]: Engine::virtual_now
    pub fn horizon(&self) -> f64 {
        self.now.max(self.dec_clock)
    }

    /// Advance the virtual clocks to `t` (idle gap between arrivals in a
    /// trace replay). Never moves backwards — and never *below* a stage
    /// clock that is already past `t`.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
        if t > self.enc_clock {
            self.enc_clock = t;
        }
        if t > self.dec_clock {
            self.dec_clock = t;
        }
    }

    /// Exact single-device decode (the reference the parallel path is
    /// checked against).
    pub fn decode_reference(&mut self, latent: &Tensor) -> Result<Tensor> {
        self.ensure_vae()?;
        let vae = self.vae.as_ref().unwrap();
        let z = latent.reshape(&[vae.hw, vae.hw, vae.c])?;
        vae.decode_full(&z)
    }

    fn ensure_vae(&mut self) -> Result<()> {
        if self.vae.is_none() {
            self.vae = Some(ParallelVae::new(self.rt)?);
            self.metrics.vae_builds += 1;
        }
        Ok(())
    }
}

/// Strategy implied by a hybrid config.
pub fn pick_method(pc: &ParallelConfig) -> driver::Method {
    if pc.pipefusion > 1 && pc.sp_degree() > 1 {
        driver::Method::Hybrid
    } else if pc.pipefusion > 1 {
        driver::Method::PipeFusion
    } else if pc.sp_degree() > 1 {
        driver::Method::Sp
    } else {
        driver::Method::Serial
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;

    fn setup() -> Runtime {
        // real artifacts when built, hermetic simulator otherwise — the
        // scheduling semantics under test are identical
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::load_or_simulated(dir).unwrap()
    }

    #[test]
    fn serves_batch_and_records_metrics() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut reqs = Vec::new();
        for i in 0..3u64 {
            let mut r = GenRequest::new(i, format!("prompt {i}"));
            r.steps = 2;
            r.arrival = i as f64 * 0.01;
            reqs.push(r);
        }
        let out = eng.serve(reqs).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(eng.metrics.served, 3);
        assert!(eng.metrics.throughput() > 0.0);
        // identical batch keys -> one shared session for all three
        assert_eq!(eng.metrics.sessions_built, 1);
        assert_eq!(eng.metrics.batches, 1);
        assert_eq!(eng.metrics.occupancy_max, 3);
        // completion order preserves arrival order within a batch
        assert!(out[0].latency <= out[2].latency + out[2].model_seconds);
        for r in &out {
            assert_eq!(r.latent.dims, vec![256, 4]);
            assert!(r.model_seconds > 0.0);
        }
        // the split accounting adds up
        assert_eq!(eng.metrics.queue_delay.count, 3);
        assert_eq!(eng.metrics.exec_time.count, 3);
    }

    #[test]
    fn staged_times_bounds_the_decode_queue_with_backpressure() {
        // synthetic stage durations so the magnitudes are controlled:
        // decode (1.0s) is 10x slower than denoise (0.1s), so the
        // denoise→decode queue must fill and stall the denoiser
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.stage_overlap = true;
        eng.stage_queue_capacity = 1;
        let mut finish = 0.0;
        for _ in 0..8 {
            finish = eng.staged_times(0.0, true, 0.1, 1.0).2;
        }
        let s = &eng.metrics.stages;
        assert!(s.decode_stalls > 0, "cap=1 with decode >> denoise must stall");
        assert!(s.stall_seconds > 0.0);
        assert!(s.queue_depth.max() <= 1, "depth above capacity: {}", s.queue_depth.max());
        assert_eq!(s.queue_depth.count, 8);
        // never worse than the serial reference 8·(0.1 + 1.0), and
        // strictly better because decode overlaps the next denoise
        assert!(finish <= 8.0 * 1.1 + 1e-9);
        assert!(finish < 8.0 * 1.1 - 1e-9, "decode must overlap denoise");
        // decode-heavy steady state: one decode in flight back to back
        assert!((eng.horizon() - finish).abs() < 1e-12);
        assert!(eng.virtual_now() < eng.horizon(), "decode tail drains past the denoise clock");

        // a roomier queue stalls strictly less and never lands later
        let rt2 = setup();
        let mut wide = Engine::new(&rt2, l40_cluster(1), 4);
        wide.stage_overlap = true;
        wide.stage_queue_capacity = 4;
        let mut wide_finish = 0.0;
        for _ in 0..8 {
            wide_finish = wide.staged_times(0.0, true, 0.1, 1.0).2;
        }
        assert!(wide.metrics.stages.stall_seconds <= s.stall_seconds);
        assert!(wide_finish <= finish + 1e-9);
        assert!(wide.metrics.stages.queue_depth.max() <= 4);
    }

    #[test]
    fn vae_is_built_once_per_engine() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut reqs = Vec::new();
        for i in 0..3u64 {
            let mut r = GenRequest::new(i, "decode me");
            r.steps = 2;
            r.decode = true;
            reqs.push(r);
        }
        let out = eng.serve(reqs).unwrap();
        assert!(out.iter().all(|r| r.image.is_some()));
        assert_eq!(eng.metrics.vae_builds, 1, "VAE must be reused across requests");
        // a second window still reuses it
        let mut r = GenRequest::new(9, "again");
        r.steps = 2;
        r.decode = true;
        eng.serve(vec![r]).unwrap();
        assert_eq!(eng.metrics.vae_builds, 1);
    }

    #[test]
    fn submit_backpressure_at_capacity() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.set_queue_capacity(2);
        assert!(eng.submit(GenRequest::new(0, "a")).is_ok());
        assert!(eng.submit(GenRequest::new(1, "b")).is_ok());
        let rej = eng.submit(GenRequest::new(2, "c")).unwrap_err();
        assert_eq!(rej.id, 2);
        assert!(rej.reason.contains("backpressure"), "{}", rej.reason);
        assert_eq!(eng.metrics.rejected, 1);
        assert_eq!(eng.pending(), 2);
        // a tick drains the queue, freeing capacity for new admissions
        let mut r = GenRequest::new(3, "d");
        r.steps = 1;
        let served = eng.tick().unwrap();
        assert_eq!(served.len(), 2);
        assert!(eng.submit(r).is_ok());
    }

    #[test]
    fn tick_launches_one_batch_and_idles_when_empty() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        // two incompatible groups -> two ticks to drain
        let mut a = GenRequest::new(0, "a");
        a.steps = 1;
        let mut b = GenRequest::new(1, "b");
        b.steps = 2;
        eng.submit(a).unwrap();
        eng.submit(b).unwrap();
        let first = eng.tick().unwrap();
        assert_eq!(first.len(), 1);
        let second = eng.tick().unwrap();
        assert_eq!(second.len(), 1);
        assert!(eng.tick().unwrap().is_empty(), "idle tick");
        assert_eq!(eng.metrics.idle_ticks, 1);
        assert_eq!(eng.metrics.batches, 2);
        // one session per batch, some possibly warm from the cache (the
        // two groups share a config iff the planner routes steps=1 and
        // steps=2 identically)
        assert_eq!(eng.metrics.sessions_built + eng.metrics.sessions_reused, 2);
        assert!(eng.metrics.sessions_built >= 1);
    }

    #[test]
    fn warm_sessions_stop_scaling_with_batch_count() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let window = |base: u64| -> Vec<GenRequest> {
            (0..2u64)
                .map(|i| {
                    let mut r = GenRequest::new(base + i, "warm");
                    r.steps = 1;
                    r
                })
                .collect()
        };
        // four identical-shape batches: one cold build, three warm reuses
        for w in 0..4u64 {
            eng.serve(window(10 * w)).unwrap();
        }
        assert_eq!(eng.metrics.batches, 4);
        assert_eq!(eng.metrics.sessions_built, 1, "repeat shapes must reuse the session");
        assert_eq!(eng.metrics.sessions_reused, 3);
        // plan memoization: one cold sweep, the rest hits
        assert_eq!(eng.metrics.plan_cache_misses, 1);
        assert_eq!(eng.metrics.plan_cache_hits, 3);

        // capacity 0 restores the cold-build path exactly
        let mut cold = Engine::new(&rt, l40_cluster(1), 4);
        cold.set_session_cache_capacity(0);
        for w in 0..4u64 {
            cold.serve(window(10 * w)).unwrap();
        }
        assert_eq!(cold.metrics.sessions_built, 4);
        assert_eq!(cold.metrics.sessions_reused, 0);
    }

    #[test]
    fn warm_and_cold_paths_serve_identical_responses() {
        let rt = setup();
        let window = || -> Vec<GenRequest> {
            (0..6u64)
                .map(|i| {
                    let mut r = GenRequest::new(i, format!("prompt {i}"));
                    r.steps = 1;
                    r.arrival = i as f64 * 0.01;
                    r
                })
                .collect()
        };
        let mut warm = Engine::new(&rt, l40_cluster(1), 4);
        // pre-warm both caches with a separate batch of the same shape
        let mut primer = GenRequest::new(99, "primer");
        primer.steps = 1;
        warm.serve(vec![primer]).unwrap();
        let a = warm.serve(window()).unwrap();
        assert!(warm.metrics.sessions_reused > 0 && warm.metrics.plan_cache_hits > 0);

        let mut cold = Engine::new(&rt, l40_cluster(1), 4);
        cold.set_session_cache_capacity(0);
        cold.set_plan_cache_enabled(false);
        let mut primer = GenRequest::new(99, "primer");
        primer.steps = 1;
        cold.serve(vec![primer]).unwrap();
        let b = cold.serve(window()).unwrap();
        assert_eq!(cold.metrics.plan_cache_hits, 0);

        // caching changes cost, never answers: bit-identical responses
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.latent, y.latent, "warm session must replay bit-identically");
            assert_eq!(x.model_seconds, y.model_seconds);
            assert_eq!(x.latency, y.latency);
            assert_eq!(x.comm_bytes, y.comm_bytes);
            assert_eq!(x.parallel_config, y.parallel_config);
            assert_eq!(x.predicted_seconds, y.predicted_seconds);
            assert_eq!(x.simulated_seconds, y.simulated_seconds);
        }
    }

    #[test]
    fn cluster_change_invalidates_the_plan_cache() {
        use crate::config::hardware::a100_node;
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let spec = ModelSpec::for_variant(crate::config::model::BlockVariant::AdaLn).unwrap();
        let before = eng.plan_for(&spec, 256, 2);
        assert_eq!(eng.plan_for(&spec, 256, 2).config, before.config); // hit
        let (hits, _, _) = eng.plan_cache.borrow().counters();
        assert_eq!(hits, 1);
        // mutate the cluster in place: the cache must self-invalidate and
        // the fresh plan must match a cold engine on the new cluster
        eng.cluster = a100_node();
        let after = eng.plan_for(&spec, 256, 2);
        let oracle = Engine::new(&rt, a100_node(), 4).plan_for(&spec, 256, 2);
        assert_eq!(after.config, oracle.config);
        assert_eq!(after.predicted.total, oracle.predicted.total);
        let (_, _, invalidations) = eng.plan_cache.borrow().counters();
        assert_eq!(invalidations, 1);
    }

    #[test]
    fn deadline_misses_are_counted() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut r = GenRequest::new(0, "tight");
        r.steps = 2;
        r.deadline = Some(1e-12); // cannot possibly be met
        eng.serve(vec![r]).unwrap();
        assert_eq!(eng.metrics.deadline_misses, 1);
        let mut r = GenRequest::new(1, "loose");
        r.steps = 2;
        r.deadline = Some(1e9);
        eng.serve(vec![r]).unwrap();
        assert_eq!(eng.metrics.deadline_misses, 1);
    }

    #[test]
    fn deadline_admission_rejects_only_infeasible_requests() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.deadline_admission = true;
        // impossible deadline: rejected at submit time, with the plan in
        // the reason so callers see *why* it could not be met
        let mut r = GenRequest::new(0, "too tight");
        r.steps = 2;
        r.deadline = Some(1e-15);
        let rej = eng.submit(r).unwrap_err();
        assert!(rej.reason.contains("deadline infeasible"), "{}", rej.reason);
        assert_eq!(eng.metrics.rejected, 1);
        assert_eq!(eng.pending(), 0);
        // generous deadline and no deadline are both admissible
        let mut ok = GenRequest::new(1, "fine");
        ok.steps = 2;
        ok.deadline = Some(1e9);
        eng.submit(ok).unwrap();
        eng.submit(GenRequest::new(2, "no deadline")).unwrap();
        assert_eq!(eng.pending(), 2);
        // admission stays opt-in: the default engine serves hopeless
        // deadlines and only counts the miss
        let mut off = Engine::new(&rt, l40_cluster(1), 4);
        let mut hopeless = GenRequest::new(3, "tight but admitted");
        hopeless.deadline = Some(1e-15);
        off.submit(hopeless).unwrap();
    }

    #[test]
    fn batch_routing_follows_the_planner() {
        use crate::config::model::BlockVariant;
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let mut r = GenRequest::new(0, "planned");
        r.steps = 2;
        let out = eng.serve(vec![r]).unwrap();
        let spec = ModelSpec::for_variant(BlockVariant::AdaLn).unwrap();
        let plan = eng.plan_for(&spec, 256, 2);
        assert_eq!(out[0].parallel_config, plan.config.describe());
        assert_eq!(out[0].predicted_seconds, plan.predicted.total);
        assert!(out[0].predicted_seconds > 0.0);
        // the per-batch event simulation rides along in every response
        assert!(out[0].simulated_seconds > 0.0);
        assert_eq!(out[0].simulated_seconds, out.last().unwrap().simulated_seconds);
    }

    #[test]
    fn cancel_queued_and_midflight_requests() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.set_queue_capacity(2);
        eng.submit(GenRequest::new(0, "a")).unwrap();
        eng.submit(GenRequest::new(1, "b")).unwrap();
        // cancel-while-queued refunds the admission slot immediately
        assert_eq!(eng.cancel(1), CancelOutcome::Queued);
        assert_eq!(eng.metrics.cancelled_queued, 1);
        assert_eq!(eng.pending(), 1);
        eng.submit(GenRequest::new(2, "c")).expect("cancellation refunded capacity");
        // two incompatible groups: one tick serves one, parks the other
        // in the waiting set — cancel it mid-flight
        let mut r = GenRequest::new(3, "d");
        r.steps = 8;
        assert_eq!(eng.cancel(3), CancelOutcome::NotFound, "not yet submitted");
        let served = eng.tick().unwrap();
        assert_eq!(served.len(), 2);
        eng.submit(r).unwrap();
        eng.submit(GenRequest::new(4, "e")).unwrap();
        let first = eng.tick().unwrap();
        assert_eq!(first.len(), 1, "one group launches, the other waits");
        let waiting_id = if first[0].id == 3 { 4 } else { 3 };
        assert_eq!(eng.cancel(waiting_id), CancelOutcome::MidFlight);
        assert_eq!(eng.metrics.cancelled_midflight, 1);
        assert_eq!(eng.pending(), 0);
        // a cancelled request is never served and cancel is idempotent
        assert!(eng.tick().unwrap().is_empty());
        assert_eq!(eng.cancel(waiting_id), CancelOutcome::NotFound);
        assert_eq!(eng.metrics.served, 3);
        assert_eq!(eng.metrics.cancelled(), 2);
    }

    #[test]
    fn slo_budgets_cap_per_class_admission() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.slo_budgets[SloClass::Batch.index()] = Some(1);
        let bulk = |id: u64| GenRequest::new(id, "bulk").with_slo(SloClass::Batch);
        eng.submit(bulk(0)).unwrap();
        let rej = eng.submit(bulk(1)).unwrap_err();
        assert!(rej.reason.contains("slo budget"), "{}", rej.reason);
        // other classes are not charged against the batch budget
        eng.submit(GenRequest::new(2, "std")).unwrap();
        // cancellation refunds the class budget ...
        assert_eq!(eng.cancel(0), CancelOutcome::Queued);
        eng.submit(bulk(3)).unwrap();
        // ... and so does completion
        while !eng.tick().unwrap().is_empty() {}
        eng.submit(bulk(4)).unwrap();
        assert_eq!(eng.metrics.rejected, 1);
    }

    #[test]
    fn degrade_ladder_sheds_steps_then_resolution() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        eng.degrade = true;
        eng.set_queue_capacity(4);
        let bulk = |id: u64| GenRequest::new(id, "bulk").with_slo(SloClass::Batch);
        for id in 0..4u64 {
            eng.submit(bulk(id)).unwrap();
        }
        // backlog 0,1: untouched; backlog 2 (≥ cap/2): steps halve;
        // backlog 3 (≥ 3·cap/4): resolution halves too
        assert_eq!(eng.metrics.degraded, 2);
        let mut responses = Vec::new();
        while let Ok(out) = eng.tick() {
            if out.is_empty() {
                break;
            }
            responses.extend(out);
        }
        assert_eq!(responses.len(), 4);
        assert!(responses.iter().any(|r| r.px == MIN_DEGRADE_PX), "level-2 degrade missing");
        assert!(responses.iter().any(|r| r.px == 256), "early admissions must stay untouched");
        // standard-tier requests are never degraded, even under overload
        let mut eng2 = Engine::new(&rt, l40_cluster(1), 4);
        eng2.degrade = true;
        eng2.set_queue_capacity(2);
        eng2.submit(GenRequest::new(0, "a")).unwrap();
        eng2.submit(GenRequest::new(1, "b")).unwrap();
        assert_eq!(eng2.metrics.degraded, 0);
    }

    #[test]
    fn preemption_slices_batch_work_and_keeps_latents_bit_identical() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(1), 4);
        let spec = ModelSpec::for_variant(BlockVariant::AdaLn).unwrap();
        let total = eng.plan_for(&spec, 256, 4).predicted.total;
        assert!(total > 0.0);
        let mut bulk = GenRequest::new(0, "bulk").with_slo(SloClass::Batch);
        bulk.steps = 4;
        eng.submit(bulk).unwrap();
        // an interactive request lands 60% into the batch with a deadline
        // that waiting would miss but prompt service meets
        let arr = 0.6 * total;
        let dl = 1.7 * total;
        eng.set_preempt_lookahead(Some((arr, Some(dl), total)));
        let out = eng.tick().unwrap();
        assert!(out.is_empty(), "the batch must yield, not complete");
        assert_eq!(eng.metrics.preemptions, 1);
        assert_eq!(eng.virtual_now(), arr, "the clock advances to the interactive arrival");
        assert_eq!(eng.pending(), 1, "the preempted request re-entered the waiting set");
        // the stale lookahead (arr <= now) no longer preempts: the batch
        // resumes and finishes, charged only for its remaining steps
        let out = eng.tick().unwrap();
        assert_eq!(out.len(), 1);
        let resumed = &out[0];
        let mut control = Engine::new(&rt, l40_cluster(1), 4);
        control.preemption = false;
        let mut same = GenRequest::new(0, "bulk").with_slo(SloClass::Batch);
        same.steps = 4;
        let ctrl = control.serve(vec![same]).unwrap();
        assert_eq!(resumed.latent, ctrl[0].latent, "preemption must not change output bits");
        assert!(
            resumed.model_seconds < ctrl[0].model_seconds,
            "progress credit: only remaining steps charged ({} vs {})",
            resumed.model_seconds,
            ctrl[0].model_seconds
        );
        // interactive batches are never preempted
        let mut eng2 = Engine::new(&rt, l40_cluster(1), 4);
        let mut int = GenRequest::new(1, "urgent").with_slo(SloClass::Interactive);
        int.steps = 4;
        eng2.submit(int).unwrap();
        eng2.set_preempt_lookahead(Some((arr, Some(dl), total)));
        assert_eq!(eng2.tick().unwrap().len(), 1);
        assert_eq!(eng2.metrics.preemptions, 0);
    }

    #[test]
    fn cluster_events_mutate_topology_and_invalidate_caches_once() {
        let rt = setup();
        let mut eng = Engine::new(&rt, l40_cluster(2), 16);
        let spec = ModelSpec::for_variant(BlockVariant::AdaLn).unwrap();
        eng.plan_for(&spec, 256, 2); // prime: first check records the fp
        let gpus = eng.cluster.n_gpus;
        // a straggler slowdown flips the fingerprint exactly once
        eng.apply_cluster_event(TraceEventKind::Straggler(0.5));
        eng.plan_for(&spec, 256, 2);
        let (_, _, inv) = eng.plan_cache.borrow().counters();
        assert_eq!(inv, 1, "one mutation, one invalidation");
        // planning again without a new event does NOT invalidate again
        eng.plan_for(&spec, 256, 2);
        let (_, _, inv) = eng.plan_cache.borrow().counters();
        assert_eq!(inv, 1);
        // rank failure loses one GPU and clamps the serving world
        eng.apply_cluster_event(TraceEventKind::RankFail);
        assert_eq!(eng.cluster.n_gpus, gpus - 1);
        assert!(eng.world <= eng.cluster.n_gpus);
        eng.plan_for(&spec, 256, 2);
        let (_, _, inv) = eng.plan_cache.borrow().counters();
        assert_eq!(inv, 2);
        // shrink then grow moves a whole node each way
        eng.apply_cluster_event(TraceEventKind::NodeShrink);
        assert_eq!(eng.cluster.n_gpus, gpus - 1 - eng.cluster.gpus_per_node);
        eng.apply_cluster_event(TraceEventKind::NodeGrow);
        assert_eq!(eng.cluster.n_gpus, gpus - 1);
        // cancel events route to Engine::cancel (no topology change)
        eng.submit(GenRequest::new(7, "x")).unwrap();
        eng.apply_cluster_event(TraceEventKind::Cancel(7));
        assert_eq!(eng.metrics.cancelled_queued, 1);
    }

    #[test]
    fn method_picker() {
        assert_eq!(pick_method(&ParallelConfig::new(2, 2, 2, 1)), driver::Method::Hybrid);
        assert_eq!(pick_method(&ParallelConfig::new(2, 4, 1, 1)), driver::Method::PipeFusion);
        assert_eq!(pick_method(&ParallelConfig::new(1, 1, 2, 2)), driver::Method::Sp);
        assert_eq!(pick_method(&ParallelConfig::new(2, 1, 1, 1)), driver::Method::Serial);
    }
}
