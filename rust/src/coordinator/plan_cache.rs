//! Memoized routing decisions: the [`PlanCache`] behind `Engine::plan_for`.
//!
//! A routing [`Plan`] is a *pure function* of
//! `(model, px, steps, world, policy, fidelity, memory cap, forced
//! config/method, forced collective algorithm)` and of the cluster spec —
//! yet before this cache the
//! engine re-ran `ParallelConfig::enumerate` plus the full latency /
//! memory / comm scoring sweep for **every launched batch**, even when
//! thousands of requests in a row shared the same shape. The cache keys
//! the decision on exactly that tuple ([`PlanKey`]) and pays one clone on
//! a hit instead of a full enumeration, which is what makes the
//! coordinator's control plane effectively free at steady state
//! (`benches/steady_state.rs` gates cached planning at ≥ 10× cold).
//!
//! **Pure memoization, never a behavior change.** A hit returns a clone
//! of the plan a cold `Planner` run produced for the same key, so cached
//! and cold plans are byte-identical (`tests/serving.rs` /
//! `tests/planner.rs` property-test this across the figs 8–17 grid, and
//! the golden `route --grid` snapshot is pinned unchanged). The planner
//! itself stays cache-free; only the engine front-end memoizes.
//!
//! **Invalidation.** The cache remembers a [`fingerprint`] of the cluster
//! spec it was filled against; a lookup under a different cluster clears
//! everything first (self-healing even when `Engine::cluster` is mutated
//! in place). Entries are evicted least-recently-used beyond
//! [`DEFAULT_PLAN_CACHE_CAPACITY`].
//!
//! Alongside each plan the cache can memoize the batch-launch event
//! simulation (`simulate_plan(..).makespan`) for the same key — the other
//! per-batch recomputation on the serve hot path.

use std::collections::HashMap;

use crate::config::hardware::{ClusterSpec, CollectiveAlgo};
use crate::config::parallel::ParallelConfig;
use crate::coordinator::planner::{Fidelity, Plan, RoutePolicy};
use crate::parallel::driver;

/// Default bound on distinct memoized routing decisions.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 256;

/// Everything a routing decision is a function of (besides the cluster,
/// which the cache tracks via [`fingerprint`]). Two engine batches with
/// equal keys are guaranteed the same plan.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Model the plan is for (`ModelSpec::name`).
    pub model: String,
    /// Target resolution (pixels, square).
    pub px: usize,
    /// Diffusion steps the prediction assumes.
    pub steps: usize,
    /// Devices the plan must fill.
    pub world: usize,
    /// Scoring policy (cost-model vs paper heuristic).
    pub policy: RoutePolicy,
    /// Scoring fidelity (closed forms vs simulator re-scoring).
    pub fidelity: Fidelity,
    /// Per-GPU HBM budget in f64 bits (`None` = cluster capacity).
    pub memory_cap_bits: Option<u64>,
    /// Engine-pinned config, if any (`Engine::force_config`).
    pub force_config: Option<ParallelConfig>,
    /// Engine-forced strategy, if any (`Engine::force_method`).
    pub force_method: Option<driver::Method>,
    /// Engine-pinned collective algorithm, if any (`None` = planner
    /// auto-selects per candidate). Part of the key because the same
    /// `(model, px, world, ...)` tuple prices differently under flat vs
    /// hierarchical collectives.
    pub collective_algo: Option<CollectiveAlgo>,
}

/// Stable-within-a-run fingerprint of a cluster spec: covers the topology
/// numbers (both tiers — node count and the inter-node link included), the
/// GPU spec and the identity of the link-model functions. Used to
/// invalidate the plan/session caches when the engine's cluster changes
/// (including in-place mutation of the public field).
pub fn fingerprint(c: &ClusterSpec) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    let mut fold = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    };
    fold(c.name.as_bytes());
    fold(&(c.n_gpus as u64).to_le_bytes());
    fold(&(c.gpus_per_node as u64).to_le_bytes());
    fold(&(c.gpus_per_numa as u64).to_le_bytes());
    fold(&(c.n_nodes() as u64).to_le_bytes());
    fold(&c.inter_node.bw.to_bits().to_le_bytes());
    fold(&c.inter_node.lat.to_bits().to_le_bytes());
    fold(&[c.has_nvlink as u8]);
    fold(c.gpu.name.as_bytes());
    fold(&c.gpu.tflops.to_bits().to_le_bytes());
    fold(&c.gpu.mem_bytes.to_bits().to_le_bytes());
    // fn-pointer identities: distinct link models hash differently even
    // under an identical name/topology
    fold(&(c.bw as usize as u64).to_le_bytes());
    fold(&(c.lat as usize as u64).to_le_bytes());
    h
}

struct Entry {
    plan: Plan,
    /// Memoized batch-launch event simulation (`simulate_plan` makespan).
    exec_sim: Option<f64>,
    last_used: u64,
}

/// Bounded LRU memo of routing decisions (see the module docs). Owned by
/// the `Engine`; the `--no-plan-cache` escape hatch and the
/// `PipelineBuilder::plan_cache(false)` knob disable it for debugging.
pub struct PlanCache {
    enabled: bool,
    capacity: usize,
    entries: HashMap<PlanKey, Entry>,
    cluster_fp: Option<u64>,
    stamp: u64,
    hits: u64,
    misses: u64,
    invalidations: u64,
}

impl PlanCache {
    /// An enabled cache bounded to `capacity` entries (clamped to ≥ 1).
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            enabled: true,
            capacity: capacity.max(1),
            entries: HashMap::new(),
            cluster_fp: None,
            stamp: 0,
            hits: 0,
            misses: 0,
            invalidations: 0,
        }
    }

    /// Turn memoization on/off (off: every lookup misses without counting,
    /// inserts are dropped — the cold path, bit-identical by contract).
    pub fn set_enabled(&mut self, enabled: bool) {
        self.enabled = enabled;
        if !enabled {
            self.entries.clear();
        }
    }

    /// Whether memoization is active.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// `(hits, misses, invalidations)` since construction.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.hits, self.misses, self.invalidations)
    }

    /// Distinct keys currently memoized.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is memoized.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Reconcile with the cluster the caller is about to plan against:
    /// a fingerprint change empties the cache (counted as an
    /// invalidation). Returns true when an invalidation happened.
    pub fn check_cluster(&mut self, fp: u64) -> bool {
        match self.cluster_fp {
            Some(old) if old == fp => false,
            Some(_) => {
                self.entries.clear();
                self.invalidations += 1;
                self.cluster_fp = Some(fp);
                true
            }
            None => {
                self.cluster_fp = Some(fp);
                false
            }
        }
    }

    /// The cluster fingerprint the cache is currently filled against
    /// (`None` until the first [`PlanCache::check_cluster`]). Exposed so
    /// elasticity tests can assert that a mid-trace cluster mutation
    /// flipped the fingerprint exactly once per event.
    pub fn cluster_fp(&self) -> Option<u64> {
        self.cluster_fp
    }

    /// Memoized plan for `key`, counting the hit/miss.
    pub fn lookup(&mut self, key: &PlanKey) -> Option<Plan> {
        if !self.enabled {
            return None;
        }
        self.stamp += 1;
        match self.entries.get_mut(key) {
            Some(e) => {
                e.last_used = self.stamp;
                self.hits += 1;
                Some(e.plan.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Memoize a cold plan for `key`, evicting the least-recently-used
    /// entry beyond capacity. No-op when disabled.
    pub fn insert(&mut self, key: PlanKey, plan: Plan) {
        if !self.enabled {
            return;
        }
        if self.entries.len() >= self.capacity && !self.entries.contains_key(&key) {
            if let Some(oldest) =
                self.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone())
            {
                self.entries.remove(&oldest);
            }
        }
        self.stamp += 1;
        self.entries.insert(key, Entry { plan, exec_sim: None, last_used: self.stamp });
    }

    /// Memoized batch-launch simulation makespan for `key`, if any.
    pub fn cached_sim(&mut self, key: &PlanKey) -> Option<f64> {
        if !self.enabled {
            return None;
        }
        self.entries.get(key).and_then(|e| e.exec_sim)
    }

    /// Attach the batch-launch simulation makespan to an existing entry.
    pub fn store_sim(&mut self, key: &PlanKey, makespan: f64) {
        if let Some(e) = self.entries.get_mut(key) {
            e.exec_sim = Some(makespan);
        }
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::config::model::ModelSpec;
    use crate::coordinator::planner::Planner;

    fn key(px: usize) -> PlanKey {
        PlanKey {
            model: "pixart".into(),
            px,
            steps: 20,
            world: 8,
            policy: RoutePolicy::CostModel,
            fidelity: Fidelity::ClosedForm,
            memory_cap_bits: None,
            force_config: None,
            force_method: None,
            collective_algo: None,
        }
    }

    fn plan_for(px: usize) -> Plan {
        let m = ModelSpec::by_name("pixart").unwrap();
        Planner::default().with_steps(20).plan(&m, px, &l40_cluster(1), 8)
    }

    #[test]
    fn hit_returns_the_exact_inserted_plan() {
        let mut c = PlanCache::default();
        c.check_cluster(fingerprint(&l40_cluster(1)));
        assert!(c.lookup(&key(2048)).is_none());
        let cold = plan_for(2048);
        c.insert(key(2048), cold.clone());
        let hit = c.lookup(&key(2048)).expect("second lookup must hit");
        // byte-identical: the memo is a clone of the cold computation
        assert_eq!(hit.to_json().to_string(), cold.to_json().to_string());
        assert_eq!(hit.describe(), cold.describe());
        assert_eq!(c.counters(), (1, 1, 0));
    }

    #[test]
    fn cluster_change_invalidates_everything() {
        let mut c = PlanCache::default();
        c.check_cluster(fingerprint(&l40_cluster(1)));
        c.insert(key(1024), plan_for(1024));
        assert!(c.lookup(&key(1024)).is_some());
        // same cluster: no invalidation
        assert!(!c.check_cluster(fingerprint(&l40_cluster(1))));
        // different cluster: wiped
        assert!(c.check_cluster(fingerprint(&a100_node())));
        assert!(c.is_empty());
        assert!(c.lookup(&key(1024)).is_none());
        let (_, _, inv) = c.counters();
        assert_eq!(inv, 1);
    }

    #[test]
    fn distinct_clusters_fingerprint_differently() {
        assert_ne!(fingerprint(&l40_cluster(1)), fingerprint(&a100_node()));
        assert_ne!(fingerprint(&l40_cluster(1)), fingerprint(&l40_cluster(2)));
        assert_eq!(fingerprint(&l40_cluster(1)), fingerprint(&l40_cluster(1)));
    }

    #[test]
    fn mutating_the_ethernet_tier_busts_the_cache() {
        use crate::config::hardware::InterNodeLink;
        // regression: routing plans priced on a 10 GB/s inter-node tier
        // must not survive an upgrade of that tier — the fingerprint has
        // to cover the two-tier fields, not just the single-tier topology
        let stock = l40_cluster(2);
        let roce = l40_cluster(2).with_inter_node(InterNodeLink { bw: 50e9, lat: 5e-6 });
        assert_ne!(fingerprint(&stock), fingerprint(&roce));

        let mut c = PlanCache::default();
        c.check_cluster(fingerprint(&stock));
        c.insert(key(2048), plan_for(2048));
        assert!(c.lookup(&key(2048)).is_some());
        // the Ethernet tier changed under the engine: everything is wiped
        assert!(c.check_cluster(fingerprint(&roce)));
        assert!(c.is_empty());
        assert!(c.lookup(&key(2048)).is_none());
        // latency-only mutation invalidates too (both fields are hashed)
        let tier = InterNodeLink { lat: 5e-6, ..Default::default() };
        assert_ne!(fingerprint(&stock), fingerprint(&l40_cluster(2).with_inter_node(tier)));
    }

    #[test]
    fn forcing_a_collective_algo_busts_the_cache() {
        // regression (same pattern as the Ethernet-tier bust): a plan
        // memoized under auto algorithm selection must not be served when
        // the engine is later pinned to flat or hierarchical collectives —
        // the forced algorithm is part of the routing key, not a detail
        // the planner can absorb.
        let auto = key(2048);
        let flat = PlanKey { collective_algo: Some(CollectiveAlgo::FlatRing), ..key(2048) };
        let hier = PlanKey { collective_algo: Some(CollectiveAlgo::Hierarchical), ..key(2048) };
        assert_ne!(auto, flat);
        assert_ne!(flat, hier);

        let mut c = PlanCache::default();
        c.check_cluster(fingerprint(&l40_cluster(2)));
        c.insert(auto.clone(), plan_for(2048));
        assert!(c.lookup(&auto).is_some());
        // pinning an algorithm is a different decision: must miss cold
        assert!(c.lookup(&flat).is_none());
        assert!(c.lookup(&hier).is_none());
        // and each pinned decision memoizes independently
        c.insert(flat.clone(), plan_for(2048));
        assert!(c.lookup(&flat).is_some());
        assert!(c.lookup(&hier).is_none());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn straggler_slowdown_flips_and_restores_the_fingerprint() {
        // elasticity regression: a straggler event scales gpu.tflops by a
        // power of two, so applying the inverse factor must restore the
        // original fingerprint bit-exactly (fp hashes the f64 bits)
        let stock = l40_cluster(1);
        let mut slowed = l40_cluster(1);
        slowed.gpu.tflops *= 0.5;
        assert_ne!(fingerprint(&stock), fingerprint(&slowed));
        slowed.gpu.tflops *= 2.0;
        assert_eq!(fingerprint(&stock), fingerprint(&slowed));

        let mut c = PlanCache::default();
        assert_eq!(c.cluster_fp(), None);
        c.check_cluster(fingerprint(&stock));
        assert_eq!(c.cluster_fp(), Some(fingerprint(&stock)));
        let mut again = l40_cluster(1);
        again.gpu.tflops *= 0.5;
        assert!(c.check_cluster(fingerprint(&again)));
        assert_eq!(c.cluster_fp(), Some(fingerprint(&again)));
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let mut c = PlanCache::new(2);
        c.check_cluster(fingerprint(&l40_cluster(1)));
        let p = plan_for(1024);
        c.insert(key(256), p.clone());
        c.insert(key(512), p.clone());
        assert!(c.lookup(&key(256)).is_some()); // refresh 256
        c.insert(key(1024), p.clone()); // evicts 512 (least recent)
        assert_eq!(c.len(), 2);
        assert!(c.lookup(&key(512)).is_none());
        assert!(c.lookup(&key(256)).is_some());
        assert!(c.lookup(&key(1024)).is_some());
    }

    #[test]
    fn disabled_cache_never_serves_or_counts() {
        let mut c = PlanCache::default();
        c.set_enabled(false);
        c.check_cluster(fingerprint(&l40_cluster(1)));
        c.insert(key(256), plan_for(1024));
        assert!(c.lookup(&key(256)).is_none());
        assert_eq!(c.counters(), (0, 0, 0));
        assert!(c.is_empty());
    }

    #[test]
    fn sim_figure_rides_alongside_the_plan() {
        let mut c = PlanCache::default();
        c.check_cluster(fingerprint(&l40_cluster(1)));
        assert!(c.cached_sim(&key(2048)).is_none());
        c.insert(key(2048), plan_for(2048));
        assert!(c.cached_sim(&key(2048)).is_none());
        c.store_sim(&key(2048), 1.25);
        assert_eq!(c.cached_sim(&key(2048)), Some(1.25));
    }
}
