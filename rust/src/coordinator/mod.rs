//! The serving coordinator: bounded request queue with backpressure, the
//! compatibility batcher with continuous per-tick batch re-formation
//! (priorities, deadlines, aging — indexed by the bucketed [`WaitingSet`]
//! so a tick never rescans the backlog), the cost-model auto-[`planner`]
//! with the memoizing [`plan_cache`] in front of it and the routing
//! policy layer over both (pick the hybrid parallel config for the
//! hardware + model at hand; §5.2.4 heuristic kept as fallback/oracle),
//! the generation engine (`submit`/`tick` admission path + virtual-time
//! accounting + warm-session reuse), deterministic arrival [`Trace`]s,
//! and metrics.
//!
//! These are the *internal* serving layers; user code enters through the
//! typed facade in `crate::pipeline`, which owns an `Engine` and the
//! session/VAE lifecycle.
//!
//! Rust owns the event loop and process topology; PJRT execution is pinned
//! to the leader thread (the `xla` client is `Rc`-based), so the whole
//! engine — admission included — runs on the leader. Cross-thread
//! producers push into an *external* thread-safe `RequestQueue` handle
//! that the leader drains into a `Trace` or `submit` loop (see
//! `examples/serve_hybrid.rs`).

/// Compatibility batching with continuous per-tick re-formation.
pub mod batcher;
/// The continuous-batching serving engine (`submit`/`tick`/`serve`).
pub mod engine;
/// Serving metrics: histograms, counters, occupancy.
pub mod metrics;
/// Memoized routing decisions (the engine's `PlanCache`).
pub mod plan_cache;
/// The cost-model auto-planner (`Plan`/`Planner`/`RoutePolicy`/`Fidelity`).
pub mod planner;
/// Bounded FIFO request queue with backpressure.
pub mod queue;
/// `GenRequest`/`GenResponse` serving types.
pub mod request;
/// Routing policy layer (§5.2.4 heuristic + cost-model default).
pub mod router;
/// Seeded adversarial serving scenarios (bursts, stragglers, failures).
pub mod scenarios;
/// Per-stage occupancy + bounded-queue stats of the staged engine.
pub mod stages;
/// Deterministic virtual-time arrival traces.
pub mod trace;

pub use batcher::{Batch, Batcher, WaitingSet};
pub use engine::{CancelOutcome, Engine, Rejection};
pub use metrics::Metrics;
pub use plan_cache::{PlanCache, PlanKey};
pub use planner::{Fidelity, Plan, Planner, RoutePolicy};
pub use queue::RequestQueue;
pub use request::{GenRequest, GenResponse, RequestId, SloClass};
pub use router::{paper_heuristic, route, route_with_policy};
pub use scenarios::Scenario;
pub use stages::{DepthStats, StageStats};
pub use trace::{Trace, TraceEvent, TraceEventKind};
