//! The serving coordinator: request queue with backpressure, compatibility
//! batcher, the §5.2.4 routing policy (pick the hybrid parallel config for
//! the hardware + model at hand), the generation engine, and metrics.
//!
//! These are the *internal* serving layers; user code enters through the
//! typed facade in `crate::pipeline`, which owns an `Engine` and the
//! session/VAE lifecycle.
//!
//! Rust owns the event loop and process topology; PJRT execution is pinned
//! to the leader thread (the `xla` client is `Rc`-based), so the engine
//! drains the queue on the leader while producers submit from any thread
//! through the `RequestQueue`'s mpsc front.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;

pub use batcher::Batcher;
pub use engine::Engine;
pub use metrics::Metrics;
pub use queue::RequestQueue;
pub use request::{GenRequest, GenResponse, RequestId};
pub use router::route;
