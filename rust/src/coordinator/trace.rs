//! Deterministic virtual-time arrival traces.
//!
//! A [`Trace`] is a sorted sequence of [`GenRequest`]s with absolute
//! virtual arrival stamps — the unit `Pipeline::serve_trace` replays
//! against the continuous-batching engine. Because arrivals, prompts,
//! priorities and deadlines are all derived from one seeded [`Rng`], a
//! whole Poisson workload replays bit-identically: same trace, same
//! batches, same latencies, same latents.
//!
//! # Event/arrival tie-breaking (the unified rule)
//!
//! When a [`TraceEvent`] and a request arrival carry the *same*
//! timestamp, **the arrival is applied first** — events fire strictly
//! *before* the next-later arrival, never at-or-before. Both replay
//! loops implement this one rule (`Pipeline::serve_trace` via its
//! `at < arrival` cursor, `fleet::Fleet::replay` via its strict
//! `at < t` pre-arrival sweep), so a cancel stamped at its target's own
//! arrival finds the request already admitted, and a replica failure
//! stamped at an arrival sees that request routed before the crash.
//! Same-timestamp regression tests in `tests/serving.rs` and
//! `tests/fleet.rs` pin the rule in both loops.

use crate::config::model::BlockVariant;
use crate::coordinator::request::{GenRequest, RequestId, SloClass, DEFAULT_PX};
use crate::diffusion::SchedulerKind;
use crate::util::rng::Rng;

/// What a mid-trace [`TraceEvent`] does to the world when the replay
/// clock reaches it. Cluster mutations flip the `ClusterSpec`
/// fingerprint, which invalidates the `PlanCache` and session cache and
/// forces a re-plan on the next batch (the PR 5 invalidation seam).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEventKind {
    /// One rank dies: the cluster loses a GPU (world clamps to fit).
    RankFail,
    /// A whole node drains: the cluster loses `gpus_per_node` GPUs.
    NodeShrink,
    /// A node joins: the cluster gains `gpus_per_node` GPUs.
    NodeGrow,
    /// Straggler: every GPU's effective throughput is scaled by the
    /// factor (< 1 slows the cluster down, 1.0 restores it).
    Straggler(f64),
    /// Cancel the request with this id (queued or mid-flight; a no-op
    /// if it already completed).
    Cancel(RequestId),
    /// A whole replica crashes with requests in flight. Fleet-scoped:
    /// meaningful only with a [`TraceEvent::replica`] target, where the
    /// fleet checkpoints the dying replica at the crash instant and
    /// migrates its backlog (`fleet/failover.rs`); a single engine has
    /// no replica identity, so this is a no-op under `serve_trace`.
    ReplicaFail,
    /// A replica is drained for maintenance: it finishes what it holds
    /// but the dispatcher stops routing new work to it. Fleet-scoped
    /// (see [`TraceEventKind::ReplicaFail`]).
    ReplicaDrain,
    /// A failed or draining replica is restored to service. Fleet-scoped
    /// (see [`TraceEventKind::ReplicaFail`]).
    ReplicaRecover,
}

/// A scheduled mid-trace event: at virtual time `at`, mutate the world.
/// An event may target one fleet replica via `replica` (index modulo the
/// fleet size); untargeted events hit every replica's cluster, exactly
/// the pre-fleet semantics. Construct via [`TraceEvent::new`] /
/// [`TraceEvent::on_replica`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Virtual time the event fires (same clock as request arrivals).
    pub at: f64,
    /// What happens.
    pub kind: TraceEventKind,
    /// Optional fleet-replica target. `None` = cluster-wide (every
    /// replica under `serve_fleet`, the single engine under
    /// `serve_trace`); `Some(i)` applies to replica `i % fleet_size`
    /// only, and is ignored by the single-engine replay loop for the
    /// replica-lifecycle kinds.
    pub replica: Option<usize>,
}

impl TraceEvent {
    /// A cluster-wide event (no replica target).
    pub fn new(at: f64, kind: TraceEventKind) -> TraceEvent {
        TraceEvent { at, kind, replica: None }
    }

    /// An event targeting one fleet replica (index taken modulo the
    /// fleet size at replay, so schedules survive `--replicas` changes).
    pub fn on_replica(at: f64, kind: TraceEventKind, replica: usize) -> TraceEvent {
        TraceEvent { at, kind, replica: Some(replica) }
    }
}

/// A virtual-time request trace, sorted by (arrival, id), plus an
/// optional sorted schedule of mid-trace [`TraceEvent`]s. The lists are
/// private so the sortedness/finiteness invariants the replay loop
/// depends on cannot be bypassed — construct via [`Trace::new`] or
/// [`Trace::poisson`], attach events via [`Trace::with_events`], read
/// via [`Trace::requests`] / [`Trace::events`].
#[derive(Debug, Clone)]
pub struct Trace {
    requests: Vec<GenRequest>,
    events: Vec<TraceEvent>,
}

impl Trace {
    /// Build from explicit requests: non-finite arrival stamps are
    /// coerced to 0.0 (a NaN arrival would otherwise hang the replay
    /// loop's admission cursor), then sorted by arrival so replay order
    /// is well-defined regardless of how the caller produced them.
    pub fn new(mut requests: Vec<GenRequest>) -> Trace {
        for r in &mut requests {
            if !r.arrival.is_finite() {
                r.arrival = 0.0;
            }
        }
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        Trace { requests, events: Vec::new() }
    }

    /// Attach a mid-trace event schedule (replacing any previous one).
    /// Non-finite fire times are coerced to 0.0, then the schedule is
    /// sorted by fire time so the replay cursor is well-defined.
    pub fn with_events(mut self, mut events: Vec<TraceEvent>) -> Trace {
        for e in &mut events {
            if !e.at.is_finite() {
                e.at = 0.0;
            }
        }
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        self.events = events;
        self
    }

    /// The requests in replay (arrival) order.
    pub fn requests(&self) -> &[GenRequest] {
        &self.requests
    }

    /// The mid-trace events in fire order (empty for a static world).
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// A Poisson arrival process: `n` requests with exponential
    /// inter-arrival gaps at `rate` requests per virtual second. Returns a
    /// builder for the per-request mix; everything is derived from `seed`.
    pub fn poisson(seed: u64, n: usize, rate: f64) -> PoissonTrace {
        PoissonTrace {
            seed,
            n,
            rate,
            steps: 4,
            guidance: 3.0,
            variants: vec![BlockVariant::AdaLn],
            schedulers: vec![None],
            resolutions: vec![DEFAULT_PX],
            priorities: vec![0],
            slos: vec![SloClass::Standard],
            deadline_slack: None,
            decode_every: 0,
            prompts: vec![
                "a red fox in snow".into(),
                "city skyline at dusk".into(),
                "an astronaut sketch".into(),
                "a bowl of fruit".into(),
            ],
        }
    }

    /// Number of requests in the trace.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the trace carries no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Arrival stamp of the last request (the offered-load horizon).
    pub fn last_arrival(&self) -> f64 {
        self.requests.last().map(|r| r.arrival).unwrap_or(0.0)
    }
}

/// Builder for a seeded Poisson workload. Each knob is a *mix*: one entry
/// pins the value, several entries sample uniformly per request.
pub struct PoissonTrace {
    seed: u64,
    n: usize,
    rate: f64,
    steps: usize,
    guidance: f32,
    variants: Vec<BlockVariant>,
    schedulers: Vec<Option<SchedulerKind>>,
    resolutions: Vec<usize>,
    priorities: Vec<i32>,
    slos: Vec<SloClass>,
    deadline_slack: Option<f64>,
    decode_every: usize,
    prompts: Vec<String>,
}

impl PoissonTrace {
    /// Diffusion steps every request runs.
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// CFG guidance scale for every request.
    pub fn guidance(mut self, guidance: f32) -> Self {
        self.guidance = guidance;
        self
    }

    /// Model-variant mix (sampled per request when several are given).
    pub fn variants(mut self, variants: &[BlockVariant]) -> Self {
        if !variants.is_empty() {
            self.variants = variants.to_vec();
        }
        self
    }

    /// Scheduler-override mix (default: the model's benchmark scheduler).
    pub fn schedulers(mut self, schedulers: &[SchedulerKind]) -> Self {
        if !schedulers.is_empty() {
            self.schedulers = schedulers.iter().copied().map(Some).collect();
        }
        self
    }

    /// Resolution mix in pixels (drives routing and batch keys).
    pub fn resolutions(mut self, resolutions: &[usize]) -> Self {
        if !resolutions.is_empty() {
            self.resolutions = resolutions.to_vec();
        }
        self
    }

    /// Priority mix (sampled per request).
    pub fn priorities(mut self, priorities: &[i32]) -> Self {
        if !priorities.is_empty() {
            self.priorities = priorities.to_vec();
        }
        self
    }

    /// SLO-class mix (sampled per request). Classes without an explicit
    /// `deadline_slack` inherit their class default slack (interactive
    /// tight, standard loose, batch none).
    pub fn slos(mut self, slos: &[SloClass]) -> Self {
        if !slos.is_empty() {
            self.slos = slos.to_vec();
        }
        self
    }

    /// Give every request a deadline `slack` virtual seconds after arrival.
    pub fn deadline_slack(mut self, slack: f64) -> Self {
        self.deadline_slack = Some(slack);
        self
    }

    /// Decode every k-th request with the parallel VAE (0 = never).
    pub fn decode_every(mut self, k: usize) -> Self {
        self.decode_every = k;
        self
    }

    /// Prompt pool (sampled per request).
    pub fn prompts(mut self, prompts: &[&str]) -> Self {
        if !prompts.is_empty() {
            self.prompts = prompts.iter().map(|p| p.to_string()).collect();
        }
        self
    }

    /// Materialize the deterministic trace (pure function of the seed).
    pub fn build(&self) -> Trace {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(self.n);
        for i in 0..self.n as u64 {
            t += rng.exp(self.rate);
            let mut r = GenRequest::new(i, rng.pick(&self.prompts).clone())
                .with_variant(*rng.pick(&self.variants))
                .with_steps(self.steps)
                .with_guidance(self.guidance)
                .with_resolution(*rng.pick(&self.resolutions))
                .with_priority(*rng.pick(&self.priorities))
                .with_arrival(t)
                .with_seed(self.seed.wrapping_add(i));
            if let Some(k) = *rng.pick(&self.schedulers) {
                r = r.with_scheduler(k);
            }
            if let Some(slack) = self.deadline_slack {
                r = r.with_deadline(t + slack);
            }
            // after the explicit deadline: with_slo only fills a missing
            // deadline from the class default slack. The all-Standard
            // default skips the draw entirely so pre-SLO traces replay
            // with a bit-identical RNG stream (and no implicit deadline).
            if self.slos.len() > 1 || self.slos[0] != SloClass::Standard {
                r = r.with_slo(*rng.pick(&self.slos));
            }
            if self.decode_every > 0 && i % self.decode_every as u64 == 0 {
                r = r.with_decode(true);
            }
            requests.push(r);
        }
        Trace::new(requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_deterministic() {
        let a = Trace::poisson(42, 32, 1.5).steps(2).priorities(&[0, 2]).build();
        let b = Trace::poisson(42, 32, 1.5).steps(2).priorities(&[0, 2]).build();
        assert_eq!(a.len(), 32);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.priority, y.priority);
        }
        let c = Trace::poisson(43, 32, 1.5).steps(2).build();
        assert_ne!(a.requests[0].arrival, c.requests[0].arrival, "seed must matter");
    }

    #[test]
    fn arrivals_sorted_and_rate_scaled() {
        let t = Trace::poisson(7, 200, 2.0).build();
        let mut prev = 0.0;
        for r in &t.requests {
            assert!(r.arrival >= prev);
            prev = r.arrival;
        }
        // 200 arrivals at 2/s should take roughly 100 virtual seconds
        assert!(t.last_arrival() > 50.0 && t.last_arrival() < 200.0, "{}", t.last_arrival());
    }

    #[test]
    fn mixes_and_deadlines_apply() {
        let t = Trace::poisson(1, 64, 1.0)
            .variants(&[BlockVariant::AdaLn, BlockVariant::MmDit])
            .resolutions(&[256, 512])
            .deadline_slack(3.0)
            .decode_every(8)
            .build();
        assert!(t.requests.iter().any(|r| r.variant == BlockVariant::MmDit));
        assert!(t.requests.iter().any(|r| r.px == 512));
        assert!(t.requests.iter().all(|r| r.deadline == Some(r.arrival + 3.0)));
        assert_eq!(t.requests.iter().filter(|r| r.decode).count(), 8);
    }

    #[test]
    fn slo_mix_preserves_the_default_rng_stream() {
        // the all-Standard default must not consume RNG draws: pre-SLO
        // traces replay bit-identically (same arrivals, same prompts)
        let plain = Trace::poisson(42, 16, 1.5).build();
        let explicit = Trace::poisson(42, 16, 1.5).slos(&[SloClass::Standard]).build();
        for (x, y) in plain.requests.iter().zip(&explicit.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.slo, SloClass::Standard);
            assert_eq!(x.deadline, None, "default trace must stay deadline-free");
        }
        // a real mix samples classes and fills class-default deadlines
        let mixed = Trace::poisson(42, 64, 1.5)
            .slos(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch])
            .build();
        assert!(mixed.requests.iter().any(|r| r.slo == SloClass::Interactive));
        assert!(mixed.requests.iter().any(|r| r.slo == SloClass::Batch));
        for r in &mixed.requests {
            match r.slo {
                SloClass::Batch => assert_eq!(r.deadline, None),
                c => assert_eq!(r.deadline, Some(r.arrival + c.deadline_slack().unwrap())),
            }
        }
        // deterministic: same seed, same class assignment
        let mixed2 = Trace::poisson(42, 64, 1.5)
            .slos(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch])
            .build();
        for (x, y) in mixed.requests.iter().zip(&mixed2.requests) {
            assert_eq!(x.slo, y.slo);
        }
        // explicit slack wins over the class default
        let slacked = Trace::poisson(9, 16, 1.0)
            .slos(&[SloClass::Interactive])
            .deadline_slack(2.0)
            .build();
        assert!(slacked.requests.iter().all(|r| r.deadline == Some(r.arrival + 2.0)));
    }

    #[test]
    fn events_sort_by_fire_time_and_coerce_nonfinite() {
        let t = Trace::new(vec![GenRequest::new(0, "a")]).with_events(vec![
            TraceEvent::new(5.0, TraceEventKind::NodeShrink),
            TraceEvent::new(f64::NAN, TraceEventKind::Straggler(0.5)),
            TraceEvent::on_replica(2.0, TraceEventKind::ReplicaFail, 1),
        ]);
        let fires: Vec<f64> = t.events().iter().map(|e| e.at).collect();
        assert_eq!(fires, vec![0.0, 2.0, 5.0], "NaN coerced to 0, schedule sorted");
        assert_eq!(t.events()[0].kind, TraceEventKind::Straggler(0.5));
        assert_eq!(t.events()[0].replica, None, "TraceEvent::new carries no target");
        assert_eq!(t.events()[1].replica, Some(1), "on_replica keeps its target");
        // a plain trace carries no events
        assert!(Trace::poisson(1, 4, 1.0).build().events().is_empty());
    }

    #[test]
    fn explicit_trace_sorts_by_arrival() {
        let t = Trace::new(vec![
            GenRequest::new(1, "b").with_arrival(5.0),
            GenRequest::new(0, "a").with_arrival(1.0),
        ]);
        assert_eq!(t.requests[0].id, 0);
        assert_eq!(t.last_arrival(), 5.0);
    }
}
