//! Per-stage accounting for the staged execution path (L4.5): busy
//! seconds of the text-encode / denoise / VAE-decode stages, the bounded
//! inter-stage queue depth distribution, and decode backpressure stalls.
//!
//! The staged engine (see `coordinator::engine`) keeps one virtual clock
//! per stage and hands each request from stage to stage through a bounded
//! queue. The numbers here answer the questions the single `horizon`
//! figure cannot: how busy was each stage, how deep did the
//! denoise→decode queue run, and how often did a full queue stall the
//! denoiser (backpressure). They are embedded in
//! [`Metrics`](crate::coordinator::metrics::Metrics) and surface in
//! `ServeReport::summary()` / the `serve` CLI as the per-stage occupancy
//! block.

/// Exact distribution of small non-negative integers (inter-stage queue
/// depths). The log-bucketed latency [`Histogram`] is built for seconds
/// spanning six decades; depths are tiny integers (bounded by the queue
/// capacity), so this counts them exactly instead — `p50`/`p95` return
/// actually-observed depths, not bucket upper bounds.
///
/// [`Histogram`]: crate::coordinator::metrics::Histogram
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DepthStats {
    /// `counts[d]` = observations of depth `d`.
    counts: Vec<u64>,
    /// Total observations.
    pub count: u64,
}

impl DepthStats {
    /// An empty distribution.
    pub fn new() -> DepthStats {
        DepthStats::default()
    }

    /// Record one observation of `depth`.
    pub fn observe(&mut self, depth: usize) {
        if self.counts.len() <= depth {
            self.counts.resize(depth + 1, 0);
        }
        self.counts[depth] += 1;
        self.count += 1;
    }

    /// Exact quantile: the smallest depth `d` such that at least
    /// `q * count` observations are `<= d` (0 when empty).
    pub fn quantile(&self, q: f64) -> usize {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (d, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return d;
            }
        }
        self.counts.len().saturating_sub(1)
    }

    /// Median observed depth.
    pub fn p50(&self) -> usize {
        self.quantile(0.50)
    }

    /// 95th-percentile observed depth.
    pub fn p95(&self) -> usize {
        self.quantile(0.95)
    }

    /// Largest observed depth (0 when empty).
    pub fn max(&self) -> usize {
        self.counts.iter().rposition(|&c| c > 0).unwrap_or(0)
    }
}

/// Per-stage occupancy and backpressure counters of the staged engine.
///
/// Busy seconds accumulate on both the serial and the staged path (the
/// work per stage is identical — staging only changes *when* it runs);
/// the queue/stall fields only move when `stage_overlap` is on, because
/// the serial path has no inter-stage queue to stall on.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    /// Virtual seconds the text-encode stage was busy. The tiny-family
    /// conditioning path is folded into the denoise forward, so the
    /// engine charges this stage zero seconds — the stage exists
    /// structurally (it gates admission ordering) and the field keeps the
    /// report shape honest for backends with a real encoder.
    pub encode_busy: f64,
    /// Virtual seconds the denoise stage was busy (`model_seconds`).
    pub denoise_busy: f64,
    /// Virtual seconds the VAE-decode stage was busy.
    pub decode_busy: f64,
    /// Denoise launches delayed because the denoise→decode queue was at
    /// capacity (backpressure events).
    pub decode_stalls: u64,
    /// Total virtual seconds denoise launches spent stalled on the full
    /// decode queue.
    pub stall_seconds: f64,
    /// Depth of the denoise→decode queue observed at every decode
    /// enqueue (bounded by the queue capacity — the stall above is what
    /// enforces the bound).
    pub queue_depth: DepthStats,
    /// Peak per-device activation bytes of any parallel decode this
    /// engine ran (`vae_peak_bytes(out_px, c) / n` — the quantity
    /// `vae::memory::vae_fits` budgets against).
    pub decode_peak_bytes: f64,
}

impl StageStats {
    /// Busy fraction of `horizon` for each stage:
    /// `(encode, denoise, decode)`. Zero horizon yields zeros.
    pub fn occupancy(&self, horizon: f64) -> (f64, f64, f64) {
        if horizon <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            self.encode_busy / horizon,
            self.denoise_busy / horizon,
            self.decode_busy / horizon,
        )
    }

    /// One-line per-stage occupancy block for reports: busy fractions at
    /// `horizon`, queue depth p50/p95, and the backpressure stall total.
    pub fn report(&self, horizon: f64) -> String {
        let (e, d, v) = self.occupancy(horizon);
        format!(
            "stages: encode {:.0}% / denoise {:.0}% / decode {:.0}% busy | \
             decode queue depth p50/p95 {}/{} | {} stalls ({:.3}s)",
            e * 100.0,
            d * 100.0,
            v * 100.0,
            self.queue_depth.p50(),
            self.queue_depth.p95(),
            self.decode_stalls,
            self.stall_seconds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_stats_are_exact() {
        let mut d = DepthStats::new();
        for depth in [1usize, 1, 1, 2, 2, 3] {
            d.observe(depth);
        }
        assert_eq!(d.count, 6);
        assert_eq!(d.p50(), 1, "median of 1,1,1,2,2,3");
        assert_eq!(d.p95(), 3);
        assert_eq!(d.max(), 3);
        assert_eq!(d.quantile(1.0), 3);
        // empty distribution divides cleanly
        let empty = DepthStats::new();
        assert_eq!(empty.p50(), 0);
        assert_eq!(empty.max(), 0);
    }

    #[test]
    fn occupancy_fractions() {
        let mut s = StageStats::default();
        s.denoise_busy = 3.0;
        s.decode_busy = 1.0;
        let (e, d, v) = s.occupancy(4.0);
        assert_eq!(e, 0.0);
        assert!((d - 0.75).abs() < 1e-12);
        assert!((v - 0.25).abs() < 1e-12);
        assert_eq!(s.occupancy(0.0), (0.0, 0.0, 0.0));
    }

    #[test]
    fn report_contains_the_pinned_segments() {
        let mut s = StageStats::default();
        s.denoise_busy = 2.0;
        s.decode_busy = 1.0;
        s.decode_stalls = 2;
        s.stall_seconds = 0.5;
        s.queue_depth.observe(1);
        s.queue_depth.observe(2);
        let r = s.report(4.0);
        assert!(r.contains("denoise 50%"), "{r}");
        assert!(r.contains("decode 25%"), "{r}");
        assert!(r.contains("depth p50/p95 1/2"), "{r}");
        assert!(r.contains("2 stalls (0.500s)"), "{r}");
    }
}
