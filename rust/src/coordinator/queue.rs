//! Bounded FIFO request queue with backpressure.
//!
//! Producers (API threads) push through a thread-safe handle; the leader
//! drains. Capacity bounds memory; a full queue rejects with `Backpressure`
//! so callers can shed or retry — the paper's engine must keep latency
//! bounded rather than buffer unboundedly.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::coordinator::request::GenRequest;

/// Why a push was refused.
#[derive(Debug)]
pub enum PushError {
    /// Queue at capacity.
    Backpressure(GenRequest),
    /// Queue closed for shutdown.
    Closed(GenRequest),
}

struct Inner {
    q: VecDeque<GenRequest>,
    closed: bool,
    accepted: u64,
    rejected: u64,
}

/// Thread-safe bounded FIFO.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    /// Maximum queued requests before pushes are refused.
    pub capacity: usize,
}

impl RequestQueue {
    /// A bounded queue holding at most `capacity` requests.
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                accepted: 0,
                rejected: 0,
            }),
            notify: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; fails with backpressure when full.
    pub fn push(&self, req: GenRequest) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(req));
        }
        if g.q.len() >= self.capacity {
            g.rejected += 1;
            return Err(PushError::Backpressure(req));
        }
        g.q.push_back(req);
        g.accepted += 1;
        self.notify.notify_one();
        Ok(())
    }

    /// Pop one request; `None` when closed and drained.
    pub fn pop(&self) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Drain up to `max` requests without blocking (batch window).
    pub fn drain_upto(&self, max: usize) -> Vec<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        let n = max.min(g.q.len());
        g.q.drain(..n).collect()
    }

    /// Remove a request by id before it was drained (cancellation while
    /// queued). Returns the request so the caller can account for it; the
    /// freed slot is immediately available to new pushes — cancellation
    /// refunds admission capacity.
    pub fn remove(&self, id: u64) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        let pos = g.q.iter().position(|r| r.id == id)?;
        let req = g.q.remove(pos);
        // the slot no longer counts against capacity, so a blocked
        // producer could now succeed; accepted stays as-is (the request
        // WAS admitted) — conservation checks account cancellations
        // separately.
        self.notify.notify_one();
        req
    }

    /// Earliest declared deadline among queued requests (∞ when none
    /// declare one). Linear in the queue length, which the leader drains
    /// every tick — the fleet's deadline-pressure view reads this.
    pub fn min_deadline(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        g.q.iter().fold(f64::INFINITY, |m, r| m.min(r.deadline.unwrap_or(f64::INFINITY)))
    }

    /// Requests currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: subsequent pushes fail with `Closed`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.notify.notify_all();
    }

    /// (accepted, rejected) counters for conservation checks.
    pub fn counters(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.accepted, g.rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(GenRequest::new(i, "p")).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().id, i);
        }
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = RequestQueue::new(2);
        q.push(GenRequest::new(0, "a")).unwrap();
        q.push(GenRequest::new(1, "b")).unwrap();
        match q.push(GenRequest::new(2, "c")) {
            Err(PushError::Backpressure(r)) => assert_eq!(r.id, 2),
            other => panic!("expected backpressure, got {other:?}"),
        }
        assert_eq!(q.counters(), (2, 1));
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = RequestQueue::new(4);
        q.push(GenRequest::new(0, "a")).unwrap();
        q.close();
        assert!(matches!(q.push(GenRequest::new(1, "b")), Err(PushError::Closed(_))));
        assert_eq!(q.pop().unwrap().id, 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn remove_refunds_capacity() {
        let q = RequestQueue::new(2);
        q.push(GenRequest::new(0, "a")).unwrap();
        q.push(GenRequest::new(1, "b")).unwrap();
        assert!(matches!(q.push(GenRequest::new(2, "c")), Err(PushError::Backpressure(_))));
        // cancelling a queued request frees its slot immediately
        let removed = q.remove(1).expect("request 1 is queued");
        assert_eq!(removed.id, 1);
        q.push(GenRequest::new(3, "d")).expect("slot was refunded");
        // FIFO order of the survivors is preserved
        assert_eq!(q.pop().unwrap().id, 0);
        assert_eq!(q.pop().unwrap().id, 3);
        // unknown ids are a no-op
        assert!(q.remove(42).is_none());
    }

    #[test]
    fn cross_thread_producers() {
        let q = std::sync::Arc::new(RequestQueue::new(100));
        let mut handles = Vec::new();
        for t in 0..4 {
            let q2 = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    q2.push(GenRequest::new(t * 100 + i, "p")).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 40);
    }

    #[test]
    fn prop_conservation_no_loss_no_dup() {
        testing::check("queue conservation", 30, |rng| {
            let cap = 1 + rng.below(8);
            let q = RequestQueue::new(cap);
            let n = rng.below(20) + 1;
            let mut pushed = Vec::new();
            for i in 0..n as u64 {
                if q.push(GenRequest::new(i, "p")).is_ok() {
                    pushed.push(i);
                }
            }
            let drained = q.drain_upto(usize::MAX);
            let got: Vec<u64> = drained.iter().map(|r| r.id).collect();
            if got != pushed {
                return Err(format!("expected {pushed:?}, got {got:?}"));
            }
            let (acc, rej) = q.counters();
            if acc as usize != pushed.len() || (acc + rej) as usize != n {
                return Err(format!("counter mismatch acc={acc} rej={rej} n={n}"));
            }
            Ok(())
        });
    }
}
