//! Compatibility batcher: groups requests that can share compiled shapes
//! (same variant / steps / CFG usage / resolution) into batches up to
//! `max_batch`.
//!
//! **Continuous batching** ([`Batcher::next_batch`]): every engine tick
//! the waiting set is re-grouped from scratch and the single most urgent
//! compatible batch is launched, so late arrivals join the next batch of
//! their group instead of waiting behind a pre-formed schedule. Every
//! serving path (`Engine::serve`, `submit`/`tick`, trace replay) goes
//! through this one selection.
//!
//! Urgency is `priority + aging_rate * time_waiting`: strict priorities in
//! the short run, but every waiting request's effective priority grows
//! linearly with virtual time, which bounds starvation (see the property
//! tests and DESIGN.md).

use std::collections::BTreeMap;

use crate::coordinator::request::GenRequest;

/// One launchable batch: requests that share a `batch_key` (compiled
/// shapes + routed mesh), at most `max_batch` of them.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Members in FIFO execution order (arrival, then id).
    pub requests: Vec<GenRequest>,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch has no members.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// The compatibility batcher: continuous per-tick re-formation of the
/// waiting set with priority aging (see the module docs).
pub struct Batcher {
    /// Most requests a single launched batch may carry.
    pub max_batch: usize,
    /// Effective-priority units gained per virtual second of waiting.
    /// 0 disables aging (strict priorities; starvation possible).
    pub aging_rate: f64,
}

impl Batcher {
    /// Batcher with `max_batch` (clamped to >= 1) and aging rate 1.0.
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { max_batch: max_batch.max(1), aging_rate: 1.0 }
    }

    /// Replace the aging rate (clamped to >= 0; 0 = strict priorities,
    /// starvation possible).
    pub fn with_aging_rate(mut self, rate: f64) -> Batcher {
        self.aging_rate = rate.max(0.0);
        self
    }

    /// Effective priority of a waiting request at virtual time `now`.
    pub fn effective_priority(&self, r: &GenRequest, now: f64) -> f64 {
        r.priority as f64 + self.aging_rate * (now - r.arrival).max(0.0)
    }

    /// Continuous-batching selection: re-form compatibility groups over the
    /// waiting set and remove + return the most urgent batch (up to
    /// `max_batch` members of one group). Groups are ranked by (max
    /// effective priority, earliest deadline, earliest arrival, lowest id);
    /// members within the winning group by (effective priority, earliest
    /// deadline, lowest id). Returns `None` iff `waiting` is empty.
    pub fn next_batch(&self, waiting: &mut Vec<GenRequest>, now: f64) -> Option<Batch> {
        if waiting.is_empty() {
            return None;
        }
        let mut groups: BTreeMap<_, Vec<usize>> = BTreeMap::new();
        for (i, r) in waiting.iter().enumerate() {
            groups.entry(r.batch_key()).or_default().push(i);
        }
        // rank the groups, scoring each once (total_cmp: even a NaN
        // arrival/deadline smuggled in by a caller orders deterministically
        // instead of panicking)
        let mut chosen = groups
            .into_values()
            .map(|idx| (self.group_score(waiting, &idx, now), idx))
            .min_by(|a, b| cmp_score(&a.0, &b.0))
            .map(|(_, idx)| idx)?;
        // most urgent first: higher effective priority, tighter deadline,
        // lowest id (members deliberately don't tie-break on arrival —
        // aging already folds waiting time into the effective priority)
        let member_key = |r: &GenRequest| {
            (-self.effective_priority(r, now), r.deadline.unwrap_or(f64::INFINITY), r.id)
        };
        chosen.sort_by(|&a, &b| {
            let (pa, da, ia) = member_key(&waiting[a]);
            let (pb, db, ib) = member_key(&waiting[b]);
            pa.total_cmp(&pb).then(da.total_cmp(&db)).then(ia.cmp(&ib))
        });
        chosen.truncate(self.max_batch);
        // extract in descending index order so earlier indices stay valid
        chosen.sort_unstable_by(|a, b| b.cmp(a));
        let mut requests: Vec<GenRequest> =
            chosen.iter().map(|&i| waiting.swap_remove(i)).collect();
        // FIFO execution order inside the batch (stable latency accounting)
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        Some(Batch { requests })
    }

    /// Group rank key: smaller = more urgent (negated priority so `min_by`
    /// picks the highest effective priority first).
    fn group_score(&self, waiting: &[GenRequest], idx: &[usize], now: f64) -> (f64, f64, f64, u64) {
        let mut best_prio = f64::NEG_INFINITY;
        let mut best_deadline = f64::INFINITY;
        let mut best_arrival = f64::INFINITY;
        let mut best_id = u64::MAX;
        for &i in idx {
            let r = &waiting[i];
            best_prio = best_prio.max(self.effective_priority(r, now));
            if let Some(d) = r.deadline {
                best_deadline = best_deadline.min(d);
            }
            best_arrival = best_arrival.min(r.arrival);
            best_id = best_id.min(r.id);
        }
        (-best_prio, best_deadline, best_arrival, best_id)
    }
}

/// Total order over a rank key — `f64::total_cmp` keeps the scheduler
/// panic-free even if a caller sneaks a NaN arrival/deadline in.
fn cmp_score(a: &(f64, f64, f64, u64), b: &(f64, f64, f64, u64)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.total_cmp(&b.2))
        .then(a.3.cmp(&b.3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::BlockVariant;
    use crate::testing;

    fn req(id: u64, variant: BlockVariant, steps: usize) -> GenRequest {
        let mut r = GenRequest::new(id, "p");
        r.variant = variant;
        r.steps = steps;
        r
    }

    /// Drain a waiting set to completion through repeated selection.
    fn drain_all(b: &Batcher, mut waiting: Vec<GenRequest>) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(batch) = b.next_batch(&mut waiting, 0.0) {
            out.push(batch);
        }
        assert!(waiting.is_empty());
        out
    }

    #[test]
    fn groups_by_compatibility() {
        let b = Batcher::new(8);
        let window = vec![
            req(0, BlockVariant::AdaLn, 4),
            req(1, BlockVariant::MmDit, 4),
            req(2, BlockVariant::AdaLn, 4),
            req(3, BlockVariant::AdaLn, 8),
        ];
        let batches = drain_all(&b, window);
        assert_eq!(batches.len(), 3);
        // equal urgency: the group holding the earliest request goes first
        assert_eq!(batches[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn splits_at_max_batch() {
        let b = Batcher::new(2);
        let window = (0..5).map(|i| req(i, BlockVariant::AdaLn, 4)).collect();
        let batches = drain_all(&b, window);
        assert_eq!(batches.iter().map(Batch::len).collect::<Vec<_>>(), vec![2, 2, 1]);
    }

    #[test]
    fn next_batch_prefers_priority_then_ages() {
        let b = Batcher::new(4).with_aging_rate(1.0);
        // a freshly arrived high-priority request beats a slightly older
        // low-priority one...
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0),
            req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(2.0),
        ];
        let first = b.next_batch(&mut waiting, 2.0).unwrap();
        assert_eq!(first.requests[0].id, 1);
        // ...but a request that has waited long enough outranks any fresh
        // arrival of bounded priority: aging bounds starvation
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0),
            req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(10.0),
        ];
        let first = b.next_batch(&mut waiting, 10.0).unwrap();
        assert_eq!(first.requests[0].id, 0, "aged request must outrank fresh priority");
    }

    #[test]
    fn next_batch_respects_deadlines_between_equal_priorities() {
        let b = Batcher::new(4).with_aging_rate(0.0);
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4),
            req(1, BlockVariant::MmDit, 4).with_deadline(1.0),
        ];
        let first = b.next_batch(&mut waiting, 0.0).unwrap();
        assert_eq!(first.requests[0].id, 1);
    }

    #[test]
    fn next_batch_drains_everything_exactly_once() {
        let b = Batcher::new(3);
        let mut waiting: Vec<GenRequest> = (0..7)
            .map(|i| req(i, if i % 2 == 0 { BlockVariant::AdaLn } else { BlockVariant::Cross }, 4))
            .collect();
        let mut seen = std::collections::BTreeSet::new();
        while let Some(batch) = b.next_batch(&mut waiting, 0.0) {
            assert!(!batch.is_empty() && batch.len() <= 3);
            let k0 = batch.requests[0].batch_key();
            for r in &batch.requests {
                assert_eq!(r.batch_key(), k0, "mixed batch");
                assert!(seen.insert(r.id), "request duplicated");
            }
        }
        assert!(waiting.is_empty());
        assert_eq!(seen.len(), 7);
    }

    #[test]
    fn prop_next_batch_invariants() {
        // continuous selection conserves requests, never mixes keys, and
        // never exceeds max_batch — under random priorities/deadlines/ages
        testing::check("next_batch invariants", 40, |rng| {
            let b = Batcher::new(1 + rng.below(4)).with_aging_rate(rng.uniform());
            let n = rng.below(14);
            let variants = [BlockVariant::AdaLn, BlockVariant::MmDit, BlockVariant::Skip];
            let mut waiting: Vec<GenRequest> = (0..n as u64)
                .map(|i| {
                    let mut r = req(i, *rng.pick(&variants), *rng.pick(&[2usize, 4]))
                        .with_resolution(*rng.pick(&[256usize, 512]))
                        .with_priority(rng.below(5) as i32)
                        .with_arrival(rng.uniform() * 8.0);
                    if rng.below(3) == 0 {
                        r = r.with_deadline(rng.uniform() * 16.0);
                    }
                    r
                })
                .collect();
            let mut now = 8.0;
            let mut seen = std::collections::BTreeSet::new();
            while let Some(batch) = b.next_batch(&mut waiting, now) {
                if batch.is_empty() || batch.len() > b.max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                let k0 = batch.requests[0].batch_key();
                for r in &batch.requests {
                    if r.batch_key() != k0 {
                        return Err("mixed batch".into());
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("duplicated request {}", r.id));
                    }
                }
                now += 0.25; // virtual time moves between ticks
            }
            if !waiting.is_empty() || seen.len() != n {
                return Err(format!("lost requests: {} of {n}", seen.len()));
            }
            Ok(())
        });
    }
}
