//! Compatibility batcher: groups queued requests that can share compiled
//! shapes (same variant / steps / CFG usage) into batches up to
//! `max_batch`, preserving arrival order within a group.

use std::collections::BTreeMap;

use crate::coordinator::request::GenRequest;

#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<GenRequest>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

pub struct Batcher {
    pub max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { max_batch: max_batch.max(1) }
    }

    /// Partition a drained request window into compatible batches.
    /// Returns batches in order of the earliest request they contain.
    pub fn form(&self, window: Vec<GenRequest>) -> Vec<Batch> {
        let mut groups: BTreeMap<String, Vec<GenRequest>> = BTreeMap::new();
        let mut order: Vec<(u64, String)> = Vec::new();
        for r in window {
            let key = format!("{:?}", r.batch_key());
            if !groups.contains_key(&key) {
                order.push((r.id, key.clone()));
            }
            groups.entry(key).or_default().push(r);
        }
        order.sort_by_key(|(id, _)| *id);
        let mut out = Vec::new();
        for (_, key) in order {
            let mut reqs = groups.remove(&key).unwrap();
            while !reqs.is_empty() {
                let take = reqs.len().min(self.max_batch);
                out.push(Batch { requests: reqs.drain(..take).collect() });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::BlockVariant;
    use crate::testing;

    fn req(id: u64, variant: BlockVariant, steps: usize) -> GenRequest {
        let mut r = GenRequest::new(id, "p");
        r.variant = variant;
        r.steps = steps;
        r
    }

    #[test]
    fn groups_by_compatibility() {
        let b = Batcher::new(8);
        let window = vec![
            req(0, BlockVariant::AdaLn, 4),
            req(1, BlockVariant::MmDit, 4),
            req(2, BlockVariant::AdaLn, 4),
            req(3, BlockVariant::AdaLn, 8),
        ];
        let batches = b.form(window);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn splits_at_max_batch() {
        let b = Batcher::new(2);
        let window = (0..5).map(|i| req(i, BlockVariant::AdaLn, 4)).collect();
        let batches = b.form(window);
        assert_eq!(batches.iter().map(Batch::len).collect::<Vec<_>>(), vec![2, 2, 1]);
    }

    #[test]
    fn prop_batches_never_mix_incompatible_and_conserve() {
        testing::check("batcher invariants", 40, |rng| {
            let b = Batcher::new(1 + rng.below(4));
            let n = rng.below(16);
            let variants = [BlockVariant::AdaLn, BlockVariant::MmDit, BlockVariant::Cross];
            let window: Vec<GenRequest> = (0..n as u64)
                .map(|i| req(i, *rng.pick(&variants), *rng.pick(&[4usize, 8])))
                .collect();
            let keys: Vec<_> = window.iter().map(|r| (r.id, r.batch_key())).collect();
            let batches = b.form(window);
            let mut seen = std::collections::BTreeSet::new();
            for batch in &batches {
                if batch.is_empty() || batch.len() > b.max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                let k0 = batch.requests[0].batch_key();
                for r in &batch.requests {
                    if r.batch_key() != k0 {
                        return Err("mixed batch".into());
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("duplicated request {}", r.id));
                    }
                }
            }
            if seen.len() != keys.len() {
                return Err(format!("lost requests: {} of {}", seen.len(), keys.len()));
            }
            Ok(())
        });
    }
}
