//! Compatibility batcher: groups requests that can share compiled shapes
//! (same variant / steps / CFG usage / resolution) into batches up to
//! `max_batch`.
//!
//! **Continuous batching** ([`Batcher::next_batch`]): every engine tick
//! the waiting set is re-grouped and the single most urgent compatible
//! batch is launched, so late arrivals join the next batch of their group
//! instead of waiting behind a pre-formed schedule. Every serving path
//! (`Engine::serve`, `submit`/`tick`, trace replay) goes through this one
//! selection.
//!
//! Urgency is `priority + aging_rate * time_waiting`: strict priorities in
//! the short run, but every waiting request's effective priority grows
//! linearly with virtual time, which bounds starvation (see the property
//! tests and DESIGN.md).
//!
//! **Two selection paths, one semantics.** [`Batcher::next_batch`] is the
//! reference implementation: it rebuilds the compatibility groups over a
//! flat `Vec` and rescans every member per call — O(n) allocations and
//! scoring per tick. The engine's hot path runs
//! [`Batcher::next_batch_indexed`] over a [`WaitingSet`] instead:
//! requests are bucketed by `batch_key()` **once at admission**, each
//! bucket maintains the aggregates group ranking needs, and a tick only
//! ranks the buckets (O(#groups)) and orders the members of the single
//! winning bucket. The two paths pick the same batches — the clamp in
//! [`Batcher::effective_priority`] commutes with `max`, so a bucket's
//! best effective priority at time `now` is exactly
//! `max(max(priority − aging·arrival) + aging·now, max(priority))`, two
//! insert-monotone aggregates — and `prop_indexed_matches_reference`
//! locks the equivalence in (on dyadic inputs the two are bit-equal; on
//! arbitrary floats they can differ only when two scores collide within
//! ~1 ulp, where the order is unspecified either way).

use std::collections::BTreeMap;

use crate::config::model::BlockVariant;
use crate::coordinator::request::GenRequest;

/// Compatibility class of a request: `GenRequest::batch_key()`.
pub type BatchKey = (BlockVariant, usize, bool, usize);

/// One launchable batch: requests that share a `batch_key` (compiled
/// shapes + routed mesh), at most `max_batch` of them.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Members in FIFO execution order (arrival, then id).
    pub requests: Vec<GenRequest>,
}

impl Batch {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch has no members.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// The compatibility batcher: continuous per-tick re-formation of the
/// waiting set with priority aging (see the module docs).
pub struct Batcher {
    /// Most requests a single launched batch may carry.
    pub max_batch: usize,
    /// Effective-priority units gained per virtual second of waiting.
    /// 0 disables aging (strict priorities; starvation possible).
    pub aging_rate: f64,
}

impl Batcher {
    /// Batcher with `max_batch` (clamped to >= 1) and aging rate 1.0.
    pub fn new(max_batch: usize) -> Batcher {
        Batcher { max_batch: max_batch.max(1), aging_rate: 1.0 }
    }

    /// Replace the aging rate (clamped to >= 0; 0 = strict priorities,
    /// starvation possible).
    pub fn with_aging_rate(mut self, rate: f64) -> Batcher {
        self.aging_rate = rate.max(0.0);
        self
    }

    /// Static (time-independent) priority of a request: the user
    /// priority plus its SLO class boost. Interactive work outranks
    /// standard, standard outranks batch; within a tier the user
    /// priority still orders requests. Boosts are finite and constant,
    /// so batch-tier aging still bounds starvation — it just takes
    /// `boost_gap / aging_rate` extra virtual seconds to catch up.
    pub fn static_priority(r: &GenRequest) -> f64 {
        r.priority as f64 + r.slo.priority_boost()
    }

    /// Effective priority of a waiting request at virtual time `now`.
    pub fn effective_priority(&self, r: &GenRequest, now: f64) -> f64 {
        Self::static_priority(r) + self.aging_rate * (now - r.arrival).max(0.0)
    }

    /// Continuous-batching selection — the **reference implementation**
    /// over a flat `Vec` (the engine's hot path is
    /// [`next_batch_indexed`](Batcher::next_batch_indexed), property-tested
    /// equivalent): re-form compatibility groups over the waiting set and
    /// remove + return the most urgent batch (up to `max_batch` members of
    /// one group). Groups are ranked by (max effective priority, earliest
    /// deadline, earliest arrival, lowest id); members within the winning
    /// group by (effective priority, earliest deadline, lowest id).
    /// Returns `None` iff `waiting` is empty.
    pub fn next_batch(&self, waiting: &mut Vec<GenRequest>, now: f64) -> Option<Batch> {
        if waiting.is_empty() {
            return None;
        }
        let mut groups: BTreeMap<_, Vec<usize>> = BTreeMap::new();
        for (i, r) in waiting.iter().enumerate() {
            groups.entry(r.batch_key()).or_default().push(i);
        }
        // rank the groups, scoring each once (total_cmp: even a NaN
        // arrival/deadline smuggled in by a caller orders deterministically
        // instead of panicking)
        let mut chosen = groups
            .into_values()
            .map(|idx| (self.group_score(waiting, &idx, now), idx))
            .min_by(|a, b| cmp_score(&a.0, &b.0))
            .map(|(_, idx)| idx)?;
        // most urgent first: higher effective priority, tighter deadline,
        // lowest id (members deliberately don't tie-break on arrival —
        // aging already folds waiting time into the effective priority)
        let member_key = |r: &GenRequest| {
            (-self.effective_priority(r, now), r.deadline.unwrap_or(f64::INFINITY), r.id)
        };
        chosen.sort_by(|&a, &b| {
            let (pa, da, ia) = member_key(&waiting[a]);
            let (pb, db, ib) = member_key(&waiting[b]);
            pa.total_cmp(&pb).then(da.total_cmp(&db)).then(ia.cmp(&ib))
        });
        chosen.truncate(self.max_batch);
        // extract in descending index order so earlier indices stay valid
        chosen.sort_unstable_by(|a, b| b.cmp(a));
        let mut requests: Vec<GenRequest> =
            chosen.iter().map(|&i| waiting.swap_remove(i)).collect();
        // FIFO execution order inside the batch (stable latency accounting)
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        Some(Batch { requests })
    }

    /// Indexed continuous-batching selection over a [`WaitingSet`] — the
    /// engine's hot path. Semantically identical to
    /// [`next_batch`](Batcher::next_batch) (same group ranking, same member
    /// ordering, same FIFO batch order) but it never rescans the whole
    /// waiting set: buckets were formed at admission, group ranking reads
    /// each bucket's maintained aggregates, and only the *winning* bucket's
    /// members are scored and sorted. Cost per call:
    /// O(#groups + winner·log winner) instead of O(n·log n + a fresh group
    /// map allocation).
    pub fn next_batch_indexed(&self, waiting: &mut WaitingSet, now: f64) -> Option<Batch> {
        waiting.reindex_if_aging_changed(self.aging_rate);
        if waiting.is_empty() {
            return None;
        }
        // rank buckets on their aggregates (exactly the reference scores:
        // the urgency clamp commutes with max — see the module docs)
        let mut best: Option<((f64, f64, f64, u64), BatchKey)> = None;
        for (key, bucket) in &waiting.buckets {
            let score = bucket.score(self.aging_rate, now);
            let better = match &best {
                None => true,
                Some((b, _)) => cmp_score(&score, b) == std::cmp::Ordering::Less,
            };
            if better {
                best = Some((score, *key));
            }
        }
        let key = best?.1;
        let (mut requests, emptied) = {
            let bucket = waiting.buckets.get_mut(&key).expect("ranked bucket exists");
            // exact member order: same comparator as the reference path,
            // computed per member only for this one bucket
            let member_key = |r: &GenRequest| {
                (-self.effective_priority(r, now), r.deadline.unwrap_or(f64::INFINITY), r.id)
            };
            let mut idx: Vec<usize> = (0..bucket.members.len()).collect();
            idx.sort_by(|&a, &b| {
                let (pa, da, ia) = member_key(&bucket.members[a]);
                let (pb, db, ib) = member_key(&bucket.members[b]);
                pa.total_cmp(&pb).then(da.total_cmp(&db)).then(ia.cmp(&ib))
            });
            idx.truncate(self.max_batch);
            // extract in descending index order so earlier indices stay
            // valid under swap_remove (same invariant as the reference)
            idx.sort_unstable_by(|a, b| b.cmp(a));
            let requests: Vec<GenRequest> =
                idx.iter().map(|&i| bucket.members.swap_remove(i)).collect();
            if !bucket.members.is_empty() {
                // removals can retire the aggregate extrema: rebuild them
                // from the survivors of this one bucket
                bucket.recompute(self.aging_rate);
            }
            (requests, bucket.members.is_empty())
        };
        waiting.len -= requests.len();
        if emptied {
            waiting.buckets.remove(&key);
        }
        // FIFO execution order inside the batch (stable latency accounting)
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        Some(Batch { requests })
    }

    /// Group rank key: smaller = more urgent (negated priority so `min_by`
    /// picks the highest effective priority first).
    fn group_score(&self, waiting: &[GenRequest], idx: &[usize], now: f64) -> (f64, f64, f64, u64) {
        let mut best_prio = f64::NEG_INFINITY;
        let mut best_deadline = f64::INFINITY;
        let mut best_arrival = f64::INFINITY;
        let mut best_id = u64::MAX;
        for &i in idx {
            let r = &waiting[i];
            best_prio = best_prio.max(self.effective_priority(r, now));
            if let Some(d) = r.deadline {
                best_deadline = best_deadline.min(d);
            }
            best_arrival = best_arrival.min(r.arrival);
            best_id = best_id.min(r.id);
        }
        (-best_prio, best_deadline, best_arrival, best_id)
    }
}

/// Total order over a rank key — `f64::total_cmp` keeps the scheduler
/// panic-free even if a caller sneaks a NaN arrival/deadline in.
fn cmp_score(a: &(f64, f64, f64, u64), b: &(f64, f64, f64, u64)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.total_cmp(&b.1))
        .then(a.2.total_cmp(&b.2))
        .then(a.3.cmp(&b.3))
}

/// One compatibility bucket of the [`WaitingSet`]: members in admission
/// order plus the aggregates group ranking needs. `max_s` / `max_prio`
/// grow monotonically on insert; removals (which only ever touch the
/// winning bucket) trigger a rebuild over that bucket's survivors.
#[derive(Debug)]
struct Bucket {
    /// Waiting members, in admission order.
    members: Vec<GenRequest>,
    /// max over members of `priority − aging·arrival` (the static part of
    /// the unclamped effective priority).
    max_s: f64,
    /// max over members of `priority` (the clamped branch: a member that
    /// has not "arrived" yet scores its bare priority).
    max_prio: f64,
    /// Earliest declared deadline (∞ when none declared).
    min_deadline: f64,
    /// Earliest arrival stamp.
    min_arrival: f64,
    /// Lowest request id.
    min_id: u64,
}

impl Bucket {
    fn new() -> Bucket {
        Bucket {
            members: Vec::new(),
            max_s: f64::NEG_INFINITY,
            max_prio: f64::NEG_INFINITY,
            min_deadline: f64::INFINITY,
            min_arrival: f64::INFINITY,
            min_id: u64::MAX,
        }
    }

    fn absorb(&mut self, r: &GenRequest, aging: f64) {
        let prio = Batcher::static_priority(r);
        self.max_s = self.max_s.max(prio - aging * r.arrival);
        self.max_prio = self.max_prio.max(prio);
        if let Some(d) = r.deadline {
            self.min_deadline = self.min_deadline.min(d);
        }
        self.min_arrival = self.min_arrival.min(r.arrival);
        self.min_id = self.min_id.min(r.id);
    }

    fn recompute(&mut self, aging: f64) {
        let members = std::mem::take(&mut self.members);
        *self = Bucket::new();
        for r in &members {
            self.absorb(r, aging);
        }
        self.members = members;
    }

    /// Group rank key at virtual time `now` (smaller = more urgent). The
    /// max clamped effective priority over the members is exactly
    /// `max(max_s + aging·now, max_prio)`: for an arrived member the
    /// first branch reproduces `priority + aging·(now − arrival)`, for a
    /// future-stamped member it undershoots its bare priority, which the
    /// second branch supplies — so the max over both branches equals the
    /// max over the per-member clamped scores.
    fn score(&self, aging: f64, now: f64) -> (f64, f64, f64, u64) {
        let max_eff = (self.max_s + aging * now).max(self.max_prio);
        (-max_eff, self.min_deadline, self.min_arrival, self.min_id)
    }
}

/// The engine's indexed waiting set: requests bucketed by `batch_key()`
/// at admission, with per-bucket urgency aggregates maintained
/// incrementally so [`Batcher::next_batch_indexed`] never rescans the
/// whole backlog. Selection semantics are identical to the flat-`Vec`
/// reference path (property-tested); only the cost per tick changes.
#[derive(Debug)]
pub struct WaitingSet {
    buckets: BTreeMap<BatchKey, Bucket>,
    len: usize,
    /// Aging rate the `max_s` aggregates were computed with; a mismatch
    /// with the batcher triggers a one-off reindex.
    aging_rate: f64,
}

impl WaitingSet {
    /// An empty waiting set whose aggregates assume `aging_rate`.
    pub fn new(aging_rate: f64) -> WaitingSet {
        WaitingSet { buckets: BTreeMap::new(), len: 0, aging_rate }
    }

    /// Admit one request into its compatibility bucket (O(log groups)).
    pub fn push(&mut self, r: GenRequest) {
        let bucket = self.buckets.entry(r.batch_key()).or_insert_with(Bucket::new);
        bucket.absorb(&r, self.aging_rate);
        bucket.members.push(r);
        self.len += 1;
    }

    /// Admit a sequence of requests in order.
    pub fn extend(&mut self, requests: impl IntoIterator<Item = GenRequest>) {
        for r in requests {
            self.push(r);
        }
    }

    /// Requests currently waiting.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Distinct compatibility groups currently waiting.
    pub fn groups(&self) -> usize {
        self.buckets.len()
    }

    /// Remove a waiting request by id (mid-flight cancellation). Linear
    /// in the backlog — cancellation is rare, selection is the hot path.
    /// Rebuilds the touched bucket's aggregates (the removed member may
    /// have carried an extremum) and drops the bucket when emptied.
    pub fn remove(&mut self, id: u64) -> Option<GenRequest> {
        let aging = self.aging_rate;
        let mut found: Option<(BatchKey, usize)> = None;
        for (key, bucket) in &self.buckets {
            if let Some(pos) = bucket.members.iter().position(|r| r.id == id) {
                found = Some((*key, pos));
                break;
            }
        }
        let (key, pos) = found?;
        let bucket = self.buckets.get_mut(&key).expect("bucket just found");
        let req = bucket.members.remove(pos);
        if bucket.members.is_empty() {
            self.buckets.remove(&key);
        } else {
            bucket.recompute(aging);
        }
        self.len -= 1;
        Some(req)
    }

    /// Take every waiting request out (failover evacuation): buckets
    /// empty in `BatchKey` order, members in admission order within each
    /// — deterministic, so migrated backlogs re-admit identically on
    /// every replay.
    pub fn drain(&mut self) -> Vec<GenRequest> {
        let buckets = std::mem::take(&mut self.buckets);
        self.len = 0;
        let mut out = Vec::new();
        for (_, bucket) in buckets {
            out.extend(bucket.members);
        }
        out
    }

    /// Earliest declared deadline over the whole backlog (∞ when none
    /// declared) — O(#groups) via the per-bucket aggregates.
    pub fn min_deadline(&self) -> f64 {
        self.buckets.values().fold(f64::INFINITY, |m, b| m.min(b.min_deadline))
    }

    /// Rebuild the aggregates if the batcher's aging rate changed since
    /// they were computed (rare: a live engine keeps one rate).
    fn reindex_if_aging_changed(&mut self, aging: f64) {
        if aging.to_bits() != self.aging_rate.to_bits() {
            self.aging_rate = aging;
            for bucket in self.buckets.values_mut() {
                bucket.recompute(aging);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::BlockVariant;
    use crate::testing;

    fn req(id: u64, variant: BlockVariant, steps: usize) -> GenRequest {
        let mut r = GenRequest::new(id, "p");
        r.variant = variant;
        r.steps = steps;
        r
    }

    /// Drain a waiting set to completion through repeated selection.
    fn drain_all(b: &Batcher, mut waiting: Vec<GenRequest>) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(batch) = b.next_batch(&mut waiting, 0.0) {
            out.push(batch);
        }
        assert!(waiting.is_empty());
        out
    }

    #[test]
    fn groups_by_compatibility() {
        let b = Batcher::new(8);
        let window = vec![
            req(0, BlockVariant::AdaLn, 4),
            req(1, BlockVariant::MmDit, 4),
            req(2, BlockVariant::AdaLn, 4),
            req(3, BlockVariant::AdaLn, 8),
        ];
        let batches = drain_all(&b, window);
        assert_eq!(batches.len(), 3);
        // equal urgency: the group holding the earliest request goes first
        assert_eq!(batches[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn splits_at_max_batch() {
        let b = Batcher::new(2);
        let window = (0..5).map(|i| req(i, BlockVariant::AdaLn, 4)).collect();
        let batches = drain_all(&b, window);
        assert_eq!(batches.iter().map(Batch::len).collect::<Vec<_>>(), vec![2, 2, 1]);
    }

    #[test]
    fn next_batch_prefers_priority_then_ages() {
        let b = Batcher::new(4).with_aging_rate(1.0);
        // a freshly arrived high-priority request beats a slightly older
        // low-priority one...
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0),
            req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(2.0),
        ];
        let first = b.next_batch(&mut waiting, 2.0).unwrap();
        assert_eq!(first.requests[0].id, 1);
        // ...but a request that has waited long enough outranks any fresh
        // arrival of bounded priority: aging bounds starvation
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0),
            req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(10.0),
        ];
        let first = b.next_batch(&mut waiting, 10.0).unwrap();
        assert_eq!(first.requests[0].id, 0, "aged request must outrank fresh priority");
    }

    #[test]
    fn next_batch_respects_deadlines_between_equal_priorities() {
        let b = Batcher::new(4).with_aging_rate(0.0);
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4),
            req(1, BlockVariant::MmDit, 4).with_deadline(1.0),
        ];
        let first = b.next_batch(&mut waiting, 0.0).unwrap();
        assert_eq!(first.requests[0].id, 1);
    }

    #[test]
    fn next_batch_drains_everything_exactly_once() {
        let b = Batcher::new(3);
        let mut waiting: Vec<GenRequest> = (0..7)
            .map(|i| req(i, if i % 2 == 0 { BlockVariant::AdaLn } else { BlockVariant::Cross }, 4))
            .collect();
        let mut seen = std::collections::BTreeSet::new();
        while let Some(batch) = b.next_batch(&mut waiting, 0.0) {
            assert!(!batch.is_empty() && batch.len() <= 3);
            let k0 = batch.requests[0].batch_key();
            for r in &batch.requests {
                assert_eq!(r.batch_key(), k0, "mixed batch");
                assert!(seen.insert(r.id), "request duplicated");
            }
        }
        assert!(waiting.is_empty());
        assert_eq!(seen.len(), 7);
    }

    #[test]
    fn extraction_survives_colliding_swap_remove_indices() {
        // Regression guard for the index-extraction step: the most urgent
        // members here sit at indices {0, 4} of the waiting vec. Removing
        // in *selection* order would swap_remove(0) first — moving the
        // tail (index 4) into slot 0 — and then index 4 would be out of
        // bounds / the wrong element. Descending-index extraction is the
        // invariant; this pins it with a case where the naive order
        // panics outright.
        let b = Batcher::new(2).with_aging_rate(0.0);
        let mut waiting: Vec<GenRequest> = (0..5)
            .map(|i| {
                req(i, BlockVariant::AdaLn, 4).with_priority(match i {
                    0 => 5,
                    4 => 4,
                    _ => 0,
                })
            })
            .collect();
        let batch = b.next_batch(&mut waiting, 0.0).unwrap();
        let got: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(got, vec![0, 4], "must extract exactly the two most urgent requests");
        let mut left: Vec<u64> = waiting.iter().map(|r| r.id).collect();
        left.sort_unstable();
        assert_eq!(left, vec![1, 2, 3], "the others must all survive, once each");

        // and the indexed path agrees on the same scenario
        let mut ws = WaitingSet::new(0.0);
        ws.extend((0..5).map(|i| {
            req(i, BlockVariant::AdaLn, 4).with_priority(match i {
                0 => 5,
                4 => 4,
                _ => 0,
            })
        }));
        let batch = b.next_batch_indexed(&mut ws, 0.0).unwrap();
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 4]);
        assert_eq!(ws.len(), 3);
    }

    #[test]
    fn prop_indexed_matches_reference() {
        // the indexed WaitingSet path must pick bit-identical batches to
        // the flat-Vec reference under random workloads with mid-drain
        // admissions. All numeric inputs are dyadic rationals (multiples
        // of 0.25) so the aggregate scoring is FP-exact, not just
        // algebraically equal (see the module docs).
        testing::check("indexed == reference selection", 60, |rng| {
            let b = Batcher::new(1 + rng.below(4)).with_aging_rate(rng.below(5) as f64 * 0.25);
            let variants = [BlockVariant::AdaLn, BlockVariant::MmDit, BlockVariant::Skip];
            let mut next_id = 0u64;
            let mut mk = |rng: &mut crate::util::rng::Rng| {
                let id = next_id;
                next_id += 1;
                let mut r = req(id, *rng.pick(&variants), *rng.pick(&[2usize, 4]))
                    .with_resolution(*rng.pick(&[256usize, 512]))
                    .with_priority(rng.below(5) as i32)
                    // arrivals 0..12 in 0.25 steps: some land in the
                    // future relative to `now`, exercising the clamp
                    .with_arrival(rng.below(48) as f64 * 0.25);
                if rng.below(3) == 0 {
                    r = r.with_deadline(rng.below(32) as f64 * 0.5);
                }
                // SLO boosts (±1e3) and class deadline slacks (30/240)
                // are dyadic, so the FP-exactness argument still holds
                use crate::coordinator::request::SloClass;
                r.with_slo(*rng.pick(&SloClass::ALL))
            };
            let mut reference: Vec<GenRequest> = Vec::new();
            let mut indexed = WaitingSet::new(b.aging_rate);
            for _ in 0..rng.below(12) {
                let r = mk(&mut *rng);
                reference.push(r.clone());
                indexed.push(r);
            }
            let mut now = 8.0;
            let mut late_admissions = 0;
            loop {
                // mid-drain admissions join both structures identically
                // (bounded so the drain terminates)
                if late_admissions < 8 && rng.below(3) == 0 {
                    late_admissions += 1;
                    let r = mk(&mut *rng);
                    reference.push(r.clone());
                    indexed.push(r);
                }
                let a = b.next_batch(&mut reference, now);
                let c = b.next_batch_indexed(&mut indexed, now);
                match (a, c) {
                    (None, None) => break,
                    (Some(x), Some(y)) => {
                        let xs: Vec<u64> = x.requests.iter().map(|r| r.id).collect();
                        let ys: Vec<u64> = y.requests.iter().map(|r| r.id).collect();
                        if xs != ys {
                            return Err(format!("batch diverged: {xs:?} vs {ys:?}"));
                        }
                        if reference.len() != indexed.len() {
                            return Err("leftover count diverged".into());
                        }
                    }
                    (x, y) => {
                        return Err(format!(
                            "one path drained early: ref={:?} indexed={:?}",
                            x.map(|b| b.len()),
                            y.map(|b| b.len())
                        ))
                    }
                }
                now += 0.25;
            }
            Ok(())
        });
    }

    #[test]
    fn indexed_reindexes_when_the_aging_rate_changes() {
        // aggregates were built at rate 0 (strict priority); switching the
        // batcher to aggressive aging must re-rank: the old waiter wins
        let mut ws = WaitingSet::new(0.0);
        ws.push(req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0));
        ws.push(req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(10.0));
        let strict = Batcher::new(4).with_aging_rate(0.0);
        let first = strict.next_batch_indexed(&mut ws, 10.0).unwrap();
        assert_eq!(first.requests[0].id, 1, "strict priorities pick the high-priority job");
        // rebuild and flip the rate on the same set
        let mut ws = WaitingSet::new(0.0);
        ws.push(req(0, BlockVariant::AdaLn, 4).with_priority(0).with_arrival(0.0));
        ws.push(req(1, BlockVariant::MmDit, 4).with_priority(3).with_arrival(10.0));
        let aging = Batcher::new(4).with_aging_rate(1.0);
        let first = aging.next_batch_indexed(&mut ws, 10.0).unwrap();
        assert_eq!(first.requests[0].id, 0, "aged request must outrank fresh priority");
        assert_eq!(ws.len(), 1);
        assert_eq!(ws.groups(), 1);
    }

    #[test]
    fn slo_boost_orders_tiers_but_aging_still_wins() {
        use crate::coordinator::request::SloClass;
        let b = Batcher::new(4).with_aging_rate(1.0);
        // an interactive request freshly arrived outranks a batch-tier
        // request of much higher user priority
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_priority(100).with_slo(SloClass::Batch),
            req(1, BlockVariant::MmDit, 4).with_priority(0).with_slo(SloClass::Interactive),
        ];
        let first = b.next_batch(&mut waiting, 0.0).unwrap();
        assert_eq!(first.requests[0].id, 1, "interactive boost dominates user priority");
        // but the boost gap is finite: after boost_gap/aging seconds of
        // waiting, the batch-tier request outranks fresh interactive work
        let gap = SloClass::Interactive.priority_boost() - SloClass::Batch.priority_boost();
        let mut waiting = vec![
            req(0, BlockVariant::AdaLn, 4).with_arrival(0.0).with_slo(SloClass::Batch),
            req(1, BlockVariant::MmDit, 4).with_arrival(gap + 1.0).with_slo(SloClass::Interactive),
        ];
        let first = b.next_batch(&mut waiting, gap + 1.0).unwrap();
        assert_eq!(first.requests[0].id, 0, "aging must still bound batch-tier starvation");
        // and the indexed path agrees on the boost
        let mut ws = WaitingSet::new(1.0);
        ws.push(req(0, BlockVariant::AdaLn, 4).with_priority(100).with_slo(SloClass::Batch));
        ws.push(req(1, BlockVariant::MmDit, 4).with_priority(0).with_slo(SloClass::Interactive));
        let first = b.next_batch_indexed(&mut ws, 0.0).unwrap();
        assert_eq!(first.requests[0].id, 1);
    }

    #[test]
    fn waiting_set_remove_maintains_len_and_aggregates() {
        let b = Batcher::new(4).with_aging_rate(0.0);
        let mut ws = WaitingSet::new(0.0);
        ws.push(req(0, BlockVariant::AdaLn, 4).with_priority(5));
        ws.push(req(1, BlockVariant::AdaLn, 4).with_priority(1));
        ws.push(req(2, BlockVariant::MmDit, 4).with_priority(3));
        // removing the priority-5 extremum must rebuild the bucket's
        // aggregates: the MmDit group (prio 3) now outranks AdaLn (prio 1)
        let removed = ws.remove(0).expect("request 0 is waiting");
        assert_eq!(removed.id, 0);
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.groups(), 2);
        let batch = b.next_batch_indexed(&mut ws, 0.0).unwrap();
        assert_eq!(batch.requests[0].id, 2, "aggregates must drop the removed extremum");
        // removing the last member of a group drops the bucket
        assert!(ws.remove(1).is_some());
        assert_eq!(ws.groups(), 0);
        assert!(ws.is_empty());
        assert!(ws.remove(1).is_none(), "double-cancel is a no-op");
    }

    #[test]
    fn prop_next_batch_invariants() {
        // continuous selection conserves requests, never mixes keys, and
        // never exceeds max_batch — under random priorities/deadlines/ages
        testing::check("next_batch invariants", 40, |rng| {
            let b = Batcher::new(1 + rng.below(4)).with_aging_rate(rng.uniform());
            let n = rng.below(14);
            let variants = [BlockVariant::AdaLn, BlockVariant::MmDit, BlockVariant::Skip];
            let mut waiting: Vec<GenRequest> = (0..n as u64)
                .map(|i| {
                    let mut r = req(i, *rng.pick(&variants), *rng.pick(&[2usize, 4]))
                        .with_resolution(*rng.pick(&[256usize, 512]))
                        .with_priority(rng.below(5) as i32)
                        .with_arrival(rng.uniform() * 8.0);
                    if rng.below(3) == 0 {
                        r = r.with_deadline(rng.uniform() * 16.0);
                    }
                    r
                })
                .collect();
            let mut now = 8.0;
            let mut seen = std::collections::BTreeSet::new();
            while let Some(batch) = b.next_batch(&mut waiting, now) {
                if batch.is_empty() || batch.len() > b.max_batch {
                    return Err(format!("bad batch size {}", batch.len()));
                }
                let k0 = batch.requests[0].batch_key();
                for r in &batch.requests {
                    if r.batch_key() != k0 {
                        return Err("mixed batch".into());
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("duplicated request {}", r.id));
                    }
                }
                now += 0.25; // virtual time moves between ticks
            }
            if !waiting.is_empty() || seen.len() != n {
                return Err(format!("lost requests: {} of {n}", seen.len()));
            }
            Ok(())
        });
    }
}
