//! Cost-model-driven hybrid-parallelism auto-planner.
//!
//! The §5.2.4 router used to pick configs with a fixed bandwidth-priority
//! order that never consulted the analytic models in `perf/`. The paper's
//! own data (Figs 8–17) shows the crossover points between PipeFusion and
//! sequence parallelism move with (model, resolution, cluster, world) —
//! so the planner does what the paper's per-figure "best hybrid" series
//! does, per request:
//!
//! 1. **enumerate** every `ParallelConfig` that `validate` admits for the
//!    world size (including the M = 2·pipefusion patch variants);
//! 2. **prune** candidates whose per-device footprint
//!    (`perf::memory_model::config_memory`) exceeds the cluster's HBM
//!    budget (or an explicit `--memory-cap-gb`);
//! 3. **score** the survivors with the closed-form step-time model
//!    (`perf::latency::predict_latency`, hybrid row) and the Table-1
//!    communication composition (`perf::comm_model::config_comm_bytes`);
//! 4. return a ranked [`Plan`] — config + predicted latency / comm bytes /
//!    peak memory + a human-readable "why".
//!
//! `coordinator::router::route` is now a thin policy over this module;
//! the old greedy heuristic survives as [`RoutePolicy::PaperHeuristic`]
//! (the fallback and the test oracle). By construction the cost-model
//! policy is never predicted-slower than the heuristic on any cell where
//! the heuristic's pick fits memory: the heuristic's config is in the
//! enumeration and both are scored by the same model.

use crate::config::hardware::{ClusterSpec, CollectiveAlgo};
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::coordinator::engine::pick_method;
use crate::coordinator::router::paper_heuristic;
use crate::parallel::driver;
use crate::perf::comm_model::config_comm_bytes;
use crate::perf::latency::{
    predict_latency_with, serial_latency, LatencyBreakdown, Method as PerfMethod,
};
use crate::perf::memory_model::{config_memory, HBM_USABLE_FRACTION};
use crate::perf::simulator::{simulate_with, Timeline};
use crate::util::json::Json;
use crate::{Error, Result};

/// How `route`/`Pipeline` pick the hybrid parallel configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RoutePolicy {
    /// Argmin of the analytic cost model over every valid config,
    /// memory-pruned (the default).
    #[default]
    CostModel,
    /// The §5.2.4 bandwidth-priority greedy heuristic, kept as the
    /// fallback and as the oracle the planner is tested against.
    PaperHeuristic,
}

impl RoutePolicy {
    /// Parse a policy name: `cost`/`cost-model`/`planner` or
    /// `paper`/`heuristic`.
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "cost" | "cost-model" | "planner" => RoutePolicy::CostModel,
            "paper" | "heuristic" => RoutePolicy::PaperHeuristic,
            _ => {
                return Err(Error::config(format!(
                    "unknown route policy '{s}' (cost|paper)"
                )))
            }
        })
    }

    /// Canonical short key, accepted back by [`RoutePolicy::parse`].
    pub fn key(&self) -> &'static str {
        match self {
            RoutePolicy::CostModel => "cost",
            RoutePolicy::PaperHeuristic => "paper",
        }
    }
}

/// Scoring fidelity of the auto-planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Fidelity {
    /// Closed-form step-time model only — the default, and what the
    /// golden-plan snapshot pins.
    #[default]
    ClosedForm,
    /// Re-score the top closed-form candidates with the discrete-event
    /// overlap simulator (`perf::simulator`): the pipeline fill bubble,
    /// partial overlap and CFG barriers are played out on per-rank
    /// clocks, ties break on the simulated makespan, and the plan's
    /// "why" cites the winner's critical path.
    Simulated,
}

/// How many top closed-form candidates `Fidelity::Simulated` re-scores.
pub const SIM_RESCORE_TOP_K: usize = 4;

/// A scored routing decision: the config plus everything the cost model
/// knows about it. This is what `Pipeline::plan`, the `route` CLI and the
/// serving admission check all consume.
///
/// ```
/// use xdit::config::hardware::l40_cluster;
/// use xdit::config::model::ModelSpec;
/// use xdit::Planner;
///
/// let m = ModelSpec::by_name("pixart")?;
/// let plan = Planner::default().plan(&m, 2048, &l40_cluster(2), 16);
/// assert_eq!(plan.config.world(), 16);
/// assert!(plan.fits && plan.speedup() > 1.0);
/// println!("{}", plan.describe());
/// # Ok::<(), xdit::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct Plan {
    /// Model the decision was made for.
    pub model: String,
    /// Target resolution (pixels, square).
    pub px: usize,
    /// Image-token sequence length the decision was made for.
    pub s_img: usize,
    /// Steps the prediction assumes.
    pub steps: usize,
    /// Devices the plan fills.
    pub world: usize,
    /// Cluster the link model priced transfers on.
    pub cluster: String,
    /// Routing policy that produced the plan.
    pub policy: RoutePolicy,
    /// The chosen hybrid parallel configuration.
    pub config: ParallelConfig,
    /// Strategy the engine would run for this config.
    pub method: driver::Method,
    /// Closed-form latency prediction for the whole generation.
    pub predicted: LatencyBreakdown,
    /// Serial (1-GPU) baseline latency for the same generation.
    pub serial_seconds: f64,
    /// Per-device bytes moved over the whole generation (steps × the
    /// per-step Table-1 composition).
    pub comm_bytes: f64,
    /// Predicted peak per-GPU memory (bytes).
    pub peak_memory_bytes: f64,
    /// Whether the config fits the memory budget the planner used. A plan
    /// with `fits == false` is the least-bad choice of an infeasible set.
    pub fits: bool,
    /// Collective algorithm the winning price assumed: `FlatRing` unless
    /// the two-level hierarchy ([`ClusterSpec::collective_cost`]) was
    /// strictly cheaper for this config's cross-node collectives.
    pub collective_algo: CollectiveAlgo,
    /// Discrete-event simulated makespan in seconds, when the planner ran
    /// at `Fidelity::Simulated` (None under the closed-form default).
    pub simulated_seconds: Option<f64>,
    /// Candidates enumerated / pruned by memory (cost-model policy only).
    pub candidates: usize,
    /// Of those, how many the memory budget cut.
    pub pruned: usize,
    /// Human-readable reason this config won.
    pub why: String,
}

impl Plan {
    /// Predicted speedup over the serial baseline.
    pub fn speedup(&self) -> f64 {
        if self.predicted.total > 0.0 {
            self.serial_seconds / self.predicted.total
        } else {
            0.0
        }
    }

    /// Predicted seconds per diffusion step for a `steps`-step run of
    /// this plan — the granularity the engine's preemption slicer
    /// credits progress at (`steps` is clamped to ≥ 1 so a degenerate
    /// probe cannot divide by zero).
    pub fn per_step(&self, steps: usize) -> f64 {
        self.predicted.total / steps.max(1) as f64
    }

    /// Multi-line human-readable report of the plan (the `route` CLI
    /// output).
    pub fn describe(&self) -> String {
        let mut out = format!(
            "{} @ {}px ({} tokens): [{}] via {} ({} collectives) — predicted {:.2}s \
             ({:.2}s compute, {:.2}s exposed comm) vs serial {:.2}s ({:.1}x), \
             comm {:.2} GB/device, peak mem {:.1} GB{}\n  why: {}",
            self.model,
            self.px,
            self.s_img,
            self.config.describe(),
            self.method.key(),
            self.collective_algo.label(),
            self.predicted.total,
            self.predicted.compute,
            self.predicted.comm_exposed,
            self.serial_seconds,
            self.speedup(),
            self.comm_bytes / 1e9,
            self.peak_memory_bytes / 1e9,
            if self.fits { "" } else { " [OVER MEMORY BUDGET]" },
            self.why,
        );
        if let Some(sim) = self.simulated_seconds {
            out.push_str(&format!("\n  simulated (event timeline): {sim:.2}s"));
        }
        out
    }

    /// Canonical JSON form (sorted keys, integer metrics) — the unit of
    /// the golden-plan CI snapshot. Floats are rounded to integral units
    /// (µs, bytes) so the file is byte-stable and reviewable.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("cluster".into(), Json::Str(self.cluster.clone()));
        o.insert("world".into(), Json::Num(self.world as f64));
        o.insert("px".into(), Json::Num(self.px as f64));
        o.insert("policy".into(), Json::Str(self.policy.key().into()));
        o.insert("config".into(), Json::Str(self.config.describe()));
        o.insert("method".into(), Json::Str(self.method.key().into()));
        o.insert("predicted_us".into(), Json::Num((self.predicted.total * 1e6).round()));
        o.insert("comm_bytes".into(), Json::Num(self.comm_bytes.round()));
        o.insert("peak_mem_bytes".into(), Json::Num(self.peak_memory_bytes.round()));
        o.insert("fits".into(), Json::Bool(self.fits));
        if self.collective_algo == CollectiveAlgo::Hierarchical {
            // only when the hierarchy strictly beat the flat ring — every
            // cell the hierarchy cannot touch (single-node groups) stays
            // byte-identical with the pre-hierarchy snapshot
            o.insert("algo".into(), Json::Str(self.collective_algo.key().into()));
        }
        if let Some(sim) = self.simulated_seconds {
            // only present under Fidelity::Simulated — the closed-form
            // golden snapshot stays byte-identical
            o.insert("simulated_us".into(), Json::Num((sim * 1e6).round()));
        }
        Json::Obj(o)
    }
}

/// The auto-planner. All fields are optional policy knobs; the zero value
/// (`Planner::default()`) is the engine's production configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct Planner {
    /// Scoring policy: cost-model argmin (default) or the §5.2.4 greedy.
    pub policy: RoutePolicy,
    /// Diffusion steps to predict for (`None` = the model's benchmark
    /// step count).
    pub steps: Option<usize>,
    /// Per-GPU HBM budget in bytes (`None` = the cluster's GPU capacity).
    pub memory_cap_bytes: Option<f64>,
    /// Scoring fidelity: closed forms only (default), or a simulator
    /// re-scoring pass over the top candidates.
    pub fidelity: Fidelity,
    /// Collective-algorithm override. `None` (default) auto-selects per
    /// config: flat ring always, the two-level hierarchy additionally
    /// priced when the intra-image group spans nodes — whichever is
    /// strictly cheaper wins (ties stay flat). `Some(algo)` forces one
    /// algorithm for every candidate (`--collective-algo` on the CLI).
    pub collective_algo: Option<CollectiveAlgo>,
}

impl Planner {
    /// Replace the routing policy.
    pub fn with_policy(mut self, policy: RoutePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Replace the scoring fidelity.
    pub fn with_fidelity(mut self, fidelity: Fidelity) -> Self {
        self.fidelity = fidelity;
        self
    }

    /// Predict for a fixed diffusion step count instead of the model's
    /// benchmark default.
    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    /// Prune candidates against an explicit per-GPU HBM budget.
    pub fn with_memory_cap_gb(mut self, gb: f64) -> Self {
        self.memory_cap_bytes = Some(gb * 1e9);
        self
    }

    /// Force one collective algorithm for every candidate instead of the
    /// per-config auto-selection.
    pub fn with_collective_algo(mut self, algo: CollectiveAlgo) -> Self {
        self.collective_algo = Some(algo);
        self
    }

    fn steps_for(&self, m: &ModelSpec) -> usize {
        self.steps.unwrap_or(m.default_steps)
    }

    fn cap_for(&self, cluster: &ClusterSpec) -> f64 {
        self.memory_cap_bytes.unwrap_or(cluster.gpu.mem_bytes)
    }

    /// Score one explicit config (no enumeration): the building block of
    /// both policies and of `ParallelPolicy::Explicit` plans.
    pub fn score(
        &self,
        m: &ModelSpec,
        px: usize,
        cluster: &ClusterSpec,
        pc: &ParallelConfig,
    ) -> Plan {
        let steps = self.steps_for(m);
        let (algo, predicted) = self.price(m, px, cluster, pc, steps);
        let mem = config_memory(m, px, pc).total();
        Plan {
            model: m.name.clone(),
            px,
            s_img: m.seq_len(px),
            steps,
            world: pc.world(),
            cluster: cluster.name.clone(),
            policy: self.policy,
            config: *pc,
            method: pick_method(pc),
            predicted,
            serial_seconds: serial_latency(m, px, cluster, steps),
            comm_bytes: steps as f64 * config_comm_bytes(m, px, pc),
            peak_memory_bytes: mem,
            fits: mem < self.cap_for(cluster) * HBM_USABLE_FRACTION,
            collective_algo: algo,
            simulated_seconds: None,
            candidates: 0,
            pruned: 0,
            why: String::new(),
        }
    }

    /// Price one config under the planner's collective-algorithm policy.
    /// An explicit override prices with that algorithm; auto (`None`)
    /// prices the flat ring and — when the intra-image group spans nodes —
    /// also the two-level hierarchy, keeping whichever is strictly
    /// cheaper. Ties stay flat, so every cell the hierarchy cannot touch
    /// is byte-identical with flat-only pricing. The `PaperHeuristic`
    /// policy always prices flat: it is the historical oracle the
    /// cost-model plans are compared against.
    fn price(
        &self,
        m: &ModelSpec,
        px: usize,
        cluster: &ClusterSpec,
        pc: &ParallelConfig,
        steps: usize,
    ) -> (CollectiveAlgo, LatencyBreakdown) {
        if let Some(algo) = self.collective_algo {
            let lb = predict_latency_with(m, px, cluster, PerfMethod::Hybrid, pc, steps, algo);
            return (algo, lb);
        }
        let flat = predict_latency_with(
            m,
            px,
            cluster,
            PerfMethod::Hybrid,
            pc,
            steps,
            CollectiveAlgo::FlatRing,
        );
        let n_intra = (pc.world().max(1) / pc.cfg.max(1)).max(1);
        if self.policy == RoutePolicy::PaperHeuristic || n_intra <= cluster.gpus_per_node {
            return (CollectiveAlgo::FlatRing, flat);
        }
        let hier = predict_latency_with(
            m,
            px,
            cluster,
            PerfMethod::Hybrid,
            pc,
            steps,
            CollectiveAlgo::Hierarchical,
        );
        if hier.total < flat.total {
            (CollectiveAlgo::Hierarchical, hier)
        } else {
            (CollectiveAlgo::FlatRing, flat)
        }
    }

    /// Every candidate for the world size, scored and ranked: feasible
    /// plans first (ascending predicted latency), then the memory-pruned
    /// ones (also ascending) so a caller can still inspect what was cut.
    /// Ties keep enumeration order (the sort is stable), which makes the
    /// ranking — and the golden snapshot built on it — deterministic.
    pub fn rank(
        &self,
        m: &ModelSpec,
        px: usize,
        cluster: &ClusterSpec,
        world: usize,
    ) -> Vec<Plan> {
        let s_img = m.seq_len(px);
        let mut plans: Vec<Plan> = ParallelConfig::enumerate(world, m, s_img)
            .iter()
            .map(|pc| self.score(m, px, cluster, pc))
            .collect();
        let candidates = plans.len();
        let pruned = plans.iter().filter(|p| !p.fits).count();
        plans.sort_by(|a, b| {
            // total_cmp: even a NaN from a degenerate cost-model edit
            // orders deterministically instead of panicking the sort
            b.fits.cmp(&a.fits).then(a.predicted.total.total_cmp(&b.predicted.total))
        });
        for p in &mut plans {
            p.candidates = candidates;
            p.pruned = pruned;
        }
        plans
    }

    /// The routing decision: best plan under this planner's policy. Always
    /// returns a config that `validate` admits; when memory pruning
    /// rejects *every* candidate the least-bad plan is returned with
    /// `fits == false` (serving admission can then refuse the request).
    pub fn plan(&self, m: &ModelSpec, px: usize, cluster: &ClusterSpec, world: usize) -> Plan {
        let heuristic_pc = paper_heuristic(m, px, cluster, world);
        if self.policy == RoutePolicy::PaperHeuristic {
            let mut plan = self.score(m, px, cluster, &heuristic_pc);
            plan.why = format!(
                "paper §5.2.4 bandwidth-priority heuristic ({} first)",
                if cluster.has_nvlink { "SP-Ulysses" } else { "PipeFusion" }
            );
            self.attach_simulation(&mut plan, m, cluster);
            return plan;
        }
        let ranked = self.rank(m, px, cluster, world);
        if ranked.is_empty() {
            // enumeration can come up empty on hostile divisibility; the
            // heuristic (which may under-fill the world) is the fallback
            let mut p = self.score(m, px, cluster, &heuristic_pc);
            p.why = "no valid config enumerates for this world; \
                     §5.2.4 heuristic fallback"
                .into();
            self.attach_simulation(&mut p, m, cluster);
            return p;
        }
        if self.fidelity == Fidelity::Simulated {
            return self.rescore_with_simulator(ranked, m, px, cluster);
        }
        let mut best = ranked.into_iter().next().expect("ranked is non-empty");
        let heuristic = self.score(m, px, cluster, &heuristic_pc);
        let surveyed = format!(
            "cost-model argmin over {} candidates ({} pruned by the {:.0} GB cap)",
            best.candidates,
            best.pruned,
            self.cap_for(cluster) / 1e9
        );
        best.why = if best.config == heuristic.config {
            format!("{surveyed}; agrees with the §5.2.4 heuristic")
        } else {
            format!(
                "{surveyed}; beats §5.2.4 heuristic [{}] ({:.2}s) by {:.2}x",
                heuristic.config.describe(),
                heuristic.predicted.total,
                heuristic.predicted.total / best.predicted.total.max(1e-12)
            )
        };
        if best.collective_algo == CollectiveAlgo::Hierarchical {
            best.why.push_str(
                "; two-level hierarchical collectives (intra-node ring + node-leader \
                 exchange) save the shared inter-node ethernet tier",
            );
        }
        best
    }

    /// `Fidelity::Simulated` second pass: play the top closed-form
    /// candidates through the discrete-event simulator, pick the smallest
    /// simulated makespan (ties keep the closed-form order) and cite the
    /// winner's critical path in the "why". Only memory-feasible
    /// candidates compete — the re-scoring must never promote a plan the
    /// budget pruned over one that fits (when nothing fits, the least-bad
    /// set is re-scored as-is).
    fn rescore_with_simulator(
        &self,
        ranked: Vec<Plan>,
        m: &ModelSpec,
        px: usize,
        cluster: &ClusterSpec,
    ) -> Plan {
        let feasible = ranked.iter().filter(|p| p.fits).count();
        let pool = if feasible > 0 { feasible } else { ranked.len() };
        let k = SIM_RESCORE_TOP_K.min(pool);
        let steps = self.steps_for(m);
        let mut top: Vec<Plan> = ranked.into_iter().take(k).collect();
        let mut best_idx = 0;
        let mut best_tl: Option<Timeline> = None;
        for (i, p) in top.iter_mut().enumerate() {
            let tl = simulate_with(
                m,
                px,
                cluster,
                PerfMethod::Hybrid,
                &p.config,
                steps,
                p.collective_algo,
            );
            p.simulated_seconds = Some(tl.makespan);
            let better = best_tl.as_ref().map(|b| tl.makespan < b.makespan).unwrap_or(true);
            if better {
                best_idx = i;
                best_tl = Some(tl);
            }
        }
        let tl = best_tl.expect("at least one candidate was simulated");
        let mut best = top.swap_remove(best_idx);
        best.why = format!(
            "event simulator re-scored the top-{k} of {} closed-form candidates \
             ({} pruned): [{}] wins at {:.2}s simulated with {} collectives \
             ({:.0}% overlap achieved); {}",
            best.candidates,
            best.pruned,
            best.config.describe(),
            tl.makespan,
            best.collective_algo.label(),
            tl.achieved_overlap() * 100.0,
            tl.critical_path()
        );
        best
    }

    /// Attach the simulated makespan to a plan that does not yet carry
    /// one, when this planner runs at `Fidelity::Simulated` (no-op
    /// otherwise). The single attach point shared by the policy
    /// fallbacks, the facade's pinned configs and the engine's forced
    /// strategies.
    pub(crate) fn attach_simulation(&self, plan: &mut Plan, m: &ModelSpec, cluster: &ClusterSpec) {
        if self.fidelity == Fidelity::Simulated && plan.simulated_seconds.is_none() {
            let tl = self.simulate_plan(plan, m, cluster);
            plan.simulated_seconds = Some(tl.makespan);
        }
    }

    /// The event timeline a plan's strategy would produce — the single
    /// mapping from an engine strategy to the simulator's method space,
    /// shared by the engine's per-batch reporting and the pipeline's
    /// `timeline()` accessor. `Method::Serial` strips the intra-image
    /// degrees but keeps the CFG dimension: the driver runs the serial
    /// strategy *per branch* (concurrently, with the per-step latent
    /// exchange), which is exactly what a CFG-only routed config executes.
    pub fn simulate_plan(&self, plan: &Plan, m: &ModelSpec, cluster: &ClusterSpec) -> Timeline {
        let method = match plan.method {
            driver::Method::Serial => {
                let pc = ParallelConfig::new(plan.config.cfg.max(1), 1, 1, 1);
                return simulate_with(
                    m,
                    plan.px,
                    cluster,
                    PerfMethod::Hybrid,
                    &pc,
                    plan.steps,
                    plan.collective_algo,
                );
            }
            driver::Method::Tp => PerfMethod::Tp,
            driver::Method::DistriFusion => PerfMethod::DistriFusion,
            _ => PerfMethod::Hybrid,
        };
        simulate_with(m, plan.px, cluster, method, &plan.config, plan.steps, plan.collective_algo)
    }
}

impl Planner {
    /// Re-price a plan for a *forced* strategy: latency from the
    /// strategy's own closed form, and — for the baselines that do not
    /// run the hybrid composition at all (Serial/TP/DistriFusion) — the
    /// comm volume, peak memory and fits verdict from that strategy's
    /// Table-1 row, so `describe()`/`to_json()` never report hybrid
    /// figures next to a baseline latency. The single source of truth
    /// shared by `PipelineBuilder::plan` and `Engine::plan_for`.
    pub fn reprice_for_method(
        &self,
        plan: &mut Plan,
        method: driver::Method,
        m: &ModelSpec,
        cluster: &ClusterSpec,
    ) {
        use crate::perf::comm_model::{comm_bytes, Row};
        use crate::perf::memory_model::{backbone_memory, serial_memory};
        plan.method = method;
        let n_intra = (plan.config.world() / plan.config.cfg).max(1);
        let s = m.attn_seq_len(plan.px);
        plan.predicted = match method {
            driver::Method::Serial => LatencyBreakdown {
                compute: plan.serial_seconds,
                comm_exposed: 0.0,
                warmup_extra: 0.0,
                total: plan.serial_seconds,
            },
            driver::Method::Tp => predict_latency_with(
                m,
                plan.px,
                cluster,
                PerfMethod::Tp,
                &plan.config,
                plan.steps,
                plan.collective_algo,
            ),
            driver::Method::DistriFusion => predict_latency_with(
                m,
                plan.px,
                cluster,
                PerfMethod::DistriFusion,
                &plan.config,
                plan.steps,
                plan.collective_algo,
            ),
            _ => predict_latency_with(
                m,
                plan.px,
                cluster,
                PerfMethod::Hybrid,
                &plan.config,
                plan.steps,
                plan.collective_algo,
            ),
        };
        let row = match method {
            driver::Method::Serial => {
                plan.comm_bytes = 0.0;
                plan.peak_memory_bytes = serial_memory(m, plan.px).total();
                None
            }
            driver::Method::Tp => Some(Row::TensorParallel),
            driver::Method::DistriFusion => Some(Row::DistriFusion),
            // Sp/PipeFusion/Hybrid run the composition the hybrid
            // comm/memory figures already describe
            _ => None,
        };
        if let Some(row) = row {
            plan.comm_bytes = plan.steps as f64 * comm_bytes(row, m, s, n_intra);
            plan.peak_memory_bytes = backbone_memory(m, plan.px, row, n_intra).total();
        }
        if matches!(
            method,
            driver::Method::Serial | driver::Method::Tp | driver::Method::DistriFusion
        ) {
            plan.fits =
                plan.peak_memory_bytes < self.cap_for(cluster) * HBM_USABLE_FRACTION;
        }
    }
}

/// The (model, representative px, cluster) cells of the paper's Figs 8–17
/// evaluation grid — shared by the golden-plan snapshot, the planner
/// bench and the acceptance tests. The four two-node rows at the end
/// (appended with the hierarchical-collective planner) exercise the
/// models whose head counts admit a node-spanning Ulysses group
/// (pixart/hunyuan: 16 heads), where the two-level hierarchy actually
/// has a cross-node collective to reprice.
pub fn paper_grid() -> Vec<(ModelSpec, usize, ClusterSpec)> {
    [
        ("pixart", 2048, "l40x16"),
        ("sd3", 2048, "l40x16"),
        ("flux", 1024, "l40x16"),
        ("cogvideox", 480, "l40x8"),
        ("pixart", 2048, "a100x8"),
        ("sd3", 2048, "a100x8"),
        ("flux", 1024, "a100x8"),
        ("hunyuan", 2048, "a100x8"),
        ("pixart", 4096, "l40x16"),
        ("hunyuan", 2048, "l40x16"),
        ("pixart", 2048, "a100x16"),
        ("hunyuan", 2048, "a100x16"),
    ]
    .into_iter()
    .map(|(name, px, cluster)| {
        (
            ModelSpec::by_name(name).expect("paper grid model"),
            px,
            ClusterSpec::by_name(cluster).expect("paper grid cluster"),
        )
    })
    .collect()
}

/// World sizes swept per grid cell (clamped to the cluster).
pub const GRID_WORLDS: [usize; 5] = [1, 2, 4, 8, 16];

/// Best sequence-parallel-only plan (cfg = 1, pipefusion = 1 — the
/// paper's "SP" figure series) for a cell under `planner`'s pricing, or
/// `None` when no pure-SP config validates for the world size. The
/// multi-node golden cells record this series under both collective
/// algorithms: it is where a node-spanning Ulysses group competes with
/// ring splits, so it is where the hierarchy flips winners.
pub fn best_sp_plan(
    planner: &Planner,
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
) -> Option<Plan> {
    ParallelConfig::enumerate(world, m, m.seq_len(px))
        .into_iter()
        .filter(|pc| pc.cfg == 1 && pc.pipefusion == 1 && !pc.is_serial())
        .map(|pc| planner.score(m, px, cluster, &pc))
        .min_by(|a, b| a.predicted.total.total_cmp(&b.predicted.total))
}

/// The canonical golden-plan snapshot: one JSON object per (model,
/// cluster, world) cell — cost-model plan plus the heuristic baseline —
/// one cell per line so CI diffs read like a review. Byte-stable:
/// everything numeric is integral, keys are sorted, ordering follows
/// [`paper_grid`] × [`GRID_WORLDS`].
///
/// Cells whose intra-image group can span nodes (world > GPUs per node)
/// additionally record the collective-algorithm provenance:
/// * `sp_flat_config`/`sp_flat_us` — the best pure-SP plan priced with
///   the flat ring, vs `sp_config`/`sp_us` under auto algorithm
///   selection (a differing config is a hierarchy-flipped winner);
/// * `ulysses_flat_us`/`ulysses_hier_us` — the deepest Ulysses closed
///   form under both algorithms, when `ulysses = world` validates;
/// * `algo: "hier"` on the winning plan itself when the hierarchy
///   strictly beat the flat ring for it.
/// Single-node cells carry none of these keys and stay byte-identical
/// with the flat-only snapshot.
pub fn grid_report() -> String {
    use crate::util::json::JsonWriter;
    let planner = Planner::default();
    let heuristic = Planner::default().with_policy(RoutePolicy::PaperHeuristic);
    // one preallocated output buffer + one reused cell writer: the
    // canonical grid renders without a per-cell String (byte-identical to
    // the old join-based emission — the golden snapshot pins it)
    let mut out = String::with_capacity(16 << 10);
    let mut writer = JsonWriter::with_capacity(512);
    out.push_str("[\n");
    let mut first = true;
    for (m, px, cluster) in paper_grid() {
        for world in GRID_WORLDS {
            if world > cluster.n_gpus {
                continue;
            }
            let best = planner.plan(&m, px, &cluster, world);
            let base = heuristic.plan(&m, px, &cluster, world);
            let mut cell = match best.to_json() {
                Json::Obj(o) => o,
                _ => unreachable!("Plan::to_json returns an object"),
            };
            cell.remove("policy");
            cell.insert("heuristic_config".into(), Json::Str(base.config.describe()));
            cell.insert(
                "heuristic_us".into(),
                Json::Num((base.predicted.total * 1e6).round()),
            );
            if world > cluster.gpus_per_node {
                // multi-node cell: record the SP figure series under both
                // collective algorithms so the golden diff shows where
                // the hierarchy strictly wins and which winners it flips
                let flat = Planner::default().with_collective_algo(CollectiveAlgo::FlatRing);
                if let (Some(sp_flat), Some(sp_auto)) = (
                    best_sp_plan(&flat, &m, px, &cluster, world),
                    best_sp_plan(&planner, &m, px, &cluster, world),
                ) {
                    cell.insert(
                        "sp_flat_config".into(),
                        Json::Str(sp_flat.config.describe()),
                    );
                    cell.insert(
                        "sp_flat_us".into(),
                        Json::Num((sp_flat.predicted.total * 1e6).round()),
                    );
                    cell.insert("sp_config".into(), Json::Str(sp_auto.config.describe()));
                    cell.insert(
                        "sp_us".into(),
                        Json::Num((sp_auto.predicted.total * 1e6).round()),
                    );
                }
                let deep = PerfMethod::SpUlysses.single_config(world);
                if deep.validate(&m, m.seq_len(px)).is_ok() {
                    let steps = m.default_steps;
                    for (key, algo) in [
                        ("ulysses_flat_us", CollectiveAlgo::FlatRing),
                        ("ulysses_hier_us", CollectiveAlgo::Hierarchical),
                    ] {
                        let lb = predict_latency_with(
                            &m,
                            px,
                            &cluster,
                            PerfMethod::SpUlysses,
                            &deep,
                            steps,
                            algo,
                        );
                        cell.insert(key.into(), Json::Num((lb.total * 1e6).round()));
                    }
                }
            }
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(writer.render(&Json::Obj(cell)));
        }
    }
    out.push_str("\n]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::perf::latency::predict_latency;

    #[test]
    fn per_step_divides_the_predicted_total() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let plan = Planner::default().with_steps(20).plan(&m, 1024, &l40_cluster(1), 4);
        assert!((plan.per_step(20) * 20.0 - plan.predicted.total).abs() < 1e-12);
        // a zero-step probe clamps instead of dividing by zero
        assert_eq!(plan.per_step(0), plan.predicted.total);
    }

    #[test]
    fn planner_matches_bruteforce_argmin() {
        let planner = Planner::default();
        let m = ModelSpec::by_name("pixart").unwrap();
        for cluster in [l40_cluster(1), a100_node()] {
            for world in [2usize, 4, 8] {
                let best = planner.plan(&m, 2048, &cluster, world);
                let brute = ParallelConfig::enumerate(world, &m, m.seq_len(2048))
                    .iter()
                    .map(|pc| {
                        predict_latency(&m, 2048, &cluster, PerfMethod::Hybrid, pc, 20).total
                    })
                    .fold(f64::INFINITY, f64::min);
                assert!(
                    (best.predicted.total - brute).abs() < 1e-12,
                    "{} w={world}: planner {} != brute {brute}",
                    cluster.name,
                    best.predicted.total
                );
            }
        }
    }

    #[test]
    fn plan_is_never_predicted_slower_than_heuristic() {
        let m = ModelSpec::by_name("sd3").unwrap();
        for cluster in [l40_cluster(2), a100_node()] {
            for world in [2usize, 4, 8] {
                let cost = Planner::default().plan(&m, 1024, &cluster, world);
                let paper = Planner::default()
                    .with_policy(RoutePolicy::PaperHeuristic)
                    .plan(&m, 1024, &cluster, world);
                // bound precondition: the heuristic's pick fits memory
                assert!(
                    !paper.fits || cost.predicted.total <= paper.predicted.total + 1e-12,
                    "{} w={world}: cost {} > paper {}",
                    cluster.name,
                    cost.predicted.total,
                    paper.predicted.total
                );
            }
        }
    }

    #[test]
    fn memory_cap_prunes_and_falls_back_gracefully() {
        let m = ModelSpec::by_name("flux").unwrap();
        let cluster = l40_cluster(1);
        // flux is 24 GB of fp16 weights: a 20 GB cap rules out everything
        // that replicates the params, leaving PipeFusion-heavy plans
        let tight = Planner::default().with_memory_cap_gb(30.0).plan(&m, 1024, &cluster, 8);
        assert!(tight.fits, "some PipeFusion split must fit 30 GB: {}", tight.describe());
        assert!(tight.config.pipefusion >= 2, "{}", tight.describe());
        assert!(tight.pruned > 0, "the cap must have pruned SP-only plans");
        // an impossible cap: the planner still returns the least-bad plan,
        // flagged infeasible
        let hopeless = Planner::default().with_memory_cap_gb(1.0).plan(&m, 1024, &cluster, 8);
        assert!(!hopeless.fits);
        assert_eq!(hopeless.pruned, hopeless.candidates);
    }

    #[test]
    fn rank_is_sorted_and_consistent() {
        let planner = Planner::default();
        let m = ModelSpec::by_name("pixart").unwrap();
        let cluster = l40_cluster(1);
        let ranked = planner.rank(&m, 1024, &cluster, 8);
        assert!(!ranked.is_empty());
        for w in ranked.windows(2) {
            if w[0].fits == w[1].fits {
                assert!(w[0].predicted.total <= w[1].predicted.total);
            } else {
                assert!(w[0].fits, "feasible plans must rank before pruned ones");
            }
        }
        let best = planner.plan(&m, 1024, &cluster, 8);
        assert_eq!(best.config, ranked[0].config);
        assert!(best.why.contains("argmin"), "{}", best.why);
    }

    #[test]
    fn reprice_gives_baselines_their_own_rows() {
        use crate::perf::comm_model::{comm_bytes, Row};
        use crate::perf::memory_model::{backbone_memory, serial_memory};
        let planner = Planner::default();
        let m = ModelSpec::by_name("pixart").unwrap();
        let cluster = l40_cluster(1);

        // forced Serial: serial latency, zero comm, serial footprint
        let mut serial = planner.plan(&m, 2048, &cluster, 8);
        planner.reprice_for_method(&mut serial, driver::Method::Serial, &m, &cluster);
        assert_eq!(serial.method, driver::Method::Serial);
        assert_eq!(serial.comm_bytes, 0.0);
        assert_eq!(serial.predicted.total, serial.serial_seconds);
        assert_eq!(serial.peak_memory_bytes, serial_memory(&m, 2048).total());

        // forced DistriFusion: its own Table-1 comm/memory rows at the
        // intra-image degree, and fits recomputed against that footprint
        let mut df = planner.plan(&m, 2048, &cluster, 8);
        planner.reprice_for_method(&mut df, driver::Method::DistriFusion, &m, &cluster);
        let n_intra = df.config.world() / df.config.cfg;
        let s = m.attn_seq_len(2048);
        let expect_comm =
            df.steps as f64 * comm_bytes(Row::DistriFusion, &m, s, n_intra);
        assert_eq!(df.comm_bytes, expect_comm);
        let expect_mem = backbone_memory(&m, 2048, Row::DistriFusion, n_intra).total();
        assert_eq!(df.peak_memory_bytes, expect_mem);
        assert_eq!(
            df.fits,
            expect_mem < cluster.gpu.mem_bytes * HBM_USABLE_FRACTION
        );
        assert!(df.peak_memory_bytes > serial.peak_memory_bytes * 0.1);

        // forced Sp keeps the hybrid composition's figures (it runs it)
        let base = planner.plan(&m, 2048, &cluster, 8);
        let mut sp = base.clone();
        planner.reprice_for_method(&mut sp, driver::Method::Sp, &m, &cluster);
        assert_eq!(sp.comm_bytes, base.comm_bytes);
        assert_eq!(sp.peak_memory_bytes, base.peak_memory_bytes);
    }

    #[test]
    fn simulated_fidelity_rescores_and_cites_critical_path() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let cluster = l40_cluster(1);
        let planner = Planner::default().with_fidelity(Fidelity::Simulated);
        let sim = planner.plan(&m, 2048, &cluster, 8);
        assert!(sim.simulated_seconds.is_some());
        assert!(sim.why.contains("finishes last"), "why must cite critical path: {}", sim.why);
        sim.config.validate(&m, m.seq_len(2048)).unwrap();
        assert_eq!(sim.config.world(), 8);
        assert!(sim.to_json().to_string().contains("simulated_us"));
        // the closed-form default is untouched (golden snapshot safety)
        let default = Planner::default().plan(&m, 2048, &cluster, 8);
        assert!(default.simulated_seconds.is_none());
        assert!(!default.to_json().to_string().contains("simulated_us"));
        assert!(default.why.contains("argmin"), "{}", default.why);
        // the heuristic policy also reports a simulated makespan on ask
        let paper = Planner::default()
            .with_policy(RoutePolicy::PaperHeuristic)
            .with_fidelity(Fidelity::Simulated)
            .plan(&m, 2048, &cluster, 8);
        assert!(paper.simulated_seconds.unwrap() > 0.0);
    }

    #[test]
    fn simulate_plan_covers_every_strategy_mapping() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let cluster = l40_cluster(1);
        let planner = Planner::default();
        for method in [
            driver::Method::Serial,
            driver::Method::Tp,
            driver::Method::Sp,
            driver::Method::DistriFusion,
            driver::Method::PipeFusion,
            driver::Method::Hybrid,
        ] {
            let mut plan = planner.plan(&m, 1024, &cluster, 8);
            planner.reprice_for_method(&mut plan, method, &m, &cluster);
            let tl = planner.simulate_plan(&plan, &m, &cluster);
            assert!(tl.makespan > 0.0, "{method:?} simulated an empty timeline");
            if method == driver::Method::Serial {
                // serial strips the intra degrees but keeps the CFG pair
                assert_eq!(tl.world(), plan.config.cfg, "{}", plan.config.describe());
            }
        }
        // a CFG-only config picks Method::Serial (serial per branch) —
        // its timeline must keep both branch ranks and their exchange
        let cfg_only = planner.score(&m, 1024, &cluster, &ParallelConfig::new(2, 1, 1, 1));
        assert_eq!(cfg_only.method, driver::Method::Serial);
        let tl = planner.simulate_plan(&cfg_only, &m, &cluster);
        assert_eq!(tl.world(), 2, "the CFG pair must keep both ranks");
        assert!(tl.exposed_comm() > 0.0, "the per-step latent exchange must appear");
    }

    #[test]
    fn simulated_rescoring_respects_the_memory_budget() {
        // the re-scoring pool is feasible-only: a pruned-but-faster plan
        // must never beat a plan that fits the cap
        let m = ModelSpec::by_name("flux").unwrap();
        let cluster = l40_cluster(1);
        let planner =
            Planner::default().with_memory_cap_gb(30.0).with_fidelity(Fidelity::Simulated);
        let plan = planner.plan(&m, 1024, &cluster, 8);
        assert!(plan.fits, "re-scoring resurrected a pruned plan: {}", plan.describe());
        assert!(plan.simulated_seconds.is_some());
        assert!(plan.pruned > 0, "the cap must actually have pruned something");
    }

    #[test]
    fn policy_parse_round_trips() {
        for (s, p) in [
            ("cost", RoutePolicy::CostModel),
            ("cost-model", RoutePolicy::CostModel),
            ("paper", RoutePolicy::PaperHeuristic),
            ("heuristic", RoutePolicy::PaperHeuristic),
        ] {
            assert_eq!(RoutePolicy::parse(s).unwrap(), p);
        }
        assert!(RoutePolicy::parse("greedy").is_err());
        let key = RoutePolicy::CostModel.key();
        assert_eq!(RoutePolicy::parse(key).unwrap(), RoutePolicy::CostModel);
    }

    #[test]
    fn grid_report_is_deterministic_canonical_json() {
        let a = grid_report();
        let b = grid_report();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        let cells = parsed.as_arr().unwrap();
        // 5 l40x16 rows x 5 worlds + 1 l40x8 row x 4 + 4 a100x8 rows x 4
        // + 2 a100x16 rows x 5
        assert_eq!(cells.len(), 55);
        for cell in cells {
            let world = cell.get("world").unwrap().as_usize().unwrap();
            assert!(GRID_WORLDS.contains(&world));
            assert!(cell.get("predicted_us").unwrap().as_f64().unwrap() >= 0.0);
            assert!(cell.get("heuristic_us").unwrap().as_f64().unwrap() >= 0.0);
            assert!(cell.get("fits").unwrap().as_bool().unwrap(), "grid cells all fit HBM");
            // (the planner-vs-heuristic acceptance bound lives in
            // tests/planner.rs, conditioned on the heuristic pick fitting
            // memory — a raw per-cell comparison here would misfire if a
            // future grid cell memory-prunes the heuristic's choice)
        }
    }

    #[test]
    fn grid_hierarchy_never_slower_and_flips_a_winner() {
        // the acceptance bar of the hierarchical-collective planner, read
        // off the golden grid itself: hierarchy never predicted-slower
        // than the flat ring anywhere, strictly faster in >= 5 multi-node
        // cells, and at least one cell's SP-series winner flips
        let parsed = Json::parse(&grid_report()).unwrap();
        let mut strictly_faster = 0;
        let mut flips = 0;
        let mut sp_cells = 0;
        for cell in parsed.as_arr().unwrap() {
            if let (Ok(uf), Ok(uh)) =
                (cell.get("ulysses_flat_us"), cell.get("ulysses_hier_us"))
            {
                let (uf, uh) = (uf.as_f64().unwrap(), uh.as_f64().unwrap());
                assert!(uh <= uf, "hier slower than flat in {cell:?}");
                if uh < uf {
                    strictly_faster += 1;
                }
            }
            if let (Ok(sf), Ok(sa)) = (cell.get("sp_flat_us"), cell.get("sp_us")) {
                sp_cells += 1;
                let (sf, sa) = (sf.as_f64().unwrap(), sa.as_f64().unwrap());
                assert!(sa <= sf, "auto SP pricing worse than flat in {cell:?}");
                if cell.get("sp_flat_config").unwrap() != cell.get("sp_config").unwrap() {
                    flips += 1;
                }
            }
        }
        assert!(sp_cells >= 5, "expected >= 5 multi-node SP cells, got {sp_cells}");
        assert!(
            strictly_faster >= 5,
            "hierarchy must win strictly in >= 5 multi-node cells, got {strictly_faster}"
        );
        assert!(flips >= 1, "the hierarchy must flip at least one SP-series winner");
    }

    #[test]
    fn auto_algo_tags_only_strict_hierarchy_wins() {
        let m = ModelSpec::by_name("pixart").unwrap();
        // single node: nothing to exploit, every plan stays flat and the
        // JSON carries no "algo" key
        let single = Planner::default().plan(&m, 2048, &l40_cluster(1), 8);
        assert_eq!(single.collective_algo, CollectiveAlgo::FlatRing);
        assert!(!single.to_json().to_string().contains("\"algo\""));
        // forced hierarchy is honored even where it cannot win
        let forced = Planner::default()
            .with_collective_algo(CollectiveAlgo::Hierarchical)
            .plan(&m, 2048, &l40_cluster(1), 8);
        assert_eq!(forced.collective_algo, CollectiveAlgo::Hierarchical);
        assert_eq!(forced.predicted.total.to_bits(), single.predicted.total.to_bits());
        assert!(forced.to_json().to_string().contains("\"algo\""));
        assert!(forced.describe().contains("hierarchical collectives"));
        // auto on a two-node SP series: the node-spanning Ulysses config
        // must price hierarchical when that is strictly cheaper
        let c = crate::config::hardware::a100_cluster(2);
        let deep = Planner::default().score(&m, 2048, &c, &ParallelConfig::new(1, 1, 16, 1));
        assert_eq!(deep.collective_algo, CollectiveAlgo::Hierarchical);
        let flat_deep = Planner::default()
            .with_collective_algo(CollectiveAlgo::FlatRing)
            .score(&m, 2048, &c, &ParallelConfig::new(1, 1, 16, 1));
        assert!(deep.predicted.total < flat_deep.predicted.total);
    }
}
