//! Seeded adversarial serving scenarios.
//!
//! Each [`Scenario`] is a *pure function* of `(seed, n)` producing a
//! [`Trace`]: arrivals, SLO classes, model mixes and mid-trace cluster
//! events all derive from one seeded [`Rng`], so a scenario replays
//! bit-identically — the property `tests/scenarios.rs` pins. The catalog
//! deliberately covers the failure modes a static Poisson workload never
//! exercises: thundering-herd bursts, diurnal load swings, mixed
//! image+video (CogVideoX-shaped) traffic, straggler ranks, and
//! mid-trace failures that force the `PlanCache` invalidation seam.

use crate::config::model::BlockVariant;
use crate::coordinator::request::{GenRequest, SloClass, DEFAULT_PX};
use crate::coordinator::trace::{Trace, TraceEvent, TraceEventKind};
use crate::util::rng::Rng;

/// Prompt pool shared by the scenario generators (sampled per request).
const PROMPTS: [&str; 4] =
    ["a red fox in snow", "city skyline at dusk", "an astronaut sketch", "a bowl of fruit"];

/// A named adversarial serving scenario (see the module docs). The CLI
/// exposes the catalog as `serve --scenario <name>`; `tests/scenarios.rs`
/// replays every variant against the SLO invariants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Quiet trickle, a lull, then a thundering herd at ~25× the base
    /// rate with interactive requests inside the burst.
    Burst,
    /// Four alternating low/high "time of day" phases — the load swings
    /// the batcher must absorb without starving the batch tier.
    Diurnal,
    /// Mixed image and video traffic: cheap AdaLn image requests plus
    /// CogVideoX-shaped MM-DiT clips (long sequences, more steps) on the
    /// batch tier — the two populations must not starve each other.
    MixedMedia,
    /// A straggler rank halves cluster throughput mid-trace, then
    /// recovers — the fingerprint must flip on both edges and restore
    /// bit-exactly (the slowdown factors are powers of two).
    Straggler,
    /// Rank failure, node drain and node re-join mid-trace, plus two
    /// cancellations — every event forces a re-plan on the next batch.
    FailureReplan,
    /// Fleet-scale: a replica dies mid-burst with requests in flight —
    /// failover must checkpoint, migrate and credit its progress while
    /// the herd keeps arriving.
    ReplicaKill,
    /// Fleet-scale: maintenance rolls a drain across replicas 0..2, one
    /// at a time, each recovering before the next drains.
    RollingDrain,
    /// Fleet-scale: straggler slowdowns cascade across replicas 0..2,
    /// then recover in order — the factors are powers of two netting
    /// 1.0, so a single-engine replay restores its fingerprint exactly.
    CascadingStragglers,
}

impl Scenario {
    /// Every scenario, in catalog order.
    pub const ALL: [Scenario; 8] = [
        Scenario::Burst,
        Scenario::Diurnal,
        Scenario::MixedMedia,
        Scenario::Straggler,
        Scenario::FailureReplan,
        Scenario::ReplicaKill,
        Scenario::RollingDrain,
        Scenario::CascadingStragglers,
    ];

    /// The fleet-scale variants (replica-targeted fault schedules) —
    /// what the `fault-smoke` CI job replays through a 4-replica fleet.
    pub const FLEET: [Scenario; 3] =
        [Scenario::ReplicaKill, Scenario::RollingDrain, Scenario::CascadingStragglers];

    /// Stable CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Burst => "burst",
            Scenario::Diurnal => "diurnal",
            Scenario::MixedMedia => "mixed-media",
            Scenario::Straggler => "straggler",
            Scenario::FailureReplan => "failure-replan",
            Scenario::ReplicaKill => "replica-kill",
            Scenario::RollingDrain => "rolling-drain",
            Scenario::CascadingStragglers => "cascading-stragglers",
        }
    }

    /// Parse a CLI name (the inverse of [`Scenario::name`]).
    pub fn by_name(name: &str) -> Option<Scenario> {
        Scenario::ALL.iter().copied().find(|s| s.name() == name)
    }

    /// One-line description for `--help` and reports.
    pub fn describe(&self) -> &'static str {
        match self {
            Scenario::Burst => "quiet trickle, then a thundering herd with interactive work",
            Scenario::Diurnal => "alternating low/high load phases (virtual time of day)",
            Scenario::MixedMedia => "image traffic plus CogVideoX-shaped video clips",
            Scenario::Straggler => "mid-trace straggler slowdown and recovery",
            Scenario::FailureReplan => "rank fail, node drain/re-join and cancellations",
            Scenario::ReplicaKill => "a replica dies mid-burst; failover migrates its work",
            Scenario::RollingDrain => "a maintenance drain rolls across the fleet, one at a time",
            Scenario::CascadingStragglers => "slowdowns cascade across replicas, then recover",
        }
    }

    /// Materialize the deterministic trace: a pure function of
    /// `(seed, n)`. `n` is clamped to ≥ 8 so every scenario keeps its
    /// shape (bursts need a pre-burst population, events need arrivals
    /// on both sides of the fire time).
    pub fn trace(&self, seed: u64, n: usize) -> Trace {
        let n = n.max(8);
        match self {
            Scenario::Burst => burst(seed, n),
            Scenario::Diurnal => diurnal(seed, n),
            Scenario::MixedMedia => mixed_media(seed, n),
            Scenario::Straggler => straggler(seed, n),
            Scenario::FailureReplan => failure_replan(seed, n),
            Scenario::ReplicaKill => replica_kill(seed, n),
            Scenario::RollingDrain => rolling_drain(seed, n),
            Scenario::CascadingStragglers => cascading_stragglers(seed, n),
        }
    }
}

/// A request with the scenario defaults (cheap, deterministic per-id
/// seed) at `arrival`, classed by `slo`.
fn request(rng: &mut Rng, seed: u64, id: u64, arrival: f64, slo: SloClass) -> GenRequest {
    GenRequest::new(id, *rng.pick(&PROMPTS))
        .with_steps(2)
        .with_arrival(arrival)
        .with_seed(seed.wrapping_add(id))
        .with_slo(slo)
}

fn burst(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let quiet = n / 2;
    let mut t = 0.0;
    for i in 0..n as u64 {
        if i == quiet as u64 {
            // the lull before the herd: the engine drains fully, then
            // the second half arrives ~25× faster than the first
            t += 5.0;
        }
        t += if (i as usize) < quiet { rng.exp(0.8) } else { rng.exp(20.0) };
        let slo = if (i as usize) >= quiet {
            // the burst mixes urgent work into the herd
            *rng.pick(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch])
        } else {
            *rng.pick(&[SloClass::Standard, SloClass::Batch])
        };
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    Trace::new(requests)
}

fn diurnal(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    // four "times of day": night trickle, morning ramp, midday plateau,
    // evening peak — the rate the exponential gaps are drawn at
    let phase_rates = [0.5, 4.0, 1.0, 6.0];
    let per_phase = n.div_ceil(phase_rates.len());
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        let phase = (i as usize / per_phase).min(phase_rates.len() - 1);
        t += rng.exp(phase_rates[phase]);
        // peak phases skew interactive, troughs skew batch
        let slo = if phase_rates[phase] >= 4.0 {
            *rng.pick(&[SloClass::Interactive, SloClass::Standard])
        } else {
            *rng.pick(&[SloClass::Standard, SloClass::Batch])
        };
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    Trace::new(requests)
}

fn mixed_media(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        t += rng.exp(1.5);
        let is_video = rng.below(4) == 0;
        let r = if is_video {
            // CogVideoX-shaped: MM-DiT, longer sequence, more steps —
            // bulky clips ride the batch tier
            request(&mut rng, seed, i, t, SloClass::Batch)
                .with_variant(BlockVariant::MmDit)
                .with_steps(8)
                .with_resolution(2 * DEFAULT_PX)
        } else {
            let slo = *rng.pick(&[SloClass::Interactive, SloClass::Standard]);
            request(&mut rng, seed, i, t, slo)
        };
        requests.push(r);
    }
    Trace::new(requests)
}

fn straggler(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        t += rng.exp(1.5);
        let slo = *rng.pick(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch]);
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    let horizon = t;
    // slowdown and recovery are powers of two, so the recovered cluster
    // fingerprint matches the original bit-exactly
    let events = vec![
        TraceEvent::new(0.25 * horizon, TraceEventKind::Straggler(0.5)),
        TraceEvent::new(0.75 * horizon, TraceEventKind::Straggler(2.0)),
    ];
    Trace::new(requests).with_events(events)
}

fn failure_replan(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        t += rng.exp(1.5);
        let slo = *rng.pick(&[SloClass::Standard, SloClass::Batch]);
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    let horizon = t;
    // cancel two mid-trace requests right after they arrive (one early,
    // one late) — queued or mid-flight depending on load at that instant
    let c1 = &requests[n / 3];
    let c2 = &requests[2 * n / 3];
    let events = vec![
        TraceEvent::new(c1.arrival, TraceEventKind::Cancel(c1.id)),
        TraceEvent::new(0.2 * horizon, TraceEventKind::RankFail),
        TraceEvent::new(0.4 * horizon, TraceEventKind::NodeShrink),
        TraceEvent::new(c2.arrival, TraceEventKind::Cancel(c2.id)),
        TraceEvent::new(0.7 * horizon, TraceEventKind::NodeGrow),
    ];
    Trace::new(requests).with_events(events)
}

fn replica_kill(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let quiet = n / 2;
    let mut t = 0.0;
    let mut herd_start = 0.0;
    for i in 0..n as u64 {
        if i == quiet as u64 {
            // a lull, then the herd — the kill lands inside the herd, so
            // the dead replica has both queued and mid-flight work
            t += 4.0;
            herd_start = t;
        }
        t += if (i as usize) < quiet { rng.exp(0.9) } else { rng.exp(16.0) };
        let slo = if (i as usize) >= quiet {
            *rng.pick(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch])
        } else {
            *rng.pick(&[SloClass::Standard, SloClass::Batch])
        };
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    let horizon = t;
    // a quarter of the way into the herd, replica 1 drops dead
    let kill_at = herd_start + 0.25 * (horizon - herd_start);
    let events = vec![TraceEvent::on_replica(kill_at, TraceEventKind::ReplicaFail, 1)];
    Trace::new(requests).with_events(events)
}

fn rolling_drain(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        t += rng.exp(2.5);
        let slo = *rng.pick(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch]);
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    let horizon = t;
    // maintenance rolls across replicas 0..2: each drains, finishes its
    // backlog, and recovers before the next one goes down
    let events = vec![
        TraceEvent::on_replica(0.15 * horizon, TraceEventKind::ReplicaDrain, 0),
        TraceEvent::on_replica(0.40 * horizon, TraceEventKind::ReplicaRecover, 0),
        TraceEvent::on_replica(0.40 * horizon, TraceEventKind::ReplicaDrain, 1),
        TraceEvent::on_replica(0.65 * horizon, TraceEventKind::ReplicaRecover, 1),
        TraceEvent::on_replica(0.65 * horizon, TraceEventKind::ReplicaDrain, 2),
        TraceEvent::on_replica(0.90 * horizon, TraceEventKind::ReplicaRecover, 2),
    ];
    Trace::new(requests).with_events(events)
}

fn cascading_stragglers(seed: u64, n: usize) -> Trace {
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n as u64 {
        t += rng.exp(1.8);
        let slo = *rng.pick(&[SloClass::Interactive, SloClass::Standard, SloClass::Batch]);
        requests.push(request(&mut rng, seed, i, t, slo));
    }
    let horizon = t;
    // the slowdown sweeps r0 -> r1 -> r2, then recovery sweeps in the
    // same order; 0.5 * 2.0 = 1.0 so each replica's cluster fingerprint
    // restores bit-exactly once its recovery lands
    let events = vec![
        TraceEvent::on_replica(0.20 * horizon, TraceEventKind::Straggler(0.5), 0),
        TraceEvent::on_replica(0.35 * horizon, TraceEventKind::Straggler(0.5), 1),
        TraceEvent::on_replica(0.50 * horizon, TraceEventKind::Straggler(0.5), 2),
        TraceEvent::on_replica(0.65 * horizon, TraceEventKind::Straggler(2.0), 0),
        TraceEvent::on_replica(0.75 * horizon, TraceEventKind::Straggler(2.0), 1),
        TraceEvent::on_replica(0.85 * horizon, TraceEventKind::Straggler(2.0), 2),
    ];
    Trace::new(requests).with_events(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn same_trace(a: &Trace, b: &Trace) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests().iter().zip(b.requests()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.slo, y.slo);
            assert_eq!(x.variant, y.variant);
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.px, y.px);
            assert_eq!(x.seed, y.seed);
        }
        assert_eq!(a.events(), b.events());
    }

    #[test]
    fn every_scenario_is_deterministic_in_the_seed() {
        for s in Scenario::ALL {
            same_trace(&s.trace(42, 32), &s.trace(42, 32));
            let other = s.trace(43, 32);
            let base = s.trace(42, 32);
            let differs = base
                .requests()
                .iter()
                .zip(other.requests())
                .any(|(x, y)| x.arrival != y.arrival || x.prompt != y.prompt);
            assert!(differs, "{}: the seed must matter", s.name());
        }
    }

    #[test]
    fn names_round_trip_and_describe() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::by_name(s.name()), Some(s));
            assert!(!s.describe().is_empty());
        }
        assert_eq!(Scenario::by_name("nope"), None);
    }

    #[test]
    fn burst_arrives_much_faster_than_the_trickle() {
        let (trickle, herd) = Scenario::Burst.trace(7, 64).mean_gaps();
        assert!(
            herd * 5.0 < trickle,
            "burst gaps ({herd:.4}s) must be far below trickle gaps ({trickle:.4}s)"
        );
        let t = Scenario::Burst.trace(7, 64);
        assert!(
            t.requests().iter().any(|r| r.slo == SloClass::Interactive),
            "the herd carries interactive work"
        );
    }

    #[test]
    fn mixed_media_has_both_populations() {
        let t = Scenario::MixedMedia.trace(11, 64);
        let videos = t.requests().iter().filter(|r| r.variant == BlockVariant::MmDit);
        let clips: Vec<_> = videos.collect();
        assert!(!clips.is_empty(), "some requests must be video-shaped");
        assert!(clips.len() < 48, "video must stay the minority population");
        for c in &clips {
            assert_eq!(c.slo, SloClass::Batch);
            assert_eq!(c.steps, 8);
            assert_eq!(c.px, 2 * DEFAULT_PX);
        }
        assert!(t.requests().iter().any(|r| r.variant == BlockVariant::AdaLn));
    }

    #[test]
    fn event_scenarios_schedule_sorted_mutations() {
        let s = Scenario::Straggler.trace(3, 32);
        assert_eq!(s.events().len(), 2);
        assert!(matches!(s.events()[0].kind, TraceEventKind::Straggler(f) if f == 0.5));
        assert!(matches!(s.events()[1].kind, TraceEventKind::Straggler(f) if f == 2.0));

        let f = Scenario::FailureReplan.trace(3, 32);
        assert_eq!(f.events().len(), 5);
        let mut prev = 0.0;
        for e in f.events() {
            assert!(e.at >= prev, "events must be sorted");
            prev = e.at;
        }
        let cancels =
            f.events().iter().filter(|e| matches!(e.kind, TraceEventKind::Cancel(_))).count();
        assert_eq!(cancels, 2);
        // burst / diurnal / mixed-media keep the world static
        assert!(Scenario::Burst.trace(3, 32).events().is_empty());
        assert!(Scenario::Diurnal.trace(3, 32).events().is_empty());
        assert!(Scenario::MixedMedia.trace(3, 32).events().is_empty());
    }

    #[test]
    fn fleet_scenarios_target_replicas_with_sorted_fault_schedules() {
        for s in Scenario::FLEET {
            let t = s.trace(3, 32);
            assert!(!t.events().is_empty(), "{}: fleet scenarios carry events", s.name());
            let mut prev = 0.0;
            for e in t.events() {
                assert!(e.at >= prev, "{}: events must be sorted", s.name());
                prev = e.at;
                assert!(e.replica.is_some(), "{}: every event targets a replica", s.name());
            }
        }
        let kills: Vec<_> = Scenario::ReplicaKill
            .trace(3, 32)
            .events()
            .iter()
            .filter(|e| matches!(e.kind, TraceEventKind::ReplicaFail))
            .cloned()
            .collect();
        assert_eq!(kills.len(), 1, "replica-kill fires exactly one failure");
        assert_eq!(kills[0].replica, Some(1));
        // the kill lands inside the herd: after the lull that follows the
        // last quiet-phase arrival (requests[15] for n = 32)
        let t = Scenario::ReplicaKill.trace(3, 32);
        let lull_end = t.requests()[15].arrival + 4.0;
        assert!(kills[0].at > lull_end, "kill must land inside the herd");

        let d = Scenario::RollingDrain.trace(3, 32);
        let drains =
            d.events().iter().filter(|e| matches!(e.kind, TraceEventKind::ReplicaDrain)).count();
        let recovers =
            d.events().iter().filter(|e| matches!(e.kind, TraceEventKind::ReplicaRecover)).count();
        assert_eq!((drains, recovers), (3, 3), "each drained replica recovers");

        let c = Scenario::CascadingStragglers.trace(3, 32);
        for replica in 0..3usize {
            let net: f64 = c
                .events()
                .iter()
                .filter(|e| e.replica == Some(replica))
                .map(|e| match e.kind {
                    TraceEventKind::Straggler(f) => f,
                    _ => panic!("cascading-stragglers only schedules slowdowns"),
                })
                .product();
            assert_eq!(net, 1.0, "replica {replica}: slowdowns must net out");
        }
    }

    #[test]
    fn tiny_n_is_clamped_so_shapes_survive() {
        for s in Scenario::ALL {
            assert!(s.trace(1, 0).len() >= 8, "{}: n clamps to 8", s.name());
        }
    }
}

#[cfg(test)]
impl Trace {
    /// (mean trickle gap, mean herd gap) of a burst trace — test helper.
    fn mean_gaps(&self) -> (f64, f64) {
        let arr: Vec<f64> = self.requests().iter().map(|r| r.arrival).collect();
        let half = arr.len() / 2;
        let mean = |xs: &[f64]| -> f64 {
            let gaps: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
            gaps.iter().sum::<f64>() / gaps.len().max(1) as f64
        };
        (mean(&arr[..half]), mean(&arr[half..]))
    }
}
