//! Serving metrics: latency histograms (log buckets), throughput counters,
//! the queue-delay vs execution-time split, batch-occupancy stats of
//! the continuous-batching scheduler, and the per-stage occupancy block
//! of the staged engine ([`StageStats`]).

use crate::coordinator::stages::StageStats;

/// Log-bucketed latency histogram over seconds (~1ms to ~1000s).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// log2 buckets over seconds: (-inf,1ms], (1,2ms], ... up to >= ~1000s
    buckets: Vec<u64>,
    /// Observations recorded.
    pub count: u64,
    /// Sum of all observed values (seconds).
    pub sum: f64,
    /// Largest observed value (seconds).
    pub max: f64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 32], count: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        let ms = (v * 1e3).max(1e-9);
        (ms.log2().floor().max(0.0) as usize).min(31)
    }

    /// Record one observation of `v` seconds.
    pub fn observe(&mut self, v: f64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return (1u64 << (i + 1)) as f64 / 1e3;
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Engine metrics snapshot.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// End-to-end virtual latency (queue delay + execution).
    pub latency: Histogram,
    /// Time between arrival and batch launch (the queueing component).
    pub queue_delay: Histogram,
    /// Time on the simulated cluster (denoise + optional VAE decode).
    pub exec_time: Histogram,
    /// Requests served to completion.
    pub served: u64,
    /// Requests refused admission (backpressure or deadline admission).
    pub rejected: u64,
    /// Total simulated device-seconds of model compute.
    pub model_seconds: f64,
    /// Virtual end-to-end seconds of the serving run (the makespan).
    pub horizon: f64,
    /// Sessions actually constructed. With the warm session cache this
    /// stays proportional to the number of *distinct* batch shapes, not
    /// the number of batches (see `sessions_reused`).
    pub sessions_built: u64,
    /// Batches served on a recycled session from the warm cache (clocks
    /// and ledger reset, mesh/model/config reused). `sessions_built +
    /// sessions_reused == batches` on the engine tick path.
    pub sessions_reused: u64,
    /// Routing decisions served from the `PlanCache` memo.
    pub plan_cache_hits: u64,
    /// Routing decisions that ran the cold enumerate + score sweep.
    pub plan_cache_misses: u64,
    /// Times the plan/session caches were wiped because the cluster spec
    /// changed under the engine.
    pub plan_cache_invalidations: u64,
    /// Parallel-VAE constructions; stays at 1 for the whole life of an
    /// engine no matter how many requests decode.
    pub vae_builds: u64,
    /// Scheduler ticks taken (continuous-batching mode).
    pub ticks: u64,
    /// Ticks that found nothing waiting.
    pub idle_ticks: u64,
    /// Batches launched.
    pub batches: u64,
    /// Sum of batch sizes (mean occupancy = occupancy_sum / batches).
    pub occupancy_sum: u64,
    /// Largest batch launched.
    pub occupancy_max: u64,
    /// Requests that finished after their declared deadline.
    pub deadline_misses: u64,
    /// Per-stage busy seconds, inter-stage queue depths, and decode
    /// backpressure stalls (the staged-execution block; busy seconds
    /// accumulate on the serial path too).
    pub stages: StageStats,
}

impl Metrics {
    /// Served requests per virtual second of the serving horizon.
    pub fn throughput(&self) -> f64 {
        if self.horizon > 0.0 {
            self.served as f64 / self.horizon
        } else {
            0.0
        }
    }

    /// Record a launched batch of `n` requests.
    pub fn observe_batch(&mut self, n: usize) {
        self.batches += 1;
        self.occupancy_sum += n as u64;
        self.occupancy_max = self.occupancy_max.max(n as u64);
    }

    /// Mean requests per launched batch.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }

    /// Fraction of routing decisions served from the plan cache.
    pub fn plan_cache_hit_rate(&self) -> f64 {
        let total = self.plan_cache_hits + self.plan_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_cache_hits as f64 / total as f64
        }
    }

    /// One-line steady-state summary of the hot-path caches: how often
    /// planning and session construction were skipped. Printed by the
    /// `serve` CLI after the serving report.
    pub fn steady_state(&self) -> String {
        format!(
            "steady-state: plan cache {}/{} hits ({:.1}% hit rate, {} invalidations) | \
             sessions {} built, {} reused | vae_builds={}",
            self.plan_cache_hits,
            self.plan_cache_hits + self.plan_cache_misses,
            self.plan_cache_hit_rate() * 100.0,
            self.plan_cache_invalidations,
            self.sessions_built,
            self.sessions_reused,
            self.vae_builds,
        )
    }

    /// Human-readable snapshot. Virtual makespan, the queue-delay vs
    /// execution split, and batch occupancy are reported separately —
    /// folding them into one latency figure hides *where* time went.
    pub fn report(&self) -> String {
        format!(
            "served={} rejected={} | makespan {:.3}s virtual, {:.2} img/s | \
             latency p50/p95/p99 {:.3}/{:.3}/{:.3}s (mean {:.3}s max {:.3}s) | \
             queue delay mean {:.3}s p95 {:.3}s | exec mean {:.3}s | \
             batches={} occupancy mean {:.2} max {} | deadline misses={} | \
             sessions={}+{} reused | plan cache {}/{} | vae_builds={}",
            self.served,
            self.rejected,
            self.horizon,
            self.throughput(),
            self.latency.quantile(0.50),
            self.latency.quantile(0.95),
            self.latency.quantile(0.99),
            self.latency.mean(),
            self.latency.max,
            self.queue_delay.mean(),
            self.queue_delay.quantile(0.95),
            self.exec_time.mean(),
            self.batches,
            self.mean_occupancy(),
            self.occupancy_max,
            self.deadline_misses,
            self.sessions_built,
            self.sessions_reused,
            self.plan_cache_hits,
            self.plan_cache_hits + self.plan_cache_misses,
            self.vae_builds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new();
        for v in [0.001, 0.002, 0.004, 0.008, 0.1, 1.0] {
            h.observe(v);
        }
        assert_eq!(h.count, 6);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert_eq!(h.max, 1.0);
    }

    #[test]
    fn throughput() {
        let mut m = Metrics::default();
        m.served = 10;
        m.horizon = 5.0;
        assert!((m.throughput() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_tracking() {
        let mut m = Metrics::default();
        m.observe_batch(4);
        m.observe_batch(2);
        m.observe_batch(3);
        assert_eq!(m.batches, 3);
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-9);
        assert_eq!(m.occupancy_max, 4);
    }

    #[test]
    fn steady_state_line_reports_cache_effectiveness() {
        let mut m = Metrics::default();
        m.plan_cache_hits = 9;
        m.plan_cache_misses = 1;
        m.sessions_built = 2;
        m.sessions_reused = 8;
        m.vae_builds = 1;
        assert!((m.plan_cache_hit_rate() - 0.9).abs() < 1e-12);
        let s = m.steady_state();
        assert!(s.contains("plan cache 9/10 hits (90.0% hit rate"), "{s}");
        assert!(s.contains("sessions 2 built, 8 reused"), "{s}");
        // empty metrics divide cleanly
        assert_eq!(Metrics::default().plan_cache_hit_rate(), 0.0);
    }

    #[test]
    fn report_separates_makespan_queue_delay_and_exec() {
        let mut m = Metrics::default();
        m.served = 2;
        m.horizon = 3.0;
        m.latency.observe(1.5);
        m.latency.observe(2.0);
        m.queue_delay.observe(0.5);
        m.exec_time.observe(1.0);
        m.observe_batch(2);
        let r = m.report();
        assert!(r.contains("makespan 3.000s virtual"), "{r}");
        assert!(r.contains("queue delay"), "{r}");
        assert!(r.contains("exec mean"), "{r}");
        assert!(r.contains("occupancy mean 2.00"), "{r}");
        assert!(r.contains("p50/p95/p99"), "{r}");
    }
}
