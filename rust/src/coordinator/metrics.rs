//! Serving metrics: latency histogram (log buckets), throughput counters,
//! per-stage timing.

#[derive(Debug, Clone)]
pub struct Histogram {
    /// log2 buckets over seconds: (-inf,1ms], (1,2ms], ... up to >= ~1000s
    buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 32], count: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        let ms = (v * 1e3).max(1e-9);
        (ms.log2().floor().max(0.0) as usize).min(31)
    }

    pub fn observe(&mut self, v: f64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return (1u64 << (i + 1)) as f64 / 1e3;
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Engine metrics snapshot.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub latency: Histogram,
    pub queue_wait: Histogram,
    pub served: u64,
    pub rejected: u64,
    /// Total simulated device-seconds of model compute.
    pub model_seconds: f64,
    /// Virtual end-to-end seconds of the serving run.
    pub horizon: f64,
    /// Sessions constructed (one per batch, not per request — reuse is the
    /// point of the batcher).
    pub sessions_built: u64,
    /// Parallel-VAE constructions; stays at 1 for the whole life of an
    /// engine no matter how many requests decode.
    pub vae_builds: u64,
}

impl Metrics {
    pub fn throughput(&self) -> f64 {
        if self.horizon > 0.0 {
            self.served as f64 / self.horizon
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "served={} rejected={} throughput={:.2} img/s  latency mean={:.3}s p50={:.3}s p90={:.3}s max={:.3}s  sessions={} vae_builds={}",
            self.served,
            self.rejected,
            self.throughput(),
            self.latency.mean(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.9),
            self.latency.max,
            self.sessions_built,
            self.vae_builds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new();
        for v in [0.001, 0.002, 0.004, 0.008, 0.1, 1.0] {
            h.observe(v);
        }
        assert_eq!(h.count, 6);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert_eq!(h.max, 1.0);
    }

    #[test]
    fn throughput() {
        let mut m = Metrics::default();
        m.served = 10;
        m.horizon = 5.0;
        assert!((m.throughput() - 2.0).abs() < 1e-9);
    }
}
