//! Serving metrics: latency histograms (log buckets), throughput counters,
//! the queue-delay vs execution-time split, batch-occupancy stats of
//! the continuous-batching scheduler, and the per-stage occupancy block
//! of the staged engine ([`StageStats`]).

use crate::coordinator::request::SloClass;
use crate::coordinator::stages::StageStats;

/// Log-bucketed latency histogram over seconds (~1ms to ~1000s).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// log2 buckets over seconds: (-inf,1ms], (1,2ms], ... up to >= ~1000s
    buckets: Vec<u64>,
    /// Observations recorded.
    pub count: u64,
    /// Sum of all observed values (seconds).
    pub sum: f64,
    /// Largest observed value (seconds).
    pub max: f64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram { buckets: vec![0; 32], count: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        let ms = (v * 1e3).max(1e-9);
        (ms.log2().floor().max(0.0) as usize).min(31)
    }

    /// Record one observation of `v` seconds.
    pub fn observe(&mut self, v: f64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// containing bucket).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return (1u64 << (i + 1)) as f64 / 1e3;
            }
        }
        self.max
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Engine metrics snapshot.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// End-to-end virtual latency (queue delay + execution).
    pub latency: Histogram,
    /// Time between arrival and batch launch (the queueing component).
    pub queue_delay: Histogram,
    /// Time on the simulated cluster (denoise + optional VAE decode).
    pub exec_time: Histogram,
    /// Requests served to completion.
    pub served: u64,
    /// Requests refused admission (backpressure or deadline admission).
    pub rejected: u64,
    /// Total simulated device-seconds of model compute.
    pub model_seconds: f64,
    /// Virtual end-to-end seconds of the serving run (the makespan).
    pub horizon: f64,
    /// Sessions actually constructed. With the warm session cache this
    /// stays proportional to the number of *distinct* batch shapes, not
    /// the number of batches (see `sessions_reused`).
    pub sessions_built: u64,
    /// Batches served on a recycled session from the warm cache (clocks
    /// and ledger reset, mesh/model/config reused). `sessions_built +
    /// sessions_reused == batches` on the engine tick path.
    pub sessions_reused: u64,
    /// Routing decisions served from the `PlanCache` memo.
    pub plan_cache_hits: u64,
    /// Routing decisions that ran the cold enumerate + score sweep.
    pub plan_cache_misses: u64,
    /// Times the plan/session caches were wiped because the cluster spec
    /// changed under the engine.
    pub plan_cache_invalidations: u64,
    /// Parallel-VAE constructions; stays at 1 for the whole life of an
    /// engine no matter how many requests decode.
    pub vae_builds: u64,
    /// Scheduler ticks taken (continuous-batching mode).
    pub ticks: u64,
    /// Ticks that found nothing waiting.
    pub idle_ticks: u64,
    /// Batches launched.
    pub batches: u64,
    /// Sum of batch sizes (mean occupancy = occupancy_sum / batches).
    pub occupancy_sum: u64,
    /// Largest batch launched.
    pub occupancy_max: u64,
    /// Requests that finished after their declared deadline.
    pub deadline_misses: u64,
    /// Deadline misses split by SLO class (`SloClass::index()` order).
    /// The aggregate counter hides interactive-tier misses behind
    /// batch-tier mass; SLO accounting needs the split.
    pub deadline_misses_by_class: [u64; SloClass::COUNT],
    /// End-to-end latency split by SLO class (`SloClass::index()` order).
    pub latency_by_class: [Histogram; SloClass::COUNT],
    /// Batch-tier preemption slices taken to protect an interactive
    /// deadline (each slice re-enqueues the batch with progress credited).
    pub preemptions: u64,
    /// Whole steps credited by crash checkpoints
    /// (`Engine::run_to_checkpoint`) — work a dying replica completed
    /// that failover migration will resume from, never redo.
    pub checkpoint_steps: u64,
    /// Requests cancelled while still in the admission queue (capacity
    /// refunded immediately).
    pub cancelled_queued: u64,
    /// Requests cancelled after admission, while waiting mid-flight in
    /// the batcher's waiting set.
    pub cancelled_midflight: u64,
    /// Batch-tier requests degraded (steps and/or resolution reduced)
    /// by the overload ladder at admission.
    pub degraded: u64,
    /// Per-stage busy seconds, inter-stage queue depths, and decode
    /// backpressure stalls (the staged-execution block; busy seconds
    /// accumulate on the serial path too).
    pub stages: StageStats,
}

impl Metrics {
    /// Served requests per virtual second of the serving horizon.
    pub fn throughput(&self) -> f64 {
        if self.horizon > 0.0 {
            self.served as f64 / self.horizon
        } else {
            0.0
        }
    }

    /// Record one served request's end-to-end latency in both the
    /// aggregate histogram and its SLO class's histogram.
    pub fn observe_latency(&mut self, class: SloClass, v: f64) {
        self.latency.observe(v);
        self.latency_by_class[class.index()].observe(v);
    }

    /// Record a deadline miss against the aggregate and per-class
    /// counters.
    pub fn observe_deadline_miss(&mut self, class: SloClass) {
        self.deadline_misses += 1;
        self.deadline_misses_by_class[class.index()] += 1;
    }

    /// Total cancellations (queued + mid-flight).
    pub fn cancelled(&self) -> u64 {
        self.cancelled_queued + self.cancelled_midflight
    }

    /// Latency quantile restricted to one SLO class (0 when the class
    /// served nothing).
    pub fn latency_quantile_class(&self, class: SloClass, q: f64) -> f64 {
        self.latency_by_class[class.index()].quantile(q)
    }

    /// Per-class latency/deadline rows, one line per class that served
    /// at least one request (empty string when everything is Standard
    /// and the split adds no information).
    pub fn slo_report(&self) -> String {
        let mut out = String::new();
        let split = SloClass::ALL
            .iter()
            .any(|c| *c != SloClass::Standard && self.latency_by_class[c.index()].count > 0);
        if !split {
            return out;
        }
        for class in SloClass::ALL {
            let h = &self.latency_by_class[class.index()];
            if h.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "  slo {:<11} served={} p50/p99 {:.3}/{:.3}s (mean {:.3}s) deadline misses={}\n",
                class.name(),
                h.count,
                h.quantile(0.50),
                h.quantile(0.99),
                h.mean(),
                self.deadline_misses_by_class[class.index()],
            ));
        }
        out
    }

    /// Record a launched batch of `n` requests.
    pub fn observe_batch(&mut self, n: usize) {
        self.batches += 1;
        self.occupancy_sum += n as u64;
        self.occupancy_max = self.occupancy_max.max(n as u64);
    }

    /// Mean requests per launched batch.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }

    /// Fraction of routing decisions served from the plan cache.
    pub fn plan_cache_hit_rate(&self) -> f64 {
        let total = self.plan_cache_hits + self.plan_cache_misses;
        if total == 0 {
            0.0
        } else {
            self.plan_cache_hits as f64 / total as f64
        }
    }

    /// One-line steady-state summary of the hot-path caches: how often
    /// planning and session construction were skipped. Printed by the
    /// `serve` CLI after the serving report.
    pub fn steady_state(&self) -> String {
        format!(
            "steady-state: plan cache {}/{} hits ({:.1}% hit rate, {} invalidations) | \
             sessions {} built, {} reused | vae_builds={}",
            self.plan_cache_hits,
            self.plan_cache_hits + self.plan_cache_misses,
            self.plan_cache_hit_rate() * 100.0,
            self.plan_cache_invalidations,
            self.sessions_built,
            self.sessions_reused,
            self.vae_builds,
        )
    }

    /// Human-readable snapshot. Virtual makespan, the queue-delay vs
    /// execution split, and batch occupancy are reported separately —
    /// folding them into one latency figure hides *where* time went.
    pub fn report(&self) -> String {
        format!(
            "served={} rejected={} | makespan {:.3}s virtual, {:.2} img/s | \
             latency p50/p95/p99 {:.3}/{:.3}/{:.3}s (mean {:.3}s max {:.3}s) | \
             queue delay mean {:.3}s p95 {:.3}s | exec mean {:.3}s | \
             batches={} occupancy mean {:.2} max {} | deadline misses={} | \
             preempted={} cancelled={}+{} degraded={} | \
             sessions={}+{} reused | plan cache {}/{} | vae_builds={}",
            self.served,
            self.rejected,
            self.horizon,
            self.throughput(),
            self.latency.quantile(0.50),
            self.latency.quantile(0.95),
            self.latency.quantile(0.99),
            self.latency.mean(),
            self.latency.max,
            self.queue_delay.mean(),
            self.queue_delay.quantile(0.95),
            self.exec_time.mean(),
            self.batches,
            self.mean_occupancy(),
            self.occupancy_max,
            self.deadline_misses,
            self.preemptions,
            self.cancelled_queued,
            self.cancelled_midflight,
            self.degraded,
            self.sessions_built,
            self.sessions_reused,
            self.plan_cache_hits,
            self.plan_cache_hits + self.plan_cache_misses,
            self.vae_builds,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new();
        for v in [0.001, 0.002, 0.004, 0.008, 0.1, 1.0] {
            h.observe(v);
        }
        assert_eq!(h.count, 6);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert_eq!(h.max, 1.0);
    }

    #[test]
    fn throughput() {
        let mut m = Metrics::default();
        m.served = 10;
        m.horizon = 5.0;
        assert!((m.throughput() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy_tracking() {
        let mut m = Metrics::default();
        m.observe_batch(4);
        m.observe_batch(2);
        m.observe_batch(3);
        assert_eq!(m.batches, 3);
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-9);
        assert_eq!(m.occupancy_max, 4);
    }

    #[test]
    fn steady_state_line_reports_cache_effectiveness() {
        let mut m = Metrics::default();
        m.plan_cache_hits = 9;
        m.plan_cache_misses = 1;
        m.sessions_built = 2;
        m.sessions_reused = 8;
        m.vae_builds = 1;
        assert!((m.plan_cache_hit_rate() - 0.9).abs() < 1e-12);
        let s = m.steady_state();
        assert!(s.contains("plan cache 9/10 hits (90.0% hit rate"), "{s}");
        assert!(s.contains("sessions 2 built, 8 reused"), "{s}");
        // empty metrics divide cleanly
        assert_eq!(Metrics::default().plan_cache_hit_rate(), 0.0);
    }

    #[test]
    fn report_separates_makespan_queue_delay_and_exec() {
        let mut m = Metrics::default();
        m.served = 2;
        m.horizon = 3.0;
        m.latency.observe(1.5);
        m.latency.observe(2.0);
        m.queue_delay.observe(0.5);
        m.exec_time.observe(1.0);
        m.observe_batch(2);
        let r = m.report();
        assert!(r.contains("makespan 3.000s virtual"), "{r}");
        assert!(r.contains("queue delay"), "{r}");
        assert!(r.contains("exec mean"), "{r}");
        assert!(r.contains("occupancy mean 2.00"), "{r}");
        assert!(r.contains("p50/p95/p99"), "{r}");
        assert!(r.contains("preempted=0 cancelled=0+0 degraded=0"), "{r}");
    }

    #[test]
    fn per_class_latency_split_tracks_each_tier() {
        let mut m = Metrics::default();
        m.observe_latency(SloClass::Interactive, 0.010);
        m.observe_latency(SloClass::Interactive, 0.020);
        m.observe_latency(SloClass::Batch, 8.0);
        m.observe_deadline_miss(SloClass::Interactive);
        // aggregate sees all three; the split keeps the tiers apart
        assert_eq!(m.latency.count, 3);
        assert_eq!(m.latency_by_class[SloClass::Interactive.index()].count, 2);
        assert_eq!(m.latency_by_class[SloClass::Batch.index()].count, 1);
        assert!(
            m.latency_quantile_class(SloClass::Interactive, 0.99)
                < m.latency_quantile_class(SloClass::Batch, 0.99)
        );
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.deadline_misses_by_class[SloClass::Interactive.index()], 1);
        let s = m.slo_report();
        assert!(s.contains("slo interactive"), "{s}");
        assert!(s.contains("slo batch"), "{s}");
        assert!(!s.contains("slo standard"), "{s}");
        // an all-Standard run collapses to no split at all
        let mut plain = Metrics::default();
        plain.observe_latency(SloClass::Standard, 1.0);
        assert!(plain.slo_report().is_empty());
    }

    #[test]
    fn cancellation_counters_sum() {
        let mut m = Metrics::default();
        m.cancelled_queued = 3;
        m.cancelled_midflight = 2;
        assert_eq!(m.cancelled(), 5);
    }
}
