//! Coordinator-side view of the tiny DiT: text embedding, KV buffers, and
//! the stage/layer call assembly over the AOT entrypoints.

pub mod dit;
pub mod kvbuffer;
pub mod text;

pub use dit::{DitModel, StageIn, StageKind, StageOut};
pub use kvbuffer::KvBuffer;
pub use text::TextEncoder;
