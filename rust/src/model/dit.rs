//! Stage/layer call assembly for the tiny DiT over the AOT entrypoints.
//!
//! This is the glue between the parallel strategies and the `Runtime`: it
//! knows the entrypoint naming grid (`{variant}_{kind}_L{ls}_p{pf}`), the
//! per-variant argument layouts and the sequence layout (`[text; image]`
//! for MM-DiT in-context conditioning).

use crate::config::model::BlockVariant;
use crate::model::kvbuffer::KvBuffer;
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Which stage entrypoint of a skip model (U-ViT halves) — `Whole` for the
/// non-skip variants and pipe=1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Whole,
    SkipEnc,
    SkipDec,
}

/// Inputs of one stage call (one patch micro-step on one device).
pub struct StageIn<'a> {
    pub x_img: &'a Tensor,
    /// MM-DiT text-stream patch.
    pub x_txt: Option<&'a Tensor>,
    /// Skip tensors for `SkipDec` stages: `[ls, p, d]`.
    pub skips: Option<&'a Tensor>,
    pub cond: &'a Tensor,
    /// Cross-attention text memory (replicated), `cross` variant only.
    pub txt_mem: Option<&'a Tensor>,
    pub kv: &'a KvBuffer,
    /// Image-row offset within the *image* sequence.
    pub off_img: usize,
    /// Text-row offset within the text sequence (MM-DiT).
    pub off_txt: usize,
}

/// Outputs of one stage call.
pub struct StageOut {
    pub y_img: Tensor,
    pub y_txt: Option<Tensor>,
    /// `[ls, p, d]` fresh K/V rows (MM-DiT: text rows first within p).
    pub k_new: Tensor,
    pub v_new: Tensor,
    /// `SkipEnc` stages: `[ls, p, d]` skip activations for the decoder.
    pub skips: Option<Tensor>,
}

/// Model-level constants resolved from the manifest.
#[derive(Debug, Clone)]
pub struct DitModel {
    pub variant: BlockVariant,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub s_img: usize,
    pub s_txt: usize,
    pub c_latent: usize,
    pub latent_hw: usize,
}

impl DitModel {
    pub fn from_manifest(rt: &Runtime, variant: BlockVariant) -> Result<DitModel> {
        let m = &rt.manifest;
        Ok(DitModel {
            variant,
            d: m.model_dim("d")?,
            heads: m.model_dim("heads")?,
            layers: m.model_dim("layers")?,
            s_img: m.model_dim("s_img")?,
            s_txt: m.model_dim("s_txt")?,
            c_latent: m.model_dim("c_latent")?,
            latent_hw: m.model_dim("latent_hw")?,
        })
    }

    pub fn key(&self) -> &'static str {
        self.variant.key()
    }

    /// Attention sequence length (image + in-context text).
    pub fn attn_seq(&self) -> usize {
        self.s_img + if self.variant.in_context_text() { self.s_txt } else { 0 }
    }

    /// Absolute buffer offset of image row `off_img` (MM-DiT keeps
    /// `[text; image]`).
    pub fn img_buf_off(&self, off_img: usize) -> usize {
        off_img + if self.variant.in_context_text() { self.s_txt } else { 0 }
    }

    /// Positional-embedding rows for an image patch.
    pub fn pos_rows(&self, rt: &Runtime, off: usize, p: usize) -> Result<Tensor> {
        let pos = rt.host_weights.get(&format!("{}.pos", self.key()))?;
        pos.slice_rows(off, off + p)
    }

    /// Timestep conditioning vector.
    pub fn t_cond(&self, rt: &Runtime, t: f32) -> Result<Tensor> {
        let ts = Tensor::scalar(t);
        let out = rt.call(&format!("{}_t_embed", self.key()), 0, &[ArgValue::F32(&ts)])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Embed an image-latent patch (patchify + positional embedding).
    pub fn embed_patch(
        &self,
        rt: &Runtime,
        pf: usize,
        latent_patch: &Tensor,
        off: usize,
    ) -> Result<Tensor> {
        let p = latent_patch.dims[0];
        let pos = self.pos_rows(rt, off, p)?;
        let out = rt.call(
            &format!("{}_embed_p{pf}", self.key()),
            0,
            &[ArgValue::F32(latent_patch), ArgValue::F32(&pos)],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Final layer: hidden patch -> epsilon patch.
    pub fn final_patch(
        &self,
        rt: &Runtime,
        pf: usize,
        x: &Tensor,
        cond: &Tensor,
    ) -> Result<Tensor> {
        let out = rt.call(
            &format!("{}_final_p{pf}", self.key()),
            0,
            &[ArgValue::F32(x), ArgValue::F32(cond)],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    fn stage_entry(&self, kind: StageKind, ls: usize, pf: usize) -> String {
        match (self.variant, kind) {
            (BlockVariant::Skip, StageKind::Whole) => format!("skip_full_L{ls}_p{pf}"),
            (BlockVariant::Skip, StageKind::SkipEnc) => format!("skip_enc_L{ls}_p{pf}"),
            (BlockVariant::Skip, StageKind::SkipDec) => format!("skip_dec_L{ls}_p{pf}"),
            (v, StageKind::Whole) => format!("{}_stage_L{ls}_p{pf}", v.key()),
            _ => unreachable!("enc/dec stages only exist for the skip variant"),
        }
    }

    /// Run one stage over a patch. `stage` indexes the pipeline stage
    /// (`SkipDec` uses decoder-relative 0 per the WeightRef convention).
    pub fn run_stage(
        &self,
        rt: &Runtime,
        kind: StageKind,
        ls: usize,
        pf: usize,
        stage: usize,
        i: &StageIn,
    ) -> Result<StageOut> {
        let name = self.stage_entry(kind, ls, pf);
        let kv_k = ArgValue::F32(&i.kv.k);
        let kv_v = ArgValue::F32(&i.kv.v);
        let cond = ArgValue::F32(i.cond);
        let x = ArgValue::F32(i.x_img);

        let outs = match self.variant {
            BlockVariant::AdaLn => rt.call(
                &name,
                stage,
                &[x, cond, kv_k, kv_v, ArgValue::I32(i.off_img as i32)],
            )?,
            BlockVariant::Cross => {
                let txt = i
                    .txt_mem
                    .ok_or_else(|| Error::Engine("cross variant needs txt_mem".into()))?;
                rt.call(
                    &name,
                    stage,
                    &[
                        x,
                        cond,
                        ArgValue::F32(txt),
                        kv_k,
                        kv_v,
                        ArgValue::I32(i.off_img as i32),
                    ],
                )?
            }
            BlockVariant::MmDit => {
                let xt = i
                    .x_txt
                    .ok_or_else(|| Error::Engine("mmdit variant needs x_txt".into()))?;
                rt.call(
                    &name,
                    stage,
                    &[
                        ArgValue::F32(xt),
                        x,
                        cond,
                        kv_k,
                        kv_v,
                        ArgValue::I32(i.off_txt as i32),
                        ArgValue::I32(self.img_buf_off(i.off_img) as i32),
                    ],
                )?
            }
            BlockVariant::Skip => match kind {
                StageKind::SkipDec => {
                    let skips = i
                        .skips
                        .ok_or_else(|| Error::Engine("skip decoder needs skips".into()))?;
                    rt.call(
                        &name,
                        stage,
                        &[
                            x,
                            ArgValue::F32(skips),
                            cond,
                            kv_k,
                            kv_v,
                            ArgValue::I32(i.off_img as i32),
                        ],
                    )?
                }
                _ => rt.call(
                    &name,
                    stage,
                    &[x, cond, kv_k, kv_v, ArgValue::I32(i.off_img as i32)],
                )?,
            },
        };

        // unpack per variant/kind
        let mut it = outs.into_iter();
        match (self.variant, kind) {
            (BlockVariant::MmDit, _) => {
                let y_txt = it.next().unwrap();
                let y_img = it.next().unwrap();
                let k_new = it.next().unwrap();
                let v_new = it.next().unwrap();
                Ok(StageOut { y_img, y_txt: Some(y_txt), k_new, v_new, skips: None })
            }
            (BlockVariant::Skip, StageKind::SkipEnc) => {
                let y_img = it.next().unwrap();
                let skips = it.next().unwrap();
                let k_new = it.next().unwrap();
                let v_new = it.next().unwrap();
                Ok(StageOut { y_img, y_txt: None, k_new, v_new, skips: Some(skips) })
            }
            _ => {
                let y_img = it.next().unwrap();
                let k_new = it.next().unwrap();
                let v_new = it.next().unwrap();
                Ok(StageOut { y_img, y_txt: None, k_new, v_new, skips: None })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rt() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn full_forward_adaln() {
        let Some(rt) = rt() else { return };
        let m = DitModel::from_manifest(&rt, BlockVariant::AdaLn).unwrap();
        let mut rng = Rng::new(0);
        let latent = Tensor::randn(&[m.s_img, m.c_latent], &mut rng);
        let x = m.embed_patch(&rt, 1, &latent, 0).unwrap();
        assert_eq!(x.dims, vec![m.s_img, m.d]);
        let cond = m.t_cond(&rt, 500.0).unwrap();
        let kv = KvBuffer::zeros(m.layers, m.s_img, m.d);
        let sin = StageIn {
            x_img: &x,
            x_txt: None,
            skips: None,
            cond: &cond,
            txt_mem: None,
            kv: &kv,
            off_img: 0,
            off_txt: 0,
        };
        let out = m.run_stage(&rt, StageKind::Whole, m.layers, 1, 0, &sin).unwrap();
        assert_eq!(out.y_img.dims, vec![m.s_img, m.d]);
        assert_eq!(out.k_new.dims, vec![m.layers, m.s_img, m.d]);
        let eps = m.final_patch(&rt, 1, &out.y_img, &cond).unwrap();
        assert_eq!(eps.dims, vec![m.s_img, m.c_latent]);
        assert!(eps.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stage_composition_matches_full() {
        // two stages of L4 == one stage of L8, given fresh-buffer scatter
        let Some(rt) = rt() else { return };
        let m = DitModel::from_manifest(&rt, BlockVariant::AdaLn).unwrap();
        let mut rng = Rng::new(1);
        let latent = Tensor::randn(&[m.s_img, m.c_latent], &mut rng);
        let x0 = m.embed_patch(&rt, 1, &latent, 0).unwrap();
        let cond = m.t_cond(&rt, 300.0).unwrap();

        let kv8 = KvBuffer::zeros(m.layers, m.s_img, m.d);
        let base = StageIn {
            x_img: &x0, x_txt: None, skips: None, cond: &cond, txt_mem: None,
            kv: &kv8, off_img: 0, off_txt: 0,
        };
        let full = m.run_stage(&rt, StageKind::Whole, 8, 1, 0, &base).unwrap();

        let kv4 = KvBuffer::zeros(4, m.s_img, m.d);
        let s0 = m
            .run_stage(&rt, StageKind::Whole, 4, 1, 0, &StageIn { kv: &kv4, ..StageIn {
                x_img: &x0, x_txt: None, skips: None, cond: &cond, txt_mem: None,
                kv: &kv4, off_img: 0, off_txt: 0 } })
            .unwrap();
        let s1 = m
            .run_stage(&rt, StageKind::Whole, 4, 1, 1, &StageIn {
                x_img: &s0.y_img, x_txt: None, skips: None, cond: &cond, txt_mem: None,
                kv: &kv4, off_img: 0, off_txt: 0 })
            .unwrap();
        assert!(
            s1.y_img.allclose(&full.y_img, 1e-4),
            "staged != full: {}",
            s1.y_img.max_abs_diff(&full.y_img).unwrap()
        );
    }

    #[test]
    fn mmdit_stage_shapes() {
        let Some(rt) = rt() else { return };
        let m = DitModel::from_manifest(&rt, BlockVariant::MmDit).unwrap();
        let mut rng = Rng::new(2);
        let x_img = Tensor::randn(&[m.s_img / 2, m.d], &mut rng);
        let x_txt = Tensor::randn(&[m.s_txt / 2, m.d], &mut rng);
        let cond = m.t_cond(&rt, 100.0).unwrap();
        let kv = KvBuffer::zeros(4, m.attn_seq(), m.d);
        let out = m
            .run_stage(&rt, StageKind::Whole, 4, 2, 0, &StageIn {
                x_img: &x_img, x_txt: Some(&x_txt), skips: None, cond: &cond,
                txt_mem: None, kv: &kv, off_img: 0, off_txt: 0 })
            .unwrap();
        assert_eq!(out.y_txt.as_ref().unwrap().dims, vec![m.s_txt / 2, m.d]);
        assert_eq!(out.k_new.dims, vec![4, (m.s_img + m.s_txt) / 2, m.d]);
    }
}
