//! Byte-level text "encoder": tokenize the prompt to byte ids, look up the
//! shared embedding table (weights.bin `shared.txt_table`). Stands in for
//! the paper models' T5/CLIP encoders (Table 2) — the parallelism work never
//! touches encoder internals, only the embedded sequence.

use crate::runtime::HostWeights;
use crate::tensor::Tensor;
use crate::Result;

pub struct TextEncoder {
    table: Tensor, // [vocab, d]
    pub s_txt: usize,
    pub d: usize,
}

impl TextEncoder {
    pub fn new(weights: &HostWeights, s_txt: usize) -> Result<TextEncoder> {
        let table = weights.get("shared.txt_table")?.clone();
        let d = table.dims[1];
        Ok(TextEncoder { table, s_txt, d })
    }

    /// Byte tokenizer: truncate/pad (id 0) to `s_txt`.
    pub fn tokenize(&self, prompt: &str) -> Vec<usize> {
        let vocab = self.table.dims[0];
        let mut ids: Vec<usize> =
            prompt.bytes().take(self.s_txt).map(|b| b as usize % vocab).collect();
        ids.resize(self.s_txt, 0);
        ids
    }

    /// Embed a prompt -> [s_txt, d].
    pub fn embed(&self, prompt: &str) -> Tensor {
        let ids = self.tokenize(prompt);
        let d = self.d;
        let mut data = Vec::with_capacity(self.s_txt * d);
        for id in ids {
            data.extend_from_slice(&self.table.data[id * d..(id + 1) * d]);
        }
        Tensor { dims: vec![self.s_txt, d], data }
    }

    /// Pooled text conditioning vector (mean of token embeddings).
    pub fn pool(&self, embedded: &Tensor) -> Tensor {
        embedded.mean_rows()
    }

    /// The unconditional (empty prompt) embedding for CFG.
    pub fn embed_uncond(&self) -> Tensor {
        self.embed("")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostWeights;

    fn enc() -> Option<TextEncoder> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights.bin");
        if !p.exists() {
            return None;
        }
        let w = HostWeights::load(p).unwrap();
        Some(TextEncoder::new(&w, 32).unwrap())
    }

    #[test]
    fn tokenize_pads_and_truncates() {
        let Some(e) = enc() else { return };
        assert_eq!(e.tokenize("hi").len(), 32);
        assert_eq!(e.tokenize(&"x".repeat(100)).len(), 32);
        assert_eq!(e.tokenize("")[0], 0);
    }

    #[test]
    fn embed_deterministic_and_distinct() {
        let Some(e) = enc() else { return };
        let a = e.embed("a photo of a cat");
        let b = e.embed("a photo of a cat");
        let c = e.embed("a watercolor of a dog");
        assert_eq!(a, b);
        assert!(a.max_abs_diff(&c).unwrap() > 1e-4);
        assert_eq!(a.dims, vec![32, 192]);
    }

    #[test]
    fn pool_shape() {
        let Some(e) = enc() else { return };
        let p = e.pool(&e.embed("prompt"));
        assert_eq!(p.dims, vec![192]);
    }
}
