//! Per-device KV buffers: the state PipeFusion / DistriFusion / the hybrid
//! SP+PipeFusion scheme keep between diffusion steps (paper §4.1.2, §4.1.4).
//!
//! Layout: one dense tensor `[layers, seq, d]` for K and V each, matching
//! the stage entrypoints' buffer inputs (zero-copy pass-through). The
//! engine scatters *fresh* rows back after each stage/layer call; which rows
//! get scattered encodes the paper's Fig-6/7 consistency rule (full
//! SP-group sequence vs. the broken own-shard-only variant).

use crate::tensor::Tensor;
use crate::{Error, Result};

#[derive(Debug, Clone)]
pub struct KvBuffer {
    pub k: Tensor, // [layers, seq, d]
    pub v: Tensor,
    pub layers: usize,
    pub seq: usize,
    pub d: usize,
}

impl KvBuffer {
    pub fn zeros(layers: usize, seq: usize, d: usize) -> KvBuffer {
        KvBuffer {
            k: Tensor::zeros(&[layers, seq, d]),
            v: Tensor::zeros(&[layers, seq, d]),
            layers,
            seq,
            d,
        }
    }

    /// Scatter fresh K/V rows for one layer at sequence offset `off`.
    /// `k_rows`/`v_rows`: `[p, d]`.
    pub fn scatter_layer(
        &mut self,
        layer: usize,
        off: usize,
        k_rows: &Tensor,
        v_rows: &Tensor,
    ) -> Result<()> {
        let p = k_rows.dims[0];
        if layer >= self.layers || off + p > self.seq {
            return Err(Error::shape(format!(
                "kv scatter out of range: layer {layer}, rows {off}..{}",
                off + p
            )));
        }
        let base = layer * self.seq * self.d + off * self.d;
        self.k.data[base..base + p * self.d].copy_from_slice(&k_rows.data);
        self.v.data[base..base + p * self.d].copy_from_slice(&v_rows.data);
        Ok(())
    }

    /// Scatter a stage output (`[layers, p, d]` fresh rows for every layer
    /// of this buffer) at offset `off` — the PipeFusion post-micro-step
    /// update.
    pub fn scatter_stage(&mut self, off: usize, k_new: &Tensor, v_new: &Tensor) -> Result<()> {
        if k_new.dims.len() != 3 || k_new.dims[0] != self.layers || k_new.dims[2] != self.d {
            return Err(Error::shape(format!(
                "scatter_stage expects [{}, p, {}], got {:?}",
                self.layers, self.d, k_new.dims
            )));
        }
        let p = k_new.dims[1];
        for l in 0..self.layers {
            let src = l * p * self.d;
            let dst = l * self.seq * self.d + off * self.d;
            self.k.data[dst..dst + p * self.d]
                .copy_from_slice(&k_new.data[src..src + p * self.d]);
            self.v.data[dst..dst + p * self.d]
                .copy_from_slice(&v_new.data[src..src + p * self.d]);
        }
        Ok(())
    }

    /// Read one layer's K/V rows (used by the per-layer SP path to assemble
    /// the attention inputs, and by tests).
    pub fn layer_rows(&self, layer: usize, off: usize, p: usize) -> Result<(Tensor, Tensor)> {
        if layer >= self.layers || off + p > self.seq {
            return Err(Error::shape("kv read out of range"));
        }
        let base = layer * self.seq * self.d + off * self.d;
        let k = Tensor::new(
            vec![p, self.d],
            self.k.data[base..base + p * self.d].to_vec(),
        )?;
        let v = Tensor::new(
            vec![p, self.d],
            self.v.data[base..base + p * self.d].to_vec(),
        )?;
        Ok((k, v))
    }

    /// Full K/V of one layer as `[seq, d]` tensors.
    pub fn layer_full(&self, layer: usize) -> Result<(Tensor, Tensor)> {
        self.layer_rows(layer, 0, self.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_and_read_roundtrip() {
        let mut b = KvBuffer::zeros(2, 8, 3);
        let k = Tensor::from_fn(&[2, 3], |i| i as f32 + 1.0);
        let v = k.scale(10.0);
        b.scatter_layer(1, 4, &k, &v).unwrap();
        let (rk, rv) = b.layer_rows(1, 4, 2).unwrap();
        assert_eq!(rk, k);
        assert_eq!(rv, v);
        // other layer untouched
        let (ok, _) = b.layer_rows(0, 4, 2).unwrap();
        assert!(ok.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scatter_stage_layout() {
        let mut b = KvBuffer::zeros(2, 6, 2);
        // k_new [2 layers, 3 rows, 2]
        let k_new = Tensor::from_fn(&[2, 3, 2], |i| i as f32);
        let v_new = k_new.scale(-1.0);
        b.scatter_stage(3, &k_new, &v_new).unwrap();
        let (k0, _) = b.layer_rows(0, 3, 3).unwrap();
        assert_eq!(k0.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let (k1, v1) = b.layer_rows(1, 3, 3).unwrap();
        assert_eq!(k1.data, vec![6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(v1.data, vec![-6.0, -7.0, -8.0, -9.0, -10.0, -11.0]);
    }

    #[test]
    fn bounds_checked() {
        let mut b = KvBuffer::zeros(1, 4, 2);
        let k = Tensor::zeros(&[3, 2]);
        assert!(b.scatter_layer(0, 2, &k, &k).is_err());
        assert!(b.scatter_layer(1, 0, &k, &k).is_err());
        assert!(b.layer_rows(0, 3, 2).is_err());
    }
}
