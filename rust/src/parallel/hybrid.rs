//! Hybrid parallelism (paper §4.1.4): `cfg × pipefusion × (ulysses × ring)`.
//!
//! The intra-image mesh is `pipefusion_degree × sp_degree`: PipeFusion on
//! the outer dimension (stages of layers), USP (Ulysses × Ring) on the
//! inner. Each PipeFusion patch is further split into `sp_degree` shards;
//! inside a micro-step every layer runs the exact two-phase SP pass whose
//! exchanged K/V covers the whole patch.
//!
//! The correctness-critical piece is the **KV buffer update rule** (Fig 6/7):
//! after the SP exchange, every device in the SP group stores the K/V of
//! the *entire patch* (the intermediate tensors standard SP would discard)
//! into its PipeFusion buffer, keeping buffers consistent across the group.
//! `KvUpdateRule::StandardSp` reproduces the broken variant — each device
//! only updates its own shard's rows — which this repo's tests/benches show
//! diverging, reproducing the paper's argument.

use crate::config::model::BlockVariant;
use crate::mesh::MeshCoord;
use crate::model::KvBuffer;
use crate::parallel::{
    flops, split_offsets, sp_layer, BranchCtx, Session, Strategy,
};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// How fresh patch K/V lands in the PipeFusion buffers of an SP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvUpdateRule {
    /// xDiT's rule: store the whole patch's exchanged K/V on every device.
    Consistent,
    /// The naive rule (Fig 7 "standard SP"): own shard only — buffers
    /// desynchronize and later steps read half-stale K/V.
    StandardSp,
}

/// The hybrid mesh strategy: PipeFusion stages × SP groups × CFG
/// branches with the Fig-6/7 KV-consistency rule.
pub struct Hybrid {
    /// Which KV update rule the SP groups apply.
    pub rule: KvUpdateRule,
    /// (branch, stage, sp_index) -> per-device buffer for its stage layers.
    buffers: std::collections::HashMap<(usize, usize, usize), KvBuffer>,
}

impl Hybrid {
    /// A fresh hybrid strategy under `rule`.
    pub fn new(rule: KvUpdateRule) -> Hybrid {
        Hybrid { rule, buffers: std::collections::HashMap::new() }
    }
}

impl Strategy for Hybrid {
    fn name(&self) -> String {
        match self.rule {
            KvUpdateRule::Consistent => "hybrid".into(),
            KvUpdateRule::StandardSp => "hybrid-standard-sp".into(),
        }
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        if model.variant == BlockVariant::Skip {
            return Err(Error::config(
                "hybrid SP+PipeFusion does not support skip models (use pipefusion or sp)",
            ));
        }
        let n_stages = sess.pc.pipefusion;
        let nsp = sess.pc.sp_degree();
        let m_patches = sess.pc.patches;
        let pf = m_patches * nsp; // entrypoint patch factor = per-device rows
        let ls = model.layers / n_stages;
        let warmup = step < sess.pc.warmup_steps;
        let is_mmdit = model.variant == BlockVariant::MmDit;
        let mesh = sess.mesh.clone();

        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;
        let txt_mem =
            if model.variant == BlockVariant::Cross { Some(branch.txt.clone()) } else { None };

        // device grid for this branch: ranks by (stage, sp-index)
        let grid: Vec<Vec<usize>> = (0..n_stages)
            .map(|s| {
                (0..nsp)
                    .map(|i| {
                        let ring = i / sess.pc.ulysses;
                        let ulysses = i % sess.pc.ulysses;
                        let cfg = branch.idx.min(sess.pc.cfg - 1);
                        mesh.rank(MeshCoord { cfg, pipe: s, ring, ulysses })
                    })
                    .collect()
            })
            .collect();

        // lazily created buffers
        for s in 0..n_stages {
            for i in 0..nsp {
                self.buffers
                    .entry((branch.idx, s, i))
                    .or_insert_with(|| KvBuffer::zeros(ls, model.attn_seq(), model.d));
            }
        }

        if warmup {
            let (eps, k_new, v_new) = crate::parallel::exact_step(sess, branch, x, &cond)?;
            let serial_fl = crate::parallel::flops_stage(
                &model,
                model.layers,
                model.s_img,
                model.s_txt,
                model.attn_seq(),
            );
            let all: Vec<usize> = grid.iter().flatten().copied().collect();
            for &d in &all {
                sess.charge_compute(d, serial_fl / all.len() as f64);
            }
            sess.clocks.sync(&all);
            for s in 0..n_stages {
                for i in 0..nsp {
                    let buf = self.buffers.get_mut(&(branch.idx, s, i)).unwrap();
                    buf.k = k_new.slice_rows(s * ls, (s + 1) * ls)?;
                    buf.v = v_new.slice_rows(s * ls, (s + 1) * ls)?;
                }
            }
            return Ok(eps);
        }

        let patch_offs = split_offsets(model.s_img, m_patches);
        let patch_toffs = split_offsets(model.s_txt, m_patches);
        let p_img_shard = model.s_img / pf;
        let p_txt_shard = if is_mmdit { model.s_txt / pf } else { 0 };

        let mut eps_parts: Vec<Tensor> = Vec::with_capacity(m_patches);

        for m in 0..m_patches {
            let (off_img, len_img) = patch_offs[m];
            let (off_txt, len_txt) = patch_toffs[m];

            // stage-0 SP group embeds its shards
            let shard_offs = split_offsets(len_img, nsp);
            let mut x_img: Vec<Tensor> = Vec::with_capacity(nsp);
            for (i, &dev) in grid[0].iter().enumerate() {
                let (so, sl) = shard_offs[i];
                let latent = x.slice_rows(off_img + so, off_img + so + sl)?;
                x_img.push(model.embed_patch(sess.rt, pf, &latent, off_img + so)?);
                sess.charge_compute(dev, flops::embed_flops(sl, model.c_latent, model.d));
            }
            let mut x_txt: Option<Vec<Tensor>> = if is_mmdit {
                let offs = split_offsets(len_txt, nsp);
                Some(
                    offs.iter()
                        .map(|&(o, l)| branch.txt.slice_rows(off_txt + o, off_txt + o + l))
                        .collect::<Result<Vec<_>>>()?,
                )
            } else {
                None
            };

            for s in 0..n_stages {
                let ranks = grid[s].clone();
                for lr in 0..ls {
                    let layer_abs = s * ls + lr;
                    // per-rank bases from the (possibly desynchronized)
                    // buffers
                    let bases: Vec<(Tensor, Tensor)> = (0..nsp)
                        .map(|i| self.buffers[&(branch.idx, s, i)].layer_full(lr))
                        .collect::<Result<Vec<_>>>()?;
                    let out = sp_layer(
                        sess,
                        &ranks,
                        layer_abs,
                        pf,
                        &x_img,
                        x_txt.as_deref(),
                        None,
                        &cond,
                        txt_mem.as_ref(),
                        &bases,
                        off_img,
                        off_txt,
                    )?;
                    x_img = out.x_img;
                    if let Some(tn) = out.x_txt {
                        x_txt = Some(tn);
                    }
                    // KV buffer update rule (Fig 6/7)
                    for i in 0..nsp {
                        let buf = self.buffers.get_mut(&(branch.idx, s, i)).unwrap();
                        match self.rule {
                            KvUpdateRule::Consistent => {
                                // whole-patch rows on every device
                                if let (Some(kt), Some(vt)) = (&out.k_txt, &out.v_txt) {
                                    buf.scatter_layer(lr, off_txt, kt, vt)?;
                                }
                                buf.scatter_layer(
                                    lr,
                                    model.img_buf_off(off_img),
                                    &out.k_img,
                                    &out.v_img,
                                )?;
                            }
                            KvUpdateRule::StandardSp => {
                                // own shard only — the broken variant
                                let (so, sl) = shard_offs[i];
                                let k_own = out.k_img.slice_rows(
                                    i * p_img_shard,
                                    i * p_img_shard + sl.min(p_img_shard),
                                )?;
                                let v_own = out.v_img.slice_rows(
                                    i * p_img_shard,
                                    i * p_img_shard + sl.min(p_img_shard),
                                )?;
                                buf.scatter_layer(
                                    lr,
                                    model.img_buf_off(off_img + so),
                                    &k_own,
                                    &v_own,
                                )?;
                                if let (Some(kt), Some(vt)) = (&out.k_txt, &out.v_txt) {
                                    let kt_own =
                                        kt.slice_rows(i * p_txt_shard, (i + 1) * p_txt_shard)?;
                                    let vt_own =
                                        vt.slice_rows(i * p_txt_shard, (i + 1) * p_txt_shard)?;
                                    buf.scatter_layer(
                                        lr,
                                        off_txt + i * p_txt_shard,
                                        &kt_own,
                                        &vt_own,
                                    )?;
                                }
                            }
                        }
                    }
                }
                // hand the patch shards to the next stage (async P2P,
                // shard i -> shard i of stage s+1)
                if s + 1 < n_stages {
                    for i in 0..nsp {
                        let bytes = x_img[i].size_bytes()
                            + x_txt.as_ref().map(|t| t[i].size_bytes()).unwrap_or(0);
                        let (src, dst) = (grid[s][i], grid[s + 1][i]);
                        let arrive = sess.with_comm(|comm| {
                            let payload = Tensor::zeros(&[bytes / 4]);
                            Ok(comm.p2p_async(src, dst, payload).1)
                        })?;
                        sess.clocks.wait_until(dst, arrive);
                    }
                }
            }

            // final layer on the last stage's SP group, shard-wise
            let last = &grid[n_stages - 1];
            let mut parts = Vec::with_capacity(nsp);
            for (i, &dev) in last.iter().enumerate() {
                parts.push(model.final_patch(sess.rt, pf, &x_img[i], &cond)?);
                sess.charge_compute(
                    dev,
                    flops::final_flops(p_img_shard, model.c_latent, model.d),
                );
            }
            // result patch returns to stage 0 for the next step
            if n_stages > 1 {
                for i in 0..nsp {
                    let (src, dst) = (grid[n_stages - 1][i], grid[0][i]);
                    let arrive = sess.with_comm(|comm| {
                        Ok(comm.p2p_async(src, dst, parts[i].clone()).1)
                    })?;
                    sess.clocks.wait_until(dst, arrive);
                }
            }
            eps_parts.push(Tensor::concat_rows(&parts)?);
        }

        Tensor::concat_rows(&eps_parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::parallel::serial::Serial;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    fn branch(rt: &Runtime, n: usize) -> BranchCtx {
        let enc = TextEncoder::new(&rt.host_weights, 32).unwrap();
        let txt = enc.embed("hybrid parallel test");
        BranchCtx { idx: 0, ranks: (0..n).collect(), txt_pool: txt.mean_rows(), txt }
    }

    /// The Fig-6/7 reproduction: along an *evolving* latent trajectory
    /// (stale != fresh), the consistent rule stays near the serial result
    /// while the standard-SP rule reads half-stale K/V and drifts further.
    /// (With a constant latent both rules are trivially exact — stale
    /// values equal fresh ones — so the trajectory must move.)
    #[test]
    fn consistent_rule_beats_standard_sp() {
        let Some(rt) = setup() else { return };
        let mut rng = Rng::new(21);
        let xs: Vec<Tensor> = (0..3)
            .map(|i| {
                let base = Tensor::randn(&[256, 4], &mut Rng::new(21));
                let drift = Tensor::randn(&[256, 4], &mut rng).scale(0.08 * i as f32);
                base.add(&drift).unwrap()
            })
            .collect();
        let mut s0 =
            Session::new(&rt, BlockVariant::AdaLn, l40_cluster(1), ParallelConfig::serial())
                .unwrap();
        // serial reference on the final latent (fresh everything)
        let e_serial = Serial.denoise(&mut s0, &xs[2], 420.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 2, 2, 1).with_patches(2);
        let run = |rule: KvUpdateRule| {
            let mut sess = Session::new(&rt, BlockVariant::AdaLn, l40_cluster(1), pc).unwrap();
            let mut h = Hybrid::new(rule);
            let b = branch(&rt, 4);
            let _ = h.denoise(&mut sess, &xs[0], 420.0, 0, &b).unwrap(); // warmup
            let _ = h.denoise(&mut sess, &xs[1], 420.0, 1, &b).unwrap();
            h.denoise(&mut sess, &xs[2], 420.0, 2, &b).unwrap()
        };
        let e_good = run(KvUpdateRule::Consistent);
        let e_bad = run(KvUpdateRule::StandardSp);
        let d_good = e_good.max_abs_diff(&e_serial).unwrap();
        let d_bad = e_bad.max_abs_diff(&e_serial).unwrap();
        assert!(d_good > 0.0, "trajectory should expose staleness");
        assert!(d_bad > d_good, "standard-SP should be worse: good={d_good} bad={d_bad}");
    }

    #[test]
    fn hybrid_mmdit_runs_all_dims() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(22));
        let pc = ParallelConfig::new(1, 2, 2, 1).with_patches(2);
        let mut sess = Session::new(&rt, BlockVariant::MmDit, l40_cluster(1), pc).unwrap();
        let mut h = Hybrid::new(KvUpdateRule::Consistent);
        let b = branch(&rt, 4);
        let e = h.denoise(&mut sess, &x, 350.0, 0, &b).unwrap();
        assert_eq!(e.dims, vec![256, 4]);
        let e2 = h.denoise(&mut sess, &x, 350.0, 1, &b).unwrap();
        assert!(e2.data.iter().all(|v| v.is_finite()));
        assert!(sess.ledger.count("all_to_all") > 0);
        assert!(sess.ledger.count("p2p_async") > 0);
    }
}
