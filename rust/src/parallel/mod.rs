//! The parallel strategies of xDiT (paper §4): intra-image SP-Ulysses /
//! SP-Ring / PipeFusion plus the TP and DistriFusion baselines, CFG
//! (inter-image) parallelism, and the hybrid mesh combining them with the
//! KV-consistency rule of Fig 6/7.
//!
//! Every strategy runs in *numeric + virtual-time* mode: activations really
//! flow through the AOT HLO executables and between simulated devices,
//! while per-device clocks are charged with analytic compute time (target
//! GPU TFLOP/s) and link-model communication time. The figures use the
//! closed-form `perf` models at paper scale; these strategies validate the
//! semantics (exactness, staleness, buffer consistency) bit-for-bit.

/// DistriFusion baseline (displaced patch parallelism, async AllGather).
pub mod distrifusion;
/// The denoising-loop driver and the `Method` strategy selector.
pub mod driver;
/// The hybrid mesh strategy (PipeFusion × USP × CFG, Fig 6/7 KV rule).
pub mod hybrid;
/// PipeFusion: patch-level pipeline with one-step-stale activations.
pub mod pipefusion;
/// Single-device reference strategy.
pub mod serial;
/// Sequence parallelism (SP-Ulysses / SP-Ring / USP).
pub mod sp;
/// Tensor-parallel baseline (per-layer AllReduce pair).
pub mod tp;

use crate::comm::{Clocks, CommLedger, Communicator};
use crate::config::hardware::ClusterSpec;
use crate::config::model::BlockVariant;
use crate::config::parallel::ParallelConfig;
use crate::mesh::Mesh;
use crate::model::DitModel;
use crate::perf::flops;
use crate::runtime::{ArgValue, Runtime};
use crate::tensor::Tensor;
use crate::{Error, Result};

pub use driver::{generate, GenParams, GenResult};

/// Shared generation session: runtime + model + simulated cluster state.
pub struct Session<'a> {
    /// Execution runtime the stage entrypoints run on.
    pub rt: &'a Runtime,
    /// Assembled tiny-DiT model (stage plan + dims).
    pub model: DitModel,
    /// Simulated cluster the clocks/links are priced on.
    pub cluster: ClusterSpec,
    /// The hybrid parallel configuration this session runs.
    pub pc: ParallelConfig,
    /// Rank geometry (cfg × pipefusion × ulysses × ring).
    pub mesh: Mesh,
    /// Per-device virtual clocks (persist across a batch).
    pub clocks: Clocks,
    /// Communication ledger (persists across a batch).
    pub ledger: CommLedger,
}

impl<'a> Session<'a> {
    /// Build a session for `variant` under config `pc`, validating the
    /// config against the model and the cluster size.
    pub fn new(
        rt: &'a Runtime,
        variant: BlockVariant,
        cluster: ClusterSpec,
        pc: ParallelConfig,
    ) -> Result<Session<'a>> {
        let model = DitModel::from_manifest(rt, variant)?;
        let spec = crate::config::model::ModelSpec::for_variant(variant)?;
        pc.validate(&spec, model.s_img)?;
        if pc.world() > cluster.n_gpus {
            return Err(Error::config(format!(
                "config needs {} devices, cluster '{}' has {}",
                pc.world(),
                cluster.name,
                cluster.n_gpus
            )));
        }
        let n = cluster.n_gpus;
        Ok(Session {
            rt,
            model,
            cluster,
            pc,
            mesh: Mesh::new(pc),
            clocks: Clocks::new(n),
            ledger: CommLedger::default(),
        })
    }

    /// Charge analytic compute time to a device.
    pub fn charge_compute(&mut self, dev: usize, fl: f64) {
        let t = flops::compute_time(fl, self.cluster.gpu.tflops);
        self.clocks.advance(dev, t);
    }

    /// Run `f` with a communicator and fold its ledger back.
    pub fn with_comm<T>(&mut self, f: impl FnOnce(&mut Communicator) -> Result<T>) -> Result<T> {
        let mut comm = Communicator::new(&self.cluster, &mut self.clocks);
        let out = f(&mut comm);
        let ops = std::mem::take(&mut comm.ledger.ops);
        self.ledger.ops.extend(ops);
        out
    }

    /// Slowest device's virtual clock (the session-lifetime makespan).
    pub fn makespan(&self) -> f64 {
        self.clocks.makespan()
    }
}

/// Per-branch (CFG cond/uncond) context.
pub struct BranchCtx {
    /// Branch index: 0 = conditional, 1 = unconditional.
    pub idx: usize,
    /// Devices this branch runs on (all devices when cfg degree is 1).
    pub ranks: Vec<usize>,
    /// Embedded text sequence [s_txt, d].
    pub txt: Tensor,
    /// Pooled text vector `[d]`.
    pub txt_pool: Tensor,
}

impl BranchCtx {
    /// Conditioning vector for the variant at timestep embedding `t_emb`.
    pub fn cond(&self, variant: BlockVariant, t_emb: &Tensor) -> Result<Tensor> {
        match variant {
            // cross-attention injects text via attention; cond is time-only
            BlockVariant::Cross => Ok(t_emb.clone()),
            _ => t_emb.add(&self.txt_pool),
        }
    }
}

/// A parallel denoising strategy.
pub trait Strategy {
    /// Strategy name as reported in `GenResult`/responses.
    fn name(&self) -> String;

    /// Predict the model output for one branch at diffusion step `step`
    /// (timestep value `t`), over the full latent `x` `[s_img, c]`.
    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor>;
}

// ---------------------------------------------------------------------------
// Shared helpers.
// ---------------------------------------------------------------------------

/// Contiguous split offsets covering all of `total`: `[(off, len); shards]`.
/// When `total % shards != 0` the first `total % shards` shards carry one
/// extra row (lengths differ by at most 1), so no remainder row is ever
/// silently dropped. The strategy paths that require *equal* shards enforce
/// divisibility up front via `ParallelConfig::validate`.
pub fn split_offsets(total: usize, shards: usize) -> Vec<(usize, usize)> {
    debug_assert!(shards > 0, "split_offsets: shards must be >= 1");
    let base = total / shards;
    let rem = total % shards;
    let mut out = Vec::with_capacity(shards);
    let mut off = 0;
    for i in 0..shards {
        let len = base + usize::from(i < rem);
        out.push((off, len));
        off += len;
    }
    out
}

/// qkv-projection FLOPs for a patch (per layer).
pub fn flops_qkv(model: &DitModel, p_img: usize, p_txt: usize) -> f64 {
    let d = model.d as f64;
    let mut f = 2.0 * p_img as f64 * d * 3.0 * d;
    if model.variant == BlockVariant::MmDit {
        f += 2.0 * p_txt as f64 * d * 3.0 * d;
    }
    f
}

/// post-phase FLOPs (attention + out-proj + MLP) for a patch (per layer).
pub fn flops_post(model: &DitModel, p_img: usize, p_txt: usize, s_kv: usize) -> f64 {
    let d = model.d as f64;
    let m = 4.0;
    let p = (p_img + if model.variant == BlockVariant::MmDit { p_txt } else { 0 }) as f64;
    let attn = 2.0 * 2.0 * p * s_kv as f64 * d;
    let proj = 2.0 * p * d * d;
    let mlp = 2.0 * 2.0 * p * d * m * d;
    let cross = if model.variant == BlockVariant::Cross {
        flops::cross_extra_flops(1, p_img, model.s_txt, model.d)
    } else {
        0.0
    };
    attn + proj + mlp + cross
}

/// Full-stage FLOPs for `ls` layers over a patch.
pub fn flops_stage(model: &DitModel, ls: usize, p_img: usize, p_txt: usize, s_kv: usize) -> f64 {
    ls as f64 * (flops_qkv(model, p_img, p_txt) + flops_post(model, p_img, p_txt, s_kv))
}

/// Result of one exact SP layer pass.
pub struct SpLayerOut {
    /// Per-rank image hidden-state shards after the layer.
    pub x_img: Vec<Tensor>,
    /// Per-rank text shards (MM-DiT in-context models).
    pub x_txt: Option<Vec<Tensor>>,
    /// Fresh K/V of the whole patch (concatenated over SP ranks).
    pub k_img: Tensor,
    /// Fresh V of the whole patch (see `k_img`).
    pub v_img: Tensor,
    /// Fresh text K (MM-DiT).
    pub k_txt: Option<Tensor>,
    /// Fresh text V (MM-DiT).
    pub v_txt: Option<Tensor>,
}

/// One exact SP layer pass over a *patch* (the whole image for pure SP; one
/// PipeFusion patch in hybrid mode) split across an SP group.
///
/// `bases`: per-rank attention base K/V `[s_attn, d]` (the PipeFusion
/// buffers in hybrid mode — identical across ranks iff the Fig-6/7
/// consistent update rule is active; zeros for pure SP where the patch
/// covers the whole sequence). The patch's fresh K/V rows — produced by
/// *all* ranks and exchanged (Ulysses All2All / Ring rotation, charged on
/// the clocks) — replace the patch rows before attention.
#[allow(clippy::too_many_arguments)]
pub fn sp_layer(
    sess: &mut Session,
    sp_ranks: &[usize],
    layer_abs: usize,
    pf: usize,
    x_img: &[Tensor],
    x_txt: Option<&[Tensor]>,
    skip_rows: Option<&[Tensor]>,
    cond: &Tensor,
    txt_mem: Option<&Tensor>,
    bases: &[(Tensor, Tensor)],
    patch_off_img: usize,
    patch_off_txt: usize,
) -> Result<SpLayerOut> {
    let model = sess.model.clone();
    let nsp = sp_ranks.len();
    debug_assert_eq!(bases.len(), nsp);
    let d = model.d;
    let half = model.layers / 2;
    let is_skip_dec = model.variant == BlockVariant::Skip && layer_abs >= half;
    let p_img = x_img[0].dims[0];
    let p_txt = x_txt.map(|t| t[0].dims[0]).unwrap_or(0);

    // ---- phase 1: local qkv on every rank --------------------------------
    let mut qs_img = Vec::with_capacity(nsp);
    let mut ks_img = Vec::with_capacity(nsp);
    let mut vs_img = Vec::with_capacity(nsp);
    let mut qs_txt = Vec::new();
    let mut ks_txt = Vec::new();
    let mut vs_txt = Vec::new();
    let mut x_img_new = x_img.to_vec();

    for (i, &dev) in sp_ranks.iter().enumerate() {
        sess.charge_compute(dev, flops_qkv(&model, p_img, p_txt));
        match model.variant {
            BlockVariant::MmDit => {
                let out = sess.rt.call(
                    &format!("mmdit_qkv_p{pf}"),
                    layer_abs,
                    &[
                        ArgValue::F32(&x_txt.unwrap()[i]),
                        ArgValue::F32(&x_img[i]),
                        ArgValue::F32(&cond),
                    ],
                )?;
                let mut it = out.into_iter();
                qs_txt.push(it.next().unwrap());
                ks_txt.push(it.next().unwrap());
                vs_txt.push(it.next().unwrap());
                qs_img.push(it.next().unwrap());
                ks_img.push(it.next().unwrap());
                vs_img.push(it.next().unwrap());
            }
            BlockVariant::Skip if is_skip_dec => {
                let out = sess.rt.call(
                    &format!("skip_dec_qkv_p{pf}"),
                    layer_abs - half,
                    &[
                        ArgValue::F32(&x_img[i]),
                        ArgValue::F32(&skip_rows.unwrap()[i]),
                        ArgValue::F32(&cond),
                    ],
                )?;
                let mut it = out.into_iter();
                x_img_new[i] = it.next().unwrap(); // x after skip-fuse
                qs_img.push(it.next().unwrap());
                ks_img.push(it.next().unwrap());
                vs_img.push(it.next().unwrap());
            }
            _ => {
                let entry = match model.variant {
                    BlockVariant::AdaLn => format!("adaln_qkv_p{pf}"),
                    BlockVariant::Cross => format!("cross_qkv_p{pf}"),
                    BlockVariant::Skip => format!("skip_enc_qkv_p{pf}"),
                    BlockVariant::MmDit => unreachable!(),
                };
                let out = sess.rt.call(
                    &entry,
                    layer_abs,
                    &[ArgValue::F32(&x_img[i]), ArgValue::F32(&cond)],
                )?;
                let mut it = out.into_iter();
                qs_img.push(it.next().unwrap());
                ks_img.push(it.next().unwrap());
                vs_img.push(it.next().unwrap());
            }
        }
    }

    // ---- phase 2: SP exchange (data + cost) -------------------------------
    let k_img = Tensor::concat_rows(&ks_img)?;
    let v_img = Tensor::concat_rows(&vs_img)?;
    let (k_txt, v_txt) = if model.variant == BlockVariant::MmDit {
        (Some(Tensor::concat_rows(&ks_txt)?), Some(Tensor::concat_rows(&vs_txt)?))
    } else {
        (None, None)
    };
    charge_sp_exchange(sess, sp_ranks, (p_img + p_txt) * d * 4);

    // ---- phase 3: attention + MLP with the exchanged K/V ------------------
    let mut x_txt_new = x_txt.map(|t| t.to_vec());
    for (i, &dev) in sp_ranks.iter().enumerate() {
        let (mut kf, mut vf) = bases[i].clone();
        if let (Some(kt), Some(vt)) = (&k_txt, &v_txt) {
            kf.scatter_rows(patch_off_txt, kt)?;
            vf.scatter_rows(patch_off_txt, vt)?;
        }
        let img_base = model.img_buf_off(patch_off_img);
        kf.scatter_rows(img_base, &k_img)?;
        vf.scatter_rows(img_base, &v_img)?;

        sess.charge_compute(dev, flops_post(&model, p_img, p_txt, model.attn_seq()));
        match model.variant {
            BlockVariant::MmDit => {
                let out = sess.rt.call(
                    &format!("mmdit_post_p{pf}"),
                    layer_abs,
                    &[
                        ArgValue::F32(&x_txt.unwrap()[i]),
                        ArgValue::F32(&x_img[i]),
                        ArgValue::F32(&qs_txt[i]),
                        ArgValue::F32(&qs_img[i]),
                        ArgValue::F32(&kf),
                        ArgValue::F32(&vf),
                        ArgValue::F32(&cond),
                    ],
                )?;
                let mut it = out.into_iter();
                x_txt_new.as_mut().unwrap()[i] = it.next().unwrap();
                x_img_new[i] = it.next().unwrap();
            }
            BlockVariant::Cross => {
                let out = sess.rt.call(
                    &format!("cross_post_p{pf}"),
                    layer_abs,
                    &[
                        ArgValue::F32(&x_img[i]),
                        ArgValue::F32(&qs_img[i]),
                        ArgValue::F32(&kf),
                        ArgValue::F32(&vf),
                        ArgValue::F32(&cond),
                        ArgValue::F32(txt_mem.unwrap()),
                    ],
                )?;
                x_img_new[i] = out.into_iter().next().unwrap();
            }
            _ => {
                let entry = if is_skip_dec {
                    format!("skip_dec_post_p{pf}")
                } else if model.variant == BlockVariant::Skip {
                    format!("skip_enc_post_p{pf}")
                } else {
                    format!("adaln_post_p{pf}")
                };
                let stage = if is_skip_dec { layer_abs - half } else { layer_abs };
                let out = sess.rt.call(
                    &entry,
                    stage,
                    &[
                        ArgValue::F32(&x_img_new[i]),
                        ArgValue::F32(&qs_img[i]),
                        ArgValue::F32(&kf),
                        ArgValue::F32(&vf),
                        ArgValue::F32(&cond),
                    ],
                )?;
                x_img_new[i] = out.into_iter().next().unwrap();
            }
        }
    }

    Ok(SpLayerOut { x_img: x_img_new, x_txt: x_txt_new, k_img, v_img, k_txt, v_txt })
}

/// One *exact* full-sequence forward (the synchronous warmup step of
/// PipeFusion / DistriFusion): embed -> whole model in one stage -> final.
/// Returns `(eps, k_new, v_new)` with `k_new: [L, s_attn, d]` fresh for the
/// entire sequence, which the caller scatters into its staleness buffers.
pub fn exact_step(
    sess: &mut Session,
    branch: &BranchCtx,
    x: &Tensor,
    cond: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    let model = sess.model.clone();
    let x_emb = model.embed_patch(sess.rt, 1, x, 0)?;
    let kv = crate::model::KvBuffer::zeros(model.layers, model.attn_seq(), model.d);
    let is_mmdit = model.variant == BlockVariant::MmDit;
    let sin = crate::model::StageIn {
        x_img: &x_emb,
        x_txt: if is_mmdit { Some(&branch.txt) } else { None },
        skips: None,
        cond,
        txt_mem: if model.variant == BlockVariant::Cross { Some(&branch.txt) } else { None },
        kv: &kv,
        off_img: 0,
        off_txt: 0,
    };
    let out = model.run_stage(sess.rt, crate::model::StageKind::Whole, model.layers, 1, 0, &sin)?;
    let eps = model.final_patch(sess.rt, 1, &out.y_img, cond)?;
    Ok((eps, out.k_new, out.v_new))
}

/// Charge the SP exchange for one layer: Ulysses All2All on the ulysses
/// subgroups (4 ops: q,k,v out + o back, paper Table 1) and Ring rotation on
/// the ring subgroups ((n-1) K/V block hops; overlap with attention is what
/// distinguishes Ring and is modelled in `perf::latency` — the live
/// simulator charges the transfers).
fn charge_sp_exchange(sess: &mut Session, sp_ranks: &[usize], shard_bytes: usize) {
    let mesh = sess.mesh.clone();
    let u = sess.pc.ulysses;
    let r = sess.pc.ring;
    if u > 1 {
        let mut seen = std::collections::BTreeSet::new();
        for &rank in sp_ranks {
            let g = mesh.ulysses_group(rank);
            if seen.insert(g.clone()) {
                let _ = sess.with_comm(|comm| {
                    comm.charge("all_to_all", &g, 4 * shard_bytes, 1.0);
                    Ok(())
                });
            }
        }
    }
    if r > 1 {
        let mut seen = std::collections::BTreeSet::new();
        for &rank in sp_ranks {
            let g = mesh.ring_group(rank);
            if seen.insert(g.clone()) {
                let _ = sess.with_comm(|comm| {
                    comm.charge("ring_kv", &g, 2 * shard_bytes * (r - 1), 1.0);
                    Ok(())
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_offsets_cover() {
        let o = split_offsets(256, 4);
        assert_eq!(o, vec![(0, 64), (64, 64), (128, 64), (192, 64)]);
    }

    #[test]
    fn split_offsets_distributes_remainder() {
        // 10 rows over 4 shards: 3,3,2,2 — contiguous, nothing dropped
        let o = split_offsets(10, 4);
        assert_eq!(o, vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        let covered: usize = o.iter().map(|&(_, l)| l).sum();
        assert_eq!(covered, 10);
        // degenerate: fewer rows than shards still covers every row once
        let o = split_offsets(2, 4);
        assert_eq!(o.iter().map(|&(_, l)| l).sum::<usize>(), 2);
        assert_eq!(o.len(), 4);
    }

    #[test]
    fn flops_helpers_positive_and_additive() {
        let m = DitModel {
            variant: BlockVariant::AdaLn,
            d: 192,
            heads: 6,
            layers: 8,
            s_img: 256,
            s_txt: 32,
            c_latent: 4,
            latent_hw: 16,
        };
        let s = flops_stage(&m, 2, 64, 0, 256);
        let per = flops_qkv(&m, 64, 0) + flops_post(&m, 64, 0, 256);
        assert!((s - 2.0 * per).abs() < 1.0);
    }
}
