//! PipeFusion: patch-level pipeline parallelism (paper §4.1.2).
//!
//! The model is split into `N = pc.pipefusion` stages of consecutive
//! layers; the image into `M = pc.patches` patches. Each device keeps a
//! per-layer **full-sequence KV buffer** for its stage; a patch micro-step
//! computes with its own rows fresh and the other patches' rows *stale*
//! (current step for earlier patches, previous step for later ones — the
//! input-temporal-redundancy bet). Activations of one patch (`p × d`) flow
//! stage-to-stage over async P2P, overlapped with compute; this is the
//! `2·O(p·hs)` communication row of Table 1 — no per-layer collectives.
//!
//! Warmup steps (paper: 1) run the patches with synchronous stage barriers
//! to initialize the buffers.

use crate::config::model::BlockVariant;
use crate::model::{KvBuffer, StageIn, StageKind, StageOut};
use crate::parallel::{flops_stage, split_offsets, BranchCtx, Session, Strategy};
use crate::perf::flops;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// The PipeFusion strategy: patch-level pipeline across layer stages
/// with one-step-stale KV buffers (see the module docs).
pub struct PipeFusion {
    /// Per (branch, stage) KV buffers, created lazily.
    buffers: std::collections::HashMap<(usize, usize), KvBuffer>,
}

impl PipeFusion {
    /// A fresh strategy instance (buffers fill during warmup).
    pub fn new() -> PipeFusion {
        PipeFusion { buffers: std::collections::HashMap::new() }
    }

    fn buffer(
        &mut self,
        branch: usize,
        stage: usize,
        ls: usize,
        s: usize,
        d: usize,
    ) -> &mut KvBuffer {
        self.buffers.entry((branch, stage)).or_insert_with(|| KvBuffer::zeros(ls, s, d))
    }

    fn ensure_buffers(&mut self, branch: usize, stages: usize, ls: usize, s: usize, d: usize) {
        for st in 0..stages {
            self.buffer(branch, st, ls, s, d);
        }
    }
}

impl Default for PipeFusion {
    fn default() -> Self {
        Self::new()
    }
}

/// Scatter a stage output's fresh K/V (`[ls, p, d]`, text rows first for
/// MM-DiT) into a buffer at the patch's offsets.
pub fn scatter_patch_kv(
    buf: &mut KvBuffer,
    k_new: &Tensor,
    v_new: &Tensor,
    p_txt: usize,
    off_txt: usize,
    off_img_abs: usize,
) -> Result<()> {
    let ls = k_new.dims[0];
    let p = k_new.dims[1];
    let d = k_new.dims[2];
    for l in 0..ls {
        let k_l = k_new.slice_rows(l, l + 1)?.reshape(&[p, d])?;
        let v_l = v_new.slice_rows(l, l + 1)?.reshape(&[p, d])?;
        if p_txt > 0 {
            buf.scatter_layer(l, off_txt, &k_l.slice_rows(0, p_txt)?, &v_l.slice_rows(0, p_txt)?)?;
            buf.scatter_layer(
                l,
                off_img_abs,
                &k_l.slice_rows(p_txt, p)?,
                &v_l.slice_rows(p_txt, p)?,
            )?;
        } else {
            buf.scatter_layer(l, off_img_abs, &k_l, &v_l)?;
        }
    }
    Ok(())
}

impl Strategy for PipeFusion {
    fn name(&self) -> String {
        "pipefusion".into()
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        let n_stages = sess.pc.pipefusion;
        let m_patches = sess.pc.patches;
        let pf = sess.pc.seq_shards();
        let ls = model.layers / n_stages;
        let warmup = step < sess.pc.warmup_steps;
        let is_skip = model.variant == BlockVariant::Skip;
        if is_skip && n_stages > 2 {
            return Err(Error::config("skip models support pipefusion <= 2"));
        }
        let stage_ranks: Vec<usize> = branch.ranks[..n_stages].to_vec();

        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;
        let txt_mem =
            if model.variant == BlockVariant::Cross { Some(branch.txt.clone()) } else { None };
        let is_mmdit = model.variant == BlockVariant::MmDit;

        let img_offs = split_offsets(model.s_img, m_patches);
        let txt_offs = split_offsets(model.s_txt, m_patches);
        let p_img = model.s_img / m_patches;
        let p_txt = if is_mmdit { model.s_txt / m_patches } else { 0 };

        if warmup {
            // Synchronous warmup (paper §4.1.2): no pipelining, buffers
            // initialized with the exact full-sequence K/V. Costs ~one
            // serial step on the whole pipeline group.
            let (eps, k_new, v_new) = crate::parallel::exact_step(sess, branch, x, &cond)?;
            let serial_fl =
                flops_stage(&model, model.layers, model.s_img, model.s_txt, model.attn_seq());
            for &d in &stage_ranks {
                sess.charge_compute(d, serial_fl / n_stages as f64);
            }
            sess.clocks.sync(&stage_ranks);
            for s in 0..n_stages {
                let buf = self.buffer(branch.idx, s, ls, model.attn_seq(), model.d);
                buf.k = k_new.slice_rows(s * ls, (s + 1) * ls)?;
                buf.v = v_new.slice_rows(s * ls, (s + 1) * ls)?;
            }
            return Ok(eps);
        }

        self.ensure_buffers(branch.idx, n_stages, ls, model.attn_seq(), model.d);
        let mut eps_parts: Vec<Option<Tensor>> = vec![None; m_patches];

        for m in 0..m_patches {
            let (off_img, len_img) = img_offs[m];
            let (off_txt, _) = txt_offs[m];
            // stage 0 embeds the arriving latent patch
            let latent = x.slice_rows(off_img, off_img + len_img)?;
            let mut x_img = model.embed_patch(sess.rt, pf, &latent, off_img)?;
            sess.charge_compute(
                stage_ranks[0],
                flops::embed_flops(len_img, model.c_latent, model.d),
            );
            let mut x_txt: Option<Tensor> = if is_mmdit {
                Some(branch.txt.slice_rows(off_txt, off_txt + p_txt)?)
            } else {
                None
            };
            let mut skips: Option<Tensor> = None;

            for s in 0..n_stages {
                let dev = stage_ranks[s];
                let kind = if !is_skip || n_stages == 1 {
                    StageKind::Whole
                } else if s == 0 {
                    StageKind::SkipEnc
                } else {
                    StageKind::SkipDec
                };
                // decoder-relative stage index per the WeightRef convention
                let w_stage = if kind == StageKind::SkipDec { 0 } else { s };
                // borrow the persistent buffer directly (no deep copy —
                // §Perf iteration 5); the mutable scatter below re-borrows
                // after the stage call completes.
                let buf = &self.buffers[&(branch.idx, s)];
                let sin = StageIn {
                    x_img: &x_img,
                    x_txt: x_txt.as_ref(),
                    skips: skips.as_ref(),
                    cond: &cond,
                    txt_mem: txt_mem.as_ref(),
                    kv: &buf,
                    off_img,
                    off_txt,
                };
                let out: StageOut = model.run_stage(sess.rt, kind, ls, pf, w_stage, &sin)?;
                sess.charge_compute(
                    dev,
                    flops_stage(&model, ls, p_img, p_txt, model.attn_seq()),
                );
                // persist the fresh rows into this stage's buffer
                let buf_mut = self.buffer(branch.idx, s, ls, model.attn_seq(), model.d);
                scatter_patch_kv(
                    buf_mut,
                    &out.k_new,
                    &out.v_new,
                    p_txt,
                    off_txt,
                    model.img_buf_off(off_img),
                )?;

                x_img = out.y_img;
                if let Some(t) = out.y_txt {
                    x_txt = Some(t);
                }
                if out.skips.is_some() {
                    skips = out.skips;
                }

                // forward the activation patch to the next stage
                if s + 1 < n_stages {
                    let next = stage_ranks[s + 1];
                    let mut bytes = x_img.size_bytes()
                        + x_txt.as_ref().map(|t| t.size_bytes()).unwrap_or(0);
                    // skip tensors ride along enc->dec (the Fig-17 penalty)
                    if kind == StageKind::SkipEnc {
                        bytes += skips.as_ref().map(|t| t.size_bytes()).unwrap_or(0);
                    }
                    let arrive = sess.with_comm(|comm| {
                        let payload = Tensor::zeros(&[bytes / 4]);
                        let (_, arrive) = comm.p2p_async(dev, next, payload);
                        Ok(arrive)
                    })?;
                    sess.clocks.wait_until(next, arrive);
                }
            }

            // final layer on the last stage
            let last = stage_ranks[n_stages - 1];
            let eps = model.final_patch(sess.rt, pf, &x_img, &cond)?;
            sess.charge_compute(last, flops::final_flops(p_img, model.c_latent, model.d));
            // result patch returns to stage 0 for the next step's input
            if n_stages > 1 {
                sess.with_comm(|comm| {
                    let (_, arrive) = comm.p2p_async(last, stage_ranks[0], eps.clone());
                    comm.clocks.wait_until(stage_ranks[0], arrive);
                    Ok(())
                })?;
            }
            eps_parts[m] = Some(eps);
        }

        Tensor::concat_rows(&eps_parts.into_iter().map(Option::unwrap).collect::<Vec<_>>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::parallel::serial::Serial;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    fn branch(rt: &Runtime, n: usize) -> BranchCtx {
        let enc = TextEncoder::new(&rt.host_weights, 32).unwrap();
        let txt = enc.embed("pipefusion test");
        BranchCtx { idx: 0, ranks: (0..n).collect(), txt_pool: txt.mean_rows(), txt }
    }

    /// Warmup step 0 processes patches sequentially, so after warmup the
    /// buffers hold fresh values; step-0 output should be close to serial
    /// (later patches saw earlier fresh rows; earlier patches saw stale
    /// zeros for later rows — the expected warmup discrepancy).
    #[test]
    fn pipefusion_bounded_divergence_after_warmup() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(9));
        let mut s0 =
            Session::new(&rt, BlockVariant::AdaLn, l40_cluster(1), ParallelConfig::serial())
                .unwrap();
        let e_serial = Serial.denoise(&mut s0, &x, 800.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 2, 1, 1).with_patches(4);
        let mut s1 = Session::new(&rt, BlockVariant::AdaLn, l40_cluster(1), pc).unwrap();
        let mut pf = PipeFusion::new();
        // warmup step
        let _ = pf.denoise(&mut s1, &x, 800.0, 0, &branch(&rt, 2)).unwrap();
        // pipelined step on the *same* latent: buffers now fresh for x
        let e_pf = pf.denoise(&mut s1, &x, 800.0, 1, &branch(&rt, 2)).unwrap();
        let diff = e_pf.max_abs_diff(&e_serial).unwrap();
        assert!(diff < 5e-3, "post-warmup divergence too large: {diff}");
        assert!(s1.ledger.count("p2p_async") > 0);
    }

    #[test]
    fn pipefusion_mmdit_runs() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(10));
        let pc = ParallelConfig::new(1, 4, 1, 1).with_patches(4);
        let mut s = Session::new(&rt, BlockVariant::MmDit, l40_cluster(1), pc).unwrap();
        let mut pf = PipeFusion::new();
        let e = pf.denoise(&mut s, &x, 500.0, 0, &branch(&rt, 4)).unwrap();
        assert_eq!(e.dims, vec![256, 4]);
        assert!(e.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pipefusion_skip_enc_dec() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(11));
        let pc = ParallelConfig::new(1, 2, 1, 1).with_patches(2);
        let mut s = Session::new(&rt, BlockVariant::Skip, l40_cluster(1), pc).unwrap();
        let mut pf = PipeFusion::new();
        let e = pf.denoise(&mut s, &x, 500.0, 0, &branch(&rt, 2)).unwrap();
        assert_eq!(e.dims, vec![256, 4]);
    }
}
