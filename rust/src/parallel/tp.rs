//! Tensor parallelism baseline (Megatron-style head/FFN sharding).
//!
//! TP's math is *identical* to serial — each device computes a head/FFN
//! shard and two AllReduces per layer restore the full activations — so the
//! numeric path reuses the serial computation while the virtual-time path
//! charges the real TP costs: compute/N per device plus per-layer
//! 2×AllReduce of the full activation (paper Table 1: 4·O(p·hs)·L with the
//! 2(n-1)/n ring factor, no overlap). The paper keeps TP only as the
//! baseline it consistently beats (Fig 9: always the highest latency).

use crate::config::model::BlockVariant;
use crate::model::{KvBuffer, StageIn, StageKind};
use crate::parallel::{flops_stage, BranchCtx, Session, Strategy};
use crate::tensor::Tensor;
use crate::Result;

/// The tensor-parallel baseline: heads/MLP sharded per layer, two
/// AllReduces per layer exposed on the critical path.
pub struct TensorParallel;

impl Strategy for TensorParallel {
    fn name(&self) -> String {
        "tp".into()
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        _step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        let group = branch.ranks.clone();
        let n = group.len();
        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;

        // numeric result == serial (TP is an exact decomposition)
        let x_emb = model.embed_patch(sess.rt, 1, x, 0)?;
        let kv = KvBuffer::zeros(model.layers, model.attn_seq(), model.d);
        let is_mmdit = model.variant == BlockVariant::MmDit;
        let sin = StageIn {
            x_img: &x_emb,
            x_txt: if is_mmdit { Some(&branch.txt) } else { None },
            skips: None,
            cond: &cond,
            txt_mem: if model.variant == BlockVariant::Cross { Some(&branch.txt) } else { None },
            kv: &kv,
            off_img: 0,
            off_txt: 0,
        };
        let out = model.run_stage(sess.rt, StageKind::Whole, model.layers, 1, 0, &sin)?;
        let eps = model.final_patch(sess.rt, 1, &out.y_img, &cond)?;

        // virtual-time: compute/N per device, 2 AllReduce of the full
        // activation per layer (attention out + MLP out)
        let full =
            flops_stage(&model, model.layers, model.s_img, model.s_txt, model.attn_seq());
        for &d in &group {
            sess.charge_compute(d, full / n as f64);
        }
        let act_bytes = model.attn_seq() * model.d * 4;
        let nf = n as f64;
        for _layer in 0..model.layers {
            for _ in 0..2 {
                sess.with_comm(|c| {
                    c.charge("all_reduce", &group, act_bytes, 2.0 * (nf - 1.0) / nf);
                    Ok(())
                })?;
            }
        }
        Ok(eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::l40_cluster;
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::parallel::serial::Serial;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    #[test]
    fn tp_matches_serial_but_pays_comm() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(dir).unwrap();
        let enc = TextEncoder::new(&rt.host_weights, 32).unwrap();
        let txt = enc.embed("city at night");
        let x = Tensor::randn(&[256, 4], &mut Rng::new(7));

        let mut s_sess = Session::new(
            &rt,
            BlockVariant::AdaLn,
            l40_cluster(1),
            ParallelConfig::serial(),
        )
        .unwrap();
        let b0 = BranchCtx { idx: 0, ranks: vec![0], txt_pool: txt.mean_rows(), txt: txt.clone() };
        let e_serial = Serial.denoise(&mut s_sess, &x, 400.0, 0, &b0).unwrap();

        // TP over 4 devices: exact numerics, nonzero all_reduce traffic
        let mut t_sess = Session::new(
            &rt,
            BlockVariant::AdaLn,
            l40_cluster(1),
            ParallelConfig::serial(),
        )
        .unwrap();
        let b4 = BranchCtx {
            idx: 0,
            ranks: vec![0, 1, 2, 3],
            txt_pool: txt.mean_rows(),
            txt: txt.clone(),
        };
        let e_tp = TensorParallel.denoise(&mut t_sess, &x, 400.0, 0, &b4).unwrap();
        assert_eq!(e_tp, e_serial);
        assert_eq!(t_sess.ledger.count("all_reduce"), 2 * 8);
        assert!(t_sess.makespan() > 0.0);
    }
}
