//! Serial (1-GPU) baseline: the reference every parallel strategy is
//! checked against. Full sequence through the whole model in one stage
//! call, fresh zero buffers (fully overwritten) — exact by construction.

use crate::config::model::BlockVariant;
use crate::model::{KvBuffer, StageIn, StageKind};
use crate::parallel::{flops_stage, BranchCtx, Session, Strategy};
use crate::tensor::Tensor;
use crate::Result;

/// The single-device reference strategy (exact, no communication).
#[derive(Default)]
pub struct Serial;

impl Strategy for Serial {
    fn name(&self) -> String {
        "serial".into()
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        _step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        let dev = branch.ranks[0];
        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;

        let x_emb = model.embed_patch(sess.rt, 1, x, 0)?;
        let kv = KvBuffer::zeros(model.layers, model.attn_seq(), model.d);
        let is_mmdit = model.variant == BlockVariant::MmDit;
        let sin = StageIn {
            x_img: &x_emb,
            x_txt: if is_mmdit { Some(&branch.txt) } else { None },
            skips: None,
            cond: &cond,
            txt_mem: if model.variant == BlockVariant::Cross { Some(&branch.txt) } else { None },
            kv: &kv,
            off_img: 0,
            off_txt: 0,
        };
        let out = model.run_stage(sess.rt, StageKind::Whole, model.layers, 1, 0, &sin)?;
        sess.charge_compute(
            dev,
            flops_stage(&model, model.layers, model.s_img, model.s_txt, model.attn_seq()),
        );
        let eps = model.final_patch(sess.rt, 1, &out.y_img, &cond)?;
        Ok(eps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::a100_node;
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    #[test]
    fn serial_denoise_runs_and_is_deterministic() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::load(dir).unwrap();
        let mut sess =
            Session::new(&rt, BlockVariant::AdaLn, a100_node(), ParallelConfig::serial()).unwrap();
        let enc = TextEncoder::new(&rt.host_weights, sess.model.s_txt).unwrap();
        let txt = enc.embed("a red fox");
        let branch = BranchCtx { idx: 0, ranks: vec![0], txt_pool: txt.mean_rows(), txt };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(0));
        let mut s = Serial;
        let e1 = s.denoise(&mut sess, &x, 500.0, 0, &branch).unwrap();
        let e2 = s.denoise(&mut sess, &x, 500.0, 0, &branch).unwrap();
        assert_eq!(e1, e2);
        assert_eq!(e1.dims, vec![256, 4]);
        assert!(sess.makespan() > 0.0);
    }
}
