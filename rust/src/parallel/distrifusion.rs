//! DistriFusion baseline (Li et al., CVPR'24): displaced patch parallelism.
//!
//! Each of the N devices owns one patch and a **full-sequence KV buffer for
//! every layer** (memory `(KV)·L` — the Table-1 row that does *not* shrink
//! with N and OOMs at 4096px in the paper's Fig 18 discussion). At step t a
//! device computes its patch against the other patches' *step t-1* K/V and
//! asynchronously AllGathers fresh K/V for the next step, overlapped with
//! the entire forward pass.

use crate::config::model::BlockVariant;
use crate::model::{KvBuffer, StageIn, StageKind};
use crate::parallel::pipefusion::scatter_patch_kv;
use crate::parallel::{flops_stage, split_offsets, BranchCtx, Session, Strategy};
use crate::perf::flops;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// The DistriFusion baseline: displaced patch parallelism whose stale
/// AllGather overlaps the whole forward (see the module docs).
pub struct DistriFusion {
    /// Per (branch, device-slot) full-depth KV buffers.
    buffers: std::collections::HashMap<(usize, usize), KvBuffer>,
}

impl DistriFusion {
    /// A fresh strategy instance (buffers fill during warmup).
    pub fn new() -> DistriFusion {
        DistriFusion { buffers: std::collections::HashMap::new() }
    }
}

impl Default for DistriFusion {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for DistriFusion {
    fn name(&self) -> String {
        "distrifusion".into()
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        if model.variant == BlockVariant::Skip {
            return Err(Error::config(
                "distrifusion baseline does not support skip-connection models",
            ));
        }
        // one device per patch; the config carries the patch count
        let n = sess.pc.patches.max(2);
        if branch.ranks.len() < n {
            return Err(Error::config(format!(
                "distrifusion needs {n} devices (one per patch), branch has {}",
                branch.ranks.len()
            )));
        }
        let ranks: Vec<usize> = branch.ranks[..n].to_vec();
        let pf = n;
        let warmup = step < sess.pc.warmup_steps;
        let is_mmdit = model.variant == BlockVariant::MmDit;
        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;
        let txt_mem =
            if model.variant == BlockVariant::Cross { Some(branch.txt.clone()) } else { None };

        let img_offs = split_offsets(model.s_img, n);
        let txt_offs = split_offsets(model.s_txt, n);
        let p_img = model.s_img / n;
        let p_txt = if is_mmdit { model.s_txt / n } else { 0 };

        for slot in 0..n {
            self.buffers
                .entry((branch.idx, slot))
                .or_insert_with(|| KvBuffer::zeros(model.layers, model.attn_seq(), model.d));
        }

        if warmup {
            // synchronous warmup: exact full-sequence forward, buffers
            // filled fresh on every device; ~serial cost, no overlap
            let (eps, k_new, v_new) = crate::parallel::exact_step(sess, branch, x, &cond)?;
            let serial_fl =
                flops_stage(&model, model.layers, model.s_img, model.s_txt, model.attn_seq());
            for &d in &ranks {
                sess.charge_compute(d, serial_fl / n as f64);
            }
            sess.clocks.sync(&ranks);
            for slot in 0..n {
                let buf = self.buffers.get_mut(&(branch.idx, slot)).unwrap();
                buf.k = k_new.clone();
                buf.v = v_new.clone();
            }
            return Ok(eps);
        }

        let mut eps_parts = Vec::with_capacity(n);
        let mut fresh_kv: Vec<(Tensor, Tensor)> = Vec::with_capacity(n);
        let stage_fl = flops_stage(&model, model.layers, p_img, p_txt, model.attn_seq());

        for (slot, &dev) in ranks.iter().enumerate() {
            let (off_img, len_img) = img_offs[slot];
            let (off_txt, _) = txt_offs[slot];
            let latent = x.slice_rows(off_img, off_img + len_img)?;
            let x_img = model.embed_patch(sess.rt, pf, &latent, off_img)?;
            let x_txt: Option<Tensor> = if is_mmdit {
                Some(branch.txt.slice_rows(off_txt, off_txt + p_txt)?)
            } else {
                None
            };
            let buf = &self.buffers[&(branch.idx, slot)];
            let sin = StageIn {
                x_img: &x_img,
                x_txt: x_txt.as_ref(),
                skips: None,
                cond: &cond,
                txt_mem: txt_mem.as_ref(),
                kv: buf,
                off_img,
                off_txt,
            };
            let out = model.run_stage(sess.rt, StageKind::Whole, model.layers, pf, 0, &sin)?;
            sess.charge_compute(dev, stage_fl);
            let eps = model.final_patch(sess.rt, pf, &out.y_img, &cond)?;
            sess.charge_compute(dev, flops::final_flops(p_img, model.c_latent, model.d));
            eps_parts.push(eps);
            fresh_kv.push((out.k_new, out.v_new));
        }

        // asynchronous KV AllGather, overlapped with the forward pass:
        // all buffers receive every patch's fresh K/V for the next step.
        let kv_bytes = 2 * model.layers * (p_img + p_txt) * model.d * 4;
        let t_comm = sess.cluster.collective_time(
            &ranks,
            kv_bytes as f64,
            n as f64 - 1.0, // each rank receives (n-1) remote chunks
        );
        let t_compute = flops::compute_time(stage_fl, sess.cluster.gpu.tflops);
        let excess = if warmup { t_comm } else { (t_comm - t_compute).max(0.0) };
        sess.with_comm(|comm| {
            comm.charge("kv_allgather", &ranks, kv_bytes, 0.0); // time charged below
            Ok(())
        })?;
        for &d in &ranks {
            sess.clocks.advance(d, excess);
        }
        sess.clocks.sync(&ranks);

        for slot in 0..n {
            let buf = self.buffers.get_mut(&(branch.idx, slot)).unwrap();
            for (other, (k_new, v_new)) in fresh_kv.iter().enumerate() {
                scatter_patch_kv(
                    buf,
                    k_new,
                    v_new,
                    p_txt,
                    txt_offs[other].0,
                    model.img_buf_off(img_offs[other].0),
                )?;
            }
        }

        Tensor::concat_rows(&eps_parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::a100_node;
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::parallel::serial::Serial;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    fn branch(rt: &Runtime, n: usize) -> BranchCtx {
        let enc = TextEncoder::new(&rt.host_weights, 32).unwrap();
        let txt = enc.embed("distrifusion test");
        BranchCtx { idx: 0, ranks: (0..n).collect(), txt_pool: txt.mean_rows(), txt }
    }

    #[test]
    fn distrifusion_close_to_serial_when_buffers_fresh() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(12));
        let mut s0 = Session::new(&rt, BlockVariant::AdaLn, a100_node(), ParallelConfig::serial())
            .unwrap();
        let e_serial = Serial.denoise(&mut s0, &x, 650.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 1, 1, 1).with_patches(4);
        let mut s1 = Session::new(&rt, BlockVariant::AdaLn, a100_node(), pc).unwrap();
        let mut df = DistriFusion::new();
        // step 0 fills buffers with x's fresh KV (patch-sequential semantics);
        // repeating the same latent at step 1 must then be near-exact.
        let _ = df.denoise(&mut s1, &x, 650.0, 0, &branch(&rt, 4)).unwrap();
        let e_df = df.denoise(&mut s1, &x, 650.0, 1, &branch(&rt, 4)).unwrap();
        let diff = e_df.max_abs_diff(&e_serial).unwrap();
        assert!(diff < 5e-3, "divergence {diff}");
        // warmup step is synchronous (no async allgather); step 1 overlaps one
        assert!(s1.ledger.count("kv_allgather") == 1);
    }

    #[test]
    fn distrifusion_kv_memory_does_not_shrink() {
        // structural check on the Table-1 claim: each device's buffer covers
        // the full sequence at every layer regardless of N
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(13));
        let pc = ParallelConfig::new(1, 1, 1, 1).with_patches(2);
        let mut s = Session::new(&rt, BlockVariant::AdaLn, a100_node(), pc).unwrap();
        let mut df = DistriFusion::new();
        let _ = df.denoise(&mut s, &x, 100.0, 0, &branch(&rt, 2)).unwrap();
        let buf = &df.buffers[&(0, 0)];
        assert_eq!(buf.k.dims, vec![8, 256, 192]); // full L x full S
    }
}
