//! The generation driver: the diffusion loop (Eq. 1) over any strategy,
//! with CFG branch handling (sequential on the same devices when cfg=1,
//! disjoint device groups + per-step latent AllGather when cfg=2 — paper
//! §4.2).

use crate::config::model::BlockVariant;
use crate::diffusion::{combine_cfg, SchedulerKind};
use crate::model::TextEncoder;
use crate::parallel::{
    distrifusion::DistriFusion,
    hybrid::{Hybrid, KvUpdateRule},
    pipefusion::PipeFusion,
    serial::Serial,
    sp::SequenceParallel,
    tp::TensorParallel,
    BranchCtx, Session, Strategy,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Serial,
    Tp,
    Sp,
    DistriFusion,
    PipeFusion,
    Hybrid,
    HybridStandardSp,
}

impl Method {
    /// Instantiate the strategy implementation this selector names.
    pub fn build(&self) -> Box<dyn Strategy> {
        match self {
            Method::Serial => Box::new(Serial),
            Method::Tp => Box::new(TensorParallel),
            Method::Sp => Box::new(SequenceParallel),
            Method::DistriFusion => Box::new(DistriFusion::new()),
            Method::PipeFusion => Box::new(PipeFusion::new()),
            Method::Hybrid => Box::new(Hybrid::new(KvUpdateRule::Consistent)),
            Method::HybridStandardSp => Box::new(Hybrid::new(KvUpdateRule::StandardSp)),
        }
    }

    /// Canonical key, accepted back by [`Method::parse`].
    pub fn key(&self) -> &'static str {
        match self {
            Method::Serial => "serial",
            Method::Tp => "tp",
            Method::Sp => "sp",
            Method::DistriFusion => "distrifusion",
            Method::PipeFusion => "pipefusion",
            Method::Hybrid => "hybrid",
            Method::HybridStandardSp => "hybrid-standard-sp",
        }
    }

    /// Parse a strategy name (accepts the `sp`/`ulysses`/`ring`/`usp`
    /// aliases).
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "serial" => Method::Serial,
            "tp" => Method::Tp,
            "sp" | "ulysses" | "ring" | "usp" => Method::Sp,
            "distrifusion" => Method::DistriFusion,
            "pipefusion" => Method::PipeFusion,
            "hybrid" => Method::Hybrid,
            "hybrid-standard-sp" => Method::HybridStandardSp,
            _ => return Err(Error::config(format!("unknown method '{s}'"))),
        })
    }
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct GenParams {
    /// Text prompt to condition on.
    pub prompt: String,
    /// Diffusion steps to run.
    pub steps: usize,
    /// RNG seed for the initial latent.
    pub seed: u64,
    /// CFG guidance scale (1.0 or 0.0 disables the uncond branch).
    pub guidance: f32,
    /// Scheduler driving the update rule.
    pub scheduler: SchedulerKind,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            prompt: "a photo".into(),
            steps: 8,
            seed: 0,
            guidance: 4.0,
            scheduler: SchedulerKind::Ddim,
        }
    }
}

/// Result of one generation.
pub struct GenResult {
    /// Final denoised latent `[s_img, c]`.
    pub latent: Tensor,
    /// Virtual wall-clock of the simulated cluster for *this* generation
    /// (seconds) — a delta, correct even when the session is reused.
    pub makespan: f64,
    /// Bytes communicated by *this* generation (delta, as above).
    pub comm_bytes: usize,
    /// Strategy name used.
    pub method: String,
}

/// Run the full denoising loop for one image. The session may be reused
/// across calls (the engine shares one per batch): time/traffic are
/// reported as deltas against the session's clocks and ledger.
pub fn generate(sess: &mut Session, method: Method, p: &GenParams) -> Result<GenResult> {
    let model = sess.model.clone();
    let span_before = sess.makespan();
    let bytes_before = sess.ledger.total_bytes();
    let mut strat = method.build();
    let sch = p.scheduler.build(p.steps);
    let enc = TextEncoder::new(&sess.rt.host_weights, model.s_txt)?;

    let world: Vec<usize> = (0..sess.pc.world()).collect();
    let use_cfg_parallel = sess.pc.cfg == 2;
    let (ranks_c, ranks_u) = if use_cfg_parallel {
        (sess.mesh.cfg_branch_ranks(0), sess.mesh.cfg_branch_ranks(1))
    } else {
        (world.clone(), world)
    };

    let txt_c = enc.embed(&p.prompt);
    let txt_u = enc.embed_uncond();
    let branch_c =
        BranchCtx { idx: 0, ranks: ranks_c, txt_pool: txt_c.mean_rows(), txt: txt_c };
    let branch_u =
        BranchCtx { idx: 1, ranks: ranks_u, txt_pool: txt_u.mean_rows(), txt: txt_u };

    let mut rng = Rng::new(p.seed);
    let mut x = Tensor::randn(&[model.s_img, model.c_latent], &mut rng);
    let needs_uncond = p.guidance != 1.0 && p.guidance != 0.0;

    for i in 0..p.steps {
        let t = sch.timestep(i);
        let eps_c = strat.denoise(sess, &x, t, i, &branch_c)?;
        let eps = if needs_uncond {
            let eps_u = strat.denoise(sess, &x, t, i, &branch_u)?;
            if use_cfg_parallel {
                // one latent AllGather between the branch groups per step
                let bytes = eps_c.size_bytes();
                let pairs: Vec<(usize, usize)> = branch_c
                    .ranks
                    .iter()
                    .zip(&branch_u.ranks)
                    .map(|(&a, &b)| (a, b))
                    .collect();
                sess.with_comm(|comm| {
                    for (a, b) in pairs {
                        comm.charge("cfg_allgather", &[a, b], bytes, 1.0);
                    }
                    Ok(())
                })?;
            }
            combine_cfg(&eps_c, &eps_u, p.guidance)?
        } else {
            eps_c
        };
        x = sch.step(&x, &eps, i)?;
    }

    Ok(GenResult {
        latent: x,
        makespan: sess.makespan() - span_before,
        comm_bytes: sess.ledger.total_bytes().saturating_sub(bytes_before),
        method: strat.name(),
    })
}

/// Convenience: serial reference generation for divergence measurements.
pub fn generate_reference(
    rt: &crate::runtime::Runtime,
    variant: BlockVariant,
    p: &GenParams,
) -> Result<Tensor> {
    let cluster = crate::config::hardware::a100_node();
    let serial = crate::config::parallel::ParallelConfig::serial();
    let mut sess = Session::new(rt, variant, cluster, serial)?;
    Ok(generate(&mut sess, Method::Serial, p)?.latent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::config::parallel::ParallelConfig;
    use crate::runtime::Runtime;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn sp_trajectory_matches_serial() {
        let Some(rt) = setup() else { return };
        let p = GenParams { steps: 3, guidance: 3.0, ..Default::default() };
        let e0 = generate_reference(&rt, BlockVariant::AdaLn, &p).unwrap();
        let pc = ParallelConfig::new(1, 1, 2, 1);
        let mut sess = Session::new(&rt, BlockVariant::AdaLn, a100_node(), pc).unwrap();
        let r = generate(&mut sess, Method::Sp, &p).unwrap();
        assert!(
            r.latent.allclose(&e0, 2e-3),
            "sp trajectory diverged: {}",
            r.latent.max_abs_diff(&e0).unwrap()
        );
        assert!(r.makespan > 0.0);
        assert!(r.comm_bytes > 0);
    }

    #[test]
    fn cfg_parallel_matches_cfg_sequential() {
        let Some(rt) = setup() else { return };
        let p = GenParams { steps: 2, guidance: 5.0, ..Default::default() };
        // cfg=1: both branches on the same device
        let mut s1 = Session::new(
            &rt,
            BlockVariant::AdaLn,
            a100_node(),
            ParallelConfig::serial(),
        )
        .unwrap();
        let r1 = generate(&mut s1, Method::Serial, &p).unwrap();
        // cfg=2: branches on disjoint devices, same math
        let pc = ParallelConfig::new(2, 1, 1, 1);
        let mut s2 = Session::new(&rt, BlockVariant::AdaLn, a100_node(), pc).unwrap();
        let r2 = generate(&mut s2, Method::Serial, &p).unwrap();
        assert!(r2.latent.allclose(&r1.latent, 1e-5));
        // cfg parallel must be faster (branches in parallel) despite the
        // per-step allgather
        assert!(
            r2.makespan < r1.makespan,
            "cfg=2 {} !< cfg=1 {}",
            r2.makespan,
            r1.makespan
        );
        assert!(s2.ledger.count("cfg_allgather") > 0);
    }

    #[test]
    fn pipefusion_full_run_bounded_divergence() {
        let Some(rt) = setup() else { return };
        let p = GenParams { steps: 4, guidance: 2.0, ..Default::default() };
        let e0 = generate_reference(&rt, BlockVariant::AdaLn, &p).unwrap();
        let pc = ParallelConfig::new(1, 2, 1, 1).with_patches(4);
        let mut sess = Session::new(&rt, BlockVariant::AdaLn, l40_cluster(1), pc).unwrap();
        let r = generate(&mut sess, Method::PipeFusion, &p).unwrap();
        let mse = r.latent.mse(&e0).unwrap();
        // staleness costs a small, bounded divergence (Fig 19 analogue)
        assert!(mse < 1e-2, "pipefusion mse too large: {mse}");
        assert!(mse > 0.0, "pipefusion should not be bit-exact");
    }
}
