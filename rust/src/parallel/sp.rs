//! Sequence parallelism: SP-Ulysses and SP-Ring (paper §4.1.1), including
//! the in-context-conditioning split of Fig 3 (both text and image are
//! sharded along the sequence so MM-DiT models stay load-balanced).
//!
//! Numerics are *exact*: each layer runs the two-phase qkv/exchange/post
//! entrypoints so every rank attends to the current step's full-sequence
//! K/V — the property the paper relies on for SP correctness. The two
//! flavours share the execution path; they differ in the communication
//! charged (Ulysses: 4 All2All per layer; Ring: (n-1) K/V block rotations
//! overlapped with attention) — set by `ParallelConfig::{ulysses, ring}`.

use crate::config::model::BlockVariant;
use crate::parallel::{
    flops, split_offsets, sp_layer, BranchCtx, Session, Strategy,
};
use crate::tensor::Tensor;
use crate::Result;

/// Pure sequence parallelism (degree = pc.ulysses * pc.ring).
pub struct SequenceParallel;

impl Strategy for SequenceParallel {
    fn name(&self) -> String {
        "sp".into()
    }

    fn denoise(
        &mut self,
        sess: &mut Session,
        x: &Tensor,
        t: f32,
        _step: usize,
        branch: &BranchCtx,
    ) -> Result<Tensor> {
        let model = sess.model.clone();
        let nsp = sess.pc.sp_degree();
        let pf = nsp; // patch factor = sp shards (whole image is the patch)
        let ranks: Vec<usize> = branch.ranks[..nsp].to_vec();
        let t_emb = model.t_cond(sess.rt, t)?;
        let cond = branch.cond(model.variant, &t_emb)?;

        // shard image (and text for in-context models) — Fig 3
        let img_offs = split_offsets(model.s_img, nsp);
        let mut x_img: Vec<Tensor> = Vec::with_capacity(nsp);
        for (i, &dev) in ranks.iter().enumerate() {
            let (off, len) = img_offs[i];
            let latent = x.slice_rows(off, off + len)?;
            x_img.push(model.embed_patch(sess.rt, pf, &latent, off)?);
            sess.charge_compute(dev, flops::embed_flops(len, model.c_latent, model.d));
        }
        let mut x_txt: Option<Vec<Tensor>> = if model.variant == BlockVariant::MmDit {
            let offs = split_offsets(model.s_txt, nsp);
            Some(
                offs.iter()
                    .map(|&(o, l)| branch.txt.slice_rows(o, o + l))
                    .collect::<Result<Vec<_>>>()?,
            )
        } else {
            None
        };
        let txt_mem =
            if model.variant == BlockVariant::Cross { Some(branch.txt.clone()) } else { None };

        // per-layer two-phase SP; skip variant carries the U-ViT skip stack
        let zero_base = (
            Tensor::zeros(&[model.attn_seq(), model.d]),
            Tensor::zeros(&[model.attn_seq(), model.d]),
        );
        let bases: Vec<(Tensor, Tensor)> = vec![zero_base; nsp];
        let mut skip_stack: Vec<Vec<Tensor>> = Vec::new();
        let half = model.layers / 2;
        for layer in 0..model.layers {
            let is_skip = model.variant == BlockVariant::Skip;
            let skip_rows: Option<Vec<Tensor>> = if is_skip && layer >= half {
                Some(skip_stack.pop().expect("skip stack underflow"))
            } else {
                None
            };
            let out = sp_layer(
                sess,
                &ranks,
                layer,
                pf,
                &x_img,
                x_txt.as_deref(),
                skip_rows.as_deref(),
                &cond,
                txt_mem.as_ref(),
                &bases,
                0,
                0,
            )?;
            x_img = out.x_img;
            if let Some(t) = out.x_txt {
                x_txt = Some(t);
            }
            if is_skip && layer < half {
                skip_stack.push(x_img.clone());
            }
        }

        // final layer per shard; assemble eps (element-wise scheduler update
        // is shard-local in the real system; assembling here is free)
        let mut eps_parts = Vec::with_capacity(nsp);
        for (i, &dev) in ranks.iter().enumerate() {
            eps_parts.push(model.final_patch(sess.rt, pf, &x_img[i], &cond)?);
            sess.charge_compute(
                dev,
                flops::final_flops(img_offs[i].1, model.c_latent, model.d),
            );
        }
        Tensor::concat_rows(&eps_parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::config::parallel::ParallelConfig;
    use crate::model::TextEncoder;
    use crate::parallel::serial::Serial;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn setup() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    fn branch(rt: &Runtime, n: usize) -> BranchCtx {
        let enc = TextEncoder::new(&rt.host_weights, 32).unwrap();
        let txt = enc.embed("sp test prompt");
        BranchCtx { idx: 0, ranks: (0..n).collect(), txt_pool: txt.mean_rows(), txt }
    }

    #[test]
    fn ulysses_exact_vs_serial_adaln() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(3));
        let mut s = Session::new(&rt, BlockVariant::AdaLn, a100_node(), ParallelConfig::serial())
            .unwrap();
        let e0 = Serial.denoise(&mut s, &x, 700.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 1, 2, 1);
        let mut s2 = Session::new(&rt, BlockVariant::AdaLn, a100_node(), pc).unwrap();
        let e1 = SequenceParallel.denoise(&mut s2, &x, 700.0, 0, &branch(&rt, 2)).unwrap();
        assert!(
            e1.allclose(&e0, 5e-4),
            "ulysses(2) != serial: {}",
            e1.max_abs_diff(&e0).unwrap()
        );
        assert!(s2.ledger.count("all_to_all") >= 8);
    }

    #[test]
    fn ring_exact_vs_serial_mmdit() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(4));
        let mut s = Session::new(&rt, BlockVariant::MmDit, a100_node(), ParallelConfig::serial())
            .unwrap();
        let e0 = Serial.denoise(&mut s, &x, 300.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 1, 1, 4);
        let mut s2 = Session::new(&rt, BlockVariant::MmDit, l40_cluster(1), pc).unwrap();
        let e1 = SequenceParallel.denoise(&mut s2, &x, 300.0, 0, &branch(&rt, 4)).unwrap();
        assert!(
            e1.allclose(&e0, 5e-4),
            "ring(4) != serial: {}",
            e1.max_abs_diff(&e0).unwrap()
        );
        assert!(s2.ledger.count("ring_kv") >= 8);
    }

    #[test]
    fn usp_exact_vs_serial_cross() {
        // hybrid ulysses x ring (USP) on the cross-attention variant
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(5));
        let mut s = Session::new(&rt, BlockVariant::Cross, a100_node(), ParallelConfig::serial())
            .unwrap();
        let e0 = Serial.denoise(&mut s, &x, 200.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 1, 2, 2);
        let mut s2 = Session::new(&rt, BlockVariant::Cross, a100_node(), pc).unwrap();
        let e1 = SequenceParallel.denoise(&mut s2, &x, 200.0, 0, &branch(&rt, 4)).unwrap();
        assert!(
            e1.allclose(&e0, 5e-4),
            "usp(2x2) != serial: {}",
            e1.max_abs_diff(&e0).unwrap()
        );
        assert!(s2.ledger.count("all_to_all") > 0);
        assert!(s2.ledger.count("ring_kv") > 0);
    }

    #[test]
    fn skip_variant_sp_exact() {
        let Some(rt) = setup() else { return };
        let x = Tensor::randn(&[256, 4], &mut Rng::new(6));
        let mut s = Session::new(&rt, BlockVariant::Skip, a100_node(), ParallelConfig::serial())
            .unwrap();
        let e0 = Serial.denoise(&mut s, &x, 600.0, 0, &branch(&rt, 1)).unwrap();

        let pc = ParallelConfig::new(1, 1, 2, 1);
        let mut s2 = Session::new(&rt, BlockVariant::Skip, a100_node(), pc).unwrap();
        let e1 = SequenceParallel.denoise(&mut s2, &x, 600.0, 0, &branch(&rt, 2)).unwrap();
        assert!(
            e1.allclose(&e0, 5e-4),
            "skip sp(2) != serial: {}",
            e1.max_abs_diff(&e0).unwrap()
        );
    }
}
