//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the default build
//! of this crate has zero external dependencies so the tier-1 gate runs
//! hermetically on stock CI runners. The `Xla` variant only exists under
//! the `pjrt` feature, which is the one build that links the `xla` crate.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),
    Io(std::io::Error),
    Json(String),
    Manifest(String),
    Weights(String),
    Config(String),
    Shape(String),
    Comm(String),
    Engine(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Weights(m) => write!(f, "weights error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Comm(m) => write!(f, "comm error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_kind() {
        assert_eq!(Error::config("bad").to_string(), "config error: bad");
        assert_eq!(Error::shape("dim").to_string(), "shape error: dim");
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().starts_with("io error:"));
    }
}
