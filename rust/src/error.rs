//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("weights error: {0}")]
    Weights(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("shape error: {0}")]
    Shape(String),

    #[error("comm error: {0}")]
    Comm(String),

    #[error("engine error: {0}")]
    Engine(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}
