//! The PJRT execution backend (feature `pjrt`): compile cache + resident
//! weight buffers + marshalling over the `xla` crate's CPU client.
//!
//! The client is `Rc`-based (not `Send`); all PJRT execution stays on the
//! leader thread, matching the coordinator's leader-pinned event loop.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::runtime::artifact::{EntryPoint, Manifest};
use crate::runtime::executor::{ArgValue, ExecBackend, ExecStats};
use crate::runtime::weights::HostWeights;
use crate::tensor::Tensor;
use crate::{Error, Result};

pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    total_layers: usize,
    host_weights: Rc<HostWeights>,
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weight_bufs: RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
}

impl PjrtBackend {
    pub fn new(manifest: &Manifest, host_weights: Rc<HostWeights>) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            client: xla::PjRtClient::cpu()?,
            dir: manifest.dir.clone(),
            total_layers: manifest.model_dim("layers").unwrap_or(8),
            host_weights,
            execs: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
        })
    }

    /// Get (or compile) the executable for an entrypoint.
    fn executable(&self, entry: &EntryPoint) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(&entry.name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.execs.borrow_mut().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Get (or upload) the resident device buffer for a weight tensor.
    fn weight_buffer(&self, name: &str, stats: &mut ExecStats) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let t = self.host_weights.get(name)?;
        let buf = self.client.buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)?;
        let rc = Rc::new(buf);
        self.weight_bufs.borrow_mut().insert(name.to_string(), rc.clone());
        stats.weight_uploads += 1;
        Ok(rc)
    }

    fn upload_arg(&self, a: &ArgValue<'_>) -> Result<xla::PjRtBuffer> {
        match a {
            ArgValue::F32(t) => {
                Ok(self.client.buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)?)
            }
            ArgValue::I32(v) => Ok(self.client.buffer_from_host_buffer::<i32>(&[*v], &[], None)?),
        }
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn requires_manifest(&self) -> bool {
        true
    }

    fn execute(
        &self,
        entry_name: &str,
        entry: Option<&EntryPoint>,
        stage: usize,
        data: &[ArgValue<'_>],
        stats: &mut ExecStats,
    ) -> Result<Vec<Tensor>> {
        let entry = entry.ok_or_else(|| {
            Error::Manifest(format!("entrypoint '{entry_name}' not in manifest"))
        })?;
        let exe = self.executable(entry)?;

        let t0 = std::time::Instant::now();
        let mut args: Vec<Rc<xla::PjRtBuffer>> =
            Vec::with_capacity(data.len() + entry.weights.len());
        for a in data {
            args.push(Rc::new(self.upload_arg(a)?));
        }
        for wr in &entry.weights {
            let name = wr.resolve(stage, entry.layers_per_stage, self.total_layers);
            args.push(self.weight_buffer(&name, stats)?);
        }
        let marshal = t0.elapsed().as_nanos();

        let t1 = std::time::Instant::now();
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let result = exe.execute_b(&arg_refs)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let v = p.to_vec::<f32>()?;
            out.push(Tensor::new(dims, v)?);
        }
        let exec = t1.elapsed().as_nanos();

        stats.marshal_ns += marshal;
        stats.exec_ns += exec;
        Ok(out)
    }

    fn warm(&self, entry: &EntryPoint) -> Result<()> {
        self.executable(entry)?;
        Ok(())
    }

    fn compiled_count(&self) -> usize {
        self.execs.borrow().len()
    }
}
