//! The PJRT executor: compile cache + resident weight buffers + marshalling.
//!
//! Hot-path contract: weights are uploaded to device once (keyed by resolved
//! tensor name) and passed by reference via `execute_b`; per-call uploads are
//! limited to the activation/KV data arguments.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::runtime::artifact::{DType, EntryPoint, Manifest};
use crate::runtime::weights::HostWeights;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A data argument for an entrypoint call. Tensors are *borrowed*: the
/// call uploads straight from the caller's buffer, so the hot path never
/// deep-copies activations/KV on the host (§Perf iteration 4).
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    F32(&'a Tensor),
    I32(i32),
}

/// Execution statistics (profiling the L3 hot path, §Perf).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: usize,
    pub exec_ns: u128,
    pub marshal_ns: u128,
    pub weight_uploads: usize,
}

/// The runtime: one PJRT CPU client, shared compile cache, resident weights.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub host_weights: Rc<HostWeights>,
    execs: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weight_bufs: RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
    pub stats: RefCell<ExecStats>,
}

impl Runtime {
    /// Load manifest + weights from the artifacts directory and connect the
    /// PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let weights =
            HostWeights::load(manifest.dir.join(&manifest.weights_file))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            host_weights: Rc::new(weights),
            execs: RefCell::new(HashMap::new()),
            weight_bufs: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    /// Get (or compile) the executable for an entrypoint.
    fn executable(&self, entry: &EntryPoint) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(&entry.name) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.execs.borrow_mut().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Get (or upload) the resident device buffer for a weight tensor.
    fn weight_buffer(&self, name: &str) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let t = self.host_weights.get(name)?;
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)?;
        let rc = Rc::new(buf);
        self.weight_bufs.borrow_mut().insert(name.to_string(), rc.clone());
        self.stats.borrow_mut().weight_uploads += 1;
        Ok(rc)
    }

    fn upload_arg(&self, a: &ArgValue<'_>) -> Result<xla::PjRtBuffer> {
        match a {
            ArgValue::F32(t) => {
                Ok(self.client.buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)?)
            }
            ArgValue::I32(v) => {
                Ok(self.client.buffer_from_host_buffer::<i32>(&[*v], &[], None)?)
            }
        }
    }

    /// Execute an entrypoint. `stage` positions stage-relative weight refs.
    /// Returns the tuple of outputs as host tensors.
    pub fn call(&self, entry_name: &str, stage: usize, data: &[ArgValue<'_>]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entry(entry_name)?;
        if data.len() != entry.data_inputs.len() {
            return Err(Error::Engine(format!(
                "{entry_name}: expected {} data args, got {}",
                entry.data_inputs.len(),
                data.len()
            )));
        }
        // shape-check data args against the manifest
        for (a, (name, dims, dt)) in data.iter().zip(&entry.data_inputs) {
            match (a, dt) {
                (ArgValue::F32(t), DType::F32) => {
                    if &t.dims != dims {
                        return Err(Error::shape(format!(
                            "{entry_name}.{name}: expected {:?}, got {:?}",
                            dims, t.dims
                        )));
                    }
                }
                (ArgValue::I32(_), DType::I32) => {}
                _ => {
                    return Err(Error::shape(format!(
                        "{entry_name}.{name}: dtype mismatch"
                    )))
                }
            }
        }
        let exe = self.executable(entry)?;
        let total_layers = self.manifest.model_dim("layers").unwrap_or(8);

        let t0 = std::time::Instant::now();
        let mut args: Vec<Rc<xla::PjRtBuffer>> = Vec::with_capacity(
            data.len() + entry.weights.len(),
        );
        for a in data {
            args.push(Rc::new(self.upload_arg(a)?));
        }
        for wr in &entry.weights {
            let name = wr.resolve(stage, entry.layers_per_stage, total_layers);
            args.push(self.weight_buffer(&name)?);
        }
        let marshal = t0.elapsed().as_nanos();

        let t1 = std::time::Instant::now();
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let result = exe.execute_b(&arg_refs)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let shape = p.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let v = p.to_vec::<f32>()?;
            out.push(Tensor::new(dims, v)?);
        }
        let exec = t1.elapsed().as_nanos();

        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.marshal_ns += marshal;
        st.exec_ns += exec;
        Ok(out)
    }

    /// Warm the compile cache for a set of entrypoints (leader startup).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let e = self.manifest.entry(n)?.clone();
            self.executable(&e)?;
        }
        Ok(())
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.execs.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn t_embed_executes() {
        let Some(rt) = runtime() else { return };
        // wrong dtype must be rejected
        assert!(rt.call("adaln_t_embed", 0, &[ArgValue::I32(0)]).is_err());
        let half = Tensor::scalar(0.5);
        let out = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![192]);
        // deterministic across calls
        let again = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out[0], again[0]);
    }

    #[test]
    fn stage_weight_residency() {
        let Some(rt) = runtime() else { return };
        let d = 192;
        let x = Tensor::zeros(&[32, d]);
        let cond = Tensor::zeros(&[d]);
        let kb = Tensor::zeros(&[2, 256, d]);
        let args = vec![
            ArgValue::F32(&x),
            ArgValue::F32(&cond),
            ArgValue::F32(&kb),
            ArgValue::F32(&kb),
            ArgValue::I32(0),
        ];
        rt.call("adaln_stage_L2_p8", 0, &args).unwrap();
        let ups = rt.stats.borrow().weight_uploads;
        assert_eq!(ups, 20); // 2 layers x 10 params
        rt.call("adaln_stage_L2_p8", 0, &args).unwrap();
        assert_eq!(rt.stats.borrow().weight_uploads, ups, "weights re-uploaded");
        // different stage -> different weights
        rt.call("adaln_stage_L2_p8", 1, &args).unwrap();
        assert_eq!(rt.stats.borrow().weight_uploads, ups + 20);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(rt) = runtime() else { return };
        let wrong = Tensor::zeros(&[1]);
        let bad = vec![ArgValue::F32(&wrong)];
        assert!(rt.call("adaln_t_embed", 0, &bad).is_err());
    }
}
