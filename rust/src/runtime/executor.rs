//! The execution runtime: manifest + resident host weights + a pluggable
//! entrypoint-execution backend.
//!
//! The [`ExecBackend`] trait is the seam between the engine and whatever
//! actually runs an entrypoint:
//!
//! * `PjrtBackend` (feature `pjrt`, `runtime/pjrt.rs`) — compiles the AOT
//!   HLO text through the PJRT CPU client, keeps weights resident on
//!   device, and executes for real. Requires the vendored `xla` crate.
//! * [`SimBackend`](crate::runtime::sim::SimBackend) (default,
//!   `runtime/sim.rs`) — a hermetic host simulation that returns
//!   deterministic pseudo-activations with the contract output shapes, so
//!   the whole serving stack (batching, routing, virtual-time accounting,
//!   VAE stitching) runs on a stock CI runner with zero native deps.
//!
//! Hot-path contract (PJRT): weights are uploaded to device once (keyed by
//! resolved tensor name) and passed by reference via `execute_b`; per-call
//! uploads are limited to the activation/KV data arguments.

use std::cell::RefCell;
use std::rc::Rc;

use crate::runtime::artifact::{DType, EntryPoint, Manifest};
use crate::runtime::sim::SimBackend;
use crate::runtime::weights::HostWeights;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// A data argument for an entrypoint call. Tensors are *borrowed*: the
/// call uploads straight from the caller's buffer, so the hot path never
/// deep-copies activations/KV on the host (§Perf iteration 4).
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    F32(&'a Tensor),
    I32(i32),
}

/// Execution statistics (profiling the L3 hot path, §Perf).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: usize,
    pub exec_ns: u128,
    pub marshal_ns: u128,
    pub weight_uploads: usize,
}

/// Entrypoint execution, behind a trait object so backends can be swapped
/// without touching the engine. `entry` is the manifest declaration when
/// one exists; backends that can derive shapes from the entrypoint naming
/// grid (the simulator) may execute undeclared entries.
pub trait ExecBackend {
    fn name(&self) -> &'static str;

    /// Whether execution requires the entry declared in the manifest.
    fn requires_manifest(&self) -> bool;

    fn execute(
        &self,
        entry_name: &str,
        entry: Option<&EntryPoint>,
        stage: usize,
        data: &[ArgValue<'_>],
        stats: &mut ExecStats,
    ) -> Result<Vec<Tensor>>;

    /// Warm caches for an entrypoint (compile for PJRT).
    fn warm(&self, entry: &EntryPoint) -> Result<()>;

    /// Number of compiled/warmed executables resident.
    fn compiled_count(&self) -> usize;
}

/// The runtime: manifest, host weights, stats, and the execution backend.
pub struct Runtime {
    pub manifest: Manifest,
    pub host_weights: Rc<HostWeights>,
    pub stats: RefCell<ExecStats>,
    backend: Box<dyn ExecBackend>,
}

impl Runtime {
    /// Load manifest + weights from the artifacts directory. With the
    /// `pjrt` feature the PJRT CPU client executes the HLO artifacts;
    /// otherwise the hermetic simulator stands in.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let weights = Rc::new(HostWeights::load(manifest.dir.join(&manifest.weights_file))?);
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn ExecBackend> =
            Box::new(crate::runtime::pjrt::PjrtBackend::new(&manifest, weights.clone())?);
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn ExecBackend> = Box::new(SimBackend::from_manifest(&manifest)?);
        Ok(Runtime {
            manifest,
            host_weights: weights,
            stats: RefCell::new(ExecStats::default()),
            backend,
        })
    }

    /// Load real artifacts when `dir/manifest.json` exists (errors on a
    /// corrupt manifest rather than hiding it), otherwise fall back to the
    /// hermetic simulated runtime. The one probe every artifacts-optional
    /// entry point (CLI serve, examples, hermetic tests) shares.
    pub fn load_or_simulated(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref();
        if dir.join("manifest.json").exists() {
            Runtime::load(dir)
        } else {
            eprintln!("(artifacts not built — serving on the simulated backend)");
            Ok(Runtime::simulated())
        }
    }

    /// A fully self-contained runtime: no artifacts on disk, the tiny
    /// family's dimensions synthesized in memory, execution through the
    /// simulator. This is what hermetic CI (and any checkout without
    /// `make artifacts`) serves with — available under every feature set.
    pub fn simulated() -> Runtime {
        let (manifest, weights) = crate::runtime::sim::simulated_artifacts();
        Runtime {
            manifest,
            host_weights: Rc::new(weights),
            stats: RefCell::new(ExecStats::default()),
            backend: Box::new(SimBackend::tiny()),
        }
    }

    /// Which backend executes entrypoints ("pjrt" or "sim").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execute an entrypoint. `stage` positions stage-relative weight refs.
    /// Returns the tuple of outputs as host tensors.
    pub fn call(
        &self,
        entry_name: &str,
        stage: usize,
        data: &[ArgValue<'_>],
    ) -> Result<Vec<Tensor>> {
        let entry = self.manifest.entries.get(entry_name);
        match entry {
            Some(e) => validate_args(e, data)?,
            // an undeclared entry is only legal on the entry-free simulated
            // manifest: when a real manifest IS loaded, a name the grid
            // doesn't declare is a bug (typo/drift) on every backend —
            // letting the simulator fabricate outputs for it would defeat
            // the anti-bit-rot gate
            None if self.backend.requires_manifest() || !self.manifest.entries.is_empty() => {
                return Err(Error::Manifest(format!(
                    "entrypoint '{entry_name}' not in manifest (rebuild artifacts?)"
                )))
            }
            None => {}
        }
        let out =
            self.backend.execute(entry_name, entry, stage, data, &mut self.stats.borrow_mut())?;
        // counted on success only, so per-call overhead stats (exec_ns /
        // calls) are not skewed by failed executions
        self.stats.borrow_mut().calls += 1;
        Ok(out)
    }

    /// Warm the compile cache for a set of entrypoints (leader startup).
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            match self.manifest.entries.get(*n) {
                Some(e) => self.backend.warm(e)?,
                None if self.backend.requires_manifest() || !self.manifest.entries.is_empty() => {
                    return Err(Error::Manifest(format!(
                        "entrypoint '{n}' not in manifest (rebuild artifacts?)"
                    )))
                }
                None => {}
            }
        }
        Ok(())
    }

    /// Number of compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.backend.compiled_count()
    }
}

/// Shape/dtype-check data args against the manifest declaration. Shared by
/// every backend so a bad call fails identically with or without PJRT.
pub(crate) fn validate_args(entry: &EntryPoint, data: &[ArgValue<'_>]) -> Result<()> {
    if data.len() != entry.data_inputs.len() {
        return Err(Error::Engine(format!(
            "{}: expected {} data args, got {}",
            entry.name,
            entry.data_inputs.len(),
            data.len()
        )));
    }
    for (a, (name, dims, dt)) in data.iter().zip(&entry.data_inputs) {
        match (a, dt) {
            (ArgValue::F32(t), DType::F32) => {
                if &t.dims != dims {
                    return Err(Error::shape(format!(
                        "{}.{name}: expected {:?}, got {:?}",
                        entry.name, dims, t.dims
                    )));
                }
            }
            (ArgValue::I32(_), DType::I32) => {}
            _ => return Err(Error::shape(format!("{}.{name}: dtype mismatch", entry.name))),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn t_embed_executes() {
        let Some(rt) = runtime() else { return };
        // wrong dtype must be rejected
        assert!(rt.call("adaln_t_embed", 0, &[ArgValue::I32(0)]).is_err());
        let half = Tensor::scalar(0.5);
        let out = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![192]);
        // deterministic across calls
        let again = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out[0], again[0]);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn stage_weight_residency() {
        let Some(rt) = runtime() else { return };
        let d = 192;
        let x = Tensor::zeros(&[32, d]);
        let cond = Tensor::zeros(&[d]);
        let kb = Tensor::zeros(&[2, 256, d]);
        let args = vec![
            ArgValue::F32(&x),
            ArgValue::F32(&cond),
            ArgValue::F32(&kb),
            ArgValue::F32(&kb),
            ArgValue::I32(0),
        ];
        rt.call("adaln_stage_L2_p8", 0, &args).unwrap();
        let ups = rt.stats.borrow().weight_uploads;
        assert_eq!(ups, 20); // 2 layers x 10 params
        rt.call("adaln_stage_L2_p8", 0, &args).unwrap();
        assert_eq!(rt.stats.borrow().weight_uploads, ups, "weights re-uploaded");
        // different stage -> different weights
        rt.call("adaln_stage_L2_p8", 1, &args).unwrap();
        assert_eq!(rt.stats.borrow().weight_uploads, ups + 20);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(rt) = runtime() else { return };
        let wrong = Tensor::zeros(&[1]);
        let bad = vec![ArgValue::F32(&wrong)];
        assert!(rt.call("adaln_t_embed", 0, &bad).is_err());
    }

    #[test]
    fn simulated_runtime_is_self_contained() {
        let rt = Runtime::simulated();
        assert_eq!(rt.backend_name(), "sim");
        assert_eq!(rt.manifest.model_dim("d").unwrap(), 192);
        // host-side weights the engine reads directly are present
        assert_eq!(rt.host_weights.get("shared.txt_table").unwrap().dims, vec![256, 192]);
        assert_eq!(rt.host_weights.get("adaln.pos").unwrap().dims, vec![256, 192]);
        // executes an undeclared entry by the naming-grid shape rules
        let half = Tensor::scalar(0.5);
        let out = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out[0].dims, vec![192]);
        let again = rt.call("adaln_t_embed", 0, &[ArgValue::F32(&half)]).unwrap();
        assert_eq!(out[0], again[0], "sim execution must be deterministic");
        assert_eq!(rt.stats.borrow().calls, 2);
        assert!(rt.compiled_count() >= 1);
    }
}
