//! weights.bin loader ("XTW1" format, see python/compile/params.py).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::tensor::Tensor;
use crate::{Error, Result};

/// All model weights, host-side.
#[derive(Debug)]
pub struct HostWeights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl HostWeights {
    pub fn load(path: impl AsRef<Path>) -> Result<HostWeights> {
        let mut f = std::fs::File::open(path.as_ref()).map_err(|e| {
            Error::Weights(format!("cannot open {}: {e}", path.as_ref().display()))
        })?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<HostWeights> {
        let mut c = Cursor { b: buf, i: 0 };
        if c.take(4)? != b"XTW1" {
            return Err(Error::Weights("bad magic (expected XTW1)".into()));
        }
        let count = c.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = c.u16()? as usize;
            let name = String::from_utf8(c.take(nlen)?.to_vec())
                .map_err(|e| Error::Weights(e.to_string()))?;
            let ndim = c.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(c.u32()? as usize);
            }
            let n: usize = dims.iter().product();
            let raw = c.take(n * 4)?;
            let mut data = Vec::with_capacity(n);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            tensors.insert(name, Tensor::new(dims, data)?);
        }
        if c.i != buf.len() {
            return Err(Error::Weights(format!("{} trailing bytes", buf.len() - c.i)));
        }
        Ok(HostWeights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Weights(format!("weight '{name}' not found")))
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.size_bytes()).sum()
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::Weights("truncated weights file".into()));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(entries: &[(&str, &[usize], &[f32])]) -> Vec<u8> {
        let mut out = b"XTW1".to_vec();
        out.extend((entries.len() as u32).to_le_bytes());
        for (name, dims, data) in entries {
            out.extend((name.len() as u16).to_le_bytes());
            out.extend(name.as_bytes());
            out.push(dims.len() as u8);
            for &d in *dims {
                out.extend((d as u32).to_le_bytes());
            }
            for &v in *data {
                out.extend(v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn parse_synthetic() {
        let buf = encode(&[
            ("a.w", &[2, 2], &[1.0, 2.0, 3.0, 4.0]),
            ("b", &[3], &[5.0, 6.0, 7.0]),
        ]);
        let w = HostWeights::parse(&buf).unwrap();
        assert_eq!(w.get("a.w").unwrap().dims, vec![2, 2]);
        assert_eq!(w.get("b").unwrap().data, vec![5.0, 6.0, 7.0]);
        assert!(w.get("missing").is_err());
        assert_eq!(w.total_bytes(), 28);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(HostWeights::parse(b"NOPE").is_err());
        let mut buf = encode(&[("a", &[4], &[0.0; 4])]);
        buf.truncate(buf.len() - 3);
        assert!(HostWeights::parse(&buf).is_err());
    }

    #[test]
    fn loads_real_weights_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights.bin");
        if !p.exists() {
            return;
        }
        let w = HostWeights::load(&p).unwrap();
        assert!(w.tensors.len() > 400);
        let q = w.get("adaln.L0.Wqkv").unwrap();
        assert_eq!(q.dims, vec![192, 3 * 192]);
        assert_eq!(w.get("shared.txt_table").unwrap().dims, vec![256, 192]);
    }
}
