//! Host-simulated execution backend (the default, dependency-free build).
//!
//! `SimBackend` executes any entrypoint of the AOT naming grid
//! (`{variant}_{kind}_L{ls}_p{pf}`, embed/final/qkv/post/t_embed, the VAE
//! strip decoders) by *shape rule*: output tensors carry the exact contract
//! shapes the real HLO artifacts produce, filled with deterministic
//! pseudo-activations derived from (entry name, stage, input data). That
//! makes the entire serving stack — admission, batching, routing, the
//! denoising loop, virtual-time accounting, VAE stitching — runnable and
//! bit-reproducible on a machine with no PJRT, no artifacts and no network.
//!
//! What the simulator is NOT: numerically faithful. Cross-strategy
//! exactness/staleness properties (SP == serial, Fig 19 divergence) only
//! hold over the real artifacts and stay gated on `artifacts/` + `pjrt`.
//!
//! Determinism contract: outputs are a pure function of the call
//! `(entry_name, stage, data)`. Identical traces replay identically;
//! different seeds/prompts diverge because their latents/embeddings differ.

use std::cell::RefCell;
use std::collections::BTreeSet;

use crate::runtime::artifact::{EntryPoint, Manifest};
use crate::runtime::executor::{ArgValue, ExecBackend, ExecStats};
use crate::runtime::weights::HostWeights;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Model dimensions the shape rules need.
#[derive(Debug, Clone, Copy)]
struct SimDims {
    d: usize,
    c_latent: usize,
    latent_hw: usize,
}

pub struct SimBackend {
    dims: SimDims,
    warmed: RefCell<BTreeSet<String>>,
}

impl SimBackend {
    /// Dimensions from a loaded manifest (checkout with artifacts but no
    /// PJRT: same shapes as the real entrypoints).
    pub fn from_manifest(m: &Manifest) -> Result<SimBackend> {
        Ok(SimBackend {
            dims: SimDims {
                d: m.model_dim("d")?,
                c_latent: m.model_dim("c_latent")?,
                latent_hw: m.model_dim("latent_hw")?,
            },
            warmed: RefCell::new(BTreeSet::new()),
        })
    }

    /// The tiny family's native dimensions (no manifest at all).
    pub fn tiny() -> SimBackend {
        SimBackend {
            dims: SimDims { d: 192, c_latent: 4, latent_hw: 16 },
            warmed: RefCell::new(BTreeSet::new()),
        }
    }

    /// Output shapes for an entrypoint, per the AOT naming-grid contract.
    fn output_shapes(&self, name: &str, data: &[ArgValue<'_>]) -> Result<Vec<Vec<usize>>> {
        let SimDims { d, c_latent, latent_hw } = self.dims;
        if name.ends_with("_t_embed") {
            return Ok(vec![vec![d]]);
        }
        if name.contains("_qkv_p") {
            if name.starts_with("mmdit") {
                let pt = rows(data, 0, name)?;
                let pi = rows(data, 1, name)?;
                return Ok(vec![
                    vec![pt, d],
                    vec![pt, d],
                    vec![pt, d],
                    vec![pi, d],
                    vec![pi, d],
                    vec![pi, d],
                ]);
            }
            let p = rows(data, 0, name)?;
            let n_out = if name.starts_with("skip_dec") { 4 } else { 3 };
            return Ok(vec![vec![p, d]; n_out]);
        }
        if name.contains("_post_p") {
            if name.starts_with("mmdit") {
                let pt = rows(data, 0, name)?;
                let pi = rows(data, 1, name)?;
                return Ok(vec![vec![pt, d], vec![pi, d]]);
            }
            return Ok(vec![vec![rows(data, 0, name)?, d]]);
        }
        if name.contains("_embed_p") {
            return Ok(vec![vec![rows(data, 0, name)?, d]]);
        }
        if name.contains("_final_p") {
            return Ok(vec![vec![rows(data, 0, name)?, c_latent]]);
        }
        if name == "vae_decode" {
            return Ok(vec![vec![8 * latent_hw, 8 * latent_hw, 3]]);
        }
        if let Some(rest) = name.strip_prefix("vae_decode_rows") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            let hp: usize = digits
                .parse()
                .map_err(|_| Error::Engine(format!("sim: bad vae strip entry '{name}'")))?;
            return Ok(vec![vec![8 * hp, 8 * latent_hw, 3]]);
        }
        if let Some(ls) = stage_layers(name) {
            if name.starts_with("mmdit_stage") {
                let pt = rows(data, 0, name)?;
                let pi = rows(data, 1, name)?;
                return Ok(vec![
                    vec![pt, d],
                    vec![pi, d],
                    vec![ls, pt + pi, d],
                    vec![ls, pt + pi, d],
                ]);
            }
            let p = rows(data, 0, name)?;
            if name.starts_with("skip_enc") {
                return Ok(vec![vec![p, d], vec![ls, p, d], vec![ls, p, d], vec![ls, p, d]]);
            }
            // adaln_stage / cross_stage / skip_full / skip_dec
            return Ok(vec![vec![p, d], vec![ls, p, d], vec![ls, p, d]]);
        }
        Err(Error::Engine(format!("sim backend: unknown entrypoint pattern '{name}'")))
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn requires_manifest(&self) -> bool {
        false
    }

    fn execute(
        &self,
        entry_name: &str,
        _entry: Option<&EntryPoint>,
        stage: usize,
        data: &[ArgValue<'_>],
        _stats: &mut ExecStats,
    ) -> Result<Vec<Tensor>> {
        let shapes = self.output_shapes(entry_name, data)?;
        let mut seed = fnv1a(0xCBF2_9CE4_8422_2325, entry_name.as_bytes());
        seed = fnv1a(seed, &(stage as u64).to_le_bytes());
        for a in data {
            seed = hash_arg(seed, a);
        }
        self.warmed.borrow_mut().insert(entry_name.to_string());
        let mut out = Vec::with_capacity(shapes.len());
        for (i, dims) in shapes.into_iter().enumerate() {
            let n: usize = dims.iter().product();
            out.push(Tensor::new(dims, fill(seed.wrapping_add(i as u64), n))?);
        }
        Ok(out)
    }

    fn warm(&self, entry: &EntryPoint) -> Result<()> {
        self.warmed.borrow_mut().insert(entry.name.clone());
        Ok(())
    }

    fn compiled_count(&self) -> usize {
        self.warmed.borrow().len()
    }
}

/// `ls` of a stage-grid entry (`..._L{ls}_p{pf}`), `None` if not one.
fn stage_layers(name: &str) -> Option<usize> {
    let i = name.rfind("_L")?;
    let rest = &name[i + 2..];
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || !rest[digits.len()..].starts_with("_p") {
        return None;
    }
    digits.parse().ok()
}

/// Leading dim of the i-th data arg (the patch-row count).
fn rows(data: &[ArgValue<'_>], i: usize, name: &str) -> Result<usize> {
    match data.get(i) {
        Some(ArgValue::F32(t)) => t
            .dims
            .first()
            .copied()
            .ok_or_else(|| Error::shape(format!("sim: {name} arg {i} is a scalar"))),
        _ => Err(Error::Engine(format!("sim: {name} needs a tensor at data arg {i}"))),
    }
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Fold a data arg into the seed. Large tensors are sampled (dims, length
/// and 64 strided elements): cheap, still a pure function of the inputs in
/// practice — diffusion latents/embeddings differ everywhere when they
/// differ at all.
fn hash_arg(mut h: u64, a: &ArgValue<'_>) -> u64 {
    match a {
        ArgValue::I32(v) => fnv1a(h, &v.to_le_bytes()),
        ArgValue::F32(t) => {
            for &dim in &t.dims {
                h = fnv1a(h, &(dim as u64).to_le_bytes());
            }
            let n = t.data.len();
            h = fnv1a(h, &(n as u64).to_le_bytes());
            if n > 0 {
                let stride = (n / 64).max(1);
                let mut i = 0;
                while i < n {
                    h = fnv1a(h, &t.data[i].to_bits().to_le_bytes());
                    i += stride;
                }
            }
            h
        }
    }
}

fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic pseudo-activations in (-0.9, 0.9): a 1024-value tile
/// seeded per call, cycled over the output. Cheap enough that a 64-request
/// trace replays in seconds in a debug test build. The output buffer comes
/// from the thread-local [`crate::tensor::pool`], so at steady state the
/// backend recycles the previous step's dead activations instead of
/// allocating fresh ones per call (values are unaffected: the buffer is
/// fully overwritten).
fn fill(seed: u64, n: usize) -> Vec<f32> {
    const TILE: usize = 1024;
    let mut tile = [0f32; TILE];
    for (i, v) in tile.iter_mut().enumerate() {
        let u = (mix64(seed ^ i as u64) >> 11) as f64 / (1u64 << 53) as f64;
        *v = (u * 1.8 - 0.9) as f32;
    }
    let mut out = crate::tensor::pool::take(n);
    out.extend((0..n).map(|i| tile[i % TILE]));
    out
}

/// Synthesized tiny-family artifacts for
/// [`Runtime::simulated`](crate::runtime::Runtime::simulated): the model
/// dims the engine reads from the manifest, plus the host-side weight
/// tensors it consumes directly (text table, positional rows).
pub fn simulated_artifacts() -> (Manifest, HostWeights) {
    let mut model = std::collections::BTreeMap::new();
    for (k, v) in [
        ("d", 192usize),
        ("heads", 6),
        ("layers", 8),
        ("s_img", 256),
        ("s_txt", 32),
        ("c_latent", 4),
        ("latent_hw", 16),
    ] {
        model.insert(k.to_string(), v);
    }
    let manifest = Manifest {
        dir: std::path::PathBuf::from("<simulated>"),
        version: 0,
        model,
        vae_halo: 1,
        weights_file: "<simulated>".into(),
        entries: std::collections::BTreeMap::new(),
    };
    let mut tensors = std::collections::BTreeMap::new();
    tensors.insert(
        "shared.txt_table".to_string(),
        Tensor::randn(&[256, 192], &mut Rng::new(0x7E87_0001)),
    );
    for (i, v) in ["adaln", "cross", "mmdit", "skip"].iter().enumerate() {
        tensors.insert(
            format!("{v}.pos"),
            Tensor::randn(&[256, 192], &mut Rng::new(0x7E87_0100 + i as u64)),
        );
    }
    (manifest, HostWeights { tensors })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec(b: &SimBackend, name: &str, stage: usize, data: &[ArgValue<'_>]) -> Vec<Tensor> {
        b.execute(name, None, stage, data, &mut ExecStats::default()).unwrap()
    }

    #[test]
    fn shape_rules_cover_the_grid() {
        let b = SimBackend::tiny();
        let x = Tensor::zeros(&[64, 192]);
        let cond = Tensor::zeros(&[192]);
        let kv = Tensor::zeros(&[8, 256, 192]);
        let latent = Tensor::zeros(&[64, 4]);
        let pos = Tensor::zeros(&[64, 192]);
        let ts = Tensor::scalar(0.5);

        let out = exec(&b, "adaln_t_embed", 0, &[ArgValue::F32(&ts)]);
        assert_eq!(out[0].dims, vec![192]);

        let out = exec(&b, "adaln_embed_p4", 0, &[ArgValue::F32(&latent), ArgValue::F32(&pos)]);
        assert_eq!(out[0].dims, vec![64, 192]);

        let out = exec(&b, "adaln_final_p4", 0, &[ArgValue::F32(&x), ArgValue::F32(&cond)]);
        assert_eq!(out[0].dims, vec![64, 4]);

        let out = exec(
            &b,
            "adaln_stage_L8_p4",
            0,
            &[
                ArgValue::F32(&x),
                ArgValue::F32(&cond),
                ArgValue::F32(&kv),
                ArgValue::F32(&kv),
                ArgValue::I32(0),
            ],
        );
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].dims, vec![64, 192]);
        assert_eq!(out[1].dims, vec![8, 64, 192]);

        let xt = Tensor::zeros(&[16, 192]);
        let out = exec(
            &b,
            "mmdit_stage_L4_p2",
            0,
            &[
                ArgValue::F32(&xt),
                ArgValue::F32(&x),
                ArgValue::F32(&cond),
                ArgValue::F32(&kv),
                ArgValue::F32(&kv),
                ArgValue::I32(0),
                ArgValue::I32(0),
            ],
        );
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].dims, vec![16, 192]);
        assert_eq!(out[1].dims, vec![64, 192]);
        assert_eq!(out[2].dims, vec![4, 80, 192]);

        let out = exec(&b, "skip_enc_L4_p1", 0, &[ArgValue::F32(&x), ArgValue::F32(&cond)]);
        assert_eq!(out.len(), 4);

        let out = exec(&b, "adaln_qkv_p2", 3, &[ArgValue::F32(&x), ArgValue::F32(&cond)]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].dims, vec![64, 192]);

        let out = exec(
            &b,
            "mmdit_qkv_p2",
            1,
            &[ArgValue::F32(&xt), ArgValue::F32(&x), ArgValue::F32(&cond)],
        );
        assert_eq!(out.len(), 6);
        assert_eq!(out[3].dims, vec![64, 192]);

        let out = exec(
            &b,
            "adaln_post_p2",
            1,
            &[
                ArgValue::F32(&x),
                ArgValue::F32(&x),
                ArgValue::F32(&kv),
                ArgValue::F32(&kv),
                ArgValue::F32(&cond),
            ],
        );
        assert_eq!(out[0].dims, vec![64, 192]);

        let z = Tensor::zeros(&[16, 16, 4]);
        let out = exec(&b, "vae_decode", 0, &[ArgValue::F32(&z)]);
        assert_eq!(out[0].dims, vec![128, 128, 3]);
        let strip = Tensor::zeros(&[5, 16, 4]);
        let out = exec(&b, "vae_decode_rows4_top", 0, &[ArgValue::F32(&strip)]);
        assert_eq!(out[0].dims, vec![32, 128, 3]);

        assert!(b
            .execute("nonsense_entry", None, 0, &[], &mut ExecStats::default())
            .is_err());
    }

    #[test]
    fn deterministic_and_input_sensitive() {
        let b = SimBackend::tiny();
        let x1 = Tensor::randn(&[32, 192], &mut Rng::new(1));
        let x2 = Tensor::randn(&[32, 192], &mut Rng::new(2));
        let cond = Tensor::zeros(&[192]);
        let a = exec(&b, "adaln_qkv_p1", 0, &[ArgValue::F32(&x1), ArgValue::F32(&cond)]);
        let a2 = exec(&b, "adaln_qkv_p1", 0, &[ArgValue::F32(&x1), ArgValue::F32(&cond)]);
        assert_eq!(a[0], a2[0], "same inputs must replay identically");
        let c = exec(&b, "adaln_qkv_p1", 0, &[ArgValue::F32(&x2), ArgValue::F32(&cond)]);
        assert_ne!(a[0], c[0], "different inputs must diverge");
        let s = exec(&b, "adaln_qkv_p1", 1, &[ArgValue::F32(&x1), ArgValue::F32(&cond)]);
        assert_ne!(a[0], s[0], "different stages must diverge");
        assert!(a[0].data.iter().all(|v| v.is_finite() && v.abs() < 1.0));
    }

    #[test]
    fn stage_layers_parser() {
        assert_eq!(stage_layers("adaln_stage_L8_p1"), Some(8));
        assert_eq!(stage_layers("skip_dec_L2_p4"), Some(2));
        assert_eq!(stage_layers("adaln_qkv_p4"), None);
        assert_eq!(stage_layers("vae_decode"), None);
    }
}
