//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + manifest.json + weights.bin) and executes them on the CPU
//! PJRT client from the coordinator's hot path.
//!
//! Design notes:
//! * HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//!   jax>=0.5 serialized protos — 64-bit instruction ids).
//! * Weights are uploaded once as resident `PjRtBuffer`s and reused across
//!   every call (`execute_b`), so the per-call marshalling cost is only the
//!   activation/KV data.
//! * The `xla` crate's client is `Rc`-based (not `Send`): all PJRT execution
//!   is owned by the leader thread. Simulated devices are scheduled by the
//!   deterministic event loop in `comm`/`parallel`, not OS threads — on this
//!   single-core testbed that is also the faster choice.

pub mod artifact;
pub mod executor;
pub mod weights;

pub use artifact::{EntryPoint, Manifest, WeightRef};
pub use executor::{ArgValue, Runtime};
pub use weights::HostWeights;
