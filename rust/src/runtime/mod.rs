//! The execution runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + manifest.json + weights.bin) and
//! executes entrypoints behind the [`ExecBackend`] seam.
//!
//! Backends:
//! * `pjrt` (feature `pjrt`) — the real thing: HLO **text** is the
//!   interchange format (xla_extension 0.5.1 rejects jax>=0.5 serialized
//!   protos — 64-bit instruction ids); weights are uploaded once as
//!   resident `PjRtBuffer`s and reused across every call (`execute_b`), so
//!   the per-call marshalling cost is only the activation/KV data. The
//!   `xla` client is `Rc`-based (not `Send`): all PJRT execution is owned
//!   by the leader thread.
//! * `sim` (default) — hermetic host simulation with the contract output
//!   shapes and deterministic pseudo-activations; `Runtime::simulated()`
//!   needs no artifacts at all. This is what stock CI runners execute.

pub mod artifact;
pub mod executor;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sim;
pub mod weights;

pub use artifact::{EntryPoint, Manifest, WeightRef};
pub use executor::{ArgValue, ExecBackend, ExecStats, Runtime};
pub use sim::SimBackend;
pub use weights::HostWeights;
