//! `artifacts/manifest.json` parsing: the contract between the AOT pipeline
//! (python) and the runtime (rust).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Data input dtype.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One positional weight argument of an entrypoint.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightRef {
    /// Stage-relative layer parameter: resolves to
    /// `{variant}.L{stage*ls + rel}.{param}` (or `L{L/2 + rel}` for
    /// skip-decoder refs, which are absolute in the decoder half).
    Layer { variant: String, rel: usize, param: String, dec: bool },
    /// Per-variant global: `{variant}.{name}`.
    Global { variant: String, name: String },
    /// `shared.{name}`.
    Shared { name: String },
    /// `vae.{name}`.
    Vae { name: String },
}

impl WeightRef {
    /// Resolve to the tensor name in weights.bin. `stage` and
    /// `layers_per_stage` position stage-relative layer refs; `total_layers`
    /// anchors decoder-half refs. Convention: for decoder (`dec`) refs the
    /// caller passes a *decoder-relative* stage (0 for the enc/dec stage
    /// split; `abs_layer - L/2` for per-layer calls).
    pub fn resolve(&self, stage: usize, layers_per_stage: usize, total_layers: usize) -> String {
        match self {
            WeightRef::Layer { variant, rel, param, dec } => {
                let abs = if *dec {
                    total_layers / 2 + stage * layers_per_stage + rel
                } else {
                    stage * layers_per_stage + rel
                };
                format!("{variant}.L{abs}.{param}")
            }
            WeightRef::Global { variant, name } => format!("{variant}.{name}"),
            WeightRef::Shared { name } => format!("shared.{name}"),
            WeightRef::Vae { name } => format!("vae.{name}"),
        }
    }

    fn parse(j: &Json) -> Result<WeightRef> {
        if let Some(p) = j.opt("param") {
            Ok(WeightRef::Layer {
                variant: j.get("variant")?.as_str()?.to_string(),
                rel: j.get("layer_rel")?.as_usize()?,
                param: p.as_str()?.to_string(),
                dec: j.opt("dec").map(|d| d.as_bool().unwrap_or(false)).unwrap_or(false),
            })
        } else if let Some(g) = j.opt("global") {
            Ok(WeightRef::Global {
                variant: j.get("variant")?.as_str()?.to_string(),
                name: g.as_str()?.to_string(),
            })
        } else if let Some(s) = j.opt("shared") {
            Ok(WeightRef::Shared { name: s.as_str()?.to_string() })
        } else if let Some(v) = j.opt("vae") {
            Ok(WeightRef::Vae { name: v.as_str()?.to_string() })
        } else {
            Err(Error::Manifest(format!("unparseable weight ref: {j:?}")))
        }
    }
}

/// One AOT entrypoint.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub variant: Option<String>,
    pub layers_per_stage: usize,
    pub patch_factor: usize,
    /// (name, dims, dtype) of each data input, in positional order.
    pub data_inputs: Vec<(String, Vec<usize>, DType)>,
    /// Weight args following the data args, in positional order.
    pub weights: Vec<WeightRef>,
    pub outputs: Vec<Vec<usize>>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    /// Tiny-model dims (d, heads, layers, s_img, s_txt, ...).
    pub model: BTreeMap<String, usize>,
    pub vae_halo: usize,
    pub weights_file: String,
    pub entries: BTreeMap<String, EntryPoint>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let version = j.get("version")?.as_usize()?;
        let mut model = BTreeMap::new();
        for (k, v) in j.get("model")?.as_obj()? {
            if let Json::Num(n) = v {
                model.insert(k.clone(), *n as usize);
            }
        }
        let vae_halo = j.get("vae")?.get("halo")?.as_usize()?;
        let weights_file = j.get("weights_file")?.as_str()?.to_string();
        let mut entries = BTreeMap::new();
        for e in j.get("entrypoints")?.as_arr()? {
            let ep = EntryPoint {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                kind: e.get("kind")?.as_str()?.to_string(),
                variant: e.opt("variant").and_then(|v| v.as_str().ok()).map(String::from),
                layers_per_stage: e
                    .opt("layers_per_stage")
                    .map(|v| v.as_usize())
                    .transpose()?
                    .unwrap_or(1),
                patch_factor: e
                    .opt("patch_factor")
                    .map(|v| v.as_usize())
                    .transpose()?
                    .unwrap_or(1),
                data_inputs: e
                    .get("data_inputs")?
                    .as_arr()?
                    .iter()
                    .map(|d| {
                        let dt = match d.get("dtype")?.as_str()? {
                            "i32" => DType::I32,
                            _ => DType::F32,
                        };
                        Ok((
                            d.get("name")?.as_str()?.to_string(),
                            d.get("dims")?.usize_arr()?,
                            dt,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?,
                weights: e
                    .get("weights")?
                    .as_arr()?
                    .iter()
                    .map(WeightRef::parse)
                    .collect::<Result<Vec<_>>>()?,
                outputs: e
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|o| o.usize_arr())
                    .collect::<Result<Vec<_>>>()?,
            };
            entries.insert(ep.name.clone(), ep);
        }
        Ok(Manifest { dir, version, model, vae_halo, weights_file, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.entries.get(name).ok_or_else(|| {
            Error::Manifest(format!("entrypoint '{name}' not in manifest (rebuild artifacts?)"))
        })
    }

    pub fn model_dim(&self, key: &str) -> Result<usize> {
        self.model
            .get(key)
            .copied()
            .ok_or_else(|| Error::Manifest(format!("model dim '{key}' missing")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_ref_resolution() {
        let r =
            WeightRef::Layer { variant: "adaln".into(), rel: 1, param: "Wqkv".into(), dec: false };
        assert_eq!(r.resolve(2, 2, 8), "adaln.L5.Wqkv");
        let d =
            WeightRef::Layer { variant: "skip".into(), rel: 3, param: "Wskip".into(), dec: true };
        assert_eq!(d.resolve(0, 4, 8), "skip.L7.Wskip");
        // per-layer decoder ref (ls=1): stage is decoder-relative layer idx
        let pl =
            WeightRef::Layer { variant: "skip".into(), rel: 0, param: "Wqkv".into(), dec: true };
        assert_eq!(pl.resolve(2, 1, 8), "skip.L6.Wqkv");
        let g = WeightRef::Global { variant: "mmdit".into(), name: "We".into() };
        assert_eq!(g.resolve(0, 1, 8), "mmdit.We");
        let shared = WeightRef::Shared { name: "txt_table".into() };
        assert_eq!(shared.resolve(0, 1, 8), "shared.txt_table");
        assert_eq!(WeightRef::Vae { name: "k0".into() }.resolve(0, 1, 8), "vae.k0");
    }

    #[test]
    fn parse_ref_json() {
        let j =
            Json::parse(r#"{"variant":"adaln","layer_rel":0,"param":"W1","dec":false}"#).unwrap();
        let r = WeightRef::parse(&j).unwrap();
        assert_eq!(r.resolve(0, 4, 8), "adaln.L0.W1");
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.contains_key("adaln_stage_L8_p1"));
        let e = m.entry("mmdit_stage_L2_p8").unwrap();
        assert_eq!(e.layers_per_stage, 2);
        assert_eq!(e.patch_factor, 8);
        assert_eq!(e.data_inputs.len(), 7);
        assert_eq!(e.weights.len(), 2 * 20);
        assert_eq!(m.model_dim("d").unwrap(), 192);
    }
}
