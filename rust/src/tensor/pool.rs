//! Thread-local f32 buffer pool behind every [`Tensor`] allocation.
//!
//! The serving hot path churns through short-lived activation tensors:
//! every `SimBackend` execution materializes fresh output buffers, every
//! scheduler step and elementwise op allocates a result, and the previous
//! step's tensors die immediately after. A steady-state trace therefore
//! spends a measurable slice of its wall time inside the allocator — for
//! buffers whose sizes repeat exactly batch after batch.
//!
//! The pool closes that loop: [`take`] hands out a cleared `Vec<f32>`
//! with enough capacity (recycled when one is available, freshly
//! allocated otherwise) and every dropped [`Tensor`] returns its backing
//! buffer via [`recycle`]. Small buffers (< [`MIN_POOLED`] elements) are
//! not worth the bookkeeping and bypass the pool; the free list is
//! bounded by [`MAX_BUFFERS`] / [`MAX_POOLED_BYTES`] so the pool's
//! footprint tracks the live working set, not the all-time high-water
//! mark.
//!
//! Correctness contract: a pooled buffer is returned *empty* (`len == 0`)
//! and the caller fully writes it before wrapping it in a `Tensor`, so
//! recycling can never leak one computation's values into another —
//! results are bit-identical with the pool on or off. [`stats`] exposes
//! hit/miss/byte counters; `benches/steady_state.rs` reports them as the
//! allocation proxy of the committed `BENCH_serve.json` trajectory.
//!
//! Thread-local by design: the engine is leader-threaded (see
//! `coordinator`), so no locks are needed and tests that run on parallel
//! test threads each see an isolated pool.
//!
//! [`Tensor`]: crate::tensor::Tensor

use std::cell::RefCell;

/// Buffers smaller than this many elements (4 KiB of f32) skip the pool.
pub const MIN_POOLED: usize = 1024;

/// Most buffers the free list retains.
pub const MAX_BUFFERS: usize = 64;

/// Byte bound on the free list (64 MiB): beyond it, returned buffers are
/// simply dropped.
pub const MAX_POOLED_BYTES: usize = 64 << 20;

/// Cumulative pool counters (one set per thread), for the steady-state
/// bench's allocation proxy and the serve CLI summary.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from the free list.
    pub hits: u64,
    /// `take` calls that had to allocate fresh.
    pub misses: u64,
    /// Buffers accepted back by `recycle`.
    pub recycled: u64,
    /// Bytes of fresh heap allocation (4 × requested elements per miss).
    pub fresh_bytes: u64,
    /// Bytes served out of the free list instead of the allocator.
    pub reused_bytes: u64,
}

impl PoolStats {
    /// Fraction of `take` calls served from the pool (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[derive(Default)]
struct Pool {
    free: Vec<Vec<f32>>,
    free_bytes: usize,
    stats: PoolStats,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// An empty `Vec<f32>` with capacity for at least `n` elements — recycled
/// when the free list has a fit, freshly allocated otherwise. The caller
/// must fill it completely before exposing it (see the module docs).
/// (`try_with`: during thread teardown the pool may already be gone — a
/// fresh allocation is always a correct fallback.)
pub fn take(n: usize) -> Vec<f32> {
    POOL.try_with(|p| {
        let mut pool = p.borrow_mut();
        if n >= MIN_POOLED {
            // best-of-first-fit: a buffer big enough but not absurdly
            // oversized, so one giant decode buffer is not burned on a
            // small activation request
            let found = pool
                .free
                .iter()
                .position(|v| v.capacity() >= n && v.capacity() <= n.saturating_mul(4));
            if let Some(i) = found {
                let v = pool.free.swap_remove(i);
                pool.free_bytes -= v.capacity() * 4;
                pool.stats.hits += 1;
                pool.stats.reused_bytes += (n * 4) as u64;
                debug_assert!(v.is_empty());
                return v;
            }
        }
        pool.stats.misses += 1;
        pool.stats.fresh_bytes += (n * 4) as u64;
        Vec::with_capacity(n)
    })
    .unwrap_or_else(|_| Vec::with_capacity(n))
}

/// Return a dead buffer to the free list (dropped instead when it is too
/// small to pool or the list is at its bound). Called by `Tensor::drop` —
/// `try_with` keeps drops during thread teardown (pool already gone)
/// panic-free: the buffer simply returns to the allocator.
pub fn recycle(mut v: Vec<f32>) {
    let cap = v.capacity();
    if cap < MIN_POOLED {
        return;
    }
    let _ = POOL.try_with(|p| {
        let mut pool = p.borrow_mut();
        if pool.free.len() >= MAX_BUFFERS || pool.free_bytes + cap * 4 > MAX_POOLED_BYTES {
            return; // bound the pool: excess buffers go back to the allocator
        }
        v.clear();
        pool.free_bytes += cap * 4;
        pool.stats.recycled += 1;
        pool.free.push(std::mem::take(&mut v));
    });
}

/// Snapshot of this thread's cumulative pool counters.
pub fn stats() -> PoolStats {
    POOL.with(|p| p.borrow().stats)
}

/// Drop every pooled buffer and zero the counters (bench isolation).
pub fn reset() {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        pool.free.clear();
        pool.free_bytes = 0;
        pool.stats = PoolStats::default();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_roundtrip_reuses_capacity() {
        reset();
        let a = take(4096);
        assert!(a.capacity() >= 4096 && a.is_empty());
        recycle(a);
        let s = stats();
        assert_eq!((s.misses, s.recycled), (1, 1));
        let b = take(4096);
        assert!(b.capacity() >= 4096 && b.is_empty());
        assert_eq!(stats().hits, 1, "second take of the same size must hit");
        reset();
    }

    #[test]
    fn tiny_buffers_bypass_the_pool() {
        reset();
        recycle(Vec::with_capacity(8));
        assert_eq!(stats().recycled, 0);
        let v = take(16);
        assert!(v.capacity() >= 16);
        assert_eq!(stats().hits, 0);
        reset();
    }

    #[test]
    fn oversized_buffers_are_not_burned_on_small_requests() {
        reset();
        recycle(Vec::with_capacity(1 << 20)); // 4 MiB buffer
        let v = take(MIN_POOLED); // 4 KiB ask: >4x smaller, must not bind it
        assert!(v.capacity() < 1 << 20);
        assert_eq!(stats().hits, 0);
        reset();
    }

    #[test]
    fn free_list_is_bounded() {
        reset();
        for _ in 0..(MAX_BUFFERS + 8) {
            recycle(Vec::with_capacity(MIN_POOLED));
        }
        assert_eq!(stats().recycled as usize, MAX_BUFFERS);
        reset();
    }

    #[test]
    fn pooled_tensors_are_bit_identical_to_fresh_ones() {
        use crate::tensor::Tensor;
        reset();
        let mk = || Tensor::from_fn(&[64, 64], |i| (i as f32).sin());
        let cold = mk();
        // cycle buffers through the pool a few times: values never leak
        for _ in 0..4 {
            let warm = mk();
            assert_eq!(cold, warm);
            let mapped = warm.map(|x| x * 2.0);
            assert_eq!(mapped.data[1], cold.data[1] * 2.0);
        }
        assert!(stats().hits > 0, "the cycle must actually exercise the pool");
        reset();
    }
}
