//! Host-side f32 tensor used by the coordinator for latents, activations
//! and KV buffers. Deliberately small: the heavy math lives in the AOT HLO
//! executables; the coordinator only splits, scatters, concatenates and
//! does elementwise scheduler updates.
//!
//! Allocation goes through the thread-local [`pool`]: constructors and
//! elementwise ops take recycled buffers, and `Drop` returns a tensor's
//! backing storage to the pool — so the steady-state serving loop, whose
//! activation shapes repeat batch after batch, stops paying the allocator
//! per call. Values are unaffected: pooled buffers are handed out empty
//! and fully overwritten (see the pool's correctness contract).

/// Recycling f32 buffer pool behind every tensor allocation.
pub mod pool;

use crate::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Clone for Tensor {
    fn clone(&self) -> Tensor {
        let mut data = pool::take(self.data.len());
        data.extend_from_slice(&self.data);
        Tensor { dims: self.dims.clone(), data }
    }
}

impl Drop for Tensor {
    fn drop(&mut self) {
        pool::recycle(std::mem::take(&mut self.data));
    }
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "dims {:?} expect {} elements, got {}",
                dims,
                n,
                data.len()
            )));
        }
        Ok(Tensor { dims, data })
    }

    pub fn zeros(dims: &[usize]) -> Tensor {
        let n = dims.iter().product();
        let mut data = pool::take(n);
        data.resize(n, 0.0);
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn from_fn(dims: &[usize], f: impl FnMut(usize) -> f32) -> Tensor {
        let n: usize = dims.iter().product();
        let mut data = pool::take(n);
        data.extend((0..n).map(f));
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn randn(dims: &[usize], rng: &mut crate::util::rng::Rng) -> Tensor {
        let n: usize = dims.iter().product();
        let mut data = pool::take(n);
        data.extend((0..n).map(|_| rng.normal()));
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Size of one "row" = product of all dims except the first.
    pub fn row_len(&self) -> usize {
        self.dims.iter().skip(1).product()
    }

    pub fn rows(&self) -> usize {
        *self.dims.first().unwrap_or(&1)
    }

    /// Bytes of payload (for comm-volume accounting).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Contiguous row-range view copy: rows [lo, hi).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if hi > self.rows() || lo > hi {
            return Err(Error::shape(format!(
                "slice_rows {lo}..{hi} out of {} rows",
                self.rows()
            )));
        }
        let rl = self.row_len();
        let mut dims = self.dims.clone();
        dims[0] = hi - lo;
        let mut data = pool::take((hi - lo) * rl);
        data.extend_from_slice(&self.data[lo * rl..hi * rl]);
        Ok(Tensor { dims, data })
    }

    /// Overwrite rows [at, at+src.rows()) with `src` (shape-checked).
    pub fn scatter_rows(&mut self, at: usize, src: &Tensor) -> Result<()> {
        if src.row_len() != self.row_len() {
            return Err(Error::shape(format!(
                "scatter_rows row_len mismatch {} vs {}",
                src.row_len(),
                self.row_len()
            )));
        }
        let end = at + src.rows();
        if end > self.rows() {
            return Err(Error::shape(format!(
                "scatter_rows {}..{} out of {} rows",
                at,
                end,
                self.rows()
            )));
        }
        let rl = self.row_len();
        self.data[at * rl..end * rl].copy_from_slice(&src.data);
        Ok(())
    }

    /// Split into `n` equal contiguous row chunks.
    pub fn split_rows(&self, n: usize) -> Result<Vec<Tensor>> {
        if n == 0 || self.rows() % n != 0 {
            return Err(Error::shape(format!(
                "cannot split {} rows into {n} chunks",
                self.rows()
            )));
        }
        let per = self.rows() / n;
        (0..n).map(|i| self.slice_rows(i * per, (i + 1) * per)).collect()
    }

    /// Concatenate along the first axis.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| Error::shape("concat of nothing"))?;
        let rl = first.row_len();
        let mut dims = first.dims.clone();
        let mut rows = 0;
        for p in parts {
            if p.row_len() != rl {
                return Err(Error::shape("concat_rows: row_len mismatch"));
            }
            rows += p.rows();
        }
        let mut data = pool::take(rows * rl);
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        dims[0] = rows;
        Tensor::new(dims, data)
    }

    /// Reshape (same element count).
    pub fn reshape(&self, dims: &[usize]) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != self.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        let mut data = pool::take(n);
        data.extend_from_slice(&self.data);
        Ok(Tensor { dims: dims.to_vec(), data })
    }

    // ---- elementwise ops used by the diffusion schedulers ----------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        let mut data = pool::take(self.data.len());
        data.extend(self.data.iter().map(|&x| f(x)));
        Tensor { dims: self.dims.clone(), data }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.dims != other.dims {
            return Err(Error::shape(format!(
                "zip shape mismatch {:?} vs {:?}",
                self.dims, other.dims
            )));
        }
        let mut data = pool::take(self.data.len());
        data.extend(self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)));
        Ok(Tensor { dims: self.dims.clone(), data })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// a + s * b (axpy), in place — hot path of every scheduler step.
    pub fn axpy_inplace(&mut self, s: f32, b: &Tensor) -> Result<()> {
        if self.dims != b.dims {
            return Err(Error::shape("axpy shape mismatch"));
        }
        for (x, &y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
        Ok(())
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Mean over the first axis -> tensor of shape dims[1..].
    pub fn mean_rows(&self) -> Tensor {
        let rl = self.row_len();
        let r = self.rows();
        let mut out = vec![0.0f32; rl];
        for i in 0..r {
            for j in 0..rl {
                out[j] += self.data[i * rl + j];
            }
        }
        for v in &mut out {
            *v /= r as f32;
        }
        Tensor { dims: self.dims[1..].to_vec(), data: out }
    }

    // ---- divergence metrics (Fig 19 reproduction) -------------------------

    pub fn mse(&self, other: &Tensor) -> Result<f64> {
        if self.dims != other.dims {
            return Err(Error::shape("mse shape mismatch"));
        }
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        Ok(s / self.data.len() as f64)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        if self.dims != other.dims {
            return Err(Error::shape("diff shape mismatch"));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs() as f64)
            .fold(0.0, f64::max))
    }

    /// PSNR in dB against `other` treated as reference (range from ref).
    pub fn psnr(&self, reference: &Tensor) -> Result<f64> {
        let mse = self.mse(reference)?;
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in &reference.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let range = (hi - lo).max(1e-12) as f64;
        Ok(10.0 * (range * range / mse.max(1e-20)).log10())
    }

    pub fn allclose(&self, other: &Tensor, atol: f64) -> bool {
        self.dims == other.dims && self.max_abs_diff(other).map(|d| d <= atol).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
    }

    #[test]
    fn slice_scatter_roundtrip() {
        let mut a = t(&[8, 4]);
        let s = a.slice_rows(2, 5).unwrap();
        assert_eq!(s.dims, vec![3, 4]);
        assert_eq!(s.data[0], 8.0);
        let orig = a.clone();
        a.scatter_rows(2, &s).unwrap();
        assert_eq!(a, orig);
    }

    #[test]
    fn split_concat_roundtrip() {
        let a = t(&[8, 3]);
        let parts = a.split_rows(4).unwrap();
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].dims, vec![2, 3]);
        let b = Tensor::concat_rows(&parts).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn split_rejects_uneven() {
        assert!(t(&[7, 2]).split_rows(2).is_err());
    }

    #[test]
    fn scatter_out_of_range_rejected() {
        let mut a = t(&[4, 2]);
        let s = t(&[3, 2]);
        assert!(a.scatter_rows(2, &s).is_err());
    }

    #[test]
    fn elementwise() {
        let a = t(&[2, 2]);
        let b = a.scale(2.0);
        assert_eq!(b.data, vec![0.0, 2.0, 4.0, 6.0]);
        let c = a.add(&a).unwrap();
        assert_eq!(c.data, b.data);
        let mut d = a.clone();
        d.axpy_inplace(0.5, &a).unwrap();
        assert_eq!(d.data, vec![0.0, 1.5, 3.0, 4.5]);
    }

    #[test]
    fn metrics() {
        let a = t(&[2, 2]);
        assert_eq!(a.mse(&a).unwrap(), 0.0);
        let b = a.map(|x| x + 1.0);
        assert_eq!(a.mse(&b).unwrap(), 1.0);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        assert!(a.psnr(&a).unwrap() > 100.0);
    }

    #[test]
    fn mean_rows() {
        let a = t(&[2, 3]); // rows [0,1,2], [3,4,5]
        let m = a.mean_rows();
        assert_eq!(m.dims, vec![3]);
        assert_eq!(m.data, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn three_dim_rows() {
        let a = t(&[2, 3, 4]);
        assert_eq!(a.row_len(), 12);
        let s = a.slice_rows(1, 2).unwrap();
        assert_eq!(s.dims, vec![1, 3, 4]);
        assert_eq!(s.data[0], 12.0);
    }
}
