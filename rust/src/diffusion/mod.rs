//! Diffusion samplers: the `Update(x_t, t, eps_t)` functions of Eq. (1) in
//! the paper, plus classifier-free guidance combination.
//!
//! Three schedulers matching the paper's benchmarks: DDIM (CogVideoX runs),
//! DPM-Solver (Pixart/HunyuanDiT runs), FlowMatch-Euler (SD3/Flux runs).

pub mod cfg;
pub mod scheduler;

pub use cfg::combine_cfg;
pub use scheduler::{make_scheduler, Scheduler, SchedulerKind};
