//! Classifier-free guidance (paper §4.2): the conditional and unconditional
//! branches are combined after each denoising forward; under CFG
//! parallelism the branches live on disjoint device groups and exchange
//! latents with one AllGather per step.

use crate::tensor::Tensor;
use crate::Result;

/// eps = eps_uncond + scale * (eps_cond - eps_uncond)
pub fn combine_cfg(eps_cond: &Tensor, eps_uncond: &Tensor, scale: f32) -> Result<Tensor> {
    eps_uncond.zip(eps_cond, move |u, c| u + scale * (c - u))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_one_is_cond() {
        let c = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let u = Tensor::new(vec![3], vec![0.0, 0.0, 0.0]).unwrap();
        assert_eq!(combine_cfg(&c, &u, 1.0).unwrap().data, c.data);
    }

    #[test]
    fn scale_zero_is_uncond() {
        let c = Tensor::new(vec![2], vec![5.0, 5.0]).unwrap();
        let u = Tensor::new(vec![2], vec![1.0, -1.0]).unwrap();
        assert_eq!(combine_cfg(&c, &u, 0.0).unwrap().data, u.data);
    }

    #[test]
    fn extrapolates_beyond_cond() {
        let c = Tensor::new(vec![1], vec![2.0]).unwrap();
        let u = Tensor::new(vec![1], vec![1.0]).unwrap();
        assert_eq!(combine_cfg(&c, &u, 3.0).unwrap().data, vec![4.0]);
    }
}
