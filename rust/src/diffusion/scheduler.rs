//! Denoising schedulers over host tensors.
//!
//! All are expressed as: given the model output `eps` at step index `i`
//! (0-based over `steps` inference steps, going from t=T to t~0), produce
//! `x_{i+1}` from `x_i`. The `timestep(i)` value is what the DiT conditions
//! on (fed to `t_embed`).

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Typed scheduler selector — the first-class form of the string keys in
/// `ModelSpec::scheduler` and the request API. `Pipeline` requests carry an
/// `Option<SchedulerKind>` so the scheduler is a per-request decision
/// rather than a hardcoded string on the serving path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    /// DDIM, eta = 0 (CogVideoX benchmarks, tiny family default).
    Ddim,
    /// First-order DPM-Solver (Pixart / HunyuanDiT benchmarks).
    Dpm,
    /// FlowMatch Euler (SD3 / Flux benchmarks).
    FlowMatch,
}

impl SchedulerKind {
    /// The manifest/CLI key of this scheduler.
    pub fn key(&self) -> &'static str {
        match self {
            SchedulerKind::Ddim => "ddim",
            SchedulerKind::Dpm => "dpm",
            SchedulerKind::FlowMatch => "flow_match",
        }
    }

    pub fn parse(s: &str) -> Result<SchedulerKind> {
        Ok(match s {
            "ddim" => SchedulerKind::Ddim,
            "dpm" => SchedulerKind::Dpm,
            "flow_match" | "flowmatch" => SchedulerKind::FlowMatch,
            _ => {
                return Err(Error::config(format!(
                    "unknown scheduler '{s}' (ddim, dpm, flow_match)"
                )))
            }
        })
    }

    pub fn build(&self, steps: usize) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Ddim => Box::new(Ddim::new(steps)),
            SchedulerKind::Dpm => Box::new(DpmSolver::new(steps)),
            SchedulerKind::FlowMatch => Box::new(FlowMatchEuler::new(steps)),
        }
    }
}

pub trait Scheduler {
    fn name(&self) -> &'static str;
    fn steps(&self) -> usize;
    /// The conditioning timestep for step index i.
    fn timestep(&self, i: usize) -> f32;
    /// One update x_i -> x_{i+1} given the model's prediction at step i.
    fn step(&self, x: &Tensor, eps: &Tensor, i: usize) -> Result<Tensor>;
}

/// Linear-beta DDPM alpha-bar schedule used by DDIM/DPM (T=1000 training
/// steps).
fn alpha_bar(t: f32) -> f64 {
    // cumulative product of (1 - beta) with beta linear in [1e-4, 2e-2]
    // approximated in closed form by the integral of log(1-beta(t)).
    let t = t as f64;
    let beta0 = 1e-4;
    let beta1 = 2e-2;
    let n = 1000.0;
    // sum_{s<=t} log(1 - beta(s)) ~ integral; beta(s) small so log(1-b) ~ -b
    let integral = -(beta0 * t + (beta1 - beta0) * t * t / (2.0 * n));
    integral.exp()
}

/// DDIM (eta = 0): deterministic probability-flow update.
pub struct Ddim {
    pub steps: usize,
    ts: Vec<f32>,
}

impl Ddim {
    pub fn new(steps: usize) -> Ddim {
        // uniform stride over the 1000 training steps, descending
        let ts = (0..steps)
            .map(|i| 1000.0 * (steps - i) as f32 / steps as f32)
            .collect();
        Ddim { steps, ts }
    }

    fn t_prev(&self, i: usize) -> f32 {
        if i + 1 < self.steps {
            self.ts[i + 1]
        } else {
            0.0
        }
    }
}

impl Scheduler for Ddim {
    fn name(&self) -> &'static str {
        "ddim"
    }

    fn steps(&self) -> usize {
        self.steps
    }

    fn timestep(&self, i: usize) -> f32 {
        self.ts[i]
    }

    fn step(&self, x: &Tensor, eps: &Tensor, i: usize) -> Result<Tensor> {
        if x.dims != eps.dims {
            return Err(Error::shape("scheduler: x/eps shape mismatch"));
        }
        let ab = alpha_bar(self.ts[i]);
        let ab_prev = alpha_bar(self.t_prev(i));
        let (sa, so) = (ab.sqrt() as f32, (1.0 - ab).sqrt() as f32);
        let (sap, sop) = (ab_prev.sqrt() as f32, (1.0 - ab_prev).sqrt() as f32);
        // x0 = (x - so * eps) / sa ; x_prev = sap * x0 + sop * eps
        let c_x = sap / sa;
        let c_e = sop - sap * so / sa;
        Ok(x.zip(eps, |xv, ev| c_x * xv + c_e * ev)?)
    }
}

/// First-order DPM-Solver (equivalent update direction to DDIM in
/// lambda-space; kept as a distinct scheduler for the paper's Pixart /
/// HunyuanDiT benchmark configuration, with its log-SNR stepping).
pub struct DpmSolver {
    pub steps: usize,
    ts: Vec<f32>,
}

impl DpmSolver {
    pub fn new(steps: usize) -> DpmSolver {
        // quadratic stride (denser near t=0), as DPM solvers prefer
        let ts = (0..steps)
            .map(|i| {
                let f = (steps - i) as f32 / steps as f32;
                1000.0 * f * f
            })
            .collect();
        DpmSolver { steps, ts }
    }

    fn t_prev(&self, i: usize) -> f32 {
        if i + 1 < self.steps {
            self.ts[i + 1]
        } else {
            0.0
        }
    }
}

impl Scheduler for DpmSolver {
    fn name(&self) -> &'static str {
        "dpm"
    }

    fn steps(&self) -> usize {
        self.steps
    }

    fn timestep(&self, i: usize) -> f32 {
        self.ts[i]
    }

    fn step(&self, x: &Tensor, eps: &Tensor, i: usize) -> Result<Tensor> {
        if x.dims != eps.dims {
            return Err(Error::shape("scheduler: x/eps shape mismatch"));
        }
        let ab = alpha_bar(self.ts[i]);
        let ab_prev = alpha_bar(self.t_prev(i));
        let (sa, so) = (ab.sqrt(), (1.0 - ab).sqrt());
        let (sap, sop) = (ab_prev.sqrt(), (1.0 - ab_prev).sqrt());
        // DPM-Solver-1: x_prev = (sap/sa) x - sop (e^{h} - 1) eps with
        // h = lambda_prev - lambda, lambda = log(sa/so). Expanded
        // algebraically (e^h = sap*so/(sop*sa)) for stability at
        // t_prev -> 0 where sop -> 0; first order this coincides with the
        // DDIM direction — the practical difference is the log-SNR
        // (quadratic) timestep spacing.
        let c_x = (sap / sa) as f32;
        let c_e = (sop - sap * so / sa) as f32;
        Ok(x.zip(eps, |xv, ev| c_x * xv + c_e * ev)?)
    }
}

/// FlowMatch Euler (SD3/Flux): the model predicts a velocity field; x moves
/// along sigma from 1 to 0.
pub struct FlowMatchEuler {
    pub steps: usize,
    sigmas: Vec<f32>,
}

impl FlowMatchEuler {
    pub fn new(steps: usize) -> FlowMatchEuler {
        let sigmas = (0..=steps)
            .map(|i| (steps - i) as f32 / steps as f32)
            .collect();
        FlowMatchEuler { steps, sigmas }
    }
}

impl Scheduler for FlowMatchEuler {
    fn name(&self) -> &'static str {
        "flow_match"
    }

    fn steps(&self) -> usize {
        self.steps
    }

    fn timestep(&self, i: usize) -> f32 {
        1000.0 * self.sigmas[i]
    }

    fn step(&self, x: &Tensor, eps: &Tensor, i: usize) -> Result<Tensor> {
        if x.dims != eps.dims {
            return Err(Error::shape("scheduler: x/eps shape mismatch"));
        }
        let dt = self.sigmas[i + 1] - self.sigmas[i]; // negative
        let mut out = x.clone();
        out.axpy_inplace(dt, eps)?;
        Ok(out)
    }
}

/// Factory by scheduler key (`ModelSpec::scheduler`).
pub fn make_scheduler(kind: &str, steps: usize) -> Result<Box<dyn Scheduler>> {
    Ok(SchedulerKind::parse(kind)?.build(steps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn noise(n: usize, seed: u64) -> Tensor {
        Tensor::randn(&[n], &mut Rng::new(seed))
    }

    #[test]
    fn alpha_bar_monotone() {
        assert!(alpha_bar(0.0) > 0.99);
        assert!(alpha_bar(1000.0) < 0.1);
        let mut prev = alpha_bar(0.0);
        for t in [100.0, 300.0, 600.0, 1000.0] {
            let a = alpha_bar(t);
            assert!(a < prev);
            prev = a;
        }
    }

    #[test]
    fn timesteps_descend() {
        for s in ["ddim", "dpm", "flow_match"] {
            let sch = make_scheduler(s, 8).unwrap();
            for i in 1..8 {
                assert!(
                    sch.timestep(i) < sch.timestep(i - 1),
                    "{s}: t({i}) >= t({})",
                    i - 1
                );
            }
        }
    }

    #[test]
    fn zero_eps_ddim_scales_toward_x0() {
        let sch = Ddim::new(4);
        let x = noise(64, 0);
        let z = Tensor::zeros(&[64]);
        let y = sch.step(&x, &z, 0).unwrap();
        // with eps=0, x is treated as sqrt(ab)*x0: magnitude grows toward x0
        let c = y.data[0] / x.data[0];
        assert!(c > 1.0 && c.is_finite(), "c={c}");
        // all elements scaled by the same factor
        for j in 0..x.len() {
            assert!((y.data[j] - c * x.data[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn flow_match_euler_linear() {
        let sch = FlowMatchEuler::new(4);
        let x = noise(16, 1);
        let v = noise(16, 2);
        let y = sch.step(&x, &v, 0).unwrap();
        // dt = -0.25
        for j in 0..16 {
            let expect = x.data[j] - 0.25 * v.data[j];
            assert!((y.data[j] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn full_trajectory_finite() {
        for s in ["ddim", "dpm", "flow_match"] {
            let sch = make_scheduler(s, 8).unwrap();
            let mut x = noise(128, 3);
            for i in 0..8 {
                let eps = x.scale(0.5); // pseudo-model
                x = sch.step(&x, &eps, i).unwrap();
                assert!(x.data.iter().all(|v| v.is_finite()), "{s} step {i}");
            }
        }
    }

    #[test]
    fn kind_parse_key_round_trip() {
        for kind in [SchedulerKind::Ddim, SchedulerKind::Dpm, SchedulerKind::FlowMatch] {
            assert_eq!(SchedulerKind::parse(kind.key()).unwrap(), kind);
            assert_eq!(kind.build(4).name(), kind.key());
        }
        assert!(SchedulerKind::parse("euler-a").is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let sch = Ddim::new(2);
        assert!(sch.step(&Tensor::zeros(&[4]), &Tensor::zeros(&[5]), 0).is_err());
    }
}
