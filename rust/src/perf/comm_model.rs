//! Closed-form per-step communication volumes — the paper's Table 1 —
//! and where those bytes land on a two-tier cluster.
//!
//! Volumes are bytes *per device per diffusion step* (fp16 activations, as
//! deployed), before the algorithm-bandwidth factor. `O(p×hs)` in the paper
//! is `seq × hidden × 2 bytes` here. [`comm_bytes`] prices the single-method
//! rows, [`config_comm_bytes`] composes them for a hybrid config, and
//! [`ethernet_bytes`] projects a collective's volume onto the inter-node
//! Ethernet tier under flat-ring vs hierarchical lowering — the quantity
//! the two-level algorithm of
//! [`ClusterSpec::collective_cost`](crate::config::hardware::ClusterSpec::collective_cost)
//! exists to shrink (see the "Communication model" chapter of `DESIGN.md`).

use crate::config::hardware::{ClusterSpec, CollectiveAlgo, CollectiveKind};
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;

/// Paper Table 1 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Row {
    /// Megatron-style TP: two all-reduces per transformer layer.
    TensorParallel,
    /// Displaced patch parallelism: stale K/V all-gather per layer.
    DistriFusion,
    /// Ring attention: K/V blocks circulate the ring every layer.
    SpRing,
    /// Ulysses sequence parallelism: four all-to-alls per layer.
    SpUlysses,
    /// Patch-level pipeline: one activation patch in + out per micro-step.
    PipeFusion,
}

impl Row {
    /// Human-readable row label (as printed in Table 1).
    pub fn label(&self) -> &'static str {
        match self {
            Row::TensorParallel => "Tensor Parallelism",
            Row::DistriFusion => "DistriFusion",
            Row::SpRing => "SP-Ring",
            Row::SpUlysses => "SP-Ulysses",
            Row::PipeFusion => "PipeFusion",
        }
    }

    /// Whether the paper classifies this row's traffic as overlappable.
    pub fn overlaps(&self) -> bool {
        matches!(self, Row::DistriFusion | Row::SpRing | Row::PipeFusion)
    }
}

/// Communication bytes per device per step (excluding algbw factors), for
/// intra-image parallel degree `n` at sequence length `s`.
pub fn comm_bytes(row: Row, m: &ModelSpec, s: usize, n: usize) -> f64 {
    let hs = s as f64 * m.hidden as f64 * 2.0; // O(p x hs) in fp16
    let l = m.layers as f64;
    match row {
        // 2 AllReduce/layer, each moving ~2x the activation (ring factor
        // folded into the time model): 4 O(p·hs) L
        Row::TensorParallel => 4.0 * hs * l,
        // K+V AllGather per layer: 2 O(p·hs) L
        Row::DistriFusion => 2.0 * hs * l,
        // K/V blocks circulate the full ring per layer: 2 O(p·hs) L
        Row::SpRing => 2.0 * hs * l,
        // 4 All2All per layer, each 1/n of the activation: 4/n O(p·hs) L
        Row::SpUlysses => 4.0 / n as f64 * hs * l,
        // one activation patch in + out per micro-step, no L factor:
        // 2 O(p·hs)
        Row::PipeFusion => 2.0 * hs,
    }
}

/// Per-device per-step communication bytes of a *hybrid* config — the
/// Table-1 rows composed the way the mesh composes them (the planner's
/// comm figure, reported in every `Plan`):
/// * SP-Ulysses moves 4 All2All/layer of `1/u` of the activation; the
///   patch split cancels (M patches × act/M), so the volume matches the
///   single-method row at degree `u`;
/// * SP-Ring circulates the K/V blocks — degree-independent, 2 O(p·hs) L;
/// * PipeFusion ships one activation patch in + out per micro-step, and
///   each SP rank only ships its sequence shard: 2 O(p·hs) / sp;
/// * CFG parallel exchanges the predicted latent between the branch pair
///   once per step (fp16).
pub fn config_comm_bytes(m: &ModelSpec, px: usize, pc: &ParallelConfig) -> f64 {
    let s = m.attn_seq_len(px);
    let hs = s as f64 * m.hidden as f64 * 2.0;
    let l = m.layers as f64;
    let mut total = 0.0;
    if pc.ulysses > 1 {
        total += 4.0 / pc.ulysses as f64 * hs * l;
    }
    if pc.ring > 1 {
        total += 2.0 * hs * l;
    }
    if pc.pipefusion > 1 {
        total += 2.0 * hs / pc.sp_degree() as f64;
    }
    if pc.cfg == 2 {
        total += (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 2.0;
    }
    total
}

/// Bytes a collective puts on the inter-node Ethernet tier, per step.
///
/// `bytes` is the per-rank payload (the same argument
/// [`ClusterSpec::collective_cost`] takes). For a group confined to one
/// node nothing crosses Ethernet and the answer is `0.0` for either
/// algorithm. For a node-spanning group:
///
/// * **Flat ring** — every rank is a ring peer, so each rank's full ring
///   volume (`bytes × flat_factor`) funnels through the node seams: the
///   whole collective is priced at the shared-NIC Ethernet bottleneck.
/// * **Hierarchical** — only the phase-2 leader exchange crosses: node
///   aggregates for all-gather, one reduced buffer (twice for all-reduce)
///   for the reduction kinds, and the node-to-node slices
///   `g·bytes·(n−g)/(n−1)` for all-to-all.
///
/// The ratio of the two is the wire saving the planner's "why" string
/// cites when it picks hierarchical collectives.
///
/// ```
/// use xdit::config::hardware::{ClusterSpec, CollectiveAlgo, CollectiveKind};
/// use xdit::perf::comm_model::ethernet_bytes;
///
/// // a 16-rank Ulysses all-to-all spanning both nodes of l40x16,
/// // 1 MB payload per rank
/// let c = ClusterSpec::by_name("l40x16")?;
/// let group: Vec<usize> = (0..16).collect();
/// let flat = ethernet_bytes(&c, &group, 1e6, CollectiveKind::AllToAll,
///                           CollectiveAlgo::FlatRing);
/// let hier = ethernet_bytes(&c, &group, 1e6, CollectiveKind::AllToAll,
///                           CollectiveAlgo::Hierarchical);
/// assert_eq!(flat, 16.0 * 1e6);           // every rank's payload crosses
/// // hierarchical: each node's leader ships only the node-to-node slice,
/// // 8 ranks x 1 MB x 8/15 each way
/// assert!((hier - 2.0 * (8.0 * 1e6 * 8.0 / 15.0)).abs() < 1.0);
/// assert!(hier < 0.54 * flat);
/// # Ok::<(), xdit::Error>(())
/// ```
///
/// [`ClusterSpec::collective_cost`]:
/// crate::config::hardware::ClusterSpec::collective_cost
pub fn ethernet_bytes(
    cluster: &ClusterSpec,
    group: &[usize],
    bytes: f64,
    kind: CollectiveKind,
    algo: CollectiveAlgo,
) -> f64 {
    let n = group.len();
    if n <= 1 {
        return 0.0;
    }
    let mut per_node: std::collections::BTreeMap<usize, usize> = Default::default();
    for &d in group {
        *per_node.entry(cluster.node_of(d)).or_insert(0) += 1;
    }
    let nodes = per_node.len();
    if nodes <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    match algo {
        CollectiveAlgo::FlatRing => nf * bytes * kind.flat_factor(n),
        CollectiveAlgo::Hierarchical => {
            let steps = nodes as f64 - 1.0;
            match kind {
                // every node aggregate traverses the leaders-only ring
                CollectiveKind::AllGather => nf * bytes * steps,
                CollectiveKind::ReduceScatter => bytes * steps,
                CollectiveKind::AllReduce => 2.0 * bytes * steps,
                CollectiveKind::AllToAll => per_node
                    .values()
                    .map(|&g| {
                        let g = g as f64;
                        g * bytes * (nf - g) / (nf - 1.0)
                    })
                    .sum(),
            }
        }
    }
}

/// Memory cost multipliers of Table 1 (params, KV), as fractions of the
/// full model parameters `P` and full per-layer KV `(KV)L`.
pub fn memory_fractions(row: Row, n: usize) -> (f64, f64) {
    let inv = 1.0 / n as f64;
    match row {
        Row::TensorParallel => (inv, inv),
        Row::DistriFusion => (1.0, 1.0),
        Row::SpRing | Row::SpUlysses => (1.0, inv),
        Row::PipeFusion => (inv, inv),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::ModelSpec;

    #[test]
    fn table1_ordering_holds() {
        // PipeFusion lowest when N < 2L (paper §4.1.3)
        let m = ModelSpec::by_name("sd3").unwrap(); // L = 24
        let s = m.seq_len(1024);
        for n in [2, 4, 8, 16] {
            let pf = comm_bytes(Row::PipeFusion, &m, s, n);
            for row in [Row::TensorParallel, Row::DistriFusion, Row::SpRing, Row::SpUlysses] {
                assert!(
                    pf < comm_bytes(row, &m, s, n),
                    "pipefusion not lowest at n={n} vs {row:?}"
                );
            }
        }
    }

    #[test]
    fn ulysses_decreases_with_n() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let s = m.seq_len(2048);
        assert!(
            comm_bytes(Row::SpUlysses, &m, s, 8) < comm_bytes(Row::SpUlysses, &m, s, 2)
        );
        // ring does not decrease
        assert_eq!(
            comm_bytes(Row::SpRing, &m, s, 8),
            comm_bytes(Row::SpRing, &m, s, 2)
        );
    }

    #[test]
    fn pipefusion_beats_ulysses_iff_n_lt_2l() {
        let m = ModelSpec::by_name("sd3").unwrap();
        let s = m.seq_len(1024);
        // n < 2L = 48 -> pipefusion wins
        assert!(comm_bytes(Row::PipeFusion, &m, s, 16) < comm_bytes(Row::SpUlysses, &m, s, 16));
        // hypothetical n > 2L -> ulysses would win
        assert!(comm_bytes(Row::PipeFusion, &m, s, 64) > comm_bytes(Row::SpUlysses, &m, s, 64));
    }

    #[test]
    fn config_comm_composes_table1_rows() {
        let m = ModelSpec::by_name("sd3").unwrap();
        let px = 1024;
        let s = m.attn_seq_len(px);
        // pure single-dimension configs reproduce their Table-1 rows
        let ul = ParallelConfig::new(1, 1, 8, 1);
        assert_eq!(config_comm_bytes(&m, px, &ul), comm_bytes(Row::SpUlysses, &m, s, 8));
        let ring = ParallelConfig::new(1, 1, 1, 8);
        assert_eq!(config_comm_bytes(&m, px, &ring), comm_bytes(Row::SpRing, &m, s, 8));
        let pf = ParallelConfig::new(1, 8, 1, 1);
        assert_eq!(config_comm_bytes(&m, px, &pf), comm_bytes(Row::PipeFusion, &m, s, 8));
        // serial moves nothing; cfg alone only the per-step latent
        assert_eq!(config_comm_bytes(&m, px, &ParallelConfig::serial()), 0.0);
        let cfg_only = config_comm_bytes(&m, px, &ParallelConfig::new(2, 1, 1, 1));
        assert!(cfg_only > 0.0 && cfg_only < comm_bytes(Row::PipeFusion, &m, s, 8));
        // a hybrid strictly adds its parts
        let hybrid = ParallelConfig::new(2, 2, 2, 1);
        let parts = config_comm_bytes(&m, px, &ParallelConfig::new(1, 1, 2, 1))
            + comm_bytes(Row::PipeFusion, &m, s, 2) / 2.0
            + cfg_only;
        assert!((config_comm_bytes(&m, px, &hybrid) - parts).abs() < 1e-6);
    }

    #[test]
    fn ethernet_bytes_shrink_under_hierarchy() {
        use crate::config::hardware::l40_cluster;
        let c = l40_cluster(2);
        let group: Vec<usize> = (0..16).collect();
        let kinds = [
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllReduce,
            CollectiveKind::AllToAll,
        ];
        for kind in kinds {
            let flat = ethernet_bytes(&c, &group, 1e6, kind, CollectiveAlgo::FlatRing);
            let hier = ethernet_bytes(&c, &group, 1e6, kind, CollectiveAlgo::Hierarchical);
            assert!(flat > 0.0, "{kind:?}");
            assert!(
                hier < flat,
                "{kind:?}: hierarchical must put fewer bytes on Ethernet ({hier} vs {flat})"
            );
            // a single-node group never touches the Ethernet tier
            let local: Vec<usize> = (0..8).collect();
            for algo in [CollectiveAlgo::FlatRing, CollectiveAlgo::Hierarchical] {
                assert_eq!(ethernet_bytes(&c, &local, 1e6, kind, algo), 0.0);
            }
        }
        // the all-reduce saving is the classic two-level one: 2(n-1)/n x n
        // per-rank volumes collapse to two reduced buffers per extra node
        let flat = ethernet_bytes(&c, &group, 1e6, CollectiveKind::AllReduce,
                                  CollectiveAlgo::FlatRing);
        let hier = ethernet_bytes(&c, &group, 1e6, CollectiveKind::AllReduce,
                                  CollectiveAlgo::Hierarchical);
        assert!((flat / hier - 15.0).abs() < 1e-9, "flat/hier = {}", flat / hier);
    }

    #[test]
    fn memory_fractions_match_table() {
        assert_eq!(memory_fractions(Row::PipeFusion, 4), (0.25, 0.25));
        assert_eq!(memory_fractions(Row::DistriFusion, 4), (1.0, 1.0));
        assert_eq!(memory_fractions(Row::SpUlysses, 4), (1.0, 0.25));
        assert_eq!(memory_fractions(Row::TensorParallel, 4), (0.25, 0.25));
    }
}
