//! Closed-form per-step communication volumes — the paper's Table 1.
//!
//! Volumes are bytes *per device per diffusion step* (fp16 activations, as
//! deployed), before the algorithm-bandwidth factor. `O(p×hs)` in the paper
//! is `seq × hidden × 2 bytes` here.

use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;

/// Paper Table 1 rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Row {
    TensorParallel,
    DistriFusion,
    SpRing,
    SpUlysses,
    PipeFusion,
}

impl Row {
    /// Human-readable row label (as printed in Table 1).
    pub fn label(&self) -> &'static str {
        match self {
            Row::TensorParallel => "Tensor Parallelism",
            Row::DistriFusion => "DistriFusion",
            Row::SpRing => "SP-Ring",
            Row::SpUlysses => "SP-Ulysses",
            Row::PipeFusion => "PipeFusion",
        }
    }

    /// Whether the paper classifies this row's traffic as overlappable.
    pub fn overlaps(&self) -> bool {
        matches!(self, Row::DistriFusion | Row::SpRing | Row::PipeFusion)
    }
}

/// Communication bytes per device per step (excluding algbw factors), for
/// intra-image parallel degree `n` at sequence length `s`.
pub fn comm_bytes(row: Row, m: &ModelSpec, s: usize, n: usize) -> f64 {
    let hs = s as f64 * m.hidden as f64 * 2.0; // O(p x hs) in fp16
    let l = m.layers as f64;
    match row {
        // 2 AllReduce/layer, each moving ~2x the activation (ring factor
        // folded into the time model): 4 O(p·hs) L
        Row::TensorParallel => 4.0 * hs * l,
        // K+V AllGather per layer: 2 O(p·hs) L
        Row::DistriFusion => 2.0 * hs * l,
        // K/V blocks circulate the full ring per layer: 2 O(p·hs) L
        Row::SpRing => 2.0 * hs * l,
        // 4 All2All per layer, each 1/n of the activation: 4/n O(p·hs) L
        Row::SpUlysses => 4.0 / n as f64 * hs * l,
        // one activation patch in + out per micro-step, no L factor:
        // 2 O(p·hs)
        Row::PipeFusion => 2.0 * hs,
    }
}

/// Per-device per-step communication bytes of a *hybrid* config — the
/// Table-1 rows composed the way the mesh composes them (the planner's
/// comm figure, reported in every `Plan`):
/// * SP-Ulysses moves 4 All2All/layer of `1/u` of the activation; the
///   patch split cancels (M patches × act/M), so the volume matches the
///   single-method row at degree `u`;
/// * SP-Ring circulates the K/V blocks — degree-independent, 2 O(p·hs) L;
/// * PipeFusion ships one activation patch in + out per micro-step, and
///   each SP rank only ships its sequence shard: 2 O(p·hs) / sp;
/// * CFG parallel exchanges the predicted latent between the branch pair
///   once per step (fp16).
pub fn config_comm_bytes(m: &ModelSpec, px: usize, pc: &ParallelConfig) -> f64 {
    let s = m.attn_seq_len(px);
    let hs = s as f64 * m.hidden as f64 * 2.0;
    let l = m.layers as f64;
    let mut total = 0.0;
    if pc.ulysses > 1 {
        total += 4.0 / pc.ulysses as f64 * hs * l;
    }
    if pc.ring > 1 {
        total += 2.0 * hs * l;
    }
    if pc.pipefusion > 1 {
        total += 2.0 * hs / pc.sp_degree() as f64;
    }
    if pc.cfg == 2 {
        total += (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 2.0;
    }
    total
}

/// Memory cost multipliers of Table 1 (params, KV), as fractions of the
/// full model parameters `P` and full per-layer KV `(KV)L`.
pub fn memory_fractions(row: Row, n: usize) -> (f64, f64) {
    let inv = 1.0 / n as f64;
    match row {
        Row::TensorParallel => (inv, inv),
        Row::DistriFusion => (1.0, 1.0),
        Row::SpRing | Row::SpUlysses => (1.0, inv),
        Row::PipeFusion => (inv, inv),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::ModelSpec;

    #[test]
    fn table1_ordering_holds() {
        // PipeFusion lowest when N < 2L (paper §4.1.3)
        let m = ModelSpec::by_name("sd3").unwrap(); // L = 24
        let s = m.seq_len(1024);
        for n in [2, 4, 8, 16] {
            let pf = comm_bytes(Row::PipeFusion, &m, s, n);
            for row in [Row::TensorParallel, Row::DistriFusion, Row::SpRing, Row::SpUlysses] {
                assert!(
                    pf < comm_bytes(row, &m, s, n),
                    "pipefusion not lowest at n={n} vs {row:?}"
                );
            }
        }
    }

    #[test]
    fn ulysses_decreases_with_n() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let s = m.seq_len(2048);
        assert!(
            comm_bytes(Row::SpUlysses, &m, s, 8) < comm_bytes(Row::SpUlysses, &m, s, 2)
        );
        // ring does not decrease
        assert_eq!(
            comm_bytes(Row::SpRing, &m, s, 8),
            comm_bytes(Row::SpRing, &m, s, 2)
        );
    }

    #[test]
    fn pipefusion_beats_ulysses_iff_n_lt_2l() {
        let m = ModelSpec::by_name("sd3").unwrap();
        let s = m.seq_len(1024);
        // n < 2L = 48 -> pipefusion wins
        assert!(comm_bytes(Row::PipeFusion, &m, s, 16) < comm_bytes(Row::SpUlysses, &m, s, 16));
        // hypothetical n > 2L -> ulysses would win
        assert!(comm_bytes(Row::PipeFusion, &m, s, 64) > comm_bytes(Row::SpUlysses, &m, s, 64));
    }

    #[test]
    fn config_comm_composes_table1_rows() {
        let m = ModelSpec::by_name("sd3").unwrap();
        let px = 1024;
        let s = m.attn_seq_len(px);
        // pure single-dimension configs reproduce their Table-1 rows
        let ul = ParallelConfig::new(1, 1, 8, 1);
        assert_eq!(config_comm_bytes(&m, px, &ul), comm_bytes(Row::SpUlysses, &m, s, 8));
        let ring = ParallelConfig::new(1, 1, 1, 8);
        assert_eq!(config_comm_bytes(&m, px, &ring), comm_bytes(Row::SpRing, &m, s, 8));
        let pf = ParallelConfig::new(1, 8, 1, 1);
        assert_eq!(config_comm_bytes(&m, px, &pf), comm_bytes(Row::PipeFusion, &m, s, 8));
        // serial moves nothing; cfg alone only the per-step latent
        assert_eq!(config_comm_bytes(&m, px, &ParallelConfig::serial()), 0.0);
        let cfg_only = config_comm_bytes(&m, px, &ParallelConfig::new(2, 1, 1, 1));
        assert!(cfg_only > 0.0 && cfg_only < comm_bytes(Row::PipeFusion, &m, s, 8));
        // a hybrid strictly adds its parts
        let hybrid = ParallelConfig::new(2, 2, 2, 1);
        let parts = config_comm_bytes(&m, px, &ParallelConfig::new(1, 1, 2, 1))
            + comm_bytes(Row::PipeFusion, &m, s, 2) / 2.0
            + cfg_only;
        assert!((config_comm_bytes(&m, px, &hybrid) - parts).abs() < 1e-6);
    }

    #[test]
    fn memory_fractions_match_table() {
        assert_eq!(memory_fractions(Row::PipeFusion, 4), (0.25, 0.25));
        assert_eq!(memory_fractions(Row::DistriFusion, 4), (1.0, 1.0));
        assert_eq!(memory_fractions(Row::SpUlysses, 4), (1.0, 0.25));
        assert_eq!(memory_fractions(Row::TensorParallel, 4), (0.25, 0.25));
    }
}
