//! # The discrete-event overlap simulator (L4)
//!
//! The closed forms in [`perf::latency`](crate::perf::latency) assume
//! perfect or zero overlap per strategy — they can rank configurations
//! but cannot *explain* a ranking step by step. This subsystem lowers any
//! valid `ParallelConfig` into a deterministic per-GPU event timeline:
//! compute segments priced by [`perf::flops`](crate::perf::flops),
//! transfer segments priced by the `ClusterSpec` link model, and the
//! overlap semantics of each strategy made explicit (PipeFusion's async
//! patch P2P hidden behind next-patch compute, ring attention's
//! comm/compute interleave, the CFG all-gather barrier, TP's exposed
//! per-layer all-reduces).
//!
//! The output is a [`Timeline`]: per-rank busy/idle/comm spans, the
//! achieved-overlap fraction, the critical path and the makespan —
//! renderable as an ASCII Gantt ([`render`], the `xdit timeline`
//! command) or exportable as canonical JSON ([`Timeline::to_json`]).
//!
//! Where a strategy's overlap is total or absent (serial, CFG pair,
//! SP-Ring, DistriFusion) the simulated makespan reproduces the closed
//! form exactly; where overlap is partial (TP and SP-Ulysses hide a
//! bounded fraction of their per-layer collectives — [`TP_OVERLAP`],
//! [`ULYSSES_OVERLAP`] — behind the next layer's compute) or pipelined
//! (PipeFusion, hybrids) the two models *disagree*, and the divergence is
//! the signal — e.g. the event pipeline amortizes the fill bubble the
//! closed form charges every step, and the simulated TP/Ulysses makespan
//! lands strictly under the fully-exposed closed form but never below
//! the busiest rank's compute. `benches/simulator.rs` sweeps the
//! Figs 8–17 grid and asserts the agreement band cell by cell;
//! `coordinator::planner` re-scores its top candidates with this
//! simulator under `Fidelity::Simulated` — [`simulate_with`] makes the
//! re-scoring see the plan's collective algorithm too.
//!
//! [`simulate_stages`] additionally lowers the *staged* serving pipeline
//! (denoise ranks feeding dedicated patch-parallel VAE decode ranks
//! through a bounded queue) so the decode-behind-denoise overlap of the
//! staged engine shows up in the same Gantt with its own span kind.

mod gantt;
mod lower;
mod timeline;

pub use gantt::{render, MAX_WIDTH, MIN_WIDTH};
pub use lower::{simulate, simulate_stages, simulate_with, StageSpec, TP_OVERLAP, ULYSSES_OVERLAP};
pub use timeline::{RankTimeline, Span, SpanKind, Timeline};

use crate::config::hardware::ClusterSpec;
use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::perf::latency::{best_hybrid, Method};
use crate::{Error, Result};

/// Strategy names `xdit timeline --strategy` accepts.
pub const STRATEGIES: [&str; 8] =
    ["serial", "cfg", "tp", "ulysses", "ring", "distrifusion", "pipefusion", "hybrid"];

/// Resolve a strategy name into the `(method, config)` pair to simulate
/// on `world` devices — the single mapping the `timeline` CLI, the tests
/// and the bench share. `hybrid` picks the best hybrid configuration for
/// the cell *at the given step count* (warmup amortizes over steps, so a
/// 1-step horizon would bias the search against pipelined configs); the
/// result is validated against the model before it is returned.
pub fn strategy_config(
    name: &str,
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
    steps: usize,
) -> Result<(Method, ParallelConfig)> {
    let (method, pc) = match name {
        "serial" => (Method::Hybrid, ParallelConfig::serial()),
        "cfg" => (Method::Hybrid, ParallelConfig::new(2, 1, 1, 1)),
        "tp" => (Method::Tp, Method::Tp.single_config(world)),
        "ulysses" => (Method::SpUlysses, Method::SpUlysses.single_config(world)),
        "ring" => (Method::SpRing, Method::SpRing.single_config(world)),
        "distrifusion" => (Method::DistriFusion, Method::DistriFusion.single_config(world)),
        "pipefusion" => (Method::PipeFusion, Method::PipeFusion.single_config(world)),
        "hybrid" => (Method::Hybrid, best_hybrid(m, px, cluster, world, steps.max(1)).0),
        _ => {
            return Err(Error::config(format!(
                "unknown strategy '{name}' (expected one of {})",
                STRATEGIES.join("|")
            )))
        }
    };
    pc.validate(m, m.seq_len(px)).map_err(|e| {
        Error::config(format!("strategy '{name}' is not valid for this cell: {e}"))
    })?;
    Ok((method, pc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};

    #[test]
    fn every_strategy_resolves_for_pixart() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let c = l40_cluster(1);
        for name in STRATEGIES {
            let (method, pc) = strategy_config(name, &m, 1024, &c, 8, 2).unwrap();
            if name == "serial" {
                assert!(pc.is_serial());
            } else if name == "cfg" {
                assert_eq!(pc.cfg, 2);
            } else {
                assert_eq!(pc.world(), 8, "{name}: {}", pc.describe());
            }
            let tl = simulate(&m, 1024, &c, method, &pc, 2);
            assert!(tl.makespan > 0.0, "{name} produced an empty timeline");
        }
    }

    #[test]
    fn invalid_strategies_error_cleanly() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let c = a100_node();
        assert!(strategy_config("warp", &m, 1024, &c, 8, 2).is_err());
        // pixart has 16 heads: ulysses degree 5 cannot divide them
        assert!(strategy_config("ulysses", &m, 1024, &c, 5, 2).is_err());
        // flux does not use CFG
        let flux = ModelSpec::by_name("flux").unwrap();
        assert!(strategy_config("cfg", &flux, 1024, &c, 2, 2).is_err());
    }
}
