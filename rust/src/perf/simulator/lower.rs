//! Lowering: `(model, px, cluster, method, config, steps)` → a per-GPU
//! event [`Timeline`].
//!
//! The lowering prices events with the *same* quantities as the
//! closed-form model in `perf::latency` — compute segments from
//! `perf::flops`, transfer segments from the `ClusterSpec` link model —
//! but plays them out on per-rank clocks with explicit overlap semantics
//! per strategy:
//!
//! * **TP / SP-Ulysses** barrier and run their per-layer collectives with
//!   *partial* overlap: a fraction of the collective ([`TP_OVERLAP`],
//!   [`ULYSSES_OVERLAP`]) hides behind the next layer's compute — layer
//!   `i`'s allreduce/all-to-all can launch while layer `i+1`'s
//!   projections run, but the dependent attention blocks eventually
//!   stall on it — capped by the compute actually available
//!   (`(L-1)/L` of the forward). The closed form stays fully exposed
//!   (conservative), so the simulated makespan is bounded by
//!   `max(compute, exposed closed form) ≤ sim ≤ closed form`;
//! * **SP-Ring** interleaves each K/V hop with one block of attention
//!   compute: only the residue `max(hop − block, 0)` plus the launch/sync
//!   cost is exposed (also exact vs the closed form);
//! * **DistriFusion** hides its step-wide AllGather behind the whole
//!   forward; the exposed part is `max(comm − compute, 0)` (exact);
//! * **PipeFusion** is a real pipeline: patches flow stage to stage over
//!   *asynchronous* P2P hidden behind next-patch compute, and the last
//!   stage returns each updated patch latent to the first (one-step-stale
//!   activations let the next step start without a flush). Unlike the
//!   closed form, which charges the `(M+N−1)/M` fill bubble every step,
//!   the event pipeline re-fills only when the return path is too slow —
//!   the bubble amortizes across steps. This is the interesting
//!   divergence `benches/simulator.rs` quantifies;
//! * **CFG parallelism** is a per-step barrier between the branch pair
//!   plus the latent exchange (which also drains a PipeFusion pipeline
//!   every step — visible in the Gantt as a per-step re-fill).
//!
//! Models that use classifier-free guidance run two forwards per step
//! when `cfg == 1` (sequentially, on the same group); a pipeline folds
//! the second forward into its per-patch slot. The hybrid composition
//! charges its USP collectives once per *forward* — the closed form
//! charges them once per *step*, another divergence the simulator makes
//! visible on CFG models.

use std::collections::VecDeque;

use crate::config::hardware::{ClusterSpec, CollectiveAlgo, CollectiveKind};
use crate::config::model::{BlockVariant, ModelSpec};
use crate::config::parallel::ParallelConfig;
use crate::perf::flops;
use crate::perf::latency::{
    best_patches, cfg_latent_bytes, predict_latency_with, ring_sync_cost, Method,
};
use crate::perf::simulator::timeline::{Sim, Timeline};
use crate::vae::memory::{vae_decode_flops, vae_decode_time};

/// Fraction of the SP-Ulysses all-to-all the event simulator lets hide
/// behind the next layer's compute: the head→sequence re-partition of
/// layer `i` can run while layer `i+1`'s QKV projections compute, but the
/// attention that needs the re-partitioned heads stalls on the second
/// half. Applied per forward, capped by the compute actually available
/// (`(L-1)/L` of it — the last layer has nothing left to hide behind).
pub const ULYSSES_OVERLAP: f64 = 0.5;

/// Fraction of the TP per-layer allreduce the event simulator lets hide
/// behind compute. Lower than [`ULYSSES_OVERLAP`]: TP allreduces sit on
/// the residual path, so only the tail of each layer's reduction can run
/// under the next layer's independent projections.
pub const TP_OVERLAP: f64 = 0.25;

/// Everything the per-strategy lowerings share, precomputed once.
struct Cell<'a> {
    m: &'a ModelSpec,
    px: usize,
    cluster: &'a ClusterSpec,
    pc: &'a ParallelConfig,
    /// CFG degree, clamped to >= 1 so degenerate configs cannot divide
    /// by zero.
    cfg: usize,
    /// Intra-image group size (world / cfg).
    n_intra: usize,
    /// Forwards per step per branch group (2 when CFG runs sequentially).
    nf: usize,
    /// Per-forward per-device compute seconds (full model / n_intra).
    fwd: f64,
    /// Full-sequence activation bytes (`O(p·hs)` in fp16).
    hs: f64,
    /// Attention sequence length (tokens).
    s: f64,
    /// Transformer depth.
    l: f64,
    /// Collective algorithm pricing the TP/Ulysses/DistriFusion
    /// collectives (ring hops and patch P2P are algorithm-free).
    algo: CollectiveAlgo,
}

impl<'a> Cell<'a> {
    fn new(
        m: &'a ModelSpec,
        px: usize,
        cluster: &'a ClusterSpec,
        pc: &'a ParallelConfig,
        algo: CollectiveAlgo,
    ) -> Self {
        let world = pc.world().max(1);
        let cfg = pc.cfg.max(1);
        let n_intra = (world / cfg).max(1);
        let branches = if m.uses_cfg { 2 } else { 1 };
        let s = m.attn_seq_len(px);
        Cell {
            m,
            px,
            cluster,
            pc,
            cfg,
            n_intra,
            nf: (branches / cfg).max(1),
            fwd: flops::compute_time(m.step_flops(px), cluster.gpu.tflops) / n_intra as f64,
            hs: s as f64 * m.hidden as f64 * 2.0,
            s: s as f64,
            l: m.layers as f64,
            algo,
        }
    }

    /// Ranks of CFG branch `b` (cfg outermost, the same placement as
    /// `perf::latency`).
    fn branch(&self, b: usize) -> Vec<usize> {
        (0..self.n_intra).map(|i| b * self.n_intra + i).collect()
    }
}

/// Cross-step pipeline state of one branch: when the last stage sent the
/// updated latent of each patch back to stage 0 (the stale return path).
struct PipeState {
    ret_sent: Vec<f64>,
}

/// Run the discrete-event simulation for one generation and return its
/// per-rank [`Timeline`]. Accepts exactly the inputs of
/// `perf::latency::predict_latency`, whose closed-form total is attached
/// to the result for comparison. The config should already satisfy
/// `ParallelConfig::validate` for the model; degenerate inputs degrade to
/// a serial timeline rather than panicking.
pub fn simulate(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
    steps: usize,
) -> Timeline {
    simulate_with(m, px, cluster, method, pc, steps, CollectiveAlgo::FlatRing)
}

/// [`simulate`] with an explicit collective algorithm: the TP allreduce,
/// Ulysses all-to-all, and DistriFusion allgather are priced through
/// [`ClusterSpec::collective_cost`], and the attached closed form is the
/// matching [`predict_latency_with`]. `Fidelity::Simulated` planning uses
/// this so re-scoring sees both the hierarchy and the overlap.
pub fn simulate_with(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
    steps: usize,
    algo: CollectiveAlgo,
) -> Timeline {
    let cell = Cell::new(m, px, cluster, pc, algo);
    let world = pc.world().max(1);
    let mut sim = Sim::new(world);
    let mut pipes: Vec<PipeState> =
        (0..cell.cfg).map(|_| PipeState { ret_sent: Vec::new() }).collect();
    for step in 0..steps {
        for b in 0..cell.cfg {
            let group = cell.branch(b);
            lower_step(&mut sim, &cell, method, &group, step, &mut pipes[b]);
        }
        if cell.cfg == 2 {
            cfg_exchange(&mut sim, &cell, world);
        }
    }
    let closed = predict_latency_with(m, px, cluster, method, pc, steps, algo);
    sim.finish(
        method.label(),
        m.name.clone(),
        px,
        cluster.name.clone(),
        pc.describe(),
        steps,
        closed.total,
    )
}

/// Per-step latent exchange + barrier between the CFG branch pair
/// (mirrors the closed form's per-step `cfg_allgather` charge).
fn cfg_exchange(sim: &mut Sim, cell: &Cell, world: usize) {
    let latent_bytes = cfg_latent_bytes(cell.m, cell.px);
    let t = cell.cluster.p2p_time(0, world / 2, latent_bytes);
    let all: Vec<usize> = (0..world).collect();
    sim.barrier(&all, "cfg sync");
    for &r in &all {
        sim.exposed(r, t, "cfg exchange");
    }
}

/// Lower one diffusion step of one branch group.
fn lower_step(
    sim: &mut Sim,
    cell: &Cell,
    method: Method,
    group: &[usize],
    step: usize,
    pipe: &mut PipeState,
) {
    let n = cell.n_intra as f64;
    // per-forward compute a layer-interleaved collective can hide behind:
    // everything but the last layer's slice
    let overlap_budget = (cell.l - 1.0).max(0.0) / cell.l * cell.fwd;
    match method {
        Method::Tp => {
            let ar =
                cell.cluster.collective_cost(group, cell.hs, CollectiveKind::AllReduce, cell.algo);
            let t = 2.0 * cell.l * ar;
            let hidden = (TP_OVERLAP * t).min(overlap_budget);
            for _ in 0..cell.nf {
                sim.barrier(group, "step sync");
                for &r in group {
                    sim.compute(r, cell.fwd, "compute");
                    sim.hidden(r, hidden);
                }
                sim.collective(group, t - hidden, "allreduce");
            }
        }
        Method::SpUlysses => {
            let a2a = cell.cluster.collective_cost(
                group,
                4.0 * cell.hs / n,
                CollectiveKind::AllToAll,
                cell.algo,
            );
            let t = cell.l * a2a;
            let hidden = (ULYSSES_OVERLAP * t).min(overlap_budget);
            for _ in 0..cell.nf {
                sim.barrier(group, "step sync");
                for &r in group {
                    sim.compute(r, cell.fwd, "compute");
                    sim.hidden(r, hidden);
                }
                sim.collective(group, t - hidden, "all2all");
            }
        }
        Method::SpRing => {
            let hop_bytes = 2.0 * cell.hs / n;
            let ring_t = cell.cluster.collective_time(group, hop_bytes, 1.0);
            let hop_t = ring_t / (n - 1.0).max(1.0);
            let blk_fl = 4.0 * (cell.s / n) * (cell.s / n) * cell.m.hidden as f64;
            let blk = flops::compute_time(blk_fl, cell.cluster.gpu.tflops);
            let hops = (n - 1.0) * cell.l;
            let residue = ((hop_t - blk).max(0.0) + ring_sync_cost(cell.cluster)) * hops;
            for _ in 0..cell.nf {
                sim.barrier(group, "step sync");
                for &r in group {
                    sim.compute(r, cell.fwd, "compute");
                    sim.exposed(r, residue, "ring residue");
                    sim.hidden(r, hop_t.min(blk) * hops);
                }
            }
        }
        Method::DistriFusion => {
            // one step-wide async AllGather hidden behind the whole
            // forward (both CFG forwards share it, as in the closed form)
            let bytes = 2.0 * cell.hs * cell.l / n;
            let t_comm = match cell.algo {
                CollectiveAlgo::FlatRing => cell.cluster.collective_time(group, bytes, n - 1.0),
                CollectiveAlgo::Hierarchical => {
                    cell.cluster.collective_cost(group, bytes, CollectiveKind::AllGather, cell.algo)
                }
            };
            let compute = cell.fwd * cell.nf as f64;
            sim.barrier(group, "step sync");
            for &r in group {
                if step == 0 {
                    // synchronous warmup step: ~full-model compute extra
                    sim.compute(r, compute * (n - 1.0), "warmup");
                }
                sim.compute(r, compute, "compute");
                sim.exposed(r, (t_comm - compute).max(0.0), "allgather residue");
                sim.hidden(r, t_comm.min(compute));
            }
        }
        Method::PipeFusion | Method::Hybrid => {
            lower_hybrid(sim, cell, method, group, step, pipe);
        }
    }
}

/// The composed lowering PipeFusion and the hybrid share: a patch
/// pipeline across stages (degree > 1) with USP communication inside
/// each stage, or the flat USP step when there is no pipeline dimension.
fn lower_hybrid(
    sim: &mut Sim,
    cell: &Cell,
    method: Method,
    group: &[usize],
    step: usize,
    pipe: &mut PipeState,
) {
    let pc = cell.pc;
    let stages = if method == Method::PipeFusion { cell.n_intra } else { pc.pipefusion };
    if stages <= 1 {
        lower_flat_usp(sim, cell, group);
        return;
    }
    let patches = if method == Method::PipeFusion {
        pc.patches.max(best_patches(cell.n_intra))
    } else {
        pc.patches.max(2)
    };
    let sp = if method == Method::PipeFusion { 1 } else { pc.sp_degree() };
    // per-patch per-stage compute slot (CFG forwards folded in)
    let u = cell.fwd * cell.nf as f64 / patches as f64;
    // per-patch intra-stage USP comm (hybrid only; zero for pure pipe)
    let (ul_patch, ul_hidden, ring_residue, ring_hidden) = stage_usp_costs(cell, group, patches, u);
    // activation patch shipped between adjacent stages (each SP rank
    // ships only its shard; CFG folds the second forward's patch in)
    let patch_bytes = cell.hs / patches as f64 / sp as f64 * cell.nf as f64;
    // updated patch latent returned from the last stage to the first
    let patch_tokens = cell.m.seq_len(cell.px) as f64 / patches as f64;
    let ret_bytes = patch_tokens * cell.m.c_latent as f64 * 2.0;
    let stage_ranks: Vec<Vec<usize>> =
        (0..stages).map(|j| group[j * sp..(j + 1) * sp].to_vec()).collect();
    // slowest rank-to-rank pair between stage j and stage j + 1
    let p2p = |j: usize| {
        let mut worst = 0.0f64;
        for i in 0..sp {
            let (a, b) = (stage_ranks[j][i], stage_ranks[j + 1][i]);
            worst = worst.max(cell.cluster.p2p_time(a, b, patch_bytes));
        }
        worst
    };
    // Fig 17: skip-connection models ship non-adjacent skip activations
    // whose transfer cannot be overlapped — charged once per patch
    let skip_t = if method == Method::PipeFusion && cell.m.variant == BlockVariant::Skip {
        cell.cluster.p2p_time(group[0], group[group.len() - 1], patch_bytes)
    } else {
        0.0
    };
    if pipe.ret_sent.len() != patches {
        pipe.ret_sent = vec![0.0; patches];
    }
    let last = stages - 1;

    if step < pc.warmup_steps {
        // synchronous warmup: a stage needs every patch's fresh hidden
        // state before attention, so stages run strictly one after
        // another — the ~serial step the closed form charges
        for j in 0..stages {
            if j > 0 {
                let sent = sim.now(stage_ranks[j - 1][0]);
                let t = p2p(j - 1);
                for &r in &stage_ranks[j] {
                    sim.recv_async(r, sent, t, "warmup p2p");
                }
            }
            for &r in &stage_ranks[j] {
                sim.compute(r, u * patches as f64, "warmup");
                // synchronous warmup: nothing interleaves, so the
                // otherwise-hidden Ulysses share is exposed too
                let comm = (ul_patch + ul_hidden + ring_residue + skip_t) * patches as f64;
                sim.exposed(r, comm, "warmup comm");
                sim.hidden(r, ring_hidden * patches as f64);
            }
        }
        let done = sim.now(stage_ranks[last][0]);
        for sent in &mut pipe.ret_sent {
            *sent = done;
        }
        return;
    }

    // overlapped steps: patch k at stage j depends on patch k at stage
    // j − 1 (async P2P) and, at stage 0, on the updated latent the last
    // stage produced for patch k one step earlier (the stale return
    // path) — both hidden behind whatever the stage is busy with
    let ret_t = cell.cluster.p2p_time(stage_ranks[last][0], stage_ranks[0][0], ret_bytes);
    for k in 0..patches {
        for j in 0..stages {
            if j == 0 {
                let sent = pipe.ret_sent[k];
                for &r in &stage_ranks[0] {
                    sim.recv_async(r, sent, ret_t, "stale return");
                }
            } else {
                let sent = sim.now(stage_ranks[j - 1][0]);
                let t = p2p(j - 1);
                for &r in &stage_ranks[j] {
                    sim.recv_async(r, sent, t, "patch p2p");
                }
            }
            if sp > 1 {
                sim.barrier(&stage_ranks[j], "stage sync");
            }
            for &r in &stage_ranks[j] {
                sim.compute(r, u, "compute");
                sim.exposed(r, ul_patch, "all2all");
                sim.exposed(r, ring_residue, "ring residue");
                sim.hidden(r, ring_hidden + ul_hidden);
                if j == last {
                    sim.exposed(r, skip_t, "skip p2p");
                }
            }
        }
        pipe.ret_sent[k] = sim.now(stage_ranks[last][0]);
    }
}

/// Shape of a staged serve to lower into the event simulator: how many
/// batches flow through the denoise→decode pipeline and how the decode
/// stage is provisioned. Mirrors the `coordinator::Engine` staged-mode
/// knobs (`stage_overlap`, `vae_parallelism`, `stage_queue_capacity`).
#[derive(Debug, Clone, Copy)]
pub struct StageSpec {
    /// Batches pushed through the pipeline (clamped to >= 1).
    pub batches: usize,
    /// Patch-parallel VAE degree: the number of dedicated decode ranks
    /// appended after the denoise ranks (clamped to >= 1).
    pub vae_parallelism: usize,
    /// Bounded denoise→decode queue: with `k` decodes in flight whose
    /// start has not yet freed a slot, the next denoise stalls (clamped
    /// to >= 1).
    pub queue_capacity: usize,
    /// `true` overlaps decode of batch N with denoise of batch N+1
    /// (subject to the queue bound); `false` replays the serial engine,
    /// draining each decode before the next denoise launches.
    pub overlap: bool,
}

/// Lower a staged serve — `spec.batches` generations flowing through the
/// denoise→decode pipeline — into a per-rank [`Timeline`].
///
/// Ranks `0..world` run the denoise stage: each batch is one Compute
/// span whose duration is the full event-simulated makespan of a single
/// generation under `(method, pc, steps)` (the same [`simulate`] the
/// `timeline` CLI plays for one image). Ranks `world..world+vae_n` are
/// the dedicated decode stage: each batch decodes as one exposed-comm
/// span (the halo exchange + stitch of the patch-parallel VAE) followed
/// by one [`SpanKind::Decode`](crate::perf::simulator::SpanKind::Decode)
/// span (the conv stack at `1/n` per rank), priced by
/// `vae::memory::vae_decode_time` on the worst link of the first
/// `vae_n` devices — the quantities the serving engine charges.
///
/// With `overlap` off, denoise of batch N+1 waits for decode of batch N
/// to *finish* (one clock, the serial engine) and the makespan equals
/// the closed form `batches · (denoise + decode)` attached to the
/// result. With `overlap` on, it waits only for decode of batch
/// N−capacity to *start* (the bounded-queue gate), so the decode tail
/// of each batch hides behind the next denoise and the makespan is
/// never worse — the Gantt shows `v` spans of batch N under `#` spans
/// of batch N+1.
pub fn simulate_stages(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
    steps: usize,
    spec: StageSpec,
) -> Timeline {
    let world = pc.world().max(1);
    let vae_n = spec.vae_parallelism.max(1);
    let batches = spec.batches.max(1);
    let cap = spec.queue_capacity.max(1);
    // one batch of denoising = the full event simulation of one image
    let den_t = simulate(m, px, cluster, method, pc, steps).makespan;
    // decode priced on the worst link among the first vae_n devices,
    // split into its conv-compute part (the Decode span) and the halo +
    // stitch + launch remainder (an exposed Comm span)
    let group: Vec<usize> = (0..vae_n.min(cluster.n_gpus.max(1))).collect();
    let k = cluster.worst_link(&group);
    let dec_t =
        vae_decode_time(px, vae_n, cluster.gpu.tflops, cluster.link_bw(k), cluster.link_lat(k));
    let dec_compute = vae_decode_flops(px) / (cluster.gpu.tflops * 1e12 * 0.15) / vae_n as f64;
    let dec_comm = (dec_t - dec_compute).max(0.0);
    let denoise_ranks: Vec<usize> = (0..world).collect();
    let decode_ranks: Vec<usize> = (world..world + vae_n).collect();
    let mut sim = Sim::new(world + vae_n);
    // start times of the last <= cap decodes (the engine's bounded queue)
    let mut dec_starts: VecDeque<f64> = VecDeque::new();
    let mut dec_fin = 0.0f64;
    for _ in 0..batches {
        let gate = if !spec.overlap {
            dec_fin
        } else if dec_starts.len() >= cap {
            *dec_starts.front().unwrap()
        } else {
            0.0
        };
        for &r in &denoise_ranks {
            sim.wait(r, gate, "decode gate");
            sim.compute(r, den_t, "denoise");
        }
        let den_fin = sim.now(denoise_ranks[0]);
        for &r in &decode_ranks {
            sim.wait(r, den_fin, "await latent");
        }
        let dec_start = sim.now(decode_ranks[0]);
        for &r in &decode_ranks {
            sim.exposed(r, dec_comm, "vae halo");
            sim.decode(r, dec_compute, "vae decode");
        }
        dec_fin = sim.now(decode_ranks[0]);
        dec_starts.push_back(dec_start);
        while dec_starts.len() > cap {
            dec_starts.pop_front();
        }
    }
    sim.finish(
        "staged",
        m.name.clone(),
        px,
        cluster.name.clone(),
        format!("{}+vae={vae_n}", pc.describe()),
        steps,
        batches as f64 * (den_t + dec_t),
    )
}

/// Flat (no-pipeline) USP step: the hybrid row's exposed Ulysses
/// collectives plus the ring-attention residue, once per CFG forward.
fn lower_flat_usp(sim: &mut Sim, cell: &Cell, group: &[usize]) {
    let (ul, ul_hidden, ring_residue, ring_hidden) = stage_usp_costs(cell, group, 1, cell.fwd);
    for _ in 0..cell.nf {
        sim.barrier(group, "step sync");
        for &r in group {
            sim.compute(r, cell.fwd, "compute");
            sim.exposed(r, ul, "all2all");
            sim.exposed(r, ring_residue, "ring residue");
            sim.hidden(r, ring_hidden + ul_hidden);
        }
    }
}

/// Per-patch USP communication inside one stage, mirroring the hybrid
/// closed form's quantities divided across the stage's layer share and
/// `patches` patch slots: `(ulysses exposed, ulysses hidden, ring exposed
/// residue, ring hidden)` seconds. The Ulysses group is priced on the
/// branch's leading ranks, as the closed form does — stages are
/// placement-symmetric. `slot_compute` is the compute seconds available
/// in the slot the collective interleaves with: [`ULYSSES_OVERLAP`] of
/// the all-to-all hides behind it, capped at `(L-1)/L` of the slot.
fn stage_usp_costs(
    cell: &Cell,
    group: &[usize],
    patches: usize,
    slot_compute: f64,
) -> (f64, f64, f64, f64) {
    let pc = cell.pc;
    let n = cell.n_intra as f64;
    let layer_share = cell.l / pc.pipefusion.max(1) as f64 / patches as f64;
    let mut ul = 0.0;
    let mut ul_hidden = 0.0;
    if pc.ulysses > 1 && pc.ulysses <= group.len() {
        let g: Vec<usize> = group[..pc.ulysses].to_vec();
        let a2a = cell.cluster.collective_cost(
            &g,
            4.0 * cell.hs / n,
            CollectiveKind::AllToAll,
            cell.algo,
        );
        let total = layer_share * a2a;
        let budget = (cell.l - 1.0).max(0.0) / cell.l * slot_compute;
        ul_hidden = (ULYSSES_OVERLAP * total).min(budget);
        ul = total - ul_hidden;
    }
    let mut residue = 0.0;
    let mut hidden = 0.0;
    if pc.ring > 1 && pc.sp_degree() <= group.len() {
        let nsp = pc.sp_degree() as f64;
        let g: Vec<usize> = group[..pc.sp_degree()].to_vec();
        let hop_bytes = 2.0 * cell.hs / nsp / pc.patches as f64;
        let ring_t = cell.cluster.collective_time(&g, hop_bytes, 1.0);
        let hop_t = ring_t / (pc.ring as f64 - 1.0).max(1.0);
        let blk_fl =
            4.0 * (cell.s / nsp) * (cell.s / nsp) * cell.m.hidden as f64 / pc.patches as f64;
        let blk = flops::compute_time(blk_fl, cell.cluster.gpu.tflops);
        let hops = (pc.ring as f64 - 1.0) * layer_share;
        residue = ((hop_t - blk).max(0.0) + ring_sync_cost(cell.cluster)) * hops;
        hidden = hop_t.min(blk) * hops;
    }
    (ul, ul_hidden, residue, hidden)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::perf::latency::{predict_latency, serial_latency};

    fn pixart() -> ModelSpec {
        ModelSpec::by_name("pixart").unwrap()
    }

    #[test]
    fn serial_matches_closed_form_exactly() {
        let m = pixart();
        let c = l40_cluster(1);
        let pc = ParallelConfig::serial();
        let tl = simulate(&m, 1024, &c, Method::Hybrid, &pc, 4);
        assert_eq!(tl.world(), 1);
        let serial = serial_latency(&m, 1024, &c, 4);
        assert!((tl.makespan - serial).abs() < 1e-9 * serial, "{} vs {serial}", tl.makespan);
        assert_eq!(tl.exposed_comm(), 0.0);
        assert_eq!(tl.achieved_overlap(), 1.0);
    }

    #[test]
    fn tp_and_ulysses_partially_hide_their_collectives() {
        // partial overlap: a bounded fraction of the per-layer collective
        // hides behind compute, so the simulated makespan lands strictly
        // between the compute floor and the fully-exposed closed form
        let m = pixart();
        for cluster in [l40_cluster(1), a100_node()] {
            for (meth, beta) in [(Method::Tp, TP_OVERLAP), (Method::SpUlysses, ULYSSES_OVERLAP)] {
                let pc = meth.single_config(8);
                let cf = predict_latency(&m, 2048, &cluster, meth, &pc, 6);
                let tl = simulate(&m, 2048, &cluster, meth, &pc, 6);
                assert!(
                    tl.makespan < cf.total,
                    "{meth:?} on {}: sim {} !< closed {}",
                    cluster.name,
                    tl.makespan,
                    cf.total
                );
                assert!(tl.makespan >= tl.max_rank_compute() - 1e-12);
                assert!(tl.hidden_comm() > 0.0, "{meth:?} must hide the overlapped share");
                // the hidden share is exactly min(beta*comm, (L-1)/L*fwd)
                // per forward — reconstruct and check the makespan algebra
                let world = pc.world() as f64;
                let nf = 2.0; // pixart uses CFG: two forwards per step
                let fwd = flops::compute_time(m.step_flops(2048), cluster.gpu.tflops) / world;
                let l = m.layers as f64;
                let per_fwd_comm = cf.comm_exposed / 6.0 / nf;
                let hidden = (beta * per_fwd_comm).min((l - 1.0) / l * fwd);
                let expect = cf.total - 6.0 * nf * hidden;
                let rel = (tl.makespan - expect).abs() / expect;
                assert!(rel < 1e-9, "{meth:?} on {}: {} vs {expect}", cluster.name, tl.makespan);
            }
        }
    }

    #[test]
    fn partial_overlap_bounded_by_compute_and_closed_form() {
        // property: for every method and enumerable config, the simulated
        // makespan with partial overlap stays within
        // max(compute floor, exposed comm) <= makespan <= fully-exposed
        // closed form (+ the pipeline strategies may amortize below it,
        // so the upper bound applies to the barrier strategies only)
        let m = pixart();
        for cluster in [l40_cluster(1), l40_cluster(2), a100_node()] {
            for world in [2usize, 4, 8] {
                for meth in [Method::Tp, Method::SpUlysses] {
                    let pc = meth.single_config(world);
                    let cf = predict_latency(&m, 1024, &cluster, meth, &pc, 4);
                    let tl = simulate(&m, 1024, &cluster, meth, &pc, 4);
                    let floor = tl.max_rank_compute().max(tl.exposed_comm() / tl.world() as f64);
                    assert!(
                        tl.makespan >= floor - 1e-12,
                        "{meth:?} w={world} on {}: makespan {} < floor {floor}",
                        cluster.name,
                        tl.makespan,
                    );
                    assert!(
                        tl.makespan <= cf.total + 1e-12,
                        "{meth:?} w={world} on {}: makespan {} > closed {}",
                        cluster.name,
                        tl.makespan,
                        cf.total
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_lowering_never_slower_cross_node() {
        // simulated multi-node cells: hierarchical collectives cannot lose
        // to the flat ring, and win outright when the collective crosses
        // Ethernet
        let m = pixart();
        let c = l40_cluster(2);
        for meth in [Method::Tp, Method::SpUlysses, Method::DistriFusion] {
            let pc = meth.single_config(16);
            let flat = simulate(&m, 2048, &c, meth, &pc, 4);
            let hier =
                simulate_with(&m, 2048, &c, meth, &pc, 4, CollectiveAlgo::Hierarchical);
            assert!(
                hier.makespan <= flat.makespan + 1e-12,
                "{meth:?}: hier {} > flat {}",
                hier.makespan,
                flat.makespan
            );
        }
        let pc = Method::SpUlysses.single_config(16);
        let flat = simulate(&m, 2048, &c, Method::SpUlysses, &pc, 4);
        let hier =
            simulate_with(&m, 2048, &c, Method::SpUlysses, &pc, 4, CollectiveAlgo::Hierarchical);
        assert!(hier.makespan < flat.makespan);
        // and on a single node the algorithm choice is invisible
        let pc8 = Method::SpUlysses.single_config(8);
        let c1 = l40_cluster(1);
        let a = simulate(&m, 2048, &c1, Method::SpUlysses, &pc8, 4);
        let b = simulate_with(&m, 2048, &c1, Method::SpUlysses, &pc8, 4, CollectiveAlgo::Hierarchical);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    }

    #[test]
    fn ring_and_distrifusion_match_closed_form() {
        // full-overlap strategies: the simulator exposes exactly the
        // residue the closed form does, and hides the rest
        let m = pixart();
        for cluster in [l40_cluster(1), a100_node()] {
            for meth in [Method::SpRing, Method::DistriFusion] {
                let pc = meth.single_config(8);
                let cf = predict_latency(&m, 2048, &cluster, meth, &pc, 6).total;
                let tl = simulate(&m, 2048, &cluster, meth, &pc, 6);
                let rel = (tl.makespan - cf).abs() / cf;
                assert!(rel < 1e-9, "{meth:?} on {}: {} vs {cf}", cluster.name, tl.makespan);
                assert!(tl.hidden_comm() > 0.0, "{meth:?} must hide transfers");
            }
        }
    }

    #[test]
    fn cfg_pair_matches_closed_form() {
        let m = pixart();
        let c = l40_cluster(1);
        let pc = ParallelConfig::new(2, 1, 1, 1);
        let cf = predict_latency(&m, 1024, &c, Method::Hybrid, &pc, 5).total;
        let tl = simulate(&m, 1024, &c, Method::Hybrid, &pc, 5);
        assert!((tl.makespan - cf).abs() < 1e-9 * cf, "sim {} cf {cf}", tl.makespan);
        // the latent exchange is a real exposed span on every rank
        assert!(tl.exposed_comm() > 0.0);
    }

    #[test]
    fn pipefusion_amortizes_the_fill_bubble() {
        // the closed form charges the (M+N-1)/M bubble every step; the
        // event pipeline pays it once — so the simulator must be faster,
        // and the per-step increment must approach M·u (no bubble)
        let m = pixart();
        let c = l40_cluster(1);
        let pc = Method::PipeFusion.single_config(8);
        let short = simulate(&m, 1024, &c, Method::PipeFusion, &pc, 6);
        let long = simulate(&m, 1024, &c, Method::PipeFusion, &pc, 12);
        let cf_long = predict_latency(&m, 1024, &c, Method::PipeFusion, &pc, 12).total;
        assert!(
            long.makespan < cf_long,
            "event pipeline must beat the per-step-bubble closed form: {} vs {cf_long}",
            long.makespan
        );
        // steady-state increment: 6 extra steps of pipelined patches
        let increment = long.makespan - short.makespan;
        let full_fwd = flops::compute_time(m.step_flops(1024), c.gpu.tflops);
        let per_step = 2.0 * full_fwd / 8.0; // 2 CFG forwards over 8 stages
        assert!(
            (increment - 6.0 * per_step).abs() < 0.35 * 6.0 * per_step,
            "steady-state step cost {increment} far from {}",
            6.0 * per_step
        );
        assert!(long.achieved_overlap() > 0.5, "patch P2P must be mostly hidden");
    }

    #[test]
    fn warmup_step_is_roughly_serial() {
        let m = pixart();
        let c = l40_cluster(1);
        let pc = Method::PipeFusion.single_config(4);
        let one = simulate(&m, 1024, &c, Method::PipeFusion, &pc, 1);
        // one warmup step ~ the serial step time (stages strictly serial)
        let serial_step = serial_latency(&m, 1024, &c, 1);
        assert!(
            one.makespan > 0.9 * serial_step && one.makespan < 1.3 * serial_step,
            "warmup {} vs serial step {serial_step}",
            one.makespan
        );
    }

    #[test]
    fn skip_models_expose_the_skip_p2p() {
        let m = ModelSpec::by_name("hunyuan").unwrap();
        let c = a100_node();
        let pc = Method::PipeFusion.single_config(2);
        let tl = simulate(&m, 2048, &c, Method::PipeFusion, &pc, 4);
        let mut skip = 0.0;
        for r in &tl.ranks {
            for s in &r.spans {
                if s.label == "skip p2p" {
                    skip += s.seconds();
                }
            }
        }
        assert!(skip > 0.0, "skip-connection P2P must appear as exposed spans");
    }

    #[test]
    fn staged_lowering_overlaps_decode_with_next_denoise() {
        use crate::perf::simulator::timeline::SpanKind;
        let m = pixart();
        let c = l40_cluster(1);
        let pc = Method::SpUlysses.single_config(4);
        let spec = StageSpec { batches: 4, vae_parallelism: 2, queue_capacity: 2, overlap: false };
        let off = simulate_stages(&m, 1024, &c, Method::SpUlysses, &pc, 2, spec);
        let on = simulate_stages(
            &m,
            1024,
            &c,
            Method::SpUlysses,
            &pc,
            2,
            StageSpec { overlap: true, ..spec },
        );
        // overlap off replays the serial engine: the makespan is exactly
        // the closed form batches·(denoise + decode)
        assert!(
            (off.makespan - off.closed_form).abs() < 1e-9 * off.closed_form,
            "{} vs {}",
            off.makespan,
            off.closed_form
        );
        // overlap on is strictly better here (each decode tail hides
        // behind the next batch's denoise) and never worse by induction
        assert!(on.makespan < off.makespan, "{} !< {}", on.makespan, off.makespan);
        // dedicated decode ranks carry the distinct Decode span kind and
        // the Gantt renders it with its own glyph
        assert_eq!(on.world(), 4 + 2);
        let decode_s: f64 = on.ranks[4..].iter().map(|r| r.seconds(SpanKind::Decode)).sum();
        assert!(decode_s > 0.0, "decode ranks must carry Decode spans");
        assert!(on.ranks[..4].iter().all(|r| r.seconds(SpanKind::Decode) == 0.0));
        assert!(on.gantt(120).contains('v'), "{}", on.gantt(120));
        // a tighter queue bound can only delay denoise launches
        let tight = simulate_stages(
            &m,
            1024,
            &c,
            Method::SpUlysses,
            &pc,
            2,
            StageSpec { overlap: true, queue_capacity: 1, ..spec },
        );
        assert!(tight.makespan >= on.makespan - 1e-12);
    }

    #[test]
    fn makespan_never_below_busiest_rank() {
        let m = pixart();
        let c = l40_cluster(2);
        for world in [2usize, 4, 8, 16] {
            for pc in ParallelConfig::enumerate(world, &m, m.seq_len(1024)) {
                let tl = simulate(&m, 1024, &c, Method::Hybrid, &pc, 3);
                assert!(
                    tl.makespan >= tl.max_rank_compute() - 1e-12,
                    "[{}] makespan {} < compute bound {}",
                    pc.describe(),
                    tl.makespan,
                    tl.max_rank_compute()
                );
            }
        }
    }
}
