//! Event-timeline types and the deterministic event core.
//!
//! A [`Timeline`] is the simulator's output: one span list per rank
//! (compute / exposed-communication / idle), plus aggregate figures — the
//! makespan, the achieved-overlap fraction and the critical rank. Spans
//! are contiguous, non-overlapping and sorted per rank; consecutive spans
//! with the same kind and label are merged, so even a long PipeFusion run
//! stays compact.
//!
//! The private `Sim` builder is the event core the lowering in
//! `perf::simulator::lower` drives: per-rank virtual clocks plus span
//! recording, with `compute` / `exposed` / `wait` / `barrier` /
//! `collective` / `recv_async` as the primitive operations. Hidden
//! (fully-overlapped) transfer time never appears as a span — it is
//! accounted per rank in [`RankTimeline::hidden_comm`], which is what the
//! achieved-overlap fraction is computed from.

use crate::util::json::Json;

/// What a rank was doing during a [`Span`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Local FLOPs (denoising compute, warmup recompute).
    Compute,
    /// Communication time that blocked the rank (exposed, not hidden).
    Comm,
    /// VAE-decode compute (the staged pipeline's third stage — kept
    /// distinct from [`SpanKind::Compute`] so the Gantt shows the
    /// denoise/decode overlap).
    Decode,
    /// Waiting on a dependency or a barrier.
    Idle,
}

impl SpanKind {
    /// Stable string key (used by the JSON export).
    pub fn key(&self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Comm => "comm",
            SpanKind::Decode => "decode",
            SpanKind::Idle => "idle",
        }
    }

    /// One-character glyph for the ASCII Gantt rendering.
    pub fn glyph(&self) -> char {
        match self {
            SpanKind::Compute => '#',
            SpanKind::Comm => '~',
            SpanKind::Decode => 'v',
            SpanKind::Idle => '.',
        }
    }
}

/// One contiguous interval of a rank's timeline.
#[derive(Debug, Clone)]
pub struct Span {
    /// What the rank was doing.
    pub kind: SpanKind,
    /// Human-readable label ("compute", "all2all", "cfg exchange", ...).
    pub label: &'static str,
    /// Start time in virtual seconds.
    pub start: f64,
    /// End time in virtual seconds (`end >= start`).
    pub end: f64,
}

impl Span {
    /// Duration in virtual seconds.
    pub fn seconds(&self) -> f64 {
        self.end - self.start
    }
}

/// The event timeline of a single rank.
#[derive(Debug, Clone)]
pub struct RankTimeline {
    /// Device index in the mesh (0-based).
    pub rank: usize,
    /// Contiguous, sorted, non-overlapping spans from t = 0.
    pub spans: Vec<Span>,
    /// Transfer seconds that were fully in flight behind this rank's
    /// compute (asynchronous P2P, ring hops under attention) — the
    /// communication the strategy successfully hid.
    pub hidden_comm: f64,
}

impl RankTimeline {
    /// Total seconds of spans of `kind`.
    pub fn seconds(&self, kind: SpanKind) -> f64 {
        self.spans.iter().filter(|s| s.kind == kind).map(Span::seconds).sum()
    }

    /// Total compute seconds.
    pub fn compute_seconds(&self) -> f64 {
        self.seconds(SpanKind::Compute)
    }

    /// Total exposed-communication seconds.
    pub fn comm_seconds(&self) -> f64 {
        self.seconds(SpanKind::Comm)
    }

    /// Total idle seconds.
    pub fn idle_seconds(&self) -> f64 {
        self.seconds(SpanKind::Idle)
    }

    /// When this rank finished its last span.
    pub fn finish(&self) -> f64 {
        self.spans.last().map(|s| s.end).unwrap_or(0.0)
    }
}

/// A per-GPU event timeline for one generation: the simulator's output.
///
/// ```
/// use xdit::config::hardware::l40_cluster;
/// use xdit::config::model::ModelSpec;
/// use xdit::perf::latency::Method;
/// use xdit::perf::simulator::simulate;
///
/// let m = ModelSpec::by_name("pixart")?;
/// let pc = Method::PipeFusion.single_config(4);
/// let tl = simulate(&m, 1024, &l40_cluster(1), Method::PipeFusion, &pc, 4);
/// assert_eq!(tl.ranks.len(), 4);
/// assert!(tl.makespan > 0.0);
/// // PipeFusion hides patch P2P behind next-patch compute
/// assert!(tl.achieved_overlap() > 0.0);
/// println!("{}", tl.gantt(64));
/// # Ok::<(), xdit::Error>(())
/// ```
#[derive(Debug, Clone)]
pub struct Timeline {
    /// Strategy that was lowered (a `perf::latency::Method` label).
    pub strategy: &'static str,
    /// Model the timeline describes.
    pub model: String,
    /// Resolution the generation was simulated at.
    pub px: usize,
    /// Cluster name (link model the transfers were priced with).
    pub cluster: String,
    /// The hybrid parallel config, as `ParallelConfig::describe()` prints.
    pub config: String,
    /// Diffusion steps simulated.
    pub steps: usize,
    /// One timeline per rank, index == rank.
    pub ranks: Vec<RankTimeline>,
    /// Virtual seconds until the slowest rank finished.
    pub makespan: f64,
    /// The closed-form prediction for the same cell
    /// (`perf::latency::predict_latency`), for side-by-side comparison.
    pub closed_form: f64,
}

impl Timeline {
    /// Number of simulated devices.
    pub fn world(&self) -> usize {
        self.ranks.len()
    }

    /// Total exposed-communication seconds across ranks.
    pub fn exposed_comm(&self) -> f64 {
        self.ranks.iter().map(RankTimeline::comm_seconds).sum()
    }

    /// Total hidden (fully-overlapped) transfer seconds across ranks.
    pub fn hidden_comm(&self) -> f64 {
        self.ranks.iter().map(|r| r.hidden_comm).sum()
    }

    /// Fraction of all transfer time that was hidden behind compute:
    /// `hidden / (hidden + exposed)`. A strategy that moves no bytes
    /// vacuously achieves 1.0.
    pub fn achieved_overlap(&self) -> f64 {
        let hidden = self.hidden_comm();
        let total = hidden + self.exposed_comm();
        if total <= 0.0 {
            1.0
        } else {
            hidden / total
        }
    }

    /// The rank that finishes last (lowest index on ties) — the rank the
    /// critical path runs through.
    pub fn critical_rank(&self) -> usize {
        let mut best = 0;
        for (i, r) in self.ranks.iter().enumerate() {
            if r.finish() > self.ranks[best].finish() {
                best = i;
            }
        }
        best
    }

    /// One-line description of the critical path: the last-finishing
    /// rank's compute / exposed-comm / idle decomposition.
    pub fn critical_path(&self) -> String {
        let r = &self.ranks[self.critical_rank()];
        format!(
            "rank {} finishes last at {:.3}s ({:.3}s compute, {:.3}s exposed comm, \
             {:.3}s idle)",
            r.rank,
            r.finish(),
            r.compute_seconds(),
            r.comm_seconds(),
            r.idle_seconds()
        )
    }

    /// Largest per-rank pure-compute total — a hard lower bound on the
    /// makespan (no schedule can beat its busiest rank).
    pub fn max_rank_compute(&self) -> f64 {
        self.ranks.iter().map(RankTimeline::compute_seconds).fold(0.0, f64::max)
    }

    /// Mean fraction of the makespan the ranks spent computing.
    pub fn busy_fraction(&self) -> f64 {
        if self.makespan <= 0.0 || self.ranks.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.ranks.iter().map(RankTimeline::compute_seconds).sum();
        busy / (self.makespan * self.ranks.len() as f64)
    }

    /// ASCII per-rank Gantt rendering, `width` columns wide — shorthand
    /// for [`render`](super::render).
    pub fn gantt(&self, width: usize) -> String {
        super::gantt::render(self, width)
    }

    /// Canonical JSON form (sorted keys; the `timeline --json` schema):
    /// scalars at the top level plus a `ranks` array whose entries carry
    /// per-kind second totals and the raw span list.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("strategy".into(), Json::Str(self.strategy.into()));
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("px".into(), Json::Num(self.px as f64));
        o.insert("cluster".into(), Json::Str(self.cluster.clone()));
        o.insert("config".into(), Json::Str(self.config.clone()));
        o.insert("steps".into(), Json::Num(self.steps as f64));
        o.insert("world".into(), Json::Num(self.world() as f64));
        o.insert("makespan_s".into(), Json::Num(self.makespan));
        o.insert("closed_form_s".into(), Json::Num(self.closed_form));
        o.insert("achieved_overlap".into(), Json::Num(self.achieved_overlap()));
        o.insert("critical_rank".into(), Json::Num(self.critical_rank() as f64));
        let mut ranks = Vec::with_capacity(self.ranks.len());
        for r in &self.ranks {
            let mut ro = std::collections::BTreeMap::new();
            ro.insert("rank".into(), Json::Num(r.rank as f64));
            ro.insert("compute_s".into(), Json::Num(r.compute_seconds()));
            ro.insert("comm_s".into(), Json::Num(r.comm_seconds()));
            ro.insert("idle_s".into(), Json::Num(r.idle_seconds()));
            ro.insert("hidden_comm_s".into(), Json::Num(r.hidden_comm));
            let mut spans = Vec::with_capacity(r.spans.len());
            for s in &r.spans {
                let mut so = std::collections::BTreeMap::new();
                so.insert("kind".into(), Json::Str(s.kind.key().into()));
                so.insert("label".into(), Json::Str(s.label.into()));
                so.insert("start_s".into(), Json::Num(s.start));
                so.insert("end_s".into(), Json::Num(s.end));
                spans.push(Json::Obj(so));
            }
            ro.insert("spans".into(), Json::Arr(spans));
            ranks.push(Json::Obj(ro));
        }
        o.insert("ranks".into(), Json::Arr(ranks));
        Json::Obj(o)
    }

    /// [`to_json`](Timeline::to_json) rendered into one buffer presized
    /// from the span count — the `timeline --json` export path, spared
    /// the rendering reallocations of a growing `to_string()` (bytes are
    /// identical; the schema test pins both).
    pub fn to_canonical_string(&self) -> String {
        let spans: usize = self.ranks.iter().map(|r| r.spans.len()).sum();
        let mut buf = String::with_capacity(512 + 96 * spans);
        self.to_json().write_to(&mut buf);
        buf
    }
}

/// The event core: per-rank clocks + span recording. Lowering code in
/// `lower.rs` drives it; `finish()` seals it into a [`Timeline`].
pub(crate) struct Sim {
    t: Vec<f64>,
    ranks: Vec<RankTimeline>,
}

impl Sim {
    pub(crate) fn new(world: usize) -> Sim {
        Sim {
            t: vec![0.0; world],
            ranks: (0..world)
                .map(|rank| RankTimeline { rank, spans: Vec::new(), hidden_comm: 0.0 })
                .collect(),
        }
    }

    /// Current virtual time of `rank`.
    pub(crate) fn now(&self, rank: usize) -> f64 {
        self.t[rank]
    }

    fn push(&mut self, rank: usize, kind: SpanKind, label: &'static str, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        let start = self.t[rank];
        let end = start + dt;
        self.t[rank] = end;
        // merge with the previous span when kind and label repeat
        if let Some(last) = self.ranks[rank].spans.last_mut() {
            if last.kind == kind && last.label == label && last.end == start {
                last.end = end;
                return;
            }
        }
        self.ranks[rank].spans.push(Span { kind, label, start, end });
    }

    /// Charge `dt` seconds of local compute to `rank`.
    pub(crate) fn compute(&mut self, rank: usize, dt: f64, label: &'static str) {
        self.push(rank, SpanKind::Compute, label, dt);
    }

    /// Charge `dt` seconds of exposed (blocking) communication to `rank`.
    pub(crate) fn exposed(&mut self, rank: usize, dt: f64, label: &'static str) {
        self.push(rank, SpanKind::Comm, label, dt);
    }

    /// Charge `dt` seconds of VAE-decode compute to `rank` (the staged
    /// lowering's distinct span kind).
    pub(crate) fn decode(&mut self, rank: usize, dt: f64, label: &'static str) {
        self.push(rank, SpanKind::Decode, label, dt);
    }

    /// Account `dt` transfer seconds that were fully hidden behind
    /// `rank`'s compute (no span — the rank never stopped).
    pub(crate) fn hidden(&mut self, rank: usize, dt: f64) {
        if dt > 0.0 {
            self.ranks[rank].hidden_comm += dt;
        }
    }

    /// Block `rank` until `until` (dependency wait); idle span if it
    /// actually waits.
    pub(crate) fn wait(&mut self, rank: usize, until: f64, label: &'static str) {
        let dt = until - self.t[rank];
        self.push(rank, SpanKind::Idle, label, dt);
    }

    /// Barrier: every rank in `group` reaches the group's max clock.
    pub(crate) fn barrier(&mut self, group: &[usize], label: &'static str) {
        let m = group.iter().map(|&r| self.t[r]).fold(0.0, f64::max);
        for &r in group {
            self.wait(r, m, label);
        }
    }

    /// Synchronous collective: barrier, then `dt` exposed comm on every
    /// rank of the group.
    pub(crate) fn collective(&mut self, group: &[usize], dt: f64, label: &'static str) {
        self.barrier(group, label);
        for &r in group {
            self.exposed(r, dt, label);
        }
    }

    /// Consume an asynchronous transfer that was launched at `sent_at`
    /// and takes `dt` link seconds: the part of the flight time the
    /// receiver had already covered with its own work counts as hidden,
    /// the remainder blocks it as exposed comm.
    pub(crate) fn recv_async(&mut self, rank: usize, sent_at: f64, dt: f64, label: &'static str) {
        let arrive = sent_at + dt;
        let blocked = (arrive - self.t[rank]).max(0.0).min(dt);
        self.hidden(rank, dt - blocked);
        self.exposed(rank, blocked, label);
        // the transfer may arrive after even the blocked wait (the rank
        // was still ahead of the send time): never consume before arrival
        self.wait(rank, arrive, label);
    }

    /// Seal the run into a [`Timeline`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn finish(
        self,
        strategy: &'static str,
        model: String,
        px: usize,
        cluster: String,
        config: String,
        steps: usize,
        closed_form: f64,
    ) -> Timeline {
        let makespan = self.t.iter().copied().fold(0.0, f64::max);
        Timeline {
            strategy,
            model,
            px,
            cluster,
            config,
            steps,
            ranks: self.ranks,
            makespan,
            closed_form,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_merge_and_clocks_advance() {
        let mut sim = Sim::new(2);
        sim.compute(0, 1.0, "compute");
        sim.compute(0, 0.5, "compute");
        sim.exposed(0, 0.25, "comm");
        assert_eq!(sim.ranks[0].spans.len(), 2, "adjacent same-label spans must merge");
        assert_eq!(sim.now(0), 1.75);
        assert_eq!(sim.now(1), 0.0);
    }

    #[test]
    fn barrier_idles_the_laggard() {
        let mut sim = Sim::new(2);
        sim.compute(0, 2.0, "compute");
        sim.barrier(&[0, 1], "sync");
        assert_eq!(sim.now(1), 2.0);
        let tl = sim.finish("test", "m".into(), 256, "c".into(), "serial".into(), 1, 0.0);
        assert_eq!(tl.ranks[1].idle_seconds(), 2.0);
        assert_eq!(tl.ranks[0].idle_seconds(), 0.0);
        assert_eq!(tl.makespan, 2.0);
    }

    #[test]
    fn recv_async_splits_hidden_and_exposed() {
        // receiver busy past arrival: transfer fully hidden
        let mut sim = Sim::new(2);
        sim.compute(0, 1.0, "compute");
        let sent = sim.now(0);
        sim.compute(1, 5.0, "compute");
        sim.recv_async(1, sent, 2.0, "p2p");
        assert_eq!(sim.ranks[1].hidden_comm, 2.0);
        assert_eq!(sim.ranks[1].comm_seconds(), 0.0);
        assert_eq!(sim.now(1), 5.0);
        // receiver idle at send time: transfer fully exposed
        let mut sim = Sim::new(2);
        sim.compute(0, 1.0, "compute");
        let sent = sim.now(0);
        sim.wait(1, 1.0, "fill");
        sim.recv_async(1, sent, 2.0, "p2p");
        assert_eq!(sim.ranks[1].hidden_comm, 0.0);
        assert_eq!(sim.ranks[1].comm_seconds(), 2.0);
        assert_eq!(sim.now(1), 3.0);
    }

    #[test]
    fn timeline_metrics_are_consistent() {
        let mut sim = Sim::new(2);
        sim.compute(0, 3.0, "compute");
        sim.compute(1, 1.0, "compute");
        sim.collective(&[0, 1], 0.5, "allreduce");
        sim.hidden(1, 0.25);
        let tl = sim.finish("test", "m".into(), 256, "c".into(), "tp".into(), 1, 3.5);
        assert_eq!(tl.world(), 2);
        assert_eq!(tl.makespan, 3.5);
        assert_eq!(tl.critical_rank(), 0);
        assert!(tl.critical_path().contains("rank 0"));
        assert_eq!(tl.exposed_comm(), 1.0);
        assert_eq!(tl.hidden_comm(), 0.25);
        assert!((tl.achieved_overlap() - 0.2).abs() < 1e-12);
        assert_eq!(tl.max_rank_compute(), 3.0);
        // json round-trips through the canonical writer
        let parsed = Json::parse(&tl.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("world").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("ranks").unwrap().as_arr().unwrap().len(), 2);
        // the presized export path emits the identical bytes
        assert_eq!(tl.to_canonical_string(), tl.to_json().to_string());
    }

    #[test]
    fn overlap_is_vacuously_total_without_comm() {
        let mut sim = Sim::new(1);
        sim.compute(0, 1.0, "compute");
        let tl = sim.finish("serial", "m".into(), 256, "c".into(), "serial".into(), 1, 1.0);
        assert_eq!(tl.achieved_overlap(), 1.0);
        assert_eq!(tl.busy_fraction(), 1.0);
    }
}
