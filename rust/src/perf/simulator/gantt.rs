//! ASCII Gantt rendering of a [`Timeline`] — what `xdit timeline` prints.
//!
//! One row per rank; the time axis is scaled to the requested width and
//! each column shows the activity that dominated its time slice
//! (`#` compute, `~` exposed comm, `.` idle). A header summarizes the
//! cell (strategy, config, makespan vs closed form, achieved overlap,
//! critical path) and each row ends with the rank's busy/comm/idle
//! decomposition.

use crate::perf::simulator::timeline::{SpanKind, Timeline};

/// Minimum/maximum chart width in columns (the flag is clamped to this).
pub const MIN_WIDTH: usize = 16;
/// See [`MIN_WIDTH`].
pub const MAX_WIDTH: usize = 240;

/// Dominant span kind of `rank` inside the window `[t0, t1)`, or `None`
/// when the rank has already finished.
fn dominant(tl: &Timeline, rank: usize, t0: f64, t1: f64) -> Option<SpanKind> {
    let mut acc = [0.0f64; 4]; // compute, comm, decode, idle
    for s in &tl.ranks[rank].spans {
        let lo = s.start.max(t0);
        let hi = s.end.min(t1);
        if hi > lo {
            let slot = match s.kind {
                SpanKind::Compute => 0,
                SpanKind::Comm => 1,
                SpanKind::Decode => 2,
                SpanKind::Idle => 3,
            };
            acc[slot] += hi - lo;
        }
    }
    if acc.iter().all(|&a| a <= 0.0) {
        return None;
    }
    // ties favour showing communication, then compute, then decode — the
    // rarer and more diagnostic signals (decode only ever shares a slice
    // with idle on its dedicated ranks, so compute-before-decode keeps
    // the pre-staged renderings byte-identical)
    if acc[1] >= acc[0] && acc[1] >= acc[2] && acc[1] >= acc[3] {
        Some(SpanKind::Comm)
    } else if acc[0] >= acc[2] && acc[0] >= acc[3] {
        Some(SpanKind::Compute)
    } else if acc[2] >= acc[3] {
        Some(SpanKind::Decode)
    } else {
        Some(SpanKind::Idle)
    }
}

/// Render the timeline as an ASCII per-rank Gantt chart, `width` columns
/// wide (clamped to `[MIN_WIDTH, MAX_WIDTH]`).
pub fn render(tl: &Timeline, width: usize) -> String {
    let width = width.clamp(MIN_WIDTH, MAX_WIDTH);
    let mut out = String::new();
    out.push_str(&format!(
        "# {} @ {}px on {} — [{}], {} steps, {} ranks\n",
        tl.model,
        tl.px,
        tl.cluster,
        tl.config,
        tl.steps,
        tl.world()
    ));
    out.push_str(&format!(
        "strategy {}: makespan {:.3}s (closed form {:.3}s), overlap achieved {:.0}%, \
         busy {:.0}%\n",
        tl.strategy,
        tl.makespan,
        tl.closed_form,
        tl.achieved_overlap() * 100.0,
        tl.busy_fraction() * 100.0
    ));
    out.push_str(&format!("critical path: {}\n", tl.critical_path()));
    if tl.makespan <= 0.0 {
        out.push_str("(empty timeline)\n");
        return out;
    }
    let dt = tl.makespan / width as f64;
    for (rank, r) in tl.ranks.iter().enumerate() {
        out.push_str(&format!("rank {rank:>3} |"));
        for c in 0..width {
            let t0 = c as f64 * dt;
            match dominant(tl, rank, t0, t0 + dt) {
                Some(kind) => out.push(kind.glyph()),
                None => out.push(' '),
            }
        }
        out.push_str(&format!(
            "| {:.2}s compute, {:.2}s comm, {:.2}s idle\n",
            r.compute_seconds(),
            r.comm_seconds(),
            r.idle_seconds()
        ));
    }
    out.push_str(&format!(
        "{:>9} 0s{:>pad$}{:.3}s   (# compute  ~ comm  v decode  . idle)\n",
        "",
        "",
        tl.makespan,
        pad = width.saturating_sub(8)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::simulator::timeline::{RankTimeline, Span};

    /// Two ranks with a known layout: rank 0 computes 1s then exposes 1s
    /// of comm; rank 1 idles for the full 2s.
    fn fixture() -> Timeline {
        let r0 = RankTimeline {
            rank: 0,
            spans: vec![
                Span { kind: SpanKind::Compute, label: "compute", start: 0.0, end: 1.0 },
                Span { kind: SpanKind::Comm, label: "allreduce", start: 1.0, end: 2.0 },
            ],
            hidden_comm: 0.0,
        };
        let r1 = RankTimeline {
            rank: 1,
            spans: vec![Span { kind: SpanKind::Idle, label: "wait", start: 0.0, end: 2.0 }],
            hidden_comm: 0.0,
        };
        Timeline {
            strategy: "tp",
            model: "pixart".into(),
            px: 1024,
            cluster: "l40x8".into(),
            config: "ulysses=2".into(),
            steps: 1,
            ranks: vec![r0, r1],
            makespan: 2.0,
            closed_form: 2.0,
        }
    }

    #[test]
    fn renders_one_row_per_rank_with_glyphs() {
        let g = render(&fixture(), 16);
        assert_eq!(g.lines().filter(|l| l.starts_with("rank")).count(), 2);
        assert!(g.contains("critical path"));
        let rows: Vec<&str> = g
            .lines()
            .filter(|l| l.starts_with("rank"))
            .map(|l| l.split('|').nth(1).unwrap())
            .collect();
        assert_eq!(rows[0], "########~~~~~~~~", "{g}");
        assert_eq!(rows[1], "................", "{g}");
    }

    #[test]
    fn width_is_clamped() {
        let g = render(&fixture(), 1);
        let row = g.lines().find(|l| l.starts_with("rank")).unwrap();
        assert_eq!(row.chars().filter(|&c| c == '|').count(), 2);
        let inner = row.split('|').nth(1).unwrap();
        assert_eq!(inner.chars().count(), MIN_WIDTH);
    }
}
