//! Memory footprint model (paper Fig 18 + Table 2 + the DistriFusion OOM
//! argument).

use crate::config::model::ModelSpec;
use crate::perf::comm_model::{memory_fractions, Row};

/// Per-device memory footprint of the DiT backbone under a parallel method.
#[derive(Debug, Clone, Copy)]
pub struct MemoryFootprint {
    /// Transformer parameter bytes on this device.
    pub params: f64,
    /// Text encoder bytes (replicated — xDiT does not shard it).
    pub text_encoder: f64,
    /// KV buffers (staleness methods) or transient K/V (SP).
    pub kv: f64,
    /// Working activations + temporaries.
    pub activations: f64,
}

impl MemoryFootprint {
    pub fn total(&self) -> f64 {
        self.params + self.text_encoder + self.kv + self.activations
    }

    /// "parameters" vs "others" split used by Fig 18's stacked bars.
    pub fn parameters_gb(&self) -> f64 {
        (self.params + self.text_encoder) / 1e9
    }

    pub fn others_gb(&self) -> f64 {
        (self.kv + self.activations) / 1e9
    }
}

/// Footprint of one device for a method at intra-image degree `n`,
/// resolution `px`.
pub fn backbone_memory(m: &ModelSpec, px: usize, row: Row, n: usize) -> MemoryFootprint {
    let (pf, kvf) = memory_fractions(row, n);
    let s = m.attn_seq_len(px) as f64;
    let kv_full = 2.0 * s * m.hidden as f64 * 2.0 * m.layers as f64; // K+V, fp16, all layers
    // KV actually held:
    //  - SP keeps only the transient per-layer shard (1/n of one layer)
    //  - DistriFusion keeps the full (KV)L buffer
    //  - PipeFusion keeps (KV)L / n (its stage's layers)
    //  - TP keeps 1/n of the transient layer
    let kv = match row {
        // full (KV)L buffer + same-size communication buffers for the
        // async AllGather (§4.1.3: "maintain communication buffers that
        // store the complete spatial shape of K and V activations"),
        // double-buffered for overlap -> ~3x (KV)L
        Row::DistriFusion => 3.0 * kv_full,
        Row::PipeFusion => kv_full * kvf,
        Row::SpRing | Row::SpUlysses => kv_full / m.layers as f64 * kvf,
        Row::TensorParallel => kv_full / m.layers as f64 * kvf,
    };
    // activations: a few live copies of the sharded hidden state + latent
    let act_shard = s / n as f64 * m.hidden as f64 * 2.0;
    let activations = 8.0 * act_shard + (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 4.0;
    MemoryFootprint {
        params: m.param_bytes() * pf,
        text_encoder: m.text_encoder_bytes,
        kv,
        activations,
    }
}

/// Serial (1 GPU) footprint.
pub fn serial_memory(m: &ModelSpec, px: usize) -> MemoryFootprint {
    let s = m.attn_seq_len(px) as f64;
    MemoryFootprint {
        params: m.param_bytes(),
        text_encoder: m.text_encoder_bytes,
        kv: 2.0 * s * m.hidden as f64 * 2.0, // one layer's transient K/V
        activations: 8.0 * s * m.hidden as f64 * 2.0
            + (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 4.0,
    }
}

/// Does the backbone fit a GPU with `mem_bytes` HBM?
pub fn fits(m: &ModelSpec, px: usize, row: Row, n: usize, mem_bytes: f64) -> bool {
    backbone_memory(m, px, row, n).total() < mem_bytes * 0.92 // allocator slack
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::ModelSpec;

    #[test]
    fn distrifusion_ooms_pixart_4096_on_l40() {
        // §5.2.1: DistriFusion cannot run 0.6B Pixart at 4096px on 8xL40
        let m = ModelSpec::by_name("pixart").unwrap();
        assert!(!fits(&m, 4096, Row::DistriFusion, 8, 48e9));
        // ...while PipeFusion and SP fit
        assert!(fits(&m, 4096, Row::PipeFusion, 8, 48e9));
        assert!(fits(&m, 4096, Row::SpUlysses, 8, 48e9));
    }

    #[test]
    fn distrifusion_memory_does_not_drop_with_n() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let m4 = backbone_memory(&m, 2048, Row::DistriFusion, 4).kv;
        let m8 = backbone_memory(&m, 2048, Row::DistriFusion, 8).kv;
        assert_eq!(m4, m8);
        let p4 = backbone_memory(&m, 2048, Row::PipeFusion, 4).kv;
        let p8 = backbone_memory(&m, 2048, Row::PipeFusion, 8).kv;
        assert!(p8 < p4);
    }

    #[test]
    fn pipefusion_flux_memory_fraction_of_sp() {
        // §5.2.3: PipeFusion total ~ 32-36% of SP on Flux.1 at 8 GPUs
        let m = ModelSpec::by_name("flux").unwrap();
        for px in [1024, 2048] {
            let pf = backbone_memory(&m, px, Row::PipeFusion, 8).total();
            let sp = backbone_memory(&m, px, Row::SpUlysses, 8).total();
            let frac = pf / sp;
            assert!(
                (0.2..0.6).contains(&frac),
                "fraction {frac:.2} at {px}px out of band"
            );
        }
    }

    #[test]
    fn pixart_parameters_dominated_by_text_encoder() {
        // Fig 18: for 0.6B Pixart the text encoder dominates "parameters"
        let m = ModelSpec::by_name("pixart").unwrap();
        let f = backbone_memory(&m, 1024, Row::SpUlysses, 8);
        assert!(f.text_encoder > f.params);
    }
}
