//! Memory footprint model (paper Fig 18 + Table 2 + the DistriFusion OOM
//! argument).

use crate::config::model::ModelSpec;
use crate::config::parallel::ParallelConfig;
use crate::perf::comm_model::{memory_fractions, Row};

/// Per-device memory footprint of the DiT backbone under a parallel method.
#[derive(Debug, Clone, Copy)]
pub struct MemoryFootprint {
    /// Transformer parameter bytes on this device.
    pub params: f64,
    /// Text encoder bytes (replicated — xDiT does not shard it).
    pub text_encoder: f64,
    /// KV buffers (staleness methods) or transient K/V (SP).
    pub kv: f64,
    /// Working activations + temporaries.
    pub activations: f64,
}

impl MemoryFootprint {
    /// Total predicted bytes per device.
    pub fn total(&self) -> f64 {
        self.params + self.text_encoder + self.kv + self.activations
    }

    /// "parameters" vs "others" split used by Fig 18's stacked bars.
    pub fn parameters_gb(&self) -> f64 {
        (self.params + self.text_encoder) / 1e9
    }

    /// The non-parameter share (KV + activations), in GB.
    pub fn others_gb(&self) -> f64 {
        (self.kv + self.activations) / 1e9
    }
}

/// Footprint of one device for a method at intra-image degree `n`,
/// resolution `px`.
pub fn backbone_memory(m: &ModelSpec, px: usize, row: Row, n: usize) -> MemoryFootprint {
    let (pf, kvf) = memory_fractions(row, n);
    let s = m.attn_seq_len(px) as f64;
    let kv_full = 2.0 * s * m.hidden as f64 * 2.0 * m.layers as f64; // K+V, fp16, all layers
    // KV actually held:
    //  - SP keeps only the transient per-layer shard (1/n of one layer)
    //  - DistriFusion keeps the full (KV)L buffer
    //  - PipeFusion keeps (KV)L / n (its stage's layers)
    //  - TP keeps 1/n of the transient layer
    let kv = match row {
        // full (KV)L buffer + same-size communication buffers for the
        // async AllGather (§4.1.3: "maintain communication buffers that
        // store the complete spatial shape of K and V activations"),
        // double-buffered for overlap -> ~3x (KV)L
        Row::DistriFusion => 3.0 * kv_full,
        Row::PipeFusion => kv_full * kvf,
        Row::SpRing | Row::SpUlysses => kv_full / m.layers as f64 * kvf,
        Row::TensorParallel => kv_full / m.layers as f64 * kvf,
    };
    // activations: a few live copies of the sharded hidden state + latent
    let act_shard = s / n as f64 * m.hidden as f64 * 2.0;
    let activations = 8.0 * act_shard + (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 4.0;
    MemoryFootprint {
        params: m.param_bytes() * pf,
        text_encoder: m.text_encoder_bytes,
        kv,
        activations,
    }
}

/// Fraction of HBM usable after allocator slack (fragmentation, cudnn
/// workspaces) — shared by every fits-style check in this module.
pub const HBM_USABLE_FRACTION: f64 = 0.92;

/// Per-device footprint of a *hybrid* config — the composition the
/// planner prunes with:
/// * parameters shard across PipeFusion stages only (SP and CFG replicate
///   the weights);
/// * the text encoder is always replicated (xDiT does not shard it);
/// * KV: a PipeFusion stage keeps the **stale full-sequence buffer** for
///   its `layers/pipefusion` layers, split across its SP group; without
///   PipeFusion only the transient per-layer K/V shard is live;
/// * activations: a few live copies of the (patch × SP)-sharded hidden
///   state plus the fp32 latent.
///
/// Corner cases collapse to the Table-1 single-method rows: pure
/// PipeFusion holds `P/n` params + `(KV)L/n`, pure SP full params + one
/// transient layer shard, serial matches [`serial_memory`].
pub fn config_memory(m: &ModelSpec, px: usize, pc: &ParallelConfig) -> MemoryFootprint {
    let s = m.attn_seq_len(px) as f64;
    let sp = pc.sp_degree() as f64;
    let pf = pc.pipefusion as f64;
    let kv_full = 2.0 * s * m.hidden as f64 * 2.0 * m.layers as f64;
    let kv = if pc.pipefusion > 1 {
        kv_full / pf / sp
    } else {
        kv_full / m.layers as f64 / sp
    };
    let act_shard = s / (sp * pc.patches.max(1) as f64) * m.hidden as f64 * 2.0;
    let activations = 8.0 * act_shard + (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 4.0;
    MemoryFootprint {
        params: m.param_bytes() / pf,
        text_encoder: m.text_encoder_bytes,
        kv,
        activations,
    }
}

/// Does a hybrid config's per-device footprint fit `mem_bytes` of HBM?
/// This is the exact predicate the planner prunes candidates with.
pub fn config_fits(m: &ModelSpec, px: usize, pc: &ParallelConfig, mem_bytes: f64) -> bool {
    config_memory(m, px, pc).total() < mem_bytes * HBM_USABLE_FRACTION
}

/// Serial (1 GPU) footprint.
pub fn serial_memory(m: &ModelSpec, px: usize) -> MemoryFootprint {
    let s = m.attn_seq_len(px) as f64;
    MemoryFootprint {
        params: m.param_bytes(),
        text_encoder: m.text_encoder_bytes,
        kv: 2.0 * s * m.hidden as f64 * 2.0, // one layer's transient K/V
        activations: 8.0 * s * m.hidden as f64 * 2.0
            + (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 4.0,
    }
}

/// Does the backbone fit a GPU with `mem_bytes` HBM?
pub fn fits(m: &ModelSpec, px: usize, row: Row, n: usize, mem_bytes: f64) -> bool {
    backbone_memory(m, px, row, n).total() < mem_bytes * HBM_USABLE_FRACTION
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::ModelSpec;

    #[test]
    fn distrifusion_ooms_pixart_4096_on_l40() {
        // §5.2.1: DistriFusion cannot run 0.6B Pixart at 4096px on 8xL40
        let m = ModelSpec::by_name("pixart").unwrap();
        assert!(!fits(&m, 4096, Row::DistriFusion, 8, 48e9));
        // ...while PipeFusion and SP fit
        assert!(fits(&m, 4096, Row::PipeFusion, 8, 48e9));
        assert!(fits(&m, 4096, Row::SpUlysses, 8, 48e9));
    }

    #[test]
    fn distrifusion_memory_does_not_drop_with_n() {
        let m = ModelSpec::by_name("pixart").unwrap();
        let m4 = backbone_memory(&m, 2048, Row::DistriFusion, 4).kv;
        let m8 = backbone_memory(&m, 2048, Row::DistriFusion, 8).kv;
        assert_eq!(m4, m8);
        let p4 = backbone_memory(&m, 2048, Row::PipeFusion, 4).kv;
        let p8 = backbone_memory(&m, 2048, Row::PipeFusion, 8).kv;
        assert!(p8 < p4);
    }

    #[test]
    fn pipefusion_flux_memory_fraction_of_sp() {
        // §5.2.3: PipeFusion total ~ 32-36% of SP on Flux.1 at 8 GPUs
        let m = ModelSpec::by_name("flux").unwrap();
        for px in [1024, 2048] {
            let pf = backbone_memory(&m, px, Row::PipeFusion, 8).total();
            let sp = backbone_memory(&m, px, Row::SpUlysses, 8).total();
            let frac = pf / sp;
            assert!(
                (0.2..0.6).contains(&frac),
                "fraction {frac:.2} at {px}px out of band"
            );
        }
    }

    #[test]
    fn config_memory_matches_serial_and_shards_with_pipefusion() {
        let m = ModelSpec::by_name("flux").unwrap();
        let px = 1024;
        // serial config == serial footprint, field by field
        let serial = serial_memory(&m, px);
        let cfg_serial = config_memory(&m, px, &ParallelConfig::serial());
        assert_eq!(serial.params, cfg_serial.params);
        assert_eq!(serial.kv, cfg_serial.kv);
        assert_eq!(serial.activations, cfg_serial.activations);
        // PipeFusion shards params + stale KV; SP replicates params
        let pf = config_memory(&m, px, &ParallelConfig::new(1, 8, 1, 1));
        assert!((pf.params - m.param_bytes() / 8.0).abs() < 1.0);
        let sp = config_memory(&m, px, &ParallelConfig::new(1, 1, 8, 1));
        assert_eq!(sp.params, m.param_bytes());
        assert!(pf.total() < sp.total(), "PipeFusion must be the lean option on a 12B model");
        // a hybrid sits between: params by its pipe degree only
        let hy = config_memory(&m, px, &ParallelConfig::new(1, 2, 2, 2));
        assert!((hy.params - m.param_bytes() / 2.0).abs() < 1.0);
    }

    #[test]
    fn config_fits_agrees_with_footprint_and_slack() {
        let m = ModelSpec::by_name("flux").unwrap();
        for pc in [
            ParallelConfig::serial(),
            ParallelConfig::new(1, 8, 1, 1),
            ParallelConfig::new(1, 1, 8, 1),
            ParallelConfig::new(1, 2, 2, 2),
        ] {
            let total = config_memory(&m, 2048, &pc).total();
            assert!(config_fits(&m, 2048, &pc, total / HBM_USABLE_FRACTION + 1.0));
            assert!(!config_fits(&m, 2048, &pc, total / HBM_USABLE_FRACTION - 1.0));
        }
    }

    #[test]
    fn pixart_parameters_dominated_by_text_encoder() {
        // Fig 18: for 0.6B Pixart the text encoder dominates "parameters"
        let m = ModelSpec::by_name("pixart").unwrap();
        let f = backbone_memory(&m, 1024, Row::SpUlysses, 8);
        assert!(f.text_encoder > f.params);
    }
}
