//! Closed-form per-generation latency prediction for every parallel method
//! on every cluster, at the paper's model scales — the engine behind the
//! scalability figures (Figs 8–17).
//!
//! Modelling choices mirror the paper's analysis (§4.1.3):
//! * compute is divided across the intra-image group; CFG models run 2
//!   branches (batch 2) unless CFG parallelism splits them;
//! * collectives are bottlenecked by the slowest link in the group
//!   (PCIe-QPI crossing, Ethernet between nodes);
//! * overlap: SP-Ring hides K/V hops behind attention blocks, DistriFusion
//!   hides its AllGather behind the whole forward, PipeFusion hides patch
//!   P2P behind micro-step compute; TP and SP-Ulysses expose their
//!   collectives;
//! * PipeFusion pays the pipeline fill bubble (M+N-1)/M and one warmup
//!   (~serial) step; skip-connection models add non-adjacent P2P that
//!   breaks overlap (Fig 17).

use crate::config::hardware::{ClusterSpec, CollectiveAlgo, CollectiveKind};
use crate::config::model::{BlockVariant, ModelSpec};
use crate::config::parallel::ParallelConfig;
use crate::perf::flops;

/// Method selector for figure series (single methods + the hybrid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Tp,
    SpUlysses,
    SpRing,
    DistriFusion,
    PipeFusion,
    /// Hybrid uses the full ParallelConfig (cfg/pipe/ulysses/ring).
    Hybrid,
}

impl Method {
    /// Series label used by the figure tables and the simulator.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Tp => "tp",
            Method::SpUlysses => "ulysses",
            Method::SpRing => "ring",
            Method::DistriFusion => "distrifusion",
            Method::PipeFusion => "pipefusion",
            Method::Hybrid => "hybrid",
        }
    }

    /// The ParallelConfig a *single* method uses at intra-image degree `n`.
    /// TP borrows the ulysses slot (it also shards heads) and DistriFusion
    /// the ring slot, purely to carry the world size for the closed forms.
    pub fn single_config(&self, n: usize) -> ParallelConfig {
        match self {
            Method::SpUlysses | Method::Tp => ParallelConfig::new(1, 1, n, 1),
            Method::SpRing => ParallelConfig::new(1, 1, 1, n),
            Method::PipeFusion => ParallelConfig::new(1, n, 1, 1).with_patches(best_patches(n)),
            Method::DistriFusion => ParallelConfig::new(1, 1, 1, n).with_patches(n),
            Method::Hybrid => ParallelConfig::new(1, 1, 1, 1),
        }
    }
}

/// Default PipeFusion patch count for an intra-image degree `n`: the
/// paper searches M in {2,4,8,16,32}; M = 2N is a good default.
pub(crate) fn best_patches(n: usize) -> usize {
    (2 * n).clamp(2, 32)
}

/// Non-overlappable per-hop launch/sync cost of ring attention: NVLink
/// P2P kickoff is cheap, PCIe pays host-driven launches. Shared by the
/// closed forms and the event simulator so the two cannot drift.
pub(crate) fn ring_sync_cost(cluster: &ClusterSpec) -> f64 {
    if cluster.has_nvlink {
        15e-6
    } else {
        40e-6
    }
}

/// Bytes of the predicted latent a CFG branch pair exchanges each step
/// (fp16). Shared by the closed forms and the event simulator.
pub(crate) fn cfg_latent_bytes(m: &ModelSpec, px: usize) -> f64 {
    (px as f64 / 8.0).powi(2) * m.c_latent as f64 * 2.0
}

/// Latency decomposition (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// Pure compute seconds on the critical path.
    pub compute: f64,
    /// Communication seconds not hidden behind compute.
    pub comm_exposed: f64,
    /// One-off warmup cost (synchronous first step).
    pub warmup_extra: f64,
    /// End-to-end predicted seconds.
    pub total: f64,
}

/// Devices 0..n-1 of the cluster in mesh order (cfg outermost): the CFG
/// pair is placed across nodes, SP innermost — the paper's §5.2.4
/// placement recommendation.
fn intra_group(_cluster: &ClusterSpec, world: usize, cfg: usize, branch: usize) -> Vec<usize> {
    let n_intra = world / cfg;
    (0..n_intra).map(|i| branch * n_intra + i).collect()
}

/// Per-generation latency of a (method, config) on `world` devices,
/// priced with the historical flat-ring collectives.
pub fn predict_latency(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
    steps: usize,
) -> LatencyBreakdown {
    predict_latency_with(m, px, cluster, method, pc, steps, CollectiveAlgo::FlatRing)
}

/// Per-generation latency of a (method, config) with an explicit
/// collective algorithm. [`CollectiveAlgo::FlatRing`] is bit-exact with
/// [`predict_latency`]; [`CollectiveAlgo::Hierarchical`] reprices the TP
/// allreduce, the Ulysses all-to-all, and the DistriFusion allgather
/// through the two-level decomposition ([`ClusterSpec::collective_cost`]).
/// Ring hops and patch/latent P2P are algorithm-free either way.
pub fn predict_latency_with(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
    steps: usize,
    algo: CollectiveAlgo,
) -> LatencyBreakdown {
    let world = pc.world().max(1);
    let cfg = pc.cfg;
    let branches = if m.uses_cfg { 2 } else { 1 };
    let n_intra = world / cfg;
    let s = m.attn_seq_len(px);
    let group = intra_group(cluster, world, cfg, 0);
    let tfl = cluster.gpu.tflops;

    // per-branch per-step full-model compute
    let step_fl = m.step_flops(px);
    // branches not parallelized over cfg run sequentially on the same group
    let branch_factor = branches as f64 / cfg as f64;

    let compute_step = flops::compute_time(step_fl, tfl) / n_intra as f64 * branch_factor;

    let hs = s as f64 * m.hidden as f64 * 2.0;
    let l = m.layers as f64;
    let n = n_intra as f64;

    let (comm_exposed_step, warmup_extra) = match method {
        Method::Tp => {
            let t = 2.0 * l * cluster.collective_cost(&group, hs, CollectiveKind::AllReduce, algo);
            (t * branch_factor, 0.0)
        }
        Method::SpUlysses => {
            let t =
                l * cluster.collective_cost(&group, 4.0 * hs / n, CollectiveKind::AllToAll, algo);
            (t * branch_factor, 0.0)
        }
        Method::SpRing => {
            // (n-1) hops/layer of the local K/V block, overlapped with the
            // per-block attention compute; each hop also pays a
            // non-overlappable launch/sync cost (block-wise attention +
            // P2P kickoff), which is why Ring trails Ulysses on fast links
            // at small sequences (paper §5.2.2) while the gap narrows as
            // compute grows.
            let hop_bytes = 2.0 * hs / n;
            let hop_t = cluster.collective_time(&group, hop_bytes, 1.0) / (n - 1.0).max(1.0);
            let blk_attn =
                flops::compute_time(4.0 * (s as f64 / n) * (s as f64 / n) * m.hidden as f64, tfl);
            let sync = ring_sync_cost(cluster);
            let exposed = ((hop_t - blk_attn).max(0.0) + sync) * (n - 1.0) * l;
            (exposed * branch_factor, 0.0)
        }
        Method::DistriFusion => {
            // flat keeps the historical `n - 1.0` factor form (bit-exact
            // with prior releases); hierarchical reprices the stale-KV
            // allgather through the two-level decomposition
            let t_comm = match algo {
                CollectiveAlgo::FlatRing => {
                    cluster.collective_time(&group, 2.0 * hs * l / n, n - 1.0)
                }
                CollectiveAlgo::Hierarchical => cluster.collective_cost(
                    &group,
                    2.0 * hs * l / n,
                    CollectiveKind::AllGather,
                    algo,
                ),
            };
            let exposed = (t_comm - compute_step).max(0.0);
            // one synchronous warmup step ~ serial compute on the group
            let warm = flops::compute_time(step_fl, tfl) * branch_factor - compute_step;
            (exposed, warm.max(0.0))
        }
        Method::PipeFusion => {
            let m_patches = pc.patches.max(best_patches(n_intra));
            let micro = compute_step / m_patches as f64;
            // pipeline bubble: (M + N - 1) micro-steps instead of M
            let bubble = (n_intra as f64 - 1.0) * micro;
            // patch activation P2P between adjacent stages, overlapped
            let patch_bytes = hs / m_patches as f64;
            let mut worst_p2p: f64 = 0.0;
            for w in group.windows(2) {
                worst_p2p = worst_p2p.max(cluster.p2p_time(w[0], w[1], patch_bytes));
            }
            let mut exposed = (worst_p2p - micro).max(0.0) * m_patches as f64 + bubble;
            // skip-connection models: non-adjacent P2P per skip pair breaks
            // overlap (Fig 17) — charge it fully
            if m.variant == BlockVariant::Skip && n_intra > 1 {
                let far = cluster.p2p_time(group[0], *group.last().unwrap(), patch_bytes);
                exposed += far * m_patches as f64;
            }
            let warm = flops::compute_time(step_fl, tfl) * branch_factor - compute_step;
            (exposed * branch_factor, warm.max(0.0))
        }
        Method::Hybrid => {
            // compose: PipeFusion across pc.pipefusion stages, USP inside,
            // CFG across branches
            let mut exposed = 0.0;
            let nsp = pc.sp_degree() as f64;
            if pc.ulysses > 1 {
                let g: Vec<usize> = group[..pc.ulysses].to_vec();
                exposed +=
                    l * cluster.collective_cost(&g, 4.0 * hs / n, CollectiveKind::AllToAll, algo);
            }
            if pc.ring > 1 {
                let g: Vec<usize> = group[..pc.sp_degree()].to_vec();
                let hop_bytes = 2.0 * hs / nsp / pc.patches as f64;
                let hop_t = cluster.collective_time(&g, hop_bytes, 1.0)
                    / (pc.ring as f64 - 1.0).max(1.0);
                let blk = flops::compute_time(
                    4.0 * (s as f64 / nsp) * (s as f64 / nsp) * m.hidden as f64
                        / pc.patches as f64,
                    tfl,
                );
                let sync = ring_sync_cost(cluster);
                exposed += ((hop_t - blk).max(0.0) + sync) * (pc.ring as f64 - 1.0) * l;
            }
            let mut warm = 0.0;
            if pc.pipefusion > 1 {
                let m_patches = pc.patches.max(2);
                let micro = compute_step / m_patches as f64;
                exposed += (pc.pipefusion as f64 - 1.0) * micro;
                let patch_bytes = hs / m_patches as f64 / nsp;
                let stride = pc.sp_degree();
                let mut worst = 0.0f64;
                for i in (stride..n_intra).step_by(stride) {
                    worst = worst.max(cluster.p2p_time(group[i - stride], group[i], patch_bytes));
                }
                exposed += (worst - micro).max(0.0) * m_patches as f64;
                warm = (flops::compute_time(step_fl, tfl) * branch_factor - compute_step).max(0.0);
            }
            if cfg == 2 {
                // latent allgather between branch pairs once per step
                let latent_bytes = cfg_latent_bytes(m, px);
                let pair = [0, world / 2];
                exposed += cluster.p2p_time(pair[0], pair[1], latent_bytes);
            }
            (exposed, warm)
        }
    };

    let total = steps as f64 * (compute_step + comm_exposed_step) + warmup_extra;
    LatencyBreakdown {
        compute: steps as f64 * compute_step,
        comm_exposed: steps as f64 * comm_exposed_step,
        warmup_extra,
        total,
    }
}

/// Best hybrid configuration for a world size (exhaustive over valid
/// configs, as the paper's per-figure "hybrid" series does), priced with
/// flat-ring collectives.
pub fn best_hybrid(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
    steps: usize,
) -> (ParallelConfig, LatencyBreakdown) {
    best_hybrid_with(m, px, cluster, world, steps, CollectiveAlgo::FlatRing)
}

/// [`best_hybrid`] with an explicit collective algorithm.
pub fn best_hybrid_with(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    world: usize,
    steps: usize,
    algo: CollectiveAlgo,
) -> (ParallelConfig, LatencyBreakdown) {
    let s_img = m.seq_len(px);
    let mut best: Option<(ParallelConfig, LatencyBreakdown)> = None;
    for pc in ParallelConfig::enumerate(world, m, s_img) {
        let lb = predict_latency_with(m, px, cluster, Method::Hybrid, &pc, steps, algo);
        if best.as_ref().map(|(_, b)| lb.total < b.total).unwrap_or(true) {
            best = Some((pc, lb));
        }
    }
    best.unwrap_or_else(|| {
        let pc = ParallelConfig::serial();
        let lb = predict_latency_with(m, px, cluster, Method::Hybrid, &pc, steps, algo);
        (pc, lb)
    })
}

/// Serial (1-GPU) baseline latency.
pub fn serial_latency(m: &ModelSpec, px: usize, cluster: &ClusterSpec, steps: usize) -> f64 {
    let branches = if m.uses_cfg { 2.0 } else { 1.0 };
    steps as f64 * branches * flops::compute_time(m.step_flops(px), cluster.gpu.tflops)
}

/// Re-export used by figure benches.
pub fn predict_step_latency(
    m: &ModelSpec,
    px: usize,
    cluster: &ClusterSpec,
    method: Method,
    pc: &ParallelConfig,
) -> LatencyBreakdown {
    predict_latency(m, px, cluster, method, pc, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::hardware::{a100_node, l40_cluster};
    use crate::config::model::ModelSpec;

    fn pixart() -> ModelSpec {
        ModelSpec::by_name("pixart").unwrap()
    }

    #[test]
    fn tp_worst_on_l40() {
        // Fig 8/9: TP consistently highest latency
        let m = pixart();
        let c = l40_cluster(1);
        let n = 8;
        let tp = predict_latency(&m, 2048, &c, Method::Tp, &Method::Tp.single_config(n), 20);
        for meth in [Method::SpUlysses, Method::SpRing, Method::PipeFusion] {
            let pc = meth.single_config(n);
            let lb = predict_latency(&m, 2048, &c, meth, &pc, 20);
            assert!(tp.total > lb.total, "{meth:?} not better than TP");
        }
    }

    #[test]
    fn pipefusion_wins_on_pcie() {
        // §5.2.1: on 8xL40 PCIe, PipeFusion beats SP at 1024px
        let m = pixart();
        let c = l40_cluster(1);
        let pf = predict_latency(
            &m, 1024, &c, Method::PipeFusion, &Method::PipeFusion.single_config(8), 20,
        );
        let ul = predict_latency(
            &m, 1024, &c, Method::SpUlysses, &Method::SpUlysses.single_config(8), 20,
        );
        assert!(pf.total < ul.total, "pipefusion {} !< ulysses {}", pf.total, ul.total);
    }

    #[test]
    fn single_methods_collapse_8_to_16_over_ethernet() {
        // §5.2.1: scaling 8 -> 16 across Ethernet makes single methods
        // slower; hybrid with cfg still improves
        let m = pixart();
        let c16 = l40_cluster(2);
        let c8 = l40_cluster(1);
        for meth in [Method::SpUlysses, Method::SpRing] {
            let l8 = predict_latency(&m, 2048, &c8, meth, &meth.single_config(8), 20);
            let l16 = predict_latency(&m, 2048, &c16, meth, &meth.single_config(16), 20);
            assert!(
                l16.total > l8.total,
                "{meth:?} should collapse over ethernet: 8={} 16={}",
                l8.total,
                l16.total
            );
        }
        let (_, h16) = best_hybrid(&m, 2048, &c16, 16, 20);
        let (_, h8) = best_hybrid(&m, 2048, &c8, 8, 20);
        assert!(h16.total < h8.total, "hybrid must keep scaling 8->16");
    }

    #[test]
    fn hybrid_speedup_pixart_4096_16gpu() {
        // headline: ~13x on 16 L40 for Pixart 4096px
        let m = pixart();
        let c = l40_cluster(2);
        let serial = serial_latency(&m, 4096, &c, 20);
        let (pc, h) = best_hybrid(&m, 4096, &c, 16, 20);
        let speedup = serial / h.total;
        assert!(
            speedup > 8.0 && speedup <= 16.0,
            "speedup {speedup:.1} out of the expected band (cfg={})",
            pc.describe()
        );
    }

    #[test]
    fn ulysses_preferred_on_nvlink_large_seq() {
        // §5.2.4: on NVLink prioritize SP-Ulysses (large sequences)
        let m = pixart();
        let c = a100_node();
        let ul = predict_latency(
            &m, 4096, &c, Method::SpUlysses, &Method::SpUlysses.single_config(8), 20,
        );
        let ring = predict_latency(
            &m, 4096, &c, Method::SpRing, &Method::SpRing.single_config(8), 20,
        );
        assert!(ul.total <= ring.total * 1.05);
    }

    #[test]
    fn skip_model_pipefusion_penalty() {
        // Fig 17: HunyuanDiT skip connections hurt PipeFusion at 2048px
        let m = ModelSpec::by_name("hunyuan").unwrap();
        let c = a100_node();
        let pf = predict_latency(
            &m, 2048, &c, Method::PipeFusion, &Method::PipeFusion.single_config(8), 50,
        );
        let ul = predict_latency(
            &m, 2048, &c, Method::SpUlysses, &Method::SpUlysses.single_config(8), 50,
        );
        assert!(pf.total > ul.total, "skip penalty missing: pf {} ul {}", pf.total, ul.total);
    }

    #[test]
    fn hierarchical_closed_forms_never_worse_cross_node() {
        // on the two-tier testbeds the leader exchange beats the
        // NIC-shared flat ring for every collective-bearing method
        let m = pixart();
        let c = l40_cluster(2);
        for meth in [Method::Tp, Method::SpUlysses, Method::DistriFusion] {
            let pc = meth.single_config(16);
            let flat = predict_latency(&m, 2048, &c, meth, &pc, 20);
            let hier = predict_latency_with(
                &m,
                2048,
                &c,
                meth,
                &pc,
                20,
                CollectiveAlgo::Hierarchical,
            );
            assert!(
                hier.total <= flat.total,
                "{meth:?}: hier {} > flat {}",
                hier.total,
                flat.total
            );
        }
        // strictly better where the collective dominates (Ulysses at 16
        // ranks funnels 8 ranks through each NIC under the flat ring)
        let pc = Method::SpUlysses.single_config(16);
        let flat = predict_latency(&m, 2048, &c, Method::SpUlysses, &pc, 20);
        let hier = predict_latency_with(
            &m,
            2048,
            &c,
            Method::SpUlysses,
            &pc,
            20,
            CollectiveAlgo::Hierarchical,
        );
        assert!(hier.total < flat.total);
    }

    #[test]
    fn hierarchical_closed_forms_bit_exact_single_node() {
        // a single-node group gives hierarchy nothing to exploit: the two
        // algorithms must agree to the bit for every method
        let m = pixart();
        for c in [l40_cluster(1), a100_node()] {
            for meth in
                [Method::Tp, Method::SpUlysses, Method::SpRing, Method::PipeFusion, Method::Hybrid]
            {
                let pc = meth.single_config(8);
                let flat = predict_latency(&m, 2048, &c, meth, &pc, 20);
                let hier = predict_latency_with(
                    &m,
                    2048,
                    &c,
                    meth,
                    &pc,
                    20,
                    CollectiveAlgo::Hierarchical,
                );
                assert_eq!(flat.total.to_bits(), hier.total.to_bits(), "{meth:?} on {}", c.name);
            }
        }
    }

    #[test]
    fn ring_gap_narrows_with_resolution() {
        // §5.2.2 Hunyuan: ring/ulysses gap shrinks as compute/comm ratio
        // falls with larger images
        let m = ModelSpec::by_name("hunyuan").unwrap();
        let c = a100_node();
        let gap = |px| {
            let upc = Method::SpUlysses.single_config(8);
            let u = predict_latency(&m, px, &c, Method::SpUlysses, &upc, 50).total;
            let rpc = Method::SpRing.single_config(8);
            let r = predict_latency(&m, px, &c, Method::SpRing, &rpc, 50).total;
            r / u
        };
        assert!(gap(2048) <= gap(1024) + 1e-9);
    }
}
