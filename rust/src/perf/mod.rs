//! Analytic performance models: FLOPs, communication volumes (paper
//! Table 1), memory footprints (Fig 18), per-step latency prediction for
//! every parallel method on every cluster — the machinery behind the
//! figure/table reproduction benches — plus the discrete-event overlap
//! [`simulator`] that lowers a config into a per-GPU event timeline and
//! explains *where* the closed forms' overlap assumptions hold.

/// Per-step communication volumes (paper Table 1) + hybrid composition.
pub mod comm_model;
/// Reusable figure/table series generators behind the benches.
pub mod figures;
/// Transformer FLOPs accounting.
pub mod flops;
/// Closed-form per-generation latency prediction (Figs 8–17 engine).
pub mod latency;
/// Per-device memory footprints (Fig 18) + the planner's fits predicate.
pub mod memory_model;
/// The discrete-event overlap simulator (per-rank event timelines).
pub mod simulator;

pub use latency::{predict_step_latency, LatencyBreakdown, Method};
pub use simulator::{simulate, Timeline};
