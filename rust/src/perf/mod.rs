//! Analytic performance models: FLOPs, communication volumes (paper
//! Table 1), memory footprints (Fig 18), per-step latency prediction for
//! every parallel method on every cluster — the machinery behind the
//! figure/table reproduction benches.

pub mod comm_model;
pub mod figures;
pub mod flops;
pub mod latency;
pub mod memory_model;

pub use latency::{predict_step_latency, LatencyBreakdown, Method};
