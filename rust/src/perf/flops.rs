//! Transformer FLOPs accounting for DiT forward passes.
//!
//! Conventions: 1 MAC = 2 FLOPs; attention counts QK^T and PV
//! (2 * 2 * Sq * Skv * d); projections count their GEMMs. Matches the
//! standard 2*P*S + attention-quadratic accounting used in the paper's
//! compute-vs-comm analysis.

/// Dense + attention FLOPs of `ls` transformer layers over a query patch of
/// `p` tokens attending to `s_kv` tokens, hidden size `d`, MLP ratio `m`.
pub fn layers_flops(ls: usize, p: usize, s_kv: usize, d: usize, m: usize) -> f64 {
    let (p, s_kv, d, m) = (p as f64, s_kv as f64, d as f64, m as f64);
    let qkv = 2.0 * p * d * 3.0 * d;
    let proj = 2.0 * p * d * d;
    let mlp = 2.0 * 2.0 * p * d * m * d;
    let attn = 2.0 * 2.0 * p * s_kv * d;
    ls as f64 * (qkv + proj + mlp + attn)
}

/// Extra FLOPs per layer for a cross-attention branch with text memory of
/// `s_txt` tokens.
pub fn cross_extra_flops(ls: usize, p: usize, s_txt: usize, d: usize) -> f64 {
    let (p, s_txt, d) = (p as f64, s_txt as f64, d as f64);
    let q = 2.0 * p * d * d;
    let kv = 2.0 * s_txt * d * 2.0 * d;
    let attn = 2.0 * 2.0 * p * s_txt * d;
    let o = 2.0 * p * d * d;
    ls as f64 * (q + kv + attn + o)
}

/// MM-DiT stage FLOPs: two streams (text patch `pt`, image patch `pi`) with
/// joint attention over `s_kv`.
pub fn mmdit_layers_flops(ls: usize, pt: usize, pi: usize, s_kv: usize, d: usize, m: usize) -> f64 {
    // dense parts per stream + joint attention over the concatenated query
    let dense_t = layers_flops(ls, pt, 0, d, m);
    let dense_i = layers_flops(ls, pi, 0, d, m);
    let attn = ls as f64 * 2.0 * 2.0 * (pt + pi) as f64 * s_kv as f64 * d as f64;
    dense_t + dense_i + attn
}

/// Embed / final layers (linear projections over the patch).
pub fn embed_flops(p: usize, c: usize, d: usize) -> f64 {
    2.0 * p as f64 * c as f64 * d as f64
}

/// Final-projection FLOPs over the patch (adaLN modulation + linear).
pub fn final_flops(p: usize, c: usize, d: usize) -> f64 {
    2.0 * p as f64 * d as f64 * (c as f64 + 2.0 * d as f64)
}

/// Seconds to execute `flops` on a GPU with `tflops` sustained throughput.
pub fn compute_time(flops: f64, tflops: f64) -> f64 {
    flops / (tflops * 1e12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_in_depth_and_patch() {
        let f1 = layers_flops(1, 64, 256, 192, 4);
        let f2 = layers_flops(2, 64, 256, 192, 4);
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
        let fp = layers_flops(1, 128, 256, 192, 4);
        assert!(fp > 1.9 * f1 && fp < 2.1 * f1);
    }

    #[test]
    fn attention_quadratic_dominates_long_seq() {
        let d = 1152;
        let short = layers_flops(1, 4096, 4096, d, 4) / 4096.0;
        let long = layers_flops(1, 65536, 65536, d, 4) / 65536.0;
        // per-token cost grows with sequence (quadratic term)
        assert!(long > 2.0 * short);
    }

    #[test]
    fn mmdit_close_to_two_streams() {
        let f = mmdit_layers_flops(1, 32, 256, 288, 192, 4);
        let approx = layers_flops(1, 288, 288, 192, 4);
        assert!((f / approx - 1.0).abs() < 0.2);
    }

    #[test]
    fn compute_time_sane() {
        // 1 TFLOP on 100 TFLOP/s = 10 ms
        assert!((compute_time(1e12, 100.0) - 0.01).abs() < 1e-12);
    }
}
